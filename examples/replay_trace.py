"""Measured-mode training on a replayed telemetry trace.

Replays the committed bursty-contention fixture through the real train
driver twice — once with the modeled χ-oracle, once fully closed-loop
(``--times=measured``: the controller only ever sees the online
StragglerEstimator's reconstruction of measured, mitigated step times) —
and shows that both converge to the same plan decisions with the same
number of compiled plan signatures.

    PYTHONPATH=src python examples/replay_trace.py [--steps 60]
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run_training           # noqa: E402

FIXTURE = os.path.join(os.path.dirname(__file__), "traces",
                       "bursty_contention.jsonl")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="vit-1b")
    ap.add_argument("--trace", default=FIXTURE)
    args = ap.parse_args()

    results = {}
    for times in ("modeled", "measured"):
        hist = run_training(
            args.arch, steps=args.steps, tp=4, batch=4, seq=16,
            control_mode="semi", hetero_kind="trace", trace_in=args.trace,
            mig_blocks=8, max_sources=2, times=times, quiet=True)
        results[times] = hist
        print(f"[{times}] final loss {hist['final_loss']:.4f}, "
              f"mean modeled step {hist['mean_modeled_step_s']*1e3:.1f} ms, "
              f"plan compiles {hist['plan_compiles']}, "
              f"signatures {sorted(set(hist['signatures']))}")

    mod, mea = results["modeled"], results["measured"]
    agree = sum(1 for a, b in zip(mod["buckets"], mea["buckets"]) if a == b)
    n = len(mod["buckets"])
    print(f"closed loop vs oracle: {agree}/{n} steps decide identically "
          f"({agree / n:.0%}); signature sets "
          f"{'MATCH' if set(mod['signatures']) == set(mea['signatures']) else 'DIFFER'}; "
          f"compiles {mod['plan_compiles']} vs {mea['plan_compiles']}")
    if "chi_hat" in mea:
        print("final estimator χ̂:", [round(c, 2) for c in mea["chi_hat"]])


if __name__ == "__main__":
    main()
