"""Regenerate the committed telemetry trace fixtures (deterministic).

    PYTHONPATH=src python examples/traces/make_fixtures.py

Three canonical heterogeneity episodes, recorded as DENSE runs (no plan
active, work_frac = 1) of an 8-rank TP group with the reference model
constants M = 10 ms, C = 1.5 ms and ±3% multiplicative measurement
noise:

* ``static_skew.jsonl``       — rank 0 χ=4 and rank 1 χ=2, 60 steps (a
  permanently slower device pair, the paper's static heterogeneity).
* ``round_robin.jsonl``       — a χ=4 straggler rotating over ranks
  0..3 every 30 steps, 120 steps (Sec. V-B's dynamic schedule).
* ``bursty_contention.jsonl`` — 200 steps; every 25 steps a burst of
  contention hits 1-2 random ranks (χ=4) for 12 steps, then releases.
  Bursts PERSIST across steps — unlike iid per-step contention — so a
  closed measurement loop can lock on within its regime-change window
  (the e2e telemetry tests replay this one).
* ``replica_skew.jsonl``      — a 12-lane CLUSTER trace (3 replicas x 4
  TP ranks, header-tagged for :func:`repro.telemetry.replica_schedules`):
  replica 1 carries a persistent χ=4 rank, replica 2 periodic transient
  bursts; the header also ships a bursty request-arrival trace
  (``arrivals``) so benchmarks/cluster_bench.py and the cluster e2e test
  replay one identical workload.

Every recorded contention episode is a deterministic regression
scenario: replay with  ``--hetero trace --trace-in <fixture>``.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import numpy as np                                    # noqa: E402

from repro.telemetry import StepSample, TraceWriter   # noqa: E402

HERE = os.path.dirname(os.path.abspath(__file__))
RANKS = 8
M, C = 0.010, 0.0015                 # reference IterationModel constants
NOISE = 0.03


def record(name: str, chi_rows: np.ndarray, meta: dict, seed: int) -> str:
    rng = np.random.default_rng(np.random.SeedSequence((0xF1C, seed)))
    path = os.path.join(HERE, f"{name}.jsonl")
    ranks = chi_rows.shape[1]
    with TraceWriter(path, ranks, matmul_time=M, other_time=C,
                     meta={"fixture": name, **meta}) as w:
        for step, chi in enumerate(chi_rows):
            t = (M * chi + C) * (1.0 + rng.uniform(-NOISE, NOISE, ranks))
            w.append(StepSample(step=step, rank_times=t,
                                work_frac=np.ones(ranks)))
    return path


def static_skew(steps: int = 60) -> np.ndarray:
    chi = np.ones((steps, RANKS))
    chi[:, 0] = 4.0
    chi[:, 1] = 2.0
    return chi


def round_robin(steps: int = 120, period: int = 30) -> np.ndarray:
    chi = np.ones((steps, RANKS))
    for s in range(steps):
        chi[s, (s // period) % 4] = 4.0
    return chi


def bursty_contention(steps: int = 200, every: int = 25,
                      burst_len: int = 12) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence((0xF1C, 99)))
    chi = np.ones((steps, RANKS))
    for start in range(0, steps, every):
        hit = rng.choice(RANKS, size=int(rng.integers(1, 3)), replace=False)
        chi[start:start + burst_len, hit] = 4.0
    return chi


# -- cluster fixture (repro.cluster) -----------------------------------------

CLUSTER_R, CLUSTER_W = 3, 4          # replicas x TP ranks per replica


def replica_skew(steps: int = 160) -> np.ndarray:
    """R·W-lane cluster trace: replica 1 is PERSISTENTLY contended —
    TWO of its four ranks at χ=4 for the whole run (a bad host), so its
    inner SEMI loop (2 stragglers, only 2 helpers) can only partially
    absorb the imbalance and a large residual slowdown leaks into the
    replica's plan-adjusted capacity. Replica 2 catches periodic
    transient bursts (χ=2, 10 of every 40 steps). The scenario where
    load-blind routing keeps feeding the slow replica while chi_aware
    steers around the residual its inner loop cannot hide."""
    chi = np.ones((steps, CLUSTER_R * CLUSTER_W))
    chi[:, 1 * CLUSTER_W + 0] = 4.0                   # replica 1, lane 0
    chi[:, 1 * CLUSTER_W + 1] = 4.0                   # replica 1, lane 1
    for start in range(20, steps, 40):                # replica 2 bursts
        chi[start:start + 10, 2 * CLUSTER_W + 1] = 2.0
    return chi


def replica_skew_arrivals(n: int = 24, seed: int = 7) -> list:
    """Bursty request-arrival trace for the cluster bench/e2e test:
    ``[[uid, arrival_step, prompt_len, gen_len], ...]`` — bursts of 3-5
    requests every ~12 cluster steps, prompts 3..8, gens 3..8. Shipped in
    the fixture header so the bench and the e2e test replay the SAME
    workload from one file."""
    rng = np.random.default_rng(np.random.SeedSequence((0xF1C, seed)))
    arrivals, uid, step = [], 0, 0
    while uid < n:
        for _ in range(int(rng.integers(3, 6))):      # one burst
            if uid >= n:
                break
            arrivals.append([uid, step + int(rng.integers(0, 3)),
                             int(rng.integers(3, 9)),
                             int(rng.integers(3, 9))])
            uid += 1
        step += int(rng.integers(8, 16))
    return arrivals


def main():
    for seed, (name, rows, meta) in enumerate((
            ("static_skew", static_skew(), {"chis": [4.0, 2.0]}),
            ("round_robin", round_robin(), {"chi": 4.0, "period": 30}),
            ("bursty_contention", bursty_contention(),
             {"chi": 4.0, "burst_every": 25, "burst_len": 12}),
            ("replica_skew", replica_skew(),
             {"chi": 4.0, "replicas": CLUSTER_R,
              "ranks_per_replica": CLUSTER_W,
              "arrivals": replica_skew_arrivals()}))):
        path = record(name, rows, meta, seed)
        print(f"wrote {path}: {len(rows)} steps x {rows.shape[1]} ranks")


if __name__ == "__main__":
    main()
