"""End-to-end driver (deliverable b): train a ~100M-class reduced LM for a
few hundred steps under dynamic (round-robin) heterogeneity, comparing
ZERO-resizing / SEMI against the uncontrolled baseline, with
checkpoint/resume.

    PYTHONPATH=src python examples/train_lm_hetero.py [--steps 200]
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                    # noqa: E402

from repro.launch.train import run_training           # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--chi", type=float, default=4.0)
    args = ap.parse_args()

    results = {}
    for mode in ("off", "zero", "semi"):
        ckpt = f"/tmp/repro_ckpt_{mode}"
        hist = run_training(
            args.arch, steps=args.steps, tp=4, batch=8, seq=64, lr=1e-3,
            control_mode=mode, hetero_kind="round_robin", chi=args.chi,
            hetero_period=25, mig_blocks=2 if mode == "semi" else 0,
            ckpt_dir=ckpt, log_every=50, quiet=False)
        results[mode] = hist
        print(f"[{mode}] final loss {hist['final_loss']:.4f}, "
              f"mean modeled step {hist['mean_modeled_step_s']*1e3:.1f} ms")

    t_off = results["off"]["mean_modeled_step_s"]
    for mode in ("zero", "semi"):
        t = results[mode]["mean_modeled_step_s"]
        dl = results[mode]["final_loss"] - results["off"]["final_loss"]
        print(f"{mode}: speedup {t_off/t:.2f}x, loss delta {dl:+.4f}")


if __name__ == "__main__":
    main()
