"""Minimal multi-pod dry-run walk-through for ONE (arch × shape): shows the
lower → compile → memory/cost/collective analysis pipeline the full sweep
(repro.launch.dryrun --all) runs for every pair.

    PYTHONPATH=src python examples/dryrun_one.py --arch yi-6b --shape train_4k
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse          # noqa: E402
import sys               # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import run_one               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    r = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                extra_tag="example")
    roof = r["roofline"]
    print(f"\n{args.arch} × {args.shape} on {r['mesh']} ({r['chips']} chips)")
    print(f"  compile: {r['compile_s']}s")
    print(f"  memory_analysis: {r['memory_analysis']}")
    print(f"  roofline: compute={roof['compute_s']:.4f}s "
          f"memory={roof['memory_s']:.4f}s "
          f"collective={roof['collective_s']:.4f}s -> {roof['dominant']}")
    print(f"  collective breakdown: {roof['coll_breakdown']}")


if __name__ == "__main__":
    main()
