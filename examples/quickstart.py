"""Quickstart: train a reduced ViT under simulated heterogeneity with the
full SEMI-migration control loop, on 4 host devices.

    PYTHONPATH=src python examples/quickstart.py

Walks through: config -> mesh -> controlled train step -> controller loop,
and prints the modeled bulk-synchronous step time with/without control —
the paper's headline effect, end to end.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                    # noqa: E402

from repro.launch.train import run_training           # noqa: E402


def main():
    print("=== baseline: χ=4 straggler, no workload control ===")
    base = run_training("vit-1b", steps=25, tp=4, batch=16,
                        control_mode="off", hetero_kind="static", chi=4.0,
                        eval_every=25, log_every=5)
    print("\n=== SEMI-migration: same straggler, controller on ===")
    semi = run_training("vit-1b", steps=25, tp=4, batch=16,
                        control_mode="semi", hetero_kind="static", chi=4.0,
                        mig_blocks=2, eval_every=25, log_every=5)

    t0 = np.mean(base["modeled_step_s"][5:])
    t1 = np.mean(semi["modeled_step_s"][5:])
    print(f"\nmodeled step time: baseline {t0*1e3:.1f} ms -> "
          f"SEMI {t1*1e3:.1f} ms  (speedup {t0/t1:.2f}x)")
    print(f"final loss: baseline {base['final_loss']:.3f}, "
          f"SEMI {semi['final_loss']:.3f}")
    if base["acc"] and semi["acc"]:
        print(f"eval acc:  baseline {base['acc'][-1]:.3f}, "
              f"SEMI {semi['acc'][-1]:.3f}")


if __name__ == "__main__":
    main()
