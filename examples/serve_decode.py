"""Serving example: the continuous-batching engine on three families —
attention KV cache (Yi), SSM state cache (Falcon-Mamba) and MoE routing
(Mixtral) — with staggered arrivals, slot recycling and (optionally) the
straggler-aware resized decode path.

    PYTHONPATH=src python examples/serve_decode.py

Each run checks the engine's outputs against the fixed-batch baseline
(token-exact: slot recycling is semantics-preserving), then replays the
same trace once more under a simulated contention schedule with
ZERO-resizing enabled.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                    # noqa: E402

from repro.control import ControlConfig               # noqa: E402
from repro.launch.serve import (FixedBatchEngine, Request,   # noqa: E402
                                ServeEngine,
                                latency_percentiles)


def serve(arch: str, num_slots=2, max_len=16):
    eng = ServeEngine(arch, num_slots=num_slots, max_len=max_len, seed=0)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, eng.cfg.vocab_size,
                                        (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate([(5, 6, 0), (7, 4, 2), (4, 5, 6)])]
    comps = eng.run(reqs)

    base = FixedBatchEngine(arch, batch=1, max_len=max_len, seed=0)
    for c in comps:
        ref = base.generate(c.prompt[None], len(c.tokens))[0, len(c.prompt):]
        assert np.array_equal(c.tokens, ref), f"{arch} req {c.uid} diverged"
    stats = latency_percentiles(comps)
    print(f"{arch}: {len(comps)} requests over {num_slots} slots, "
          f"{stats['tokens']} tokens, traces={eng.trace_counts()}, "
          f"token-exact vs fixed-batch baseline OK")
    return eng


def serve_fused(arch: str, num_slots=2, max_len=16):
    """Fused decode-attention path, selected through the ONE shared
    config plumbing (ControlConfig.fused_attention — same knob the serve
    CLI and benches use; no per-driver env sniffing). On CPU the kernel
    transparently runs in interpret mode. Must be token-exact vs the
    plain engine."""
    control = ControlConfig(fused_attention=True,
                           psum_chunks=2)
    eng = ServeEngine(arch, num_slots=num_slots, max_len=max_len, seed=0,
                      control=control)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, eng.cfg.vocab_size,
                                        (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate([(5, 6, 0), (7, 4, 2), (4, 5, 6)])]
    comps = eng.run(reqs)

    plain = ServeEngine(arch, num_slots=num_slots, max_len=max_len, seed=0)
    ref = {c.uid: c.tokens for c in plain.run(reqs)}
    for c in comps:
        assert np.array_equal(c.tokens, ref[c.uid]), \
            f"{arch} req {c.uid}: fused attention diverged"
    print(f"{arch}: fused decode attention token-exact vs oracle path "
          f"({len(comps)} requests)")


def serve_controlled(arch: str):
    """Same engine under χ=4 contention with ZERO-resized decode."""
    control = ControlConfig(mode="zero", hetero_kind="contention",
                                 chi=4.0, contention_p=0.15, sim_ranks=8)
    eng = ServeEngine(arch, num_slots=2, max_len=16, seed=0, control=control)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, eng.cfg.vocab_size,
                                        (5,)).astype(np.int32),
                    max_new_tokens=6, arrival_step=2 * i)
            for i in range(3)]
    eng.run(reqs)
    ctrl = sum(h["latency_s"] for h in eng.history)
    dense = sum(h["dense_latency_s"] for h in eng.history)
    print(f"{arch} under contention: modeled {ctrl*1e3:.2f}ms resized vs "
          f"{dense*1e3:.2f}ms dense "
          f"({dense/max(ctrl, 1e-12):.2f}x), "
          f"plan compiles={eng.trace_counts()['plan_compiles']}")


def main():
    for arch in ("yi-6b", "falcon-mamba-7b", "mixtral-8x7b"):
        serve(arch)
    serve_fused("yi-6b")
    serve_controlled("yi-6b")
    print("serving paths OK (KV slots, SSM state reset, MoE decode, "
          "fused decode attention, straggler-aware resizing)")


if __name__ == "__main__":
    main()
