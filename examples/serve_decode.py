"""Serving example: batched greedy decoding with a KV cache on the reduced
Yi-6B and Falcon-Mamba (SSM state cache) variants — exercises the same
serve_step the decode_32k / long_500k dry-runs lower.

    PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np                                    # noqa: E402
import jax                                            # noqa: E402
import jax.numpy as jnp                               # noqa: E402

from repro.config import get_config, smoke_variant    # noqa: E402
from repro.models import get_api                      # noqa: E402


def greedy_decode(arch: str, prompt_len=8, gen_len=24, batch=4):
    cfg = smoke_variant(get_config(arch))
    api = get_api(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    max_len = prompt_len + gen_len
    # periodic prompt so the (untrained) model at least sees structure
    pat = rng.integers(0, cfg.vocab_size, (batch, 4))
    prompt = np.tile(pat, (1, prompt_len // 4 + 1))[:, :prompt_len]

    cache = api.init_cache(cfg, batch, max_len)
    step = jax.jit(lambda p, c, t, pos: api.decode_step(p, cfg, c, t, pos))

    toks = jnp.asarray(prompt[:, 0])
    out = [np.asarray(toks)]
    logits = None
    for t in range(max_len - 1):
        logits, cache = step(params, cache,
                             jnp.asarray(out[-1]).astype(jnp.int32),
                             jnp.full((batch,), t, jnp.int32))
        if t + 1 < prompt_len:
            nxt = prompt[:, t + 1]                    # teacher-forced prompt
        else:
            nxt = np.asarray(logits.argmax(-1))       # greedy
        out.append(nxt)
    seq = np.stack(out, axis=1)
    print(f"{arch}: decoded {seq.shape} tokens; sample row: {seq[0][:16]}...")
    return seq


def main():
    for arch in ("yi-6b", "falcon-mamba-7b", "mixtral-8x7b"):
        greedy_decode(arch)
    print("serving paths OK (attention KV cache, SSM state, MoE decode)")


if __name__ == "__main__":
    main()
