"""Controller (Eqs. 1-3, Alg. 2) and priority (Alg. 1) tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import WorkloadControlConfig
from repro.core import hetero as hetero_lib
from repro.core import priority as pri_lib
from repro.core.controller import (CostFunctions, SemiController, eq1_gamma,
                                   eq2_beta, eq3_migration_prefix,
                                   work_fraction)
from repro.core.workload import keep_blocks_for_bucket


COSTS = CostFunctions(omega1=1e-3, omega2_slope=1e-5, phi1_base=5e-5,
                      phi1_slope=2e-5, phi2_slope=1e-4)


class TestEq1:
    def test_no_gap_no_pruning(self):
        assert eq1_gamma(1.0, 1.0, 1.0) == 0.0

    def test_gap_offset(self):
        # 2x slower with matmul share 1.0 of runtime: prune half
        assert eq1_gamma(2.0, 1.0, 2.0) == pytest.approx(0.5)

    @given(t=st.floats(0.1, 10), ref=st.floats(0.1, 10), m=st.floats(0.01, 10))
    @settings(max_examples=50, deadline=None)
    def test_bounds(self, t, ref, m):
        g = eq1_gamma(t, ref, m)
        assert 0.0 <= g <= 0.875


class TestEq2:
    def test_zero_workload(self):
        assert eq2_beta(0.0, COSTS, 8) == 0.0

    @given(lg=st.floats(1.0, 1e4), e=st.integers(2, 64))
    @settings(max_examples=50, deadline=None)
    def test_beta_in_unit_interval(self, lg, e):
        assert 0.0 <= eq2_beta(lg, COSTS, e) <= 1.0

    def test_cheap_migration_prefers_migration(self):
        cheap = CostFunctions(omega1=1.0, omega2_slope=1.0,
                              phi1_base=0.0, phi1_slope=1e-9,
                              phi2_slope=1e-9)
        assert eq2_beta(100.0, cheap, 8) == 1.0

    def test_expensive_migration_prefers_resizing(self):
        dear = CostFunctions(omega1=0.0, omega2_slope=1e-9,
                             phi1_base=10.0, phi1_slope=10.0, phi2_slope=10.0)
        assert eq2_beta(100.0, dear, 8) == 0.0


class TestEq3:
    def test_uniform_times_no_migration(self):
        t = np.ones(8)
        x = eq3_migration_prefix(t, np.full(8, 100.0), COSTS, 8)
        assert x == 0

    def test_single_heavy_straggler_migrates(self):
        t = np.array([8.0, 1, 1, 1, 1, 1, 1, 1])
        x = eq3_migration_prefix(t, np.full(8, 100.0), COSTS, 8)
        assert x >= 1

    def test_prefix_grows_with_cheaper_comm(self):
        t = np.array([8.0, 6, 4, 3, 1, 1, 1, 1])
        w = np.full(8, 100.0)
        cheap = CostFunctions(0, 0, 0, 1e-9, 1e-9)
        dear = CostFunctions(0, 0, 1.0, 1.0, 1.0)
        assert (eq3_migration_prefix(t, w, cheap, 8)
                >= eq3_migration_prefix(t, w, dear, 8))


class TestPriority:
    def test_incremental_update_preserves_pruned_stats(self):
        """The endless-loop fix (Sec. III-B): pruned blocks keep their old
        statistic — zero-imputed non-updates must not look 'unimportant'."""
        st_ = pri_lib.PriorityState.create(4)
        w0 = np.zeros((4 * 8, 3))
        st_ = pri_lib.update_state(st_, w0, 8)
        # big refinement on blocks 0,1; none on 2,3 (they were pruned)
        w1 = w0.copy()
        w1[:16] += 1.0
        st_ = pri_lib.update_state(st_, w1, 8)
        pri = pri_lib.build_pri_list(st_)
        st_ = pri_lib.mark_pruned(st_, pri, keep_blocks=2)   # prune 2 worst
        assert set(np.asarray(st_.pruned_last).nonzero()[0]) == {2, 3}
        var_before = st_.w_var.copy()
        # next epoch: pruned blocks didn't move (zero imputation), others did
        w2 = w1.copy()
        w2[:16] += 1.0
        st_ = pri_lib.update_state(st_, w2, 8)
        # pruned blocks' stats preserved, NOT refreshed to ~0
        np.testing.assert_array_equal(st_.w_var[2:], var_before[2:])

    def test_priority_keeps_high_variation(self):
        st_ = pri_lib.PriorityState.create(3)
        st_.w_var[:] = [0.5, 0.1, 0.9]
        pri = pri_lib.build_pri_list(st_)
        assert list(pri) == [2, 0, 1]

    def test_differentiated_gamma_floor(self):
        """γ_k >= α·γ_uniform (Alg. 1 line 11, bucket-rounded)."""
        states = {"a": pri_lib.PriorityState.create(8)}
        states["a"].w_var[:] = 1.0      # everything still moving -> γ_k = 0
        buckets = (0.0, 0.25, 0.5, 0.75)
        out = pri_lib.differentiated_gamma(states, 0.5, alpha=0.8,
                                           theta=1e-3, buckets=buckets)
        assert buckets[out["a"]] >= 0.8 * 0.5 - 1e-9


class TestSemiController:
    def _mk(self, mode="semi", tp=8):
        cfg = WorkloadControlConfig(enabled=True, mode=mode, block_size=8)
        model = hetero_lib.IterationModel(matmul_time=1.0, other_time=0.1)
        return SemiController(cfg, tp, model, num_blocks=64)

    def test_no_stragglers_neutral(self):
        c = self._mk()
        plan, rep = c.plan(np.ones(8))
        assert plan.is_neutral()
        assert rep.stragglers == []

    def test_zero_mode_buckets_straggler(self):
        c = self._mk("zero")
        times = np.ones(8)
        times[3] = 2.0
        plan, rep = c.plan(times)
        assert plan.dynamic.bucket_by_rank[3] > 0
        assert all(plan.dynamic.bucket_by_rank[i] == 0 for i in range(8) if i != 3)

    def test_semi_single_straggler_splits(self):
        c = self._mk("semi")
        times = np.ones(8)
        times[0] = 3.0
        plan, rep = c.plan(times)
        assert rep.stragglers == [0]
        assert 0.0 <= rep.beta <= 1.0
        # the straggler either migrates, resizes, or both
        assert rep.mig_blocks > 0 or plan.dynamic.bucket_by_rank[0] > 0

    def test_semi_multi_straggler_grouping(self):
        c = self._mk("semi")
        times = np.array([8.0, 6, 4, 2, 1, 1, 1, 1], float)
        plan, rep = c.plan(times)
        assert len(rep.stragglers) == 4
        # heaviest rank migrates (if cost-effective) or resizes hardest
        assert rep.mig_src in (-1, 0)

    def test_work_fraction_balances(self):
        """After planning, the modeled per-rank times should be closer to
        uniform than before (the whole point of Eq. 1)."""
        c = self._mk("zero")
        model = c.model
        chi = np.ones(8)
        chi[2] = 3.0
        times0 = model.times(chi, np.ones(8))
        plan, _ = c.plan(times0)
        frac = work_fraction(plan, c.num_blocks)
        times1 = model.times(chi, frac)
        assert times1.max() / times1.min() < times0.max() / times0.min()


class TestHetero:
    def test_round_robin_single_straggler(self):
        s = hetero_lib.HeteroSchedule(num_ranks=4, kind="round_robin",
                                      chis=(3.0,), period=5)
        for step in range(20):
            chi = s.chi(step)
            assert (chi > 1).sum() == 1
        assert np.argmax(s.chi(0)) != np.argmax(s.chi(5))

    def test_static(self):
        s = hetero_lib.HeteroSchedule(num_ranks=4, kind="static",
                                      chis=(2.0, 1.0, 1.0, 1.0))
        np.testing.assert_array_equal(s.chi(0), [2, 1, 1, 1])

    def test_iteration_model_step_time_is_max(self):
        m = hetero_lib.IterationModel(matmul_time=1.0, other_time=0.0)
        chi = np.array([1.0, 4.0])
        assert m.step_time(chi, np.ones(2)) == pytest.approx(4.0)
        # pruning the straggler to 1/4 work restores balance
        assert m.step_time(chi, np.array([1.0, 0.25])) == pytest.approx(1.0)
