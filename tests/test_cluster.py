"""Multi-replica cluster tests: router policies, replica lifecycle, and
the zero-drop invariant (src/repro/cluster/, DESIGN_CLUSTER.md).

The load-bearing claims:

* routing NEVER changes a token — a request completed through drains,
  failures, and reassignment matches the single-request fixed-batch
  baseline exactly (greedy decode is deterministic, so a from-scratch
  re-run on another replica regenerates the same output);
* every submitted request completes exactly once (zero dropped, zero
  duplicated) across drain → warm-spare promotion and fail → restart;
* the chi_aware policy prices requests against each replica's
  PLAN-ADJUSTED capacity, so under the committed ``replica_skew``
  fixture it beats load-blind round-robin on p95 per-token latency and
  mean TTFT — the outer loop of the paper's nested workload control.
"""
import collections
import os
import types

import jax
import numpy as np
import pytest

from repro.cluster import (ACTIVE, DRAINED, DRAINING, FAILED, POLICIES,
                           SPARE, ReplicaHandle, ReplicaManager, Router,
                           chi_aware_cost)
from repro.control import ControlConfig
from repro.launch.serve import (FixedBatchEngine, LoadSnapshot, Request,
                                ServeEngine)
from repro.telemetry import replica_schedules

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURE = os.path.join(ROOT, "examples", "traces", "replica_skew.jsonl")

ARCH = "yi-6b"


def _mk_requests(vocab, specs, seed=0):
    """specs: list of (prompt_len, gen_len, arrival_step)."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate(specs)]


def _factory(num_slots=2, max_len=12, control=None, **kw):
    def build():
        return ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                           seed=0, control=control or ControlConfig(), **kw)
    return build


def _assert_token_exact(engine_max_len, completions):
    base = FixedBatchEngine(ARCH, batch=1, max_len=engine_max_len, seed=0)
    for c in completions:
        seq = base.generate(c.prompt[None], len(c.tokens))
        ref = seq[0, len(c.prompt):]
        np.testing.assert_array_equal(
            c.tokens, ref,
            err_msg=f"request {c.uid} diverged after cluster routing")


# ---------------------------------------------------------------------------
# Router policies — pure ranking math over synthetic snapshots (no engines)
# ---------------------------------------------------------------------------


def _snap(step_time_s=1.0, backlog_steps=0, queue_depth=0, active=0,
          num_slots=2):
    return LoadSnapshot(step=0, clock=0.0, queue_depth=queue_depth,
                        active=active, free_slots=num_slots - active,
                        free_pages=None, num_slots=num_slots,
                        chi=np.ones(4), work_frac=np.ones(4),
                        step_time_s=step_time_s, dense_step_time_s=1.0,
                        backlog_steps=backlog_steps)


class _FakeHandle:
    """Routing-interface stub: fixed snapshot + scripted admission."""

    def __init__(self, name, snap, accept=True, cost_steps=5):
        self.name = name
        self.state = ACTIVE
        self._snap = snap
        self._accept = accept
        self.admitted = []
        self.engine = types.SimpleNamespace(
            request_cost_steps=lambda p, g: cost_steps)

    @property
    def admitting(self):
        return self.state == ACTIVE

    def snapshot(self):
        return self._snap

    def try_route(self, req):
        if self._accept:
            self.admitted.append(req.uid)
        return self._accept


def _req(uid=0, arrival=0):
    return Request(uid=uid, prompt=np.zeros(4, np.int32),
                   max_new_tokens=4, arrival_step=arrival)


class TestRouterPolicies:
    def test_chi_aware_cost_formula(self):
        """cost = step_time * (backlog + request_cost) / num_slots."""
        h = _FakeHandle("r0", _snap(step_time_s=2.0, backlog_steps=3,
                                    num_slots=2), cost_steps=5)
        assert chi_aware_cost(_req(), (0, h, h.snapshot())) == \
            pytest.approx(2.0 * (3 + 5) / 2)

    def test_chi_aware_prefers_residual_capacity(self):
        """A replica whose plan-adjusted step time is slower loses to a
        dense one even with an empty queue — and backlog flips the
        ranking back once the fast replica is saturated."""
        slow = _FakeHandle("slow", _snap(step_time_s=2.0))
        fast = _FakeHandle("fast", _snap(step_time_s=1.0))
        r = Router("chi_aware")
        ranked = r.rank(_req(), [slow, fast])
        assert [h.name for _, h, _ in ranked] == ["fast", "slow"]
        # saturate the fast replica: 2x step time < 12-step backlog
        busy = _FakeHandle("fast", _snap(step_time_s=1.0, backlog_steps=12))
        ranked = r.rank(_req(), [slow, busy])
        assert [h.name for _, h, _ in ranked] == ["slow", "fast"]

    def test_chi_aware_tie_breaks_lowest_index(self):
        hs = [_FakeHandle(f"r{i}", _snap()) for i in range(3)]
        ranked = Router("chi_aware").rank(_req(), hs)
        assert [i for i, _, _ in ranked] == [0, 1, 2]

    def test_least_queue_counts_waiting_plus_active(self):
        a = _FakeHandle("a", _snap(queue_depth=2, active=0))
        b = _FakeHandle("b", _snap(queue_depth=0, active=1))
        ranked = Router("least_queue").rank(_req(), [a, b])
        assert [h.name for _, h, _ in ranked] == ["b", "a"]

    def test_round_robin_rotates_only_on_success(self):
        hs = [_FakeHandle(f"r{i}", _snap()) for i in range(3)]
        r = Router("round_robin")
        names = [r.route(_req(uid=u), hs).name for u in range(4)]
        assert names == ["r0", "r1", "r2", "r0"]
        # a refused round does NOT advance the cursor
        for h in hs:
            h._accept = False
        assert r.route(_req(uid=9), hs) is None
        for h in hs:
            h._accept = True
        assert r.route(_req(uid=10), hs).name == "r1"

    def test_route_falls_through_refused_admission(self):
        """Best-ranked replica refuses (bounded queue full) -> the request
        lands on the next-best instead of being dropped."""
        best = _FakeHandle("best", _snap(step_time_s=1.0), accept=False)
        worse = _FakeHandle("worse", _snap(step_time_s=2.0))
        got = Router("chi_aware").route(_req(uid=7), [best, worse])
        assert got is worse and worse.admitted == [7]

    def test_non_admitting_replicas_are_invisible(self):
        h0 = _FakeHandle("r0", _snap())
        h1 = _FakeHandle("r1", _snap())
        h0.state = DRAINING
        ranked = Router("chi_aware").rank(_req(), [h0, h1])
        assert [h.name for _, h, _ in ranked] == ["r1"]

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="unknown routing policy"):
            Router("fastest_first")
        assert set(POLICIES) == {"round_robin", "least_queue", "chi_aware"}

    def test_custom_callable_policy(self):
        def reverse(req, cands):
            return list(reversed(cands))
        hs = [_FakeHandle(f"r{i}", _snap()) for i in range(2)]
        r = Router(reverse)
        assert r.policy_name == "reverse"
        assert r.route(_req(), hs).name == "r1"


# ---------------------------------------------------------------------------
# ReplicaHandle lifecycle state machine (real engines, control off)
# ---------------------------------------------------------------------------


class TestReplicaLifecycle:
    def test_spare_ticks_idle_but_does_not_admit(self):
        h = ReplicaHandle("s", _factory(), spare=True)
        assert h.state == SPARE and not h.admitting
        assert not h.try_route(_req())
        before = h.engine.step_count
        h.tick()
        # the idle tick keeps the χ-schedule lane cluster-aligned ...
        assert h.engine.step_count == before + 1
        # ... without burning modeled time
        assert h.engine.clock == 0.0
        h.promote()
        assert h.state == ACTIVE and h.admitting
        h.close()

    def test_invalid_transitions_raise(self):
        h = ReplicaHandle("r", _factory())
        with pytest.raises(ValueError, match="only a SPARE"):
            h.promote()                      # ACTIVE -> promote
        with pytest.raises(ValueError, match="restart"):
            h.restart()                      # ACTIVE -> restart
        h.fail()
        with pytest.raises(ValueError, match="begin_drain"):
            h.begin_drain()                  # FAILED -> drain
        with pytest.raises(RuntimeError, match="failed"):
            h.snapshot()                     # FAILED has no engine
        h.close()

    def test_drain_finishes_inflight_and_returns_queue(self):
        h = ReplicaHandle("r", _factory(num_slots=1))
        reqs = _mk_requests(h.engine.cfg.vocab_size, [(3, 3, 0), (3, 3, 0)])
        assert h.try_route(reqs[0]) and h.try_route(reqs[1])
        h.tick()                             # admit req 0; req 1 queued
        evicted = h.begin_drain()
        assert [r.uid for r in evicted] == [1]
        assert h.state == DRAINING and not h.admitting
        for _ in range(10):
            if h.state == DRAINED:
                break
            h.tick()
        assert h.state == DRAINED
        # the in-flight request FINISHED on the draining replica
        assert [c.uid for c in h.harvest()] == [0]
        h.close()

    def test_fail_returns_incomplete_work_and_restart_rejoins(self):
        h = ReplicaHandle("r", _factory(num_slots=1))
        reqs = _mk_requests(h.engine.cfg.vocab_size, [(3, 3, 0), (3, 3, 0)])
        h.try_route(reqs[0]), h.try_route(reqs[1])
        h.tick()
        lost = h.fail()
        # in-flight first (admission order), then the queue; engine gone
        assert [r.uid for r in lost] == [0, 1]
        assert h.state == FAILED and h.engine is None
        assert h.harvest() == [] and h.fail() == []
        h.restart(sync_step=17)
        assert h.state == ACTIVE and h.restarts == 1
        # the rebuilt engine rejoined the cluster time base
        assert h.engine.step_count == 17
        h.close()


# ---------------------------------------------------------------------------
# ReplicaManager: lockstep driving + zero-drop reassignment
# ---------------------------------------------------------------------------


class TestReplicaManager:
    def test_duplicate_names_rejected(self):
        hs = [ReplicaHandle("r", _factory()) for _ in range(2)]
        with pytest.raises(ValueError, match="duplicate"):
            ReplicaManager(hs)
        for h in hs:
            h.close()

    def test_fail_midrun_zero_drop_zero_dup_token_exact(self):
        """Replica failure mid-decode: finished work is harvested, every
        incomplete request is re-routed and completes token-exactly —
        nothing dropped, nothing duplicated."""
        hs = [ReplicaHandle(f"r{i}", _factory(num_slots=1, max_len=16))
              for i in range(2)]
        mgr = ReplicaManager(hs, Router("round_robin"))
        reqs = _mk_requests(hs[0].engine.cfg.vocab_size,
                            [(4, 6, 0), (4, 6, 0), (4, 6, 1), (4, 6, 1)])

        def hook(m):
            if m.cluster_step == 3:
                m.fail("r0", promote_spare=False)

        comps = mgr.run(reqs, on_step=hook)
        assert [c.uid for c in comps] == [0, 1, 2, 3]
        assert mgr.duplicate_completions == 0
        assert mgr.reassigned > 0
        assert any(e["kind"] == "fail" for e in mgr.events)
        # the failed replica's survivors finished on r1
        assert all(mgr.owner[uid] == "r1" for uid in mgr.owner)
        _assert_token_exact(16, comps)
        mgr.close()

    def test_drain_promotes_spare_and_inflight_finishes_in_place(self):
        hs = [ReplicaHandle("r0", _factory(num_slots=1, max_len=16)),
              ReplicaHandle("spare", _factory(num_slots=1, max_len=16),
                            spare=True)]
        mgr = ReplicaManager(hs, Router("least_queue"))
        reqs = _mk_requests(hs[0].engine.cfg.vocab_size,
                            [(4, 5, 0), (4, 5, 0), (4, 5, 2)])

        def hook(m):
            if m.cluster_step == 2:
                m.drain("r0")

        comps = mgr.run(reqs, on_step=hook)
        assert [c.uid for c in comps] == [0, 1, 2]
        kinds = [e["kind"] for e in mgr.events if e["kind"] != "route"]
        assert kinds == ["drain", "promote"]
        assert hs[0].state == DRAINED and hs[1].state == ACTIVE
        # request 0 was in-flight on r0 at the drain: it finished THERE
        assert mgr.owner[0] == "r0"
        # evicted/later requests ran on the promoted spare
        assert {mgr.owner[1], mgr.owner[2]} == {"spare"}
        assert mgr.duplicate_completions == 0
        _assert_token_exact(16, comps)
        mgr.close()

    def test_restart_rejoins_and_serves(self):
        hs = [ReplicaHandle(f"r{i}", _factory(num_slots=1, max_len=16))
              for i in range(2)]
        mgr = ReplicaManager(hs, Router("round_robin"))
        reqs = _mk_requests(hs[0].engine.cfg.vocab_size,
                            [(4, 4, 0), (4, 4, 0), (4, 4, 6), (4, 4, 6)])

        def hook(m):
            if m.cluster_step == 2:
                m.fail("r0", promote_spare=False)
            if m.cluster_step == 5:
                m.restart("r0")

        comps = mgr.run(reqs, on_step=hook)
        assert len(comps) == 4 and mgr.duplicate_completions == 0
        assert hs[0].restarts == 1
        assert hs[0].engine.step_count >= 5        # rejoined the time base
        # the restarted replica served some of the later arrivals
        assert "r0" in set(mgr.owner.values())
        _assert_token_exact(16, comps)
        mgr.close()

    def test_all_replicas_down_raises_instead_of_spinning(self):
        h = ReplicaHandle("r0", _factory())
        mgr = ReplicaManager([h])
        reqs = _mk_requests(h.engine.cfg.vocab_size, [(3, 3, 0)])

        def hook(m):
            if m.cluster_step == 0:
                m.fail("r0", promote_spare=False)

        with pytest.raises(RuntimeError, match="unplaced"):
            mgr.run(reqs, max_steps=8, on_step=hook)
        mgr.close()

    def test_warm_spare_serves_checkpoint_params(self, tmp_path):
        """The promotion path end-to-end: a spare built against a
        checkpoint directory decodes with the CHECKPOINTED params (loaded
        at construction via the race-tolerant load_latest_params), not
        its init params — promotion itself touches no disk."""
        from repro.checkpoint import store
        d = str(tmp_path)
        donor = ServeEngine(ARCH, num_slots=1, max_len=16, seed=7)
        store.save(d, 3, jax.tree_util.tree_map(np.asarray, donor.params))

        def build():
            return ServeEngine(ARCH, num_slots=1, max_len=16, seed=0,
                               ckpt_dir=d)
        hs = [ReplicaHandle("r0", _factory(num_slots=1, max_len=16)),
              ReplicaHandle("spare", build, spare=True)]
        mgr = ReplicaManager(hs, Router("round_robin"))
        reqs = _mk_requests(donor.cfg.vocab_size, [(4, 5, 0), (4, 5, 2)])

        def hook(m):
            if m.cluster_step == 1:
                m.drain("r0")          # promotes the spare

        comps = mgr.run(reqs, on_step=hook)
        assert [c.uid for c in comps] == [0, 1]
        assert mgr.owner[1] == "spare"
        # the spare's output matches the DONOR's params (seed 7), not a
        # seed-0 engine's — proof the checkpoint actually loaded
        c1 = mgr.completions[1]
        base = FixedBatchEngine(ARCH, batch=1, max_len=16, seed=7)
        ref = base.generate(c1.prompt[None], len(c1.tokens))[0,
                                                             len(c1.prompt):]
        np.testing.assert_array_equal(c1.tokens, ref)
        # the discriminating half: seed-0 init params decode DIFFERENTLY,
        # so matching the donor proves the checkpoint actually loaded
        alt = FixedBatchEngine(ARCH, batch=1, max_len=16, seed=0)
        alt_ref = alt.generate(c1.prompt[None], len(c1.tokens))[0,
                                                                len(c1.prompt):]
        assert not np.array_equal(ref, alt_ref)
        mgr.close()

    def test_stats_empty_cluster_is_well_defined(self):
        h = ReplicaHandle("r0", _factory())
        mgr = ReplicaManager([h])
        s = mgr.stats()
        assert s["requests"] == 0 and s["tokens"] == 0
        assert s["p95_ms"] == 0.0 and s["duplicates"] == 0
        assert mgr.scores()["r0"] > 0
        mgr.close()


# ---------------------------------------------------------------------------
# E2E: the committed replica_skew fixture — nested SEMI control
# ---------------------------------------------------------------------------


def _skew_factory(lane, W, num_slots, max_len):
    def build():
        control = ControlConfig(mode="semi", hetero_kind="trace",
                                sim_ranks=W, trace_in=FIXTURE,
                                trace_rank_offset=lane * W)
        return ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                           seed=0, control=control, prefill_chunk=2)
    return build


class TestClusterE2E:
    """R=3 replicas replaying the committed fixture (replica 1 carries
    two persistent χ=4 ranks its inner SEMI loop can only partially
    absorb), mid-run drain + warm-spare promotion, per-policy."""

    NUM_SLOTS, MAX_LEN = 4, 16

    def _run(self, policy, reqs, R, W, drain_step, record_trace=None):
        handles = [ReplicaHandle(f"r{i}",
                                 _skew_factory(i, W, self.NUM_SLOTS,
                                               self.MAX_LEN))
                   for i in range(R)]
        handles.append(ReplicaHandle("spare",
                                     _skew_factory(0, W, self.NUM_SLOTS,
                                                   self.MAX_LEN),
                                     spare=True))
        mgr = ReplicaManager(handles, Router(policy),
                             record_trace=record_trace)

        def hook(m):
            if m.cluster_step == drain_step:
                m.drain("r0")

        comps = mgr.run(reqs, on_step=hook)
        stats = mgr.stats()
        kinds = [e["kind"] for e in mgr.events if e["kind"] != "route"]
        routed = collections.Counter(mgr.routed_to.values())
        mgr.close()
        return comps, stats, kinds, routed

    def test_chi_aware_beats_round_robin_token_exact(self, tmp_path):
        import json
        with open(FIXTURE) as f:
            hdr = json.loads(f.readline())
        R, W = int(hdr["replicas"]), int(hdr["ranks_per_replica"])
        assert R == 3 and W == 4
        # same request materialization as benchmarks/cluster_bench.py
        rng = np.random.default_rng(np.random.SeedSequence((0xC1, 5)))
        reqs = []
        for uid, step, p, g in hdr["arrivals"]:
            prompt = rng.integers(0, 100, (p,)).astype(np.int32)
            if len(reqs) < 8:                # the bench's dry-run subset
                reqs.append(Request(uid=int(uid), prompt=prompt,
                                    max_new_tokens=int(g),
                                    arrival_step=int(step)))
        drain_step = max(4, max(r.arrival_step for r in reqs) // 2)
        trace_out = str(tmp_path / "cluster.jsonl")

        results = {}
        for policy in ("round_robin", "chi_aware"):
            comps, stats, kinds, routed = self._run(
                policy, reqs, R, W, drain_step,
                record_trace=trace_out if policy == "chi_aware" else None)
            # zero-drop through the drain + promotion, token-exact
            assert [c.uid for c in comps] == sorted(r.uid for r in reqs)
            assert stats["duplicates"] == 0
            assert "drain" in kinds and "promote" in kinds
            _assert_token_exact(self.MAX_LEN, comps)
            results[policy] = (stats, routed)

        rr, ca = results["round_robin"][0], results["chi_aware"][0]
        # the headline: pricing against plan-adjusted residual capacity
        # beats load-blind rotation under persistent replica skew
        assert ca["p95_ms"] < rr["p95_ms"], (ca["p95_ms"], rr["p95_ms"])
        assert ca["ttft_mean_ms"] < rr["ttft_mean_ms"]
        # chi_aware actually avoided the contended replica; round_robin,
        # being load-blind, kept feeding it
        assert results["chi_aware"][1].get("r1", 0) \
            < results["round_robin"][1]["r1"]

        # one-JSONL cluster replay: the recorded trace splits into R + 1
        # per-replica schedules, and the contended replica's lanes carry
        # its raw (pre-mitigation) χ so a replay reproduces the scenario
        scheds = replica_schedules(trace_out)
        assert len(scheds) == R + 1
        assert all(s.kind == "trace" and s.num_ranks == W for s in scheds)
        chi_r1 = scheds[1].chi(0)
        np.testing.assert_allclose(chi_r1[:2], [4.0, 4.0], rtol=0.1)
        np.testing.assert_allclose(chi_r1[2:], [1.0, 1.0], rtol=0.1)
