"""Unit tests for the unified control plane (repro.control).

Covers: sim→real plan projection (the removal of the serve engine's
"migration needs sim == real" restriction), the lossless β-policy, and
the control-plane checkpoint state round-trip that backs crash-safe
resume.
"""
import numpy as np
import pytest

from repro.config import WorkloadControlConfig, get_config, smoke_variant
from repro.control import ControlPlane, make_schedule, project_plan
from repro.core.controller import SemiController
from repro.core.hetero import IterationModel
from repro.core.workload import PlanDynamic, PlanStatic, WorkloadPlan
from repro.launch.mesh import make_small_mesh


def _plan(tp, buckets, srcs, sheds):
    static = PlanStatic(tp_size=tp, block_size=8, mig_shed=tuple(sheds))
    dyn = PlanDynamic(
        bucket_by_rank=np.asarray(buckets, np.int32),
        mig_src=(np.asarray(srcs, np.int32) if len(srcs)
                 else np.array(-1, np.int32)))
    return WorkloadPlan(static, dyn)


class TestProjection:
    def test_identity_when_sim_equals_real(self):
        plan = _plan(4, [0, 2, 0, 0], [1], [6])
        proj = project_plan(plan, sim_ranks=4, tp=4)
        assert not proj.folded
        np.testing.assert_array_equal(proj.bucket_by_rank, [0, 2, 0, 0])
        assert proj.mig_srcs == (1,)
        assert proj.mig_sheds == (6,)

    def test_folded_buckets_broadcast_critical_path(self):
        """Resize buckets keep the previous sim-scale semantics: every
        real rank executes the slowest sim rank's branch."""
        plan = _plan(8, [0, 0, 3, 0, 1, 0, 0, 0], [], [])
        proj = project_plan(plan, sim_ranks=8, tp=4)
        assert proj.folded
        np.testing.assert_array_equal(proj.bucket_by_rank, [3, 3, 3, 3])
        assert proj.mig_sheds == ()

    def test_folded_migration_slots_map_mod_tp(self):
        """Sim source 6 folds onto real rank 6 % 4 = 2; the shed count
        survives (this is the restriction removal)."""
        plan = _plan(8, [0] * 8, [6], [5])
        proj = project_plan(plan, sim_ranks=8, tp=4)
        assert proj.mig_srcs == (2,)
        assert proj.mig_sheds == (5,)
        np.testing.assert_array_equal(proj.bucket_by_rank, [0, 0, 0, 0])

    def test_folded_collisions_keep_heaviest(self):
        """Two sim sources folding onto the same real rank keep only the
        first (canonical shed-descending order = heaviest)."""
        plan = _plan(8, [0] * 8, [1, 5, 2], [7, 6, 4])   # 1%4 == 5%4 == 1
        proj = project_plan(plan, sim_ranks=8, tp=4)
        assert proj.mig_srcs == (1, 2)
        assert proj.mig_sheds == (7, 4)

    def test_folded_keeps_at_least_one_helper(self):
        plan = _plan(8, [0] * 8, [0, 1, 2, 3], [4, 4, 4, 4])
        proj = project_plan(plan, sim_ranks=8, tp=4)
        assert len(proj.mig_srcs) <= 3               # tp - 1 helpers floor

    def test_tp1_folds_to_no_migration(self):
        plan = _plan(8, [0] * 8, [3], [5])
        proj = project_plan(plan, sim_ranks=8, tp=1)
        assert proj.mig_srcs == ()
        assert proj.mig_sheds == ()

    def test_shed_clamped_to_real_shard(self):
        """A sim-scale shed larger than the real local shard is clamped so
        the source keeps >= 1 block."""
        plan = _plan(8, [0] * 8, [5], [14])
        proj = project_plan(plan, sim_ranks=8, tp=4, real_nb=8)
        assert proj.mig_sheds == (7,)


class TestLosslessBetaPolicy:
    def _controller(self, policy):
        cfg = WorkloadControlConfig(enabled=True, mode="semi", block_size=8,
                                    max_migration_sources=3,
                                    beta_policy=policy)
        model = IterationModel(matmul_time=1.0, other_time=0.15)
        return SemiController(cfg, 8, model, num_blocks=16, seed=0)

    def test_lossless_single_straggler_pure_migration(self):
        """With β forced to 1, the Eq.(3)-selected straggler sheds its
        FULL offset volume: residual resize bucket 0 ⇒ output-preserving
        plan."""
        ctl = self._controller("lossless")
        times = np.array([4.15] + [1.15] * 7)
        plan, rep = ctl.plan(times)
        assert rep.mig_srcs == (0,)
        assert rep.betas == (1.0,)
        assert int(plan.dynamic.bucket_by_rank.max()) == 0   # no resize
        assert sum(rep.mig_shed) > 0

    def test_unknown_beta_policy_rejected(self):
        """A typo'd policy must fail loudly, not silently fall through to
        the lossy eq2 split."""
        with pytest.raises(ValueError, match="beta_policy"):
            WorkloadControlConfig(beta_policy="loss-less")

    def test_eq2_default_unchanged(self):
        """The training default still splits per Eq.(2) (β < 1 leaves a
        residual resize bucket when migration is not free)."""
        ctl = self._controller("eq2")
        times = np.array([4.15] + [1.15] * 7)
        _, rep = ctl.plan(times)
        assert rep.betas and rep.betas[0] <= 1.0


class TestControlPlaneState:
    def _plane(self, seed=0):
        cfg = smoke_variant(get_config("yi-6b"))
        wc = WorkloadControlConfig(enabled=True, mode="semi", block_size=8,
                                   max_migration_sources=3,
                                   times="measured")
        mesh = make_small_mesh(1, 1)
        model = IterationModel(matmul_time=1.0, other_time=0.15)
        builder = (lambda static:
                   (object(),
                    max(1, static.num_sources) if static is not None else 0,
                    None))
        return ControlPlane(cfg, wc, mesh=mesh, tp=1, builder=builder,
                            it_model=model, sim_ranks=8,
                            hetero_kind="contention", chi=4.0, seed=seed)

    def test_state_round_trip_resumes_identically(self):
        """Drive a plane N steps, checkpoint, restore into a FRESH plane,
        and verify the next decisions + estimator state are identical to
        continuing uninterrupted."""
        a = self._plane()
        for step in range(6):
            chis = a.chis(step)
            plan, _ = a.decide(a.controller_times(chis))
            a.capture(chis, a.work_frac(plan), step=step, plan=plan,
                      wall=0.0)
        arrays, meta = a.state_arrays(), a.state_meta()

        b = self._plane()
        b.load_state(arrays, meta)
        np.testing.assert_array_equal(a.estimator.chi_hat,
                                      b.estimator.chi_hat)
        assert a.estimator.updates == b.estimator.updates
        for step in range(6, 12):
            chis_a, chis_b = a.chis(step), b.chis(step)
            np.testing.assert_array_equal(chis_a, chis_b)
            plan_a, rep_a = a.decide(a.controller_times(chis_a))
            plan_b, rep_b = b.decide(b.controller_times(chis_b))
            assert plan_a.static.signature_str() == \
                plan_b.static.signature_str()
            np.testing.assert_array_equal(plan_a.dynamic.bucket_by_rank,
                                          plan_b.dynamic.bucket_by_rank)
            assert rep_a.mig_srcs == rep_b.mig_srcs
            a.capture(chis_a, a.work_frac(plan_a), step=step, plan=plan_a,
                      wall=0.0)
            b.capture(chis_b, b.work_frac(plan_b), step=step, plan=plan_b,
                      wall=0.0)

    def test_state_meta_is_json_round_trippable(self):
        import json
        a = self._plane()
        meta = json.loads(json.dumps(a.state_meta()))
        b = self._plane(seed=1)
        b.load_state({}, meta)
        # RNG streams now aligned with plane a
        assert (b.measure_rng.bit_generator.state
                == a.measure_rng.bit_generator.state)

    def test_make_schedule_none_and_trace_error(self):
        assert make_schedule("none", 4) is None
        with pytest.raises(ValueError, match="trace_in"):
            make_schedule("trace", 4)
