"""Minimal stand-in for the `hypothesis` API surface this suite uses.

Activated by conftest.py ONLY when the real hypothesis is not installed
(e.g. a bare container without the `[test]` extra). Property tests then
run a fixed number of deterministic seeded random examples — no shrinking,
no example database, but the same assertions against the same strategies,
so `pytest` stays runnable everywhere. CI installs the real thing via
`pip install -e .[test]`.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types


class _Strategy:
    def __init__(self, sample):
        self._sample = sample

    def sample(self, rng: random.Random):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def sampled_from(elements) -> _Strategy:
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    return _Strategy(lambda rng: [
        elements.sample(rng)
        for _ in range(rng.randint(min_size, max_size))])


class _Data:
    """Interactive draw object mirroring hypothesis' `st.data()`."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label=None):
        return strategy.sample(self._rng)


def data() -> _Strategy:
    return _Strategy(lambda rng: _Data(rng))


def settings(max_examples: int = None, deadline=None, **_kw):  # noqa: D103
    def deco(fn):
        if max_examples is not None:
            fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**kw_strategies):
    """Kwargs-style @given: runs the test body over seeded random draws.

    Works in either decorator order relative to @settings (the example
    count is looked up at call time on both the wrapper and the wrapped
    function, whichever @settings annotated).
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", 20))
            rng = random.Random(0xC0FFEE)
            for _ in range(n):
                draw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                fn(*args, **draw, **kwargs)
        # pytest must not see the strategy-filled params as fixtures: hide
        # them from the (wraps-copied) signature and drop __wrapped__ so
        # inspect does not tunnel back to the original function.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items()
            if name not in kw_strategies])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        wrapper.is_hypothesis_test_fallback = True
        return wrapper
    return deco


def assume(condition) -> bool:
    """Real hypothesis aborts the example; here we just require truthiness
    of draws to be handled by the strategies, so assume() is a no-op pass
    for truthy and an explicit skip-signal (exception-free) for falsy —
    tests in this suite don't use assume, this exists for safety only."""
    return bool(condition)


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    all = classmethod(lambda cls: [cls.too_slow, cls.data_too_large])


def install() -> types.ModuleType:
    """Register this module as `hypothesis` (+ `.strategies`) in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.HealthCheck = HealthCheck
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "data"):
        setattr(strat, name, globals()[name])
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
    return mod
