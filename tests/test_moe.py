"""MoE dispatch/combine correctness (sort-based grouped dispatch)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.config import MoEConfig
from repro.layers import moe as moe_lib


def _dense_oracle(xt, params, idx, weights, act):
    """Per-token loop: y_t = sum_k w_k * FFN_{e_k}(x_t)."""
    T, d = xt.shape
    out = np.zeros((T, d), np.float32)
    wg, wu, wd = params.get("w_gate"), params["w_up"], params["w_down"]
    for t in range(T):
        for j in range(idx.shape[1]):
            e = int(idx[t, j])
            h = xt[t] @ wu[e]
            if wg is not None:
                h = np.asarray(act(jnp.asarray(xt[t] @ wg[e]))) * h
            else:
                h = np.asarray(act(jnp.asarray(h)))
            out[t] += float(weights[t, j]) * (h @ wd[e])
    return out


@pytest.mark.parametrize("sharding", ["expert", "tp"])
def test_moe_matches_per_token_oracle(sharding):
    rng = np.random.default_rng(0)
    B, S, d, E, f, k = 2, 8, 16, 4, 32, 2
    cfg = MoEConfig(num_experts=E, top_k=k, d_expert=f,
                    capacity_factor=8.0)   # big capacity: no drops
    params = {
        "router": jnp.asarray(rng.standard_normal((d, E)) * .5, jnp.float32),
        "w_gate": jnp.asarray(rng.standard_normal((E, d, f)) * .1, jnp.float32),
        "w_up": jnp.asarray(rng.standard_normal((E, d, f)) * .1, jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((E, f, d)) * .1, jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((B, S, d)), jnp.float32)
    y, aux = moe_lib.moe_ffn(x, params, cfg, jax.nn.silu,
                             expert_sharding=sharding)
    assert jnp.isfinite(aux)

    xt = np.asarray(x.reshape(-1, d))
    idx, weights, _ = moe_lib.router_topk(jnp.asarray(xt), params["router"], cfg)
    want = _dense_oracle(xt, jax.device_get(params), np.asarray(idx),
                         np.asarray(weights), jax.nn.silu)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, d), want,
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens_not_crash():
    rng = np.random.default_rng(1)
    cfg = MoEConfig(num_experts=2, top_k=1, d_expert=8, capacity_factor=0.1)
    d = 8
    params = {
        "router": jnp.asarray(np.eye(d)[:, :2] * 10, jnp.float32),  # all -> e0
        "w_gate": None,
        "w_up": jnp.asarray(rng.standard_normal((2, d, 8)) * .1, jnp.float32),
        "w_down": jnp.asarray(rng.standard_normal((2, 8, d)) * .1, jnp.float32),
    }
    x = jnp.asarray(rng.standard_normal((1, 64, d)), jnp.float32)
    y, _ = moe_lib.moe_ffn(x, params, cfg, jax.nn.gelu)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


@given(T=st.integers(4, 32), E=st.integers(2, 8), k=st.integers(1, 3),
       seed=st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_dispatch_roundtrip_property(T, E, k, seed):
    """Every non-dropped (token, expert) pair lands in exactly one slot with
    its weight; empty slots carry weight 0 and token id == T."""
    k = min(k, E)
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(
        np.stack([rng.choice(E, k, replace=False) for _ in range(T)]),
        jnp.int32)
    w = jnp.asarray(rng.random((T, k)), jnp.float32)
    cap = T * k  # no drops
    gather_t, comb_w = moe_lib._grouped_dispatch(idx, w, T, E, cap)
    gather_t, comb_w = np.asarray(gather_t), np.asarray(comb_w)
    # count appearances
    pairs = {}
    for e in range(E):
        for g in range(cap):
            t = gather_t[e, g]
            if t < T and comb_w[e, g] > 0:
                pairs[(t, e)] = pairs.get((t, e), 0) + 1
    want = {(t, int(idx[t, j])): 1 for t in range(T) for j in range(k)}
    assert pairs == want
    # weights preserved
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            g = [g for g in range(cap)
                 if gather_t[e, g] == t and comb_w[e, g] > 0]
            assert len(g) == 1
            np.testing.assert_allclose(comb_w[e, g[0]], w[t, j], rtol=1e-6)
