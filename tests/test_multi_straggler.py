"""Property tests for CONCURRENT multi-straggler SEMI-migration.

The paper's Fig. 11 scenario: several ranks of one TP group straggle at
once. Migration must stay LOSSLESS — forward outputs and all parameter
gradients equal the dense TP reference — for 2 and 3 simultaneous
stragglers, and the plan-signature compile cache must build each bucketed
signature at most once across a replanning sweep.

Multi-device cases run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=N (the main pytest
process keeps 1 device per the brief).
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def preamble(e: int) -> str:
    return f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.layers.tp_linear import ControlContext, controlled_ffn
from repro.core.workload import PlanStatic
e, B, S, d, H, block = {e}, 2, 8, 48, {e * 32}, 8
nb_loc = (H // e) // block
mesh = Mesh(np.array(jax.devices()).reshape(1, e), ("data", "model"))
act = jax.nn.silu
buckets = (0.0, 0.25, 0.5)
def make_ctx(sheds, bucket_vec, srcs):
    st = PlanStatic(buckets=buckets, block_size=block,
                    mig_shed=tuple(sheds), tp_size=e)
    pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))
    return ControlContext(mesh=mesh, axis="model", static=st,
        bucket_by_rank=jnp.array(bucket_vec, jnp.int32),
        mig_src=jnp.array(srcs, jnp.int32), pri={{"ffn": pri}})
def weights(seed):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
    wg = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
    wu = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
    wd = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
    return x, wg, wu, wd
"""


LOSSLESS_BODY = """
rng = np.random.default_rng(7)
for trial in range(trials):
    x, wg, wu, wd = weights(trial)
    srcs = sorted(rng.choice(e, size=n_src, replace=False).tolist())
    sheds = sorted(rng.integers(1, nb_loc, size=n_src).tolist(), reverse=True)
    ctx = make_ctx(sheds, [0]*e, srcs)
    ref = (act(x @ wg) * (x @ wu)) @ wd
    y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
    err = np.abs(np.array(y) - ref).max()
    assert np.allclose(y, ref, atol=2e-4), (trial, srcs, sheds, err)
    # gradient round-trip: every weight gradient matches the dense VJP
    def loss(wu, wd, wg):
        return jnp.sum(controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)**2)
    g = jax.grad(loss, (0, 1, 2))(wu, wd, wg)
    gref = jax.grad(lambda wu, wd, wg:
                    jnp.sum(((act(x@wg))*(x@wu)@wd)**2), (0, 1, 2))(wu, wd, wg)
    for a, b in zip(g, gref):
        gerr = np.abs(np.array(a) - np.array(b)).max()
        assert np.allclose(a, b, atol=2e-3), (trial, srcs, sheds, gerr)
print("ok")
"""


class TestLosslessConcurrentMigration:
    def test_two_stragglers_4dev_fwd_and_grad(self):
        """2 simultaneous sources on a 4-rank group (2 helpers)."""
        run_py(preamble(4) + "trials, n_src = 3, 2" + LOSSLESS_BODY,
               devices=4)

    def test_three_stragglers_8dev_fwd_and_grad(self):
        """3 simultaneous sources on an 8-rank group (5 helpers)."""
        run_py(preamble(8) + "trials, n_src = 3, 3" + LOSSLESS_BODY,
               devices=8)

    def test_three_stragglers_single_helper_4dev(self):
        """e − S = 1: the lone helper absorbs all three sources' sheds."""
        run_py(preamble(4) + "trials, n_src = 2, 3" + LOSSLESS_BODY,
               devices=4)

    def test_semi_mix_resize_plus_concurrent_migrate(self):
        """SEMI with 2 sources AND resizing ranks: migrated blocks stay
        exact, pruned blocks match the masked oracle."""
        run_py(preamble(8) + """
x, wg, wu, wd = weights(0)
# ranks 2 and 5 migrate (sheds 2,1) and also carry resize buckets; rank 6
# only resizes. The oracle: every rank keeps kc_b blocks of its keep-first
# list; migration moves (not drops) blocks, so the mask is resize-only.
bucket_vec = [0, 0, 1, 0, 0, 2, 1, 0]
ctx = make_ctx((2, 1), bucket_vec, (2, 5))
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
mask = np.ones(H // block, bool)
from repro.core.workload import keep_blocks_for_bucket
for r, b in enumerate(bucket_vec):
    kc = keep_blocks_for_bucket(buckets[b], nb_loc)
    mask[r * nb_loc + kc : (r + 1) * nb_loc] = False
ref = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y, ref, atol=2e-4), np.abs(np.array(y)-ref).max()
print("ok")
""")

    def test_shed_exceeding_keep_stays_disjoint(self):
        """Regression: a source whose residual keep count clamps to 1
        (kc − m_s < 1) must NOT double-compute blocks — the migrated
        window starts after the clamped keep prefix. Source keeps
        pri[:1] locally, helpers compute pri[1:1+m] exactly, the rest
        is pruned."""
        run_py(preamble(4) + """
x, wg, wu, wd = weights(0)
# nb_loc = 4; source rank 1 in bucket index 2 (γ=0.5 -> kc=2) sheds 2:
# kc - m = 0 -> clamped local keep is pri[:1], migrated window pri[1:3]
bucket_vec = [0, 2, 0, 0]
ctx = make_ctx((2,), bucket_vec, (1,))
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
mask = np.ones(H // block, bool)
mask[1 * nb_loc + 3 : 2 * nb_loc] = False     # only pri[3] of rank 1 pruned
ref = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y, ref, atol=2e-4), np.abs(np.array(y)-ref).max()
print("ok")
""", devices=4)

    def test_retarget_source_set_no_recompile(self):
        """Changing WHICH ranks straggle (same shed signature) must hit the
        jit cache — retargeting is a runtime input."""
        run_py(preamble(8) + """
x, wg, wu, wd = weights(0)
ctx = make_ctx((2, 1), [0]*e, (0, 1))
f = jax.jit(lambda bucket, srcs: controlled_ffn(
    x, wu, wd, ControlContext(mesh=mesh, axis="model", static=ctx.static,
        bucket_by_rank=bucket, mig_src=srcs, pri=ctx.pri),
    "ffn", act, w_gate=wg))
b0 = jnp.zeros((e,), jnp.int32)
ref = (act(x @ wg) * (x @ wu)) @ wd
y1 = f(b0, jnp.array([0, 1], jnp.int32))
y2 = f(b0, jnp.array([6, 3], jnp.int32))
y3 = f(b0, jnp.array([-1, -1], jnp.int32))   # all slots idle -> dense
assert f._cache_size() == 1, f._cache_size()
for y in (y1, y2, y3):
    assert np.allclose(y, ref, atol=2e-4)
print("ok")
""")


class TestPlanSignatureCache:
    def test_each_bucketed_signature_compiles_at_most_once(self):
        """Replanning sweep with noisy straggler times: the signature set
        stays small (shed quantization) and the compile-count hook shows
        each signature built exactly once; a second identical sweep adds
        zero compiles and the jitted executables never retrace."""
        run_py(preamble(4) + """
from repro.config import WorkloadControlConfig
from repro.core.hetero import IterationModel
from repro.core.controller import SemiController
from repro.core.workload import PlanCompileCache
x, wg, wu, wd = weights(0)
pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))

def build(static):
    def f(bucket, srcs):
        ctx = ControlContext(mesh=mesh, axis="model", static=static,
                             bucket_by_rank=bucket, mig_src=srcs,
                             pri={"ffn": pri})
        return controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
    return jax.jit(f)

cache = PlanCompileCache(build)
built = []
cache.on_compile = built.append

cfg = WorkloadControlConfig(enabled=True, mode="semi", block_size=block,
                            max_migration_sources=2)
ctl = SemiController(cfg, e, IterationModel(matmul_time=1.0, other_time=0.1),
                     num_blocks=nb_loc)

def sweep(seed):
    rng = np.random.default_rng(seed)
    sigs = []
    for step in range(20):
        t = np.ones(e)
        t[0] = 4.0 + rng.normal(0, 0.4)
        t[2] = 2.5 + rng.normal(0, 0.3)
        plan, rep = ctl.plan(np.maximum(t, 1.0))
        sig = plan.static.signature()
        fjit = cache.get(sig)
        srcs = plan.dynamic.mig_srcs(max(1, sig.num_sources))
        y = fjit(jnp.asarray(plan.dynamic.bucket_by_rank), jnp.asarray(srcs))
        y.block_until_ready()
        sigs.append(sig)
    return sigs

sigs = sweep(0)
assert cache.compile_count == len(set(sigs)), (cache.compile_count, set(sigs))
assert len(built) == len(set(built))            # hook: no signature rebuilt
assert cache.compile_count <= 5, cache.compile_count   # bucketing bounds it
before = cache.compile_count
sweep(0)                                        # identical replanning sweep
assert cache.compile_count == before, "cache missed a known signature"
# and the underlying jit never retraced within a signature
for fn in cache._entries.values():
    assert fn._cache_size() == 1, fn._cache_size()
print("ok:", cache.compile_count, "signatures,", cache.hit_count, "hits")
""", devices=4)
