"""Gradient correctness of the kernel-level backward against the XLA /
ref.py oracle lineage, under awkward shapes (ISSUE 2 satellite):

* M not a multiple of tm, N not a multiple of tn (padding paths)
* keep-count 1 and keep-count = all blocks (degenerate grids)
* bf16 inputs (f32 accumulation, bf16 outputs)
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import resizing
from repro.kernels import ops


def _mk(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32), dtype)


def _assert_close(a, b, tol):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    scale = max(1.0, float(np.abs(b).max()))
    np.testing.assert_allclose(a / scale, b / scale, atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# block_pruned_matmul VJP vs the XLA gather/scatter lineage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M,K,N,block,tm,tn,kb", [
    (40, 128, 72, 32, 16, 32, 2),    # M % tm != 0, N % tn != 0
    (16, 128, 32, 32, 16, 32, 1),    # keep-count 1
    (24, 96, 48, 32, 16, 16, 3),     # keep-count = all blocks
    (33, 160, 50, 32, 32, 32, 3),    # both dims ragged vs tiles
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pruned_matmul_grads_match_oracle(M, K, N, block, tm, tn, kb, dtype):
    rng = np.random.default_rng(M * 7 + N)
    x, w = _mk(rng, (M, K), dtype), _mk(rng, (K, N), dtype)
    nb = K // block
    keep = jnp.asarray(np.sort(rng.choice(nb, kb, replace=False)), jnp.int32)
    cot = _mk(rng, (M, N), dtype)

    def loss_k(x_, w_):
        y = ops.block_pruned_matmul(x_, w_, keep, block, tm, tn)
        return jnp.sum(y.astype(jnp.float32) * cot.astype(jnp.float32))

    def loss_o(x_, w_):
        y = resizing.resized_matmul(x_, w_, keep, block=block)
        return jnp.sum(y.astype(jnp.float32) * cot.astype(jnp.float32))

    gk = jax.grad(loss_k, (0, 1))(x, w)
    go = jax.grad(loss_o, (0, 1))(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    _assert_close(gk[0], go[0], tol)
    _assert_close(gk[1], go[1], tol)
    # lineage: pruned blocks must carry exactly zero gradient
    mask = np.asarray(resizing.keep_mask(keep, nb, block))
    assert np.all(np.asarray(gk[0], np.float32)[:, ~mask] == 0)
    assert np.all(np.asarray(gk[1], np.float32)[~mask, :] == 0)


def test_pruned_matmul_grad_batched_leading_dims():
    rng = np.random.default_rng(11)
    x = _mk(rng, (2, 5, 128), jnp.float32)
    w = _mk(rng, (128, 40), jnp.float32)
    keep = jnp.asarray([0, 3], jnp.int32)

    gk = jax.grad(lambda x_: jnp.sum(
        ops.block_pruned_matmul(x_, w, keep, 32, 16, 32) ** 2))(x)
    go = jax.grad(lambda x_: jnp.sum(
        resizing.resized_matmul(x_, w, keep, block=32) ** 2))(x)
    _assert_close(gk, go, 1e-4)


# ---------------------------------------------------------------------------
# fused_pruned_ffn VJP vs the explicit gather composition
# ---------------------------------------------------------------------------


def _ffn_oracle(x, wu, wd, keep, act, wg=None, *, block):
    return resizing.resized_ffn(x, wu, wd, keep, act, wg, block=block,
                                use_kernel=False)


@pytest.mark.parametrize("M,d,H,D2,block,kb", [
    (10, 48, 128, 40, 32, 2),        # ragged M/D2 vs tiles
    (8, 32, 64, 32, 32, 1),          # keep-count 1
    (12, 32, 96, 24, 32, 3),         # keep-count = all blocks
])
@pytest.mark.parametrize("gated", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ffn_grads_match_oracle(M, d, H, D2, block, kb, gated, dtype):
    rng = np.random.default_rng(M + H + D2)
    x = _mk(rng, (M, d), dtype)
    wu = _mk(rng, (d, H), dtype) * 0.2
    wd = _mk(rng, (H, D2), dtype) * 0.2
    wg = _mk(rng, (d, H), dtype) * 0.2 if gated else None
    nb = H // block
    keep = jnp.asarray(np.sort(rng.choice(nb, kb, replace=False)), jnp.int32)
    act = jax.nn.silu
    cot = _mk(rng, (M, D2), dtype)

    def loss_k(x_, wu_, wd_, wg_):
        y = ops.fused_pruned_ffn(x_, wu_, wd_, keep, wg_, act, block, 16)
        return jnp.sum(y.astype(jnp.float32) * cot.astype(jnp.float32))

    def loss_o(x_, wu_, wd_, wg_):
        y = _ffn_oracle(x_, wu_, wd_, keep, act, wg_, block=block)
        return jnp.sum(y.astype(jnp.float32) * cot.astype(jnp.float32))

    argnums = (0, 1, 2, 3) if gated else (0, 1, 2)
    args = (x, wu, wd, wg) if gated else (x, wu, wd, None)
    gk = jax.grad(loss_k, argnums)(*args)
    go = jax.grad(loss_o, argnums)(*args)
    tol = 2e-4 if dtype == jnp.float32 else 4e-2
    for a, b in zip(gk, go):
        _assert_close(a, b, tol)
    # lineage: pruned H-blocks of dWup / dWdown carry exactly zero gradient
    mask = np.asarray(resizing.keep_mask(keep, nb, block))
    assert np.all(np.asarray(gk[1], np.float32)[:, ~mask] == 0)
    assert np.all(np.asarray(gk[2], np.float32)[~mask, :] == 0)


def test_fused_ffn_forward_matches_oracle_batched():
    rng = np.random.default_rng(3)
    x = _mk(rng, (2, 6, 32), jnp.float32)
    wu = _mk(rng, (32, 64), jnp.float32) * 0.2
    wd = _mk(rng, (64, 24), jnp.float32) * 0.2
    keep = jnp.asarray([1], jnp.int32)
    y = ops.fused_pruned_ffn(x, wu, wd, keep, None, jax.nn.gelu, 32, 16)
    y_ref = _ffn_oracle(x, wu, wd, keep, jax.nn.gelu, block=32)
    assert y.shape == (2, 6, 24)
    _assert_close(y, y_ref, 1e-4)


def test_grads_correct_for_unsorted_keep_idx():
    """Regression: the backward's inverse order must keep keep_idx in
    CALLER order — compact hidden slot k pairs with block keep_idx[k].
    With a sorted-prefix order an unsorted keep_idx scrambled
    dWup/dWdown across blocks while the forward stayed correct."""
    rng = np.random.default_rng(42)
    keep = jnp.asarray([3, 0, 2], jnp.int32)           # deliberately unsorted
    x = _mk(rng, (10, 32), jnp.float32)
    wu = _mk(rng, (32, 128), jnp.float32) * 0.2
    wd = _mk(rng, (128, 24), jnp.float32) * 0.2

    def loss_k(wu_, wd_):
        return jnp.sum(ops.fused_pruned_ffn(
            x, wu_, wd_, keep, None, jax.nn.silu, 32, 16) ** 2)

    def loss_o(wu_, wd_):
        return jnp.sum(_ffn_oracle(x, wu_, wd_, keep, jax.nn.silu,
                                   block=32) ** 2)

    gk = jax.grad(loss_k, (0, 1))(wu, wd)
    go = jax.grad(loss_o, (0, 1))(wu, wd)
    _assert_close(gk[0], go[0], 1e-4)
    _assert_close(gk[1], go[1], 1e-4)

    # plain pruned matmul too
    w = _mk(rng, (96, 40), jnp.float32)
    x2 = _mk(rng, (8, 96), jnp.float32)
    keep2 = jnp.asarray([2, 0], jnp.int32)
    gk2 = jax.grad(lambda x_, w_: jnp.sum(
        ops.block_pruned_matmul(x_, w_, keep2, 32, 8, 16) ** 2), (0, 1))(x2, w)
    go2 = jax.grad(lambda x_, w_: jnp.sum(
        resizing.resized_matmul(x_, w_, keep2, block=32) ** 2), (0, 1))(x2, w)
    _assert_close(gk2[0], go2[0], 1e-4)
    _assert_close(gk2[1], go2[1], 1e-4)


def test_validation_errors_are_actionable():
    x = jnp.zeros((8, 100))      # K=100 not a multiple of block=32
    w = jnp.zeros((100, 16))
    keep = jnp.asarray([0], jnp.int32)
    with pytest.raises(ValueError, match="not a multiple"):
        ops.block_pruned_matmul(x, w, keep, 32, 8, 16)
    x2, w2 = jnp.zeros((8, 64)), jnp.zeros((64, 16))
    with pytest.raises(ValueError, match="blocks"):
        ops.block_pruned_matmul(x2, w2, jnp.zeros((5,), jnp.int32), 32, 8, 16)
    with pytest.raises(ValueError, match="integer"):
        ops.block_pruned_matmul(x2, w2, jnp.zeros((1,)), 32, 8, 16)
    with pytest.raises(ValueError, match="contraction mismatch"):
        ops.block_pruned_matmul(x2, jnp.zeros((32, 16)), keep, 32, 8, 16)
