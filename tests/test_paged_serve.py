"""Paged KV cache + chunked prefill (ISSUE 8).

The load-bearing claims: the block-paged cache is INVISIBLE — a request
decoded through the shared page pool (any page order, recycled pages,
chunked prefill, preemption/restart) produces exactly the tokens the
fixed ``num_slots x max_len`` slot cache produces — and the page
allocator never hands one slot another slot's pages.
"""
import numpy as np
import pytest

from repro.control import ControlConfig
from repro.core import paging
from repro.launch.serve import Request, ServeEngine


def _mk(vocab, specs, seed=0):
    """specs: list of (prompt_len, gen_len, arrival_step)."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate(specs)]


def _tokens(comps):
    return {c.uid: list(c.tokens) for c in comps}


def _run(arch, specs, **kw):
    eng = ServeEngine(arch, seed=0, **kw)
    comps = eng.run(_mk(eng.cfg.vocab_size, specs))
    eng.close()
    return eng, _tokens(comps)


class TestPageAllocator:
    def test_layout_math(self):
        lay = paging.paged_layout(max_len=10, page_size=4, num_slots=3)
        assert lay.pages_per_slot == 3
        assert lay.num_pages == 9            # defaults to full capacity
        assert lay.padded_len == 12
        assert lay.pages_for(0) == 0
        assert lay.pages_for(1) == 1
        assert lay.pages_for(4) == 1
        assert lay.pages_for(5) == 2

    def test_grow_free_and_recycle(self):
        lay = paging.paged_layout(max_len=8, page_size=4, num_slots=2)
        al = paging.PageAllocator(lay, num_slots=2)
        assert al.free_pages == 4
        assert al.ensure(0, upto_pos=0)          # 1 page
        assert al.ensure(0, upto_pos=5)          # grows to 2
        assert al.used_pages(0) == 2 and al.free_pages == 2
        assert al.ensure(0, upto_pos=3)          # no shrink, no-op
        assert al.used_pages(0) == 2
        t = al.table()
        assert t.shape == (2, 2) and (t[1] == -1).all()
        assert (t[0] >= 0).all()
        owned = set(t[0].tolist())
        al.free_slot(0)
        assert al.free_pages == 4
        assert (al.table() == -1).all()
        # recycled pages are re-issued (free list, not a bump allocator)
        assert al.ensure(1, upto_pos=7)
        assert set(al.table()[1].tolist()) == owned

    def test_exhaustion_is_all_or_nothing(self):
        lay = paging.paged_layout(max_len=8, page_size=4, num_slots=2,
                                  num_pages=3)
        al = paging.PageAllocator(lay, num_slots=2)
        assert al.ensure(0, upto_pos=7)          # slot 0 takes 2 of 3
        assert al.ensure(1, upto_pos=3)          # slot 1 takes the last
        before = al.table().copy()
        assert not al.ensure(1, upto_pos=7)      # needs 1 more: refused...
        np.testing.assert_array_equal(al.table(), before)  # ...atomically
        assert not al.can_fit(5)
        assert al.can_fit(0)

    def test_over_capacity_request_raises(self):
        lay = paging.paged_layout(max_len=8, page_size=4, num_slots=2)
        al = paging.PageAllocator(lay, num_slots=2)
        with pytest.raises(ValueError):
            al.ensure(0, upto_pos=8)             # needs 3 > pages_per_slot


class TestPagedServe:
    def test_paged_and_chunked_token_exact_gqa(self):
        """Paged (C=1) and paged+chunked (C=3, chunks CROSS the page_size=4
        boundary) both reproduce the fixed-slot engine token-for-token
        through slot recycling, with one trace of the jitted step."""
        specs = [(5, 6, 0), (7, 4, 2), (4, 5, 6)]
        kw = dict(num_slots=2, max_len=16)
        _, ref = _run("yi-6b", specs, **kw)
        _, got1 = _run("yi-6b", specs, page_size=4, **kw)
        eng3, got3 = _run("yi-6b", specs, page_size=4, prefill_chunk=3, **kw)
        assert got1 == ref
        assert got3 == ref
        tc = eng3.trace_counts()
        assert tc["plan_compiles"] == 1
        assert tc["base_step_traces"] in (1, -1)

    def test_paged_token_exact_mla(self):
        """The MLA (latent + rope row) cache family through the paged
        pool, chunked prefill crossing a page boundary."""
        specs = [(5, 4, 0), (6, 3, 2)]
        kw = dict(num_slots=2, max_len=12)
        _, ref = _run("deepseek-v2-lite-16b", specs, **kw)
        _, got = _run("deepseek-v2-lite-16b", specs, page_size=4,
                      prefill_chunk=3, **kw)
        assert got == ref

    def test_exhaustion_preempts_without_corrupting_neighbors(self):
        """A pool too small for both requests at full length (5 pages for
        2 slots x 4) forces a preemption mid-flight: the evicted request
        restarts and STILL matches the fixed-slot engine, and the
        surviving neighbor's pages are untouched (its tokens match too)."""
        specs = [(5, 6, 0), (7, 4, 0)]
        kw = dict(num_slots=2, max_len=16)
        _, ref = _run("yi-6b", specs, **kw)
        eng, got = _run("yi-6b", specs, page_size=4, num_pages=5, **kw)
        assert eng.preemptions > 0
        assert got == ref
        assert eng.alloc.free_pages == 5         # everything returned

    def test_exhaustion_with_no_victim_raises(self):
        """A single request that outgrows a pool with nobody to preempt
        must fail loudly, not scatter out-of-bounds."""
        eng = ServeEngine("yi-6b", num_slots=1, max_len=16, seed=0,
                          page_size=4, num_pages=2)
        req = _mk(eng.cfg.vocab_size, [(6, 8, 0)])
        with pytest.raises(RuntimeError, match="page pool exhausted"):
            eng.run(req)
        eng.close()

    def test_max_new_zero_completes_with_empty_generation(self):
        """max_new_tokens=0 completes on the final teacher-forced prefill
        step with ``generated == []`` — the engine must not emit the
        spurious post-prefill token (ISSUE 8 bugfix), on both cache
        layouts."""
        for kw in ({}, {"page_size": 4, "prefill_chunk": 2}):
            eng, toks = _run("yi-6b", [(3, 0, 0), (4, 2, 0)],
                             num_slots=2, max_len=8, **kw)
            assert toks[0] == []
            assert len(toks[1]) == 2

    def test_semi_control_paged_token_exact(self):
        """Under SEMI control with chi=4 contention, the paged engine
        matches the fixed engine on the SAME stepping trajectory (equal
        prefill_chunk — the tp=1 projection folds migration to lossy
        resize, so plan trajectories must line up for exactness)."""
        specs = [(5, 4, 0), (6, 3, 2)]
        ctl = lambda: ControlConfig(mode="semi", hetero_kind="contention",
                                    chi=4.0, contention_p=0.15,
                                    sim_ranks=8, seed=3)
        kw = dict(num_slots=2, max_len=12, prefill_chunk=2)
        _, ref = _run("yi-6b", specs, control=ctl(), **kw)
        _, got = _run("yi-6b", specs, control=ctl(), page_size=4, **kw)
        assert got == ref

    def test_kv_int8_runs_and_shrinks_pool(self):
        """int8 K/V pool: same completion lengths (tokens may differ —
        quantization is not bit-exact) at well under half the f32 pool
        bytes, and the config validations reject unsupported combos."""
        specs = [(5, 4, 0), (6, 3, 2)]
        kw = dict(num_slots=2, max_len=12, page_size=4)
        q = ServeEngine("yi-6b", seed=0, kv_int8=True, **kw)
        comps = q.run(_mk(q.cfg.vocab_size, specs))
        q.close()
        assert sorted(len(c.tokens) for c in comps) == [3, 4]
        f = ServeEngine("yi-6b", seed=0, **kw)
        assert q.kv_cache_bytes() < f.kv_cache_bytes() / 2
        f.close()
        with pytest.raises(ValueError, match="kv_int8"):
            ServeEngine("yi-6b", num_slots=2, max_len=12, kv_int8=True)
        fused = ControlConfig(fused_attention=True)
        with pytest.raises(ValueError, match="fused"):
            ServeEngine("yi-6b", num_slots=2, max_len=12, page_size=8,
                        kv_int8=True, control=fused)
        with pytest.raises(ValueError, match="multiple of 8"):
            ServeEngine("yi-6b", num_slots=2, max_len=12, page_size=4,
                        control=fused)
