"""Fused decode-attention kernel family vs the layer oracles (ISSUE 7).

The fused GQA/MLA kernels must match ``decode_attention`` /
``mla_decode_attention`` exactly where the oracle is exact (f32,
interpret mode) and within bf16 tolerance otherwise, across GQA group
sizes (including groups the kernel must pad to the sublane multiple),
ragged ``cur_pos`` with empty slots, and sliding windows. The unfused
three-kernel bench baseline must match the SAME contract. The kernels
are inference-only: differentiating through them must raise.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.layers import attention as att


def _mk(shape, seed, dtype=jnp.float32):
    x = np.random.default_rng(seed).standard_normal(shape)
    return jnp.asarray(x, dtype)


def _gqa_case(B, Hq, Hkv, D, S, seed=0, dtype=jnp.float32):
    q = _mk((B, Hq, 1, D), seed, dtype)
    k = _mk((B, Hkv, S, D), seed + 1, dtype)
    v = _mk((B, Hkv, S, D), seed + 2, dtype)
    return q, k, v


RAGGED = lambda B, S: np.linspace(0, S - 1, B).astype(np.int32)  # noqa: E731


class TestFusedGQA:
    @pytest.mark.parametrize("Hq,Hkv", [(8, 2), (4, 4), (4, 1), (6, 2)])
    def test_matches_oracle_across_group_sizes(self, Hq, Hkv):
        # G = 4, 1 (MHA), 4 (MQA), 3 (pads to the sublane multiple of 8)
        B, D, S = 4, 64, 96
        q, k, v = _gqa_case(B, Hq, Hkv, D, S, seed=Hq * 10 + Hkv)
        cur = jnp.asarray(RAGGED(B, S))
        ref = att.decode_attention(q, k, v, cur_pos=cur)
        got = ops.fused_decode_attention(q, k, v, cur_pos=cur)
        assert got.shape == ref.shape and got.dtype == ref.dtype
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_ragged_cur_pos_with_empty_slots(self):
        # cur_pos = 0 is a slot with ONE occupied row (position 0);
        # the tile skip must not drop it, nor corrupt fuller slots
        B, S = 5, 160
        q, k, v = _gqa_case(B, 8, 2, 64, S, seed=3)
        cur = jnp.asarray([0, 0, 7, 100, S - 1], jnp.int32)
        ref = att.decode_attention(q, k, v, cur_pos=cur)
        got = ops.fused_decode_attention(q, k, v, cur_pos=cur)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    @pytest.mark.parametrize("window", [8, 130])
    def test_sliding_window(self, window):
        # window smaller than a tile AND window spanning tiles: the
        # tile-skip predicate must stay exact on both sides
        B, S = 4, 256
        q, k, v = _gqa_case(B, 8, 2, 64, S, seed=7)
        cur = jnp.asarray([0, 40, 140, S - 1], jnp.int32)
        ref = att.decode_attention(q, k, v, cur_pos=cur, window=window)
        got = ops.fused_decode_attention(q, k, v, cur_pos=cur,
                                         window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_bf16_within_tolerance(self):
        B, S = 4, 96
        q, k, v = _gqa_case(B, 8, 2, 64, S, seed=11, dtype=jnp.bfloat16)
        cur = jnp.asarray(RAGGED(B, S))
        ref = att.decode_attention(q, k, v, cur_pos=cur)
        got = ops.fused_decode_attention(q, k, v, cur_pos=cur)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(ref, np.float32),
            atol=3e-2)

    def test_odd_head_dim_and_cache_len_are_padded(self):
        # D=40 and S=50 hit every padding branch in the ops wrapper;
        # the scale must still use the ORIGINAL head dim
        B, S = 3, 50
        q, k, v = _gqa_case(B, 4, 2, 40, S, seed=13)
        cur = jnp.asarray([0, 20, S - 1], jnp.int32)
        ref = att.decode_attention(q, k, v, cur_pos=cur)
        got = ops.fused_decode_attention(q, k, v, cur_pos=cur)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_unfused_baseline_matches_same_contract(self):
        B, S = 4, 160
        q, k, v = _gqa_case(B, 8, 2, 64, S, seed=17)
        cur = jnp.asarray([0, 10, 100, S - 1], jnp.int32)
        ref = att.decode_attention(q, k, v, cur_pos=cur)
        got = ops.unfused_decode_attention(q, k, v, cur_pos=cur)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_inference_only_grad_raises(self):
        q, k, v = _gqa_case(2, 4, 2, 64, 96, seed=19)
        cur = jnp.asarray([5, 90], jnp.int32)

        def loss(q_):
            return jnp.sum(
                ops.fused_decode_attention(q_, k, v, cur_pos=cur) ** 2)

        with pytest.raises(Exception):
            jax.grad(loss)(q)


class TestFusedMLA:
    def test_matches_oracle(self):
        B, H, R, Dr, S = 3, 8, 64, 32, 96
        qa = _mk((B, H, R), 23)
        qr = _mk((B, H, Dr), 29)
        lat = _mk((B, S, R), 31)
        rope = _mk((B, S, Dr), 37)
        cur = jnp.asarray([0, 50, S - 1], jnp.int32)
        ref = att.mla_decode_attention(qa, qr, lat, rope, cur_pos=cur,
                                       head_dim_for_scale=R + Dr)
        got = ops.fused_mla_decode_attention(qa, qr, lat, rope,
                                             cur_pos=cur,
                                             head_dim_for_scale=R + Dr)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_odd_heads_and_ranks_are_padded(self):
        B, H, R, Dr, S = 2, 5, 48, 20, 40
        qa = _mk((B, H, R), 41)
        qr = _mk((B, H, Dr), 43)
        lat = _mk((B, S, R), 47)
        rope = _mk((B, S, Dr), 53)
        cur = jnp.asarray([3, S - 1], jnp.int32)
        ref = att.mla_decode_attention(qa, qr, lat, rope, cur_pos=cur,
                                       head_dim_for_scale=R + Dr)
        got = ops.fused_mla_decode_attention(qa, qr, lat, rope,
                                             cur_pos=cur,
                                             head_dim_for_scale=R + Dr)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-6)

    def test_inference_only_grad_raises(self):
        B, H, R, Dr, S = 2, 8, 64, 32, 64
        qa = _mk((B, H, R), 59)
        qr = _mk((B, H, Dr), 61)
        lat = _mk((B, S, R), 67)
        rope = _mk((B, S, Dr), 71)
        cur = jnp.asarray([5, 60], jnp.int32)

        def loss(qa_):
            return jnp.sum(ops.fused_mla_decode_attention(
                qa_, qr, lat, rope, cur_pos=cur,
                head_dim_for_scale=R + Dr) ** 2)

        with pytest.raises(Exception):
            jax.grad(loss)(qa)


class TestEngineComposition:
    def test_fused_serve_engine_token_exact_with_zero_resizing(self):
        """The tentpole composition: fused attention selected through the
        shared ControlConfig, running UNDER the ZERO-resized control
        plane, must generate the same tokens as the oracle path."""
        from repro.control import ControlConfig
        from repro.launch.serve import Request, ServeEngine

        def run(fused):
            control = ControlConfig(
                mode="zero", hetero_kind="contention", chi=4.0,
                contention_p=0.15, sim_ranks=8, fused_attention=fused,
                psum_chunks=2 if fused else 1, seed=0)
            eng = ServeEngine("yi-6b", num_slots=2, max_len=16, seed=0,
                              control=control)
            rng = np.random.default_rng(0)
            reqs = [Request(uid=i,
                            prompt=rng.integers(
                                0, eng.cfg.vocab_size, (4,)).astype(np.int32),
                            max_new_tokens=4, arrival_step=2 * i)
                    for i in range(3)]
            comps = eng.run(reqs)
            eng.close()
            return {c.uid: c.tokens for c in comps}

        ref, got = run(False), run(True)
        assert set(ref) == set(got)
        for uid in ref:
            assert np.array_equal(ref[uid], got[uid]), f"req {uid} diverged"
