"""Substrate tests: data pipeline, optimizer, checkpointing, sharding utils."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.checkpoint import store as ckpt
from repro.config import TrainConfig
from repro.data.pipeline import (PatternImageStream, TokenTaskStream,
                                 eval_accuracy, patchify)
from repro.optim import adamw
from repro.sharding import (DEFAULT_RULES, fit_spec_to_shape,
                            logical_to_spec, make_rules)
from jax.sharding import PartitionSpec as P


class TestData:
    def test_token_stream_deterministic_and_learnable(self):
        s1 = iter(TokenTaskStream(64, 16, 4, seed=3))
        s2 = iter(TokenTaskStream(64, 16, 4, seed=3))
        b1, b2 = next(s1), next(s2)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels are the next-token shift of the same underlying sequence
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
        # periodic-copy structure: token t equals token t-period
        np.testing.assert_array_equal(b1["tokens"][:, 4:], b1["tokens"][:, :-4])

    def test_image_stream_shapes(self):
        b = next(iter(PatternImageStream(batch_size=5, seed=1)))
        assert b["images"].shape == (5, 32, 32, 3)
        assert b["labels"].shape == (5,)
        p = patchify(b["images"], 4)
        assert p.shape == (5, 64, 48)

    def test_patchify_roundtrip_content(self):
        img = np.arange(2 * 8 * 8 * 3, dtype=np.float32).reshape(2, 8, 8, 3)
        p = patchify(img, 4)
        # first patch = top-left 4x4 block
        np.testing.assert_array_equal(
            p[0, 0].reshape(4, 4, 3), img[0, :4, :4, :])


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = TrainConfig(learning_rate=0.1, warmup_steps=1, grad_clip=0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        target = jnp.array([1.0, 2.0])
        for _ in range(300):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, _ = adamw.apply(params, g, state, cfg)
        np.testing.assert_allclose(params["w"], target, atol=1e-2)

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(200.0)
        assert float(jnp.linalg.norm(clipped["w"])) == pytest.approx(1.0, rel=1e-3)

    def test_moments_match_param_tree(self):
        params = {"a": jnp.zeros((3, 4)), "b": {"c": jnp.ones((2,))}}
        st_ = adamw.init(params)
        assert jax.tree.structure(st_.mu) == jax.tree.structure(params)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
                "nested": {"b": np.array([1, 2], np.int32)}}
        ckpt.save(str(tmp_path), 7, tree)
        assert ckpt.latest_step(str(tmp_path)) == 7
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            tree)
        out = ckpt.restore(str(tmp_path), 7, like)
        np.testing.assert_array_equal(out["w"], tree["w"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])

    def test_shape_mismatch_raises(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"w": np.zeros((2, 2))})
        bad = {"w": jax.ShapeDtypeStruct((3, 3), np.float32)}
        with pytest.raises(ValueError):
            ckpt.restore(str(tmp_path), 1, bad)


class TestShardingRules:
    def test_logical_to_spec(self):
        spec = logical_to_spec(("batch", None, "mlp"))
        assert spec == P(("pod", "data"), None, "model")

    def test_rule_override(self):
        rules = make_rules(batch=None)
        assert logical_to_spec(("batch", "vocab"), rules) == P(None, "model")

    @given(dim=st.integers(1, 4096))
    @settings(max_examples=50, deadline=None)
    def test_fit_spec_never_violates_divisibility(self, dim):
        import jax as _jax
        from jax.sharding import Mesh
        devs = np.array(_jax.devices()[:1])
        # synthesize a mesh-shape check without real devices: use shape math
        class FakeMesh:
            shape = {"data": 16, "model": 16}
        spec = fit_spec_to_shape(P(("data", "model")), (dim,), FakeMesh)
        entry = spec[0]
        n = 1
        if entry is not None:
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                n *= FakeMesh.shape[a]
        assert dim % n == 0
