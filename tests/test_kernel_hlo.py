"""ISSUE 2 acceptance check, verified at the HLO level: the kernel-path
backward materializes NO full-size zero-scattered dx/dw and NO gathered
wk/xk temporaries — the compiled gradient module is entirely free of
gather/scatter ops (the pruning rides the Pallas BlockSpec index maps).

The XLA zero-imputation path is compiled alongside as a positive control:
it MUST show gathers, proving the detector sees them when present.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import resizing
from repro.kernels import ops
from repro.launch.hlo_inspect import op_histogram

BLOCK = 32
BANNED = ("scatter", "select-and-scatter", "gather", "all-gather")


def _grad_hlo(loss, *args):
    return jax.jit(jax.grad(loss, tuple(range(len(args))))) \
        .lower(*args).compile().as_text()


def _mk(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32)


def test_pruned_matmul_bwd_hlo_has_no_gather_scatter():
    x, w = _mk((16, 128), 0), _mk((128, 64), 1)
    keep = jnp.asarray([0, 2], jnp.int32)

    def loss_k(x_, w_):
        return jnp.sum(ops.block_pruned_matmul(x_, w_, keep, BLOCK, 16, 32) ** 2)

    hist = op_histogram(_grad_hlo(loss_k, x, w))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"kernel backward leaked gather/scatter temporaries: {offending}")

    # positive control: the XLA zero-imputation lineage gathers wk/xk
    def loss_x(x_, w_):
        return jnp.sum(resizing.resized_matmul(x_, w_, keep, block=BLOCK) ** 2)

    hist_xla = op_histogram(_grad_hlo(loss_x, x, w))
    assert hist_xla.get("gather", 0) > 0, (
        "detector sanity check failed: XLA path shows no gathers")


def test_fused_ffn_bwd_hlo_has_no_gather_scatter():
    x = _mk((8, 32), 2)
    wu, wg = _mk((32, 64), 3) * 0.2, _mk((32, 64), 4) * 0.2
    wd = _mk((64, 24), 5) * 0.2
    keep = jnp.asarray([1], jnp.int32)

    def loss(x_, wu_, wd_, wg_):
        y = ops.fused_pruned_ffn(x_, wu_, wd_, keep, wg_, jax.nn.silu,
                                 BLOCK, 16)
        return jnp.sum(y ** 2)

    hist = op_histogram(_grad_hlo(loss, x, wu, wd, wg))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"fused-FFN backward leaked gather/scatter temporaries: {offending}")


def test_fused_ffn_forward_is_one_fusion_no_hidden_roundtrip():
    """Forward: the resized hidden activation must not be written out as a
    separate [M, kb*block] HBM tensor — with the fused kernel the only
    custom-call/fusion outputs are the final [M, d_out] result."""
    x = _mk((8, 32), 6)
    wu, wd = _mk((32, 64), 7) * 0.2, _mk((64, 24), 8) * 0.2
    keep = jnp.asarray([0, 1], jnp.int32)

    def fwd(x_):
        return ops.fused_pruned_ffn(x_, wu, wd, keep, None, jax.nn.silu,
                                    BLOCK, 16)

    hist = op_histogram(jax.jit(fwd).lower(x).compile().as_text())
    assert not any(k in BANNED for k in hist), hist
