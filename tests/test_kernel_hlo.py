"""ISSUE 2 acceptance check, verified at the HLO level: the kernel-path
backward materializes NO full-size zero-scattered dx/dw and NO gathered
wk/xk temporaries — the compiled gradient module is entirely free of
gather/scatter ops (the pruning rides the Pallas BlockSpec index maps).

The XLA zero-imputation path is compiled alongside as a positive control:
it MUST show gathers, proving the detector sees them when present.

ISSUE 7 adds the chunked-epilogue check; since ISSUE 10 both it and the
op histograms run through the shared static-analysis engine
(repro.analysis): the chunked invariant is the R3 rule's own
``audit_chunked_all_reduce`` over the analyzer's ``micro_collective``
cases — one source of truth with ``python -m repro.analysis --check``.
Multi-device HLO is compiled in a subprocess (the main pytest process
keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.hlo import op_histogram
from repro.core import resizing
from repro.kernels import ops

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BLOCK = 32
BANNED = ("scatter", "select-and-scatter", "gather", "all-gather")


def _grad_hlo(loss, *args):
    return jax.jit(jax.grad(loss, tuple(range(len(args))))) \
        .lower(*args).compile().as_text()


def _mk(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32)


def test_pruned_matmul_bwd_hlo_has_no_gather_scatter():
    x, w = _mk((16, 128), 0), _mk((128, 64), 1)
    keep = jnp.asarray([0, 2], jnp.int32)

    def loss_k(x_, w_):
        return jnp.sum(ops.block_pruned_matmul(x_, w_, keep, BLOCK, 16, 32) ** 2)

    hist = op_histogram(_grad_hlo(loss_k, x, w))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"kernel backward leaked gather/scatter temporaries: {offending}")

    # positive control: the XLA zero-imputation lineage gathers wk/xk
    def loss_x(x_, w_):
        return jnp.sum(resizing.resized_matmul(x_, w_, keep, block=BLOCK) ** 2)

    hist_xla = op_histogram(_grad_hlo(loss_x, x, w))
    assert hist_xla.get("gather", 0) > 0, (
        "detector sanity check failed: XLA path shows no gathers")


def test_fused_ffn_bwd_hlo_has_no_gather_scatter():
    x = _mk((8, 32), 2)
    wu, wg = _mk((32, 64), 3) * 0.2, _mk((32, 64), 4) * 0.2
    wd = _mk((64, 24), 5) * 0.2
    keep = jnp.asarray([1], jnp.int32)

    def loss(x_, wu_, wd_, wg_):
        y = ops.fused_pruned_ffn(x_, wu_, wd_, keep, wg_, jax.nn.silu,
                                 BLOCK, 16)
        return jnp.sum(y ** 2)

    hist = op_histogram(_grad_hlo(loss, x, wu, wd, wg))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"fused-FFN backward leaked gather/scatter temporaries: {offending}")


def test_fused_ffn_forward_is_one_fusion_no_hidden_roundtrip():
    """Forward: the resized hidden activation must not be written out as a
    separate [M, kb*block] HBM tensor — with the fused kernel the only
    custom-call/fusion outputs are the final [M, d_out] result."""
    x = _mk((8, 32), 6)
    wu, wd = _mk((32, 64), 7) * 0.2, _mk((64, 24), 8) * 0.2
    keep = jnp.asarray([0, 1], jnp.int32)

    def fwd(x_):
        return ops.fused_pruned_ffn(x_, wu, wd, keep, None, jax.nn.silu,
                                    BLOCK, 16)

    hist = op_histogram(jax.jit(fwd).lower(x).compile().as_text())
    assert not any(k in BANNED for k in hist), hist


def test_chunked_psum_hlo_splits_the_epilogue_all_reduce():
    """ISSUE 7 via the ISSUE 10 engine: the analyzer's micro_collective
    cases ARE the chunked-projection harness — with psum_chunks=4 the
    compiled epilogue holds 4 chunk-width all-reduces and NO full-width
    one; the psum_chunks=1 positive control shows exactly the single fat
    all-reduce. Numerics are checked alongside (y == x @ w)."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
        import json
        import numpy as np, jax, jax.numpy as jnp
        from repro.analysis import engine, micro, rules
        from repro.analysis.registry import CaseEnv

        env = CaseEnv(max_devices=jax.device_count())
        cases = {c.name: c for c in micro._collective_cases(env)}
        rng = np.random.default_rng(0)
        x = jnp.array(rng.standard_normal((2, 8, 128)), jnp.float32)
        w = jnp.array(rng.standard_normal((128, 256)) * .1, jnp.float32)

        res = {}
        for name in ("proj_psum_chunks1", "proj_psum_chunks4"):
            c = cases[name]
            a = engine.trace_artifact(c, env)
            assert not a.error, a.error
            exp = c.expect["chunked_all_reduce"]
            msgs, observed = rules.audit_chunked_all_reduce(
                a.hlo_text, exp["chunks"], exp["full_dims"],
                exp["chunk_dims"])
            y = jax.jit(c.fn)(x, w)
            assert np.allclose(np.asarray(y), np.asarray(x @ w),
                               atol=1e-3)
            res[name] = {"violations": msgs, "observed": observed}
        print(json.dumps(res))
        """)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    import json
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # the rule itself is clean on both cases
    assert res["proj_psum_chunks1"]["violations"] == [], res
    assert res["proj_psum_chunks4"]["violations"] == [], res
    # positive control: one fat full-width [B, S, N] all-reduce
    assert res["proj_psum_chunks1"]["observed"] == ["2,8,256"], res
    # chunked: 4 chunk-width all-reduces, and the fat one is GONE
    assert len(res["proj_psum_chunks4"]["observed"]) == 4, res
    assert all(s == "2,8,64" for s in res["proj_psum_chunks4"]["observed"]), res
