"""ISSUE 2 acceptance check, verified at the HLO level: the kernel-path
backward materializes NO full-size zero-scattered dx/dw and NO gathered
wk/xk temporaries — the compiled gradient module is entirely free of
gather/scatter ops (the pruning rides the Pallas BlockSpec index maps).

The XLA zero-imputation path is compiled alongside as a positive control:
it MUST show gathers, proving the detector sees them when present.

ISSUE 7 adds the chunked-epilogue check: with ``psum_chunks=k`` the
controlled projection must compile to k independent chunk-width
all-reduces — async-overlappable by the latency-hiding scheduler —
and NO single fat full-width all-reduce (the positive control with
``psum_chunks=1`` shows exactly that fat one).  Multi-device HLO is
compiled in a subprocess (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import resizing
from repro.kernels import ops
from repro.launch.hlo_inspect import op_histogram

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

BLOCK = 32
BANNED = ("scatter", "select-and-scatter", "gather", "all-gather")


def _grad_hlo(loss, *args):
    return jax.jit(jax.grad(loss, tuple(range(len(args))))) \
        .lower(*args).compile().as_text()


def _mk(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal(shape), jnp.float32)


def test_pruned_matmul_bwd_hlo_has_no_gather_scatter():
    x, w = _mk((16, 128), 0), _mk((128, 64), 1)
    keep = jnp.asarray([0, 2], jnp.int32)

    def loss_k(x_, w_):
        return jnp.sum(ops.block_pruned_matmul(x_, w_, keep, BLOCK, 16, 32) ** 2)

    hist = op_histogram(_grad_hlo(loss_k, x, w))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"kernel backward leaked gather/scatter temporaries: {offending}")

    # positive control: the XLA zero-imputation lineage gathers wk/xk
    def loss_x(x_, w_):
        return jnp.sum(resizing.resized_matmul(x_, w_, keep, block=BLOCK) ** 2)

    hist_xla = op_histogram(_grad_hlo(loss_x, x, w))
    assert hist_xla.get("gather", 0) > 0, (
        "detector sanity check failed: XLA path shows no gathers")


def test_fused_ffn_bwd_hlo_has_no_gather_scatter():
    x = _mk((8, 32), 2)
    wu, wg = _mk((32, 64), 3) * 0.2, _mk((32, 64), 4) * 0.2
    wd = _mk((64, 24), 5) * 0.2
    keep = jnp.asarray([1], jnp.int32)

    def loss(x_, wu_, wd_, wg_):
        y = ops.fused_pruned_ffn(x_, wu_, wd_, keep, wg_, jax.nn.silu,
                                 BLOCK, 16)
        return jnp.sum(y ** 2)

    hist = op_histogram(_grad_hlo(loss, x, wu, wd, wg))
    offending = {k: v for k, v in hist.items() if k in BANNED}
    assert not offending, (
        f"fused-FFN backward leaked gather/scatter temporaries: {offending}")


def test_fused_ffn_forward_is_one_fusion_no_hidden_roundtrip():
    """Forward: the resized hidden activation must not be written out as a
    separate [M, kb*block] HBM tensor — with the fused kernel the only
    custom-call/fusion outputs are the final [M, d_out] result."""
    x = _mk((8, 32), 6)
    wu, wd = _mk((32, 64), 7) * 0.2, _mk((64, 24), 8) * 0.2
    keep = jnp.asarray([0, 1], jnp.int32)

    def fwd(x_):
        return ops.fused_pruned_ffn(x_, wu, wd, keep, None, jax.nn.silu,
                                    BLOCK, 16)

    hist = op_histogram(jax.jit(fwd).lower(x).compile().as_text())
    assert not any(k in BANNED for k in hist), hist


def test_chunked_psum_hlo_splits_the_epilogue_all_reduce():
    """ISSUE 7: with psum_chunks=4 the controlled row-projection epilogue
    compiles to 4 independent chunk-width all-reduces and NO full-width
    one; the psum_chunks=1 positive control shows exactly the single fat
    all-reduce the chunking is meant to break up."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
        import json, re
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro.core.workload import PlanStatic
        from repro.layers.tp_linear import ControlContext, controlled_proj

        e, B, S, d, N, block = 8, 2, 8, 128, 256, 8
        nb_loc = (d // e) // block
        mesh = Mesh(np.array(jax.devices()).reshape(1, e), ("data", "model"))
        rng = np.random.default_rng(0)
        x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
        w = jnp.array(rng.standard_normal((d, N)) * .1, jnp.float32)
        st = PlanStatic(buckets=(0.0, 0.25, 0.5), block_size=block,
                        mig_blocks=0, tp_size=e)
        pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))

        def run(k):
            ctx = ControlContext(mesh=mesh, axis="model", static=st,
                                 bucket_by_rank=jnp.zeros((e,), jnp.int32),
                                 mig_src=jnp.array(-1, jnp.int32),
                                 pri={"proj": pri}, psum_chunks=k)
            fn = jax.jit(lambda x_, w_: controlled_proj(
                x_, w_, ctx, "proj", split="row"))
            y = fn(x, w)
            assert np.allclose(np.asarray(y), np.asarray(x @ w), atol=1e-3)
            hlo = fn.lower(x, w).compile().as_text()
            # shapes of every all-reduce / all-reduce-start (NOT -done)
            return [m.group(1) for line in hlo.splitlines()
                    for m in [re.search(r"f32\\[([0-9,]*)\\]", line)]
                    if m and re.search(r"all-reduce(?:-start)?\\(", line)]

        print(json.dumps({"k1": run(1), "k4": run(4)}))
        """)],
        capture_output=True, text=True, timeout=420,
        env={**os.environ,
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    import json
    shapes = json.loads(out.stdout.strip().splitlines()[-1])
    # positive control: one fat full-width [B, S, N] all-reduce
    assert shapes["k1"] == ["2,8,256"], shapes
    # chunked: 4 chunk-width all-reduces, and the fat one is GONE
    assert len(shapes["k4"]) == 4, shapes
    assert all(s == "2,8,64" for s in shapes["k4"]), shapes
