"""Edge cases of the migration helper-partition (single and multi source).

Host-only: migration_assignment / multi_migration_assignment are pure
index arithmetic (jnp on scalars), no mesh needed.
"""
import numpy as np
import pytest

from repro.core.migration import (migration_assignment,
                                  multi_migration_assignment)


def _cover_single(e, src, m_pad):
    """Lanes of [0, m_pad) computed per rank under the single-source rule."""
    cover = np.zeros(m_pad, np.int32)
    for r in range(e):
        lo, m_per, is_h = migration_assignment(r, src, e, m_pad)
        if bool(is_h):
            for b in range(int(lo), int(lo) + int(m_per)):
                if b < m_pad:
                    cover[b] += 1
    return cover


class TestSingleSource:
    def test_e2_single_helper_takes_everything(self):
        """e=2: the one helper owns the full padded export."""
        for src in (0, 1):
            helper = 1 - src
            lo, m_per, is_h = migration_assignment(helper, src, 2, 4)
            assert bool(is_h) and int(lo) == 0 and int(m_per) == 4
            _, _, src_is_h = migration_assignment(src, src, 2, 4)
            assert not bool(src_is_h)

    def test_m_pad_not_divisible_by_helper_count(self):
        """m_pad % (e-1) != 0: ceil partition still covers every block
        exactly once (the surplus lanes fall off the padded end)."""
        e, m_pad = 4, 5                      # 3 helpers, ceil -> 2 each
        lo0, m_per, _ = migration_assignment((0 + 1) % e, 0, e, m_pad)
        assert int(m_per) == 2
        assert (_cover_single(e, 0, m_pad) == 1).all()

    def test_straggler_is_rank0_renumbering(self):
        """src=0: r' = r, helpers 1..e-1 take consecutive slices."""
        e, m_pad = 4, 6
        los = []
        for r in range(e):
            lo, m_per, is_h = migration_assignment(r, 0, e, m_pad)
            if r == 0:
                assert not bool(is_h)
            else:
                assert bool(is_h)
                los.append(int(lo))
        assert los == [0, 2, 4]
        assert (_cover_single(e, 0, m_pad) == 1).all()

    @pytest.mark.parametrize("e,src,m_pad", [
        (2, 0, 3), (4, 3, 8), (8, 0, 7), (8, 5, 12), (8, 7, 1)])
    def test_exact_cover_property(self, e, src, m_pad):
        assert (_cover_single(e, src, m_pad) == 1).all()


class TestMultiSource:
    def test_single_slot_reduces_to_paper_renumbering(self):
        """S=1 multi-source partition == the paper's r' rule, every rank."""
        for e in (2, 4, 8):
            for src in range(e):
                for m in (1, 3, 2 * e):
                    H = e - 1
                    m_per_ref = -(-m // H) if H else m
                    m_pad = m_per_ref * max(H, 1)
                    for r in range(e):
                        lo1, mp1, h1 = migration_assignment(r, src, e, m_pad)
                        los, mps, helps = multi_migration_assignment(
                            r, np.array([src]), e, [m])
                        assert int(mps[0]) == int(mp1)
                        assert bool(helps[0]) == bool(h1)
                        if bool(h1):
                            assert int(los[0]) == int(lo1)

    def test_concurrent_sources_disjoint_exact_cover(self):
        """3 simultaneous stragglers: each slot's shed blocks are computed
        exactly once, never by a source rank."""
        e, srcs, sheds = 8, np.array([1, 4, 6]), (5, 3, 1)
        H = e - len(sheds)
        for s, m_s in enumerate(sheds):
            m_per = -(-m_s // H)
            cover = np.zeros(m_s, np.int32)
            for r in range(e):
                los, mps, helps = multi_migration_assignment(r, srcs, e, sheds)
                if bool(helps[s]):
                    assert r not in set(srcs.tolist())
                    for b in range(int(los[s]), int(los[s]) + int(mps[s])):
                        if b < m_s:
                            cover[b] += 1
            assert (cover == 1).all(), (s, cover)

    def test_idle_slots_free_surplus_helpers(self):
        """Slots padded with -1: nobody helps them; real slots still get
        full coverage from the first H helpers only."""
        e, sheds = 8, (4, 2, 2)
        srcs = np.array([2, -1, -1])
        H = e - len(sheds)
        helping = [r for r in range(e)
                   if bool(multi_migration_assignment(r, srcs, e, sheds)[2][0])]
        assert len(helping) == H and 2 not in helping
        for s in (1, 2):                      # idle slots
            for r in range(e):
                assert not bool(
                    multi_migration_assignment(r, srcs, e, sheds)[2][s])
        cover = np.zeros(sheds[0], np.int32)
        for r in helping:
            los, mps, _ = multi_migration_assignment(r, srcs, e, sheds)
            for b in range(int(los[0]), int(los[0]) + int(mps[0])):
                if b < sheds[0]:
                    cover[b] += 1
        assert (cover == 1).all()

    def test_e_minus_s_equals_one_single_helper(self):
        """e=4 with 3 sources: the lone helper absorbs every slot."""
        e, srcs, sheds = 4, np.array([0, 1, 3]), (2, 2, 1)
        helper = 2
        los, mps, helps = multi_migration_assignment(helper, srcs, e, sheds)
        assert all(bool(h) for h in helps)
        assert [int(lo) for lo in los] == [0, 0, 0]
        assert [int(mp) for mp in mps] == list(sheds)
        for r in (0, 1, 3):
            assert not any(
                bool(h) for h in
                multi_migration_assignment(r, srcs, e, sheds)[2])
