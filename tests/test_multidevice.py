"""Multi-device semantics tests, run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main pytest
process keeps 1 device per the brief)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


PREAMBLE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.layers.tp_linear import ControlContext, controlled_ffn, controlled_proj
from repro.core.workload import PlanStatic
e, B, S, d, H, block = 8, 2, 8, 64, 256, 8
nb_loc = (H // e) // block
mesh = Mesh(np.array(jax.devices()).reshape(1, e), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
wg = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wu = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wd = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
act = jax.nn.silu
ref = (act(x @ wg) * (x @ wu)) @ wd
buckets = (0.0, 0.25, 0.5)
def make_ctx(m, bucket_vec, src):
    st = PlanStatic(buckets=buckets, block_size=block, mig_blocks=m, tp_size=e)
    pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))
    return ControlContext(mesh=mesh, axis="model", static=st,
        bucket_by_rank=jnp.array(bucket_vec, jnp.int32),
        mig_src=jnp.array(src, jnp.int32), pri={"ffn": pri})
"""


class TestControlledFFN:
    def test_neutral_equals_dense(self):
        run_py(PREAMBLE + """
ctx = make_ctx(0, [0]*e, -1)
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
assert np.allclose(y, ref, atol=1e-4), np.abs(np.array(y)-ref).max()
print("ok")
""")

    def test_migration_is_lossless_fwd_and_bwd(self):
        """The paper's claim: migration re-balances with NO accuracy loss.
        Forward outputs and all weight gradients must equal the dense run."""
        run_py(PREAMBLE + """
ctx = make_ctx(2, [0]*e, 5)
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
assert np.allclose(y, ref, atol=1e-4)
def loss(wu, wd, wg):
    return jnp.sum(controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)**2)
g = jax.grad(loss, (0, 1, 2))(wu, wd, wg)
gr = jax.grad(lambda wu, wd, wg: jnp.sum((((act(x@wg))*(x@wu))@wd)**2), (0,1,2))(wu, wd, wg)
for a, b in zip(g, gr):
    assert np.allclose(a, b, atol=1e-3), np.abs(np.array(a)-np.array(b)).max()
print("ok")
""")

    def test_resizing_matches_masked_oracle(self):
        run_py(PREAMBLE + """
ctx = make_ctx(0, [0,0,0,2,0,0,0,0], -1)
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
mask = np.ones(H//block, bool); mask[3*nb_loc+2:3*nb_loc+4] = False
ref_p = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y, ref_p, atol=1e-4)
print("ok")
""")

    def test_semi_resize_plus_migrate(self):
        """SEMI on one straggler: migrated blocks stay exact (computed by
        helpers), pruned blocks are dropped — matches the masked oracle."""
        run_py(PREAMBLE + """
ctx = make_ctx(1, [0,0,0,1,0,0,0,0], 3)
y = controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
mask = np.ones(H//block, bool); mask[3*nb_loc+3] = False
ref_sm = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y, ref_sm, atol=1e-4)
print("ok")
""")

    def test_kernel_path_matches_xla_inside_shard_map(self):
        """ISSUE 2: with use_kernel the controlled FFN runs the fused
        pruned-FFN pallas_call (+ kernel-level backward) inside shard_map;
        outputs and gradients must match the XLA gather path and the
        masked oracle — resizing AND migration active together."""
        run_py(PREAMBLE + """
import dataclasses
ctx_x = make_ctx(1, [0,0,0,1,0,0,0,0], 3)
ctx_k = dataclasses.replace(ctx_x, use_kernel=True)
y_x = controlled_ffn(x, wu, wd, ctx_x, "ffn", act, w_gate=wg)
y_k = controlled_ffn(x, wu, wd, ctx_k, "ffn", act, w_gate=wg)
assert np.allclose(y_k, y_x, atol=1e-4), np.abs(np.array(y_k)-np.array(y_x)).max()
mask = np.ones(H//block, bool); mask[3*nb_loc+3] = False
ref_sm = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y_k, ref_sm, atol=1e-4)
def loss(ctx, wu_, wd_, wg_):
    return jnp.sum(controlled_ffn(x, wu_, wd_, ctx, "ffn", act, w_gate=wg_)**2)
gk = jax.grad(lambda *a: loss(ctx_k, *a), (0, 1, 2))(wu, wd, wg)
gx = jax.grad(lambda *a: loss(ctx_x, *a), (0, 1, 2))(wu, wd, wg)
for a, b in zip(gk, gx):
    assert np.allclose(a, b, atol=1e-3), np.abs(np.array(a)-np.array(b)).max()
print("ok")
""")

    def test_runtime_straggler_retarget_no_recompile(self):
        """Changing mig_src / buckets must hit the jit cache (plan arrays
        are runtime inputs — the controller retargets for free)."""
        run_py(PREAMBLE + """
ctx = make_ctx(1, [0]*e, 0)
f = jax.jit(lambda bucket, src: controlled_ffn(
    x, wu, wd, ControlContext(mesh=mesh, axis="model", static=ctx.static,
        bucket_by_rank=bucket, mig_src=src, pri=ctx.pri),
    "ffn", act, w_gate=wg))
b0 = jnp.zeros((e,), jnp.int32)
y1 = f(b0, jnp.array(2, jnp.int32))
y2 = f(b0, jnp.array(6, jnp.int32))
y3 = f(b0.at[1].set(2), jnp.array(-1, jnp.int32))
assert f._cache_size() == 1, f._cache_size()
assert np.allclose(y1, ref, atol=1e-4) and np.allclose(y2, ref, atol=1e-4)
print("ok")
""")


class TestMigrationPrimitives:
    def test_broadcast_reduce_and_scatter_gather_agree(self):
        """Table I setup: both comm policies compute identical results."""
        run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import migration
from repro.sharding import shard_map
e, T, d, H, block = 8, 16, 32, 128, 4
mesh = Mesh(np.array(jax.devices()).reshape(e), ("model",))
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((T, d)), jnp.float32)
w1 = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
w2 = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
act = jax.nn.silu
ids = jnp.array([0, 2, 3], jnp.int32)
kw = dict(axis="model", mig_src=jnp.array(4, jnp.int32),
          mig_block_ids=ids, block=block, act_fn=act)
f1 = shard_map(lambda x,a,b: migration.migrated_pair_matmul(x,a,b,**kw),
    mesh=mesh, in_specs=(P(), P(None,"model"), P("model",None)),
    out_specs=P(), check_vma=False)
f2 = shard_map(lambda x,a,b: migration.scatter_gather_pair_matmul(x,a,b,**kw),
    mesh=mesh, in_specs=(P(), P(None,"model"), P("model",None)),
    out_specs=P(), check_vma=False)
y1, y2 = f1(x, w1, w2), f2(x, w1, w2)
ref = act(x @ w1) @ w2
assert np.allclose(y1, ref, atol=1e-3)
assert np.allclose(y2, ref, atol=1e-3)
print("ok")
""")


class TestShardedModel:
    def test_tp_model_matches_single_device(self):
        """Same params, same batch: the (data=2, model=4) sharded train step
        must produce the same loss as the unsharded model."""
        run_py("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.config import get_config, smoke_variant, ShapeConfig, TrainConfig
from repro.launch import steps
from repro.models import get_api
from repro.sharding import use_mesh
cfg = smoke_variant(get_config("yi-6b"))
api = get_api(cfg)
params, _ = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)), jnp.int32)}
loss_1dev, _ = api.loss_fn(params, cfg, batch)

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
from repro.optim import adamw
with use_mesh(mesh):
    fn, args, in_sh, out_sh = steps.build_train_step(
        cfg, ShapeConfig("s", 32, 8, "train"), mesh, TrainConfig())
    step = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
    p = jax.device_put(params, in_sh[0])
    opt = jax.device_put(adamw.init(params), in_sh[1])
    b = jax.device_put(batch, in_sh[2])
    _, _, metrics = step(p, opt, b)
assert np.allclose(float(metrics["loss"]), float(loss_1dev), atol=1e-3), \
    (float(metrics["loss"]), float(loss_1dev))
print("ok")
""")
