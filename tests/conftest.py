"""Shared pytest config.

If `hypothesis` is missing (bare container, no `[test]` extra installed),
swap in the deterministic fallback from tests/_hypothesis_fallback.py so
the suite still collects and the property tests run seeded random
examples. `pip install -e .[test]` (what CI does) gets the real engine.
"""
import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_fallback
    _hypothesis_fallback.install()
