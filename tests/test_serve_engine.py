"""End-to-end tests for the continuous-batching serve engine.

The load-bearing claims: slot recycling is SEMANTICS-PRESERVING — a
request decoded in a shared, recycled slot produces exactly the tokens it
would produce running alone through the fixed-batch engine — the jitted
decode step never re-traces across arrivals/completions (fixed slot
count ⇒ fixed shapes), and SEMI-mode decode under contention is
LOSSLESS: migration redistributes the straggler's shed blocks without
changing a single output token.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.control import ControlConfig
from repro.launch.serve import (EMPTY_LATENCY_STATS, FixedBatchEngine,
                                Request, ServeEngine, latency_percentiles)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mk_requests(vocab, specs, seed=0):
    """specs: list of (prompt_len, gen_len, arrival_step)."""
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate(specs)]


def _assert_token_exact(arch, engine, completions):
    base = FixedBatchEngine(arch, batch=1, max_len=engine.max_len, seed=0)
    for c in completions:
        seq = base.generate(c.prompt[None], len(c.tokens))
        ref = seq[0, len(c.prompt):]
        np.testing.assert_array_equal(
            c.tokens, ref,
            err_msg=f"request {c.uid} (slot {c.slot}) diverged from the "
                    "single-request fixed-batch baseline")


class TestServeEngine:
    def test_staggered_arrivals_token_exact_and_no_retrace(self):
        """3 requests with staggered arrivals and unequal lengths through
        2 slots (forcing recycling): outputs are token-exact vs running
        each request ALONE through the fixed-batch engine, and the jitted
        step traced exactly once (jit cache size via the compile counter)."""
        eng = ServeEngine("yi-6b", num_slots=2, max_len=16, seed=0)
        reqs = _mk_requests(eng.cfg.vocab_size,
                            [(5, 6, 0), (7, 4, 2), (4, 5, 6)])
        comps = eng.run(reqs)
        assert len(comps) == 3
        assert [len(c.tokens) for c in comps] == [6, 4, 5]
        # slot recycling actually happened (3 requests, 2 slots)
        assert len({c.slot for c in comps}) == 2
        _assert_token_exact("yi-6b", eng, comps)
        tc = eng.trace_counts()
        assert tc["plan_compiles"] == 1          # one executable total
        assert tc["base_step_traces"] in (1, -1)  # -1: no counter API
        # per-token latencies were collected for every emitted token
        stats = latency_percentiles(comps)
        assert stats["tokens"] == 15
        assert stats["p50_ms"] > 0

    def test_queue_admission_control(self):
        """Bounded queue rejects overflow; FIFO order is preserved."""
        eng = ServeEngine("yi-6b", num_slots=1, max_len=8, seed=0,
                          max_queue=2)
        reqs = _mk_requests(eng.cfg.vocab_size,
                            [(3, 2, 0), (3, 2, 0), (3, 2, 0)])
        assert eng.submit(reqs[0])
        assert eng.submit(reqs[1])
        assert not eng.submit(reqs[2])           # queue full -> rejected
        while eng.queue or any(s is not None for s in eng.slots):
            eng.step()
        done = sorted(c.uid for c in eng.completions)
        assert done == [0, 1]
        # FIFO: request 0 finished before request 1 was admitted
        c0, c1 = sorted(eng.completions, key=lambda c: c.uid)
        assert c1.admitted_step >= c0.finished_step


class TestTrySubmit:
    """Non-blocking admission (the cluster router's contract): False
    means NOTHING was enqueued — never an exception, never a request
    parked behind a bound it can never clear."""

    def test_full_queue_rejects_without_enqueueing(self):
        eng = ServeEngine("yi-6b", num_slots=1, max_len=8, seed=0,
                          max_queue=1)
        reqs = _mk_requests(eng.cfg.vocab_size, [(3, 2, 0), (3, 2, 0)])
        assert eng.try_submit(reqs[0])
        assert len(eng.queue) == 1
        assert not eng.try_submit(reqs[1])       # bounded queue at capacity
        assert len(eng.queue) == 1               # nothing was enqueued

    def test_oversize_and_empty_requests_rejected_up_front(self):
        eng = ServeEngine("yi-6b", num_slots=1, max_len=8, seed=0)
        big = _mk_requests(eng.cfg.vocab_size, [(6, 4, 0)])[0]  # 10 > 8
        assert not eng.try_submit(big)
        empty = Request(uid=9, prompt=np.zeros((0,), np.int32),
                        max_new_tokens=2, arrival_step=0)
        assert not eng.try_submit(empty)
        assert len(eng.queue) == 0

    def test_never_fits_paged_request_rejected_not_deadlocked(self):
        """A request whose pages can NEVER be satisfied by the pool (even
        running alone) must be refused at admission — accepted, it would
        deadlock the admit loop at the queue head."""
        eng = ServeEngine("yi-6b", num_slots=2, max_len=16, seed=0,
                          page_size=4, num_pages=2)     # pool: 8 tokens
        never = _mk_requests(eng.cfg.vocab_size, [(8, 4, 0)])[0]  # 12 > 8
        assert not eng.try_submit(never)
        fits = _mk_requests(eng.cfg.vocab_size, [(4, 3, 0)])[0]   # 7 <= 8
        assert eng.try_submit(fits)
        while not eng.idle:                      # drive the admitted one
            eng.tick()
        assert [c.uid for c in eng.completions] == [0]


class TestLatencyStatsContract:
    """latency_percentiles' empty-stats record is API: the cluster
    manager and the benches key on these exact fields."""

    def test_empty_completions_pinned_record(self):
        stats = latency_percentiles([])
        assert stats == {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                         "mean_ms": 0.0, "ttft_mean_ms": 0.0, "tokens": 0,
                         "requests": 0, "tok_per_s": 0.0}
        # a COPY: callers mutate their stats dicts freely
        stats["p50_ms"] = 99.0
        assert EMPTY_LATENCY_STATS["p50_ms"] == 0.0
        assert latency_percentiles([]) == EMPTY_LATENCY_STATS

    def test_nonempty_stats_carry_ttft_and_request_count(self):
        eng = ServeEngine("yi-6b", num_slots=1, max_len=8, seed=0)
        comps = eng.run(_mk_requests(eng.cfg.vocab_size,
                                     [(3, 2, 0), (3, 2, 1)]))
        stats = latency_percentiles(comps)
        assert stats["requests"] == 2 and stats["tokens"] == 4
        # TTFT (queue wait + prefill) dominates the steady-state token
        assert stats["ttft_mean_ms"] >= stats["p50_ms"]
        assert set(stats) == set(EMPTY_LATENCY_STATS)


class TestServeSemiMigration:
    def test_semi_tp1_degrades_to_resize_gracefully(self):
        """On a single-device mesh there are no helpers to migrate to:
        the projection folds the sim-scale migration plan to resize-only
        and the engine still completes every request."""
        ctl = ControlConfig(mode="semi", hetero_kind="contention",
                                 chi=4.0, contention_p=0.15, sim_ranks=8,
                                 seed=3)
        eng = ServeEngine("yi-6b", num_slots=2, max_len=12, seed=0,
                          control=ctl)
        comps = eng.run(_mk_requests(eng.cfg.vocab_size,
                                     [(4, 4, 0), (4, 4, 2)]))
        assert len(comps) == 2
        # the controller PLANNED migration; the real mesh executed NONE
        # (mig_srcs reports post-projection execution ground truth)
        assert any(h.get("planned_mig_srcs") for h in eng.history)
        assert not any(h.get("mig_srcs") for h in eng.history)
        assert eng.trace_counts()["plan_compiles"] == 1

    def test_semi_migrated_decode_token_exact_vs_dense(self):
        """The serve SEMI e2e (real 4-rank mesh, subprocess): under χ=4
        contention the Eq.(3)-selected stragglers MIGRATE their decode
        blocks (lossless β-policy) — outputs are token-exact vs. the
        uncontended dense baseline, modeled latency beats dense under the
        same schedule, and migration genuinely executed."""
        code = """
import numpy as np
from repro.control import ControlConfig
from repro.launch.serve import (FixedBatchEngine, Request,
                                ServeEngine)

def mk(vocab, specs, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                    max_new_tokens=g, arrival_step=a)
            for i, (p, g, a) in enumerate(specs)]

ctl = ControlConfig(mode="semi", hetero_kind="contention", chi=4.0,
                         contention_p=0.2, sim_ranks=4, max_sources=3,
                         seed=3)
eng = ServeEngine("yi-6b", num_slots=2, max_len=16, seed=0, tp=4,
                  control=ctl)
reqs = mk(eng.cfg.vocab_size, [(5, 6, 0), (5, 6, 2), (5, 6, 4)])
comps = eng.run(reqs)
assert len(comps) == 3
mig = sum(1 for h in eng.history if h.get("mig_srcs"))
assert mig > 0, "no step migrated — the scenario lost its point"
resize = sum(1 for h in eng.history if h.get("max_bucket", 0) > 0)
assert resize == 0, f"{resize} steps resized — semi plan was not lossless"
base = FixedBatchEngine("yi-6b", batch=1, max_len=eng.max_len, seed=0)
for c in comps:
    ref = base.generate(c.prompt[None], len(c.tokens))[0, len(c.prompt):]
    assert np.array_equal(c.tokens, ref), f"req {c.uid} diverged"
ctrl = sum(h["latency_s"] for h in eng.history)
dense = sum(h["dense_latency_s"] for h in eng.history)
assert ctrl < dense, (ctrl, dense)
print("semi e2e ok: mig steps", mig, "speedup", dense / ctrl)
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert out.returncode == 0, \
            f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
        assert "semi e2e ok" in out.stdout


@pytest.mark.slow
class TestServeEngineSlow:
    @pytest.mark.parametrize("arch", ["falcon-mamba-7b", "mixtral-8x7b"])
    def test_recycling_exact_recurrent_and_moe(self, arch):
        """Slot recycling must also reset RECURRENT state (SSM h/conv) —
        zeroed inside the step — and hold for MoE routing."""
        eng = ServeEngine(arch, num_slots=2, max_len=12, seed=0)
        reqs = _mk_requests(eng.cfg.vocab_size,
                            [(4, 5, 0), (6, 3, 1), (3, 4, 5)], seed=1)
        comps = eng.run(reqs)
        assert len({c.slot for c in comps}) == 2
        _assert_token_exact(arch, eng, comps)
        assert eng.trace_counts()["base_step_traces"] in (1, -1)

    def test_straggler_aware_decode_resizes_and_caches(self):
        """Contended ranks (χ=4 contention schedule) trigger ZERO-resizing
        of the decode matmuls: the controlled engine's modeled step times
        beat dense under the SAME schedule, the plan compile cache builds
        each signature once, and the controlled step still completes every
        request."""
        ctl = ControlConfig(mode="zero", hetero_kind="contention",
                                 chi=4.0, contention_p=0.15, sim_ranks=8,
                                 seed=3)
        eng = ServeEngine("yi-6b", num_slots=2, max_len=16, seed=0,
                          control=ctl)
        reqs = _mk_requests(eng.cfg.vocab_size,
                            [(5, 6, 0), (5, 6, 1), (5, 6, 4)], seed=2)
        comps = eng.run(reqs)
        assert len(comps) == 3
        ctrl = sum(h["latency_s"] for h in eng.history)
        dense = sum(h["dense_latency_s"] for h in eng.history)
        assert ctrl < dense                      # resizing absorbed stragglers
        assert any(h.get("max_bucket", 0) > 0 for h in eng.history)
        tc = eng.trace_counts()
        assert tc["plan_compiles"] <= 2          # zero mode: one signature
        assert tc["plan_cache_hits"] >= len(eng.history) - tc["plan_compiles"]

    def test_neutral_control_is_token_exact(self):
        """With control enabled but NO straggler, every rank keeps its
        full workload (bucket 0 dense branch) and the controlled step's
        tokens match the uncontrolled baseline exactly."""
        ctl = ControlConfig(mode="zero", hetero_kind="none")
        eng = ServeEngine("yi-6b", num_slots=2, max_len=12, seed=0,
                          control=ctl)
        reqs = _mk_requests(eng.cfg.vocab_size, [(4, 4, 0), (5, 3, 2)])
        comps = eng.run(reqs)
        _assert_token_exact("yi-6b", eng, comps)
