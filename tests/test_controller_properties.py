"""Property-based tests for the controller's closed-form math (Eq. 2/3)
and the pinned Φ1 cost-function behavior.

Runs under real `hypothesis` when installed (CI) and under the seeded
deterministic fallback otherwise (tests/_hypothesis_fallback.py).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.controller import (CostFunctions, eq2_beta,
                                   eq3_migration_prefix)


def _costs(omega1, omega2, phi1b, phi1s, phi2s):
    return CostFunctions(omega1=omega1, omega2_slope=omega2,
                         phi1_base=phi1b, phi1_slope=phi1s, phi2_slope=phi2s)


# ---------------------------------------------------------------------------
# Φ1: the intended discontinuity at n = 0 (satellite fix, pinned)
# ---------------------------------------------------------------------------


class TestPhi1:
    C = _costs(1e-3, 1e-5, 5e-5, 2e-5, 1e-4)

    def test_zero_columns_cost_nothing(self):
        """Migrating nothing launches no collective: Φ1(0) = 0 exactly."""
        assert self.C.phi1(0.0) == 0.0

    def test_negative_clamped_to_zero(self):
        assert self.C.phi1(-3.0) == 0.0

    def test_first_column_pays_full_launch_latency(self):
        """The jump at 0+ IS the collective launch cost — intended and
        documented; Eq.(3) prices the first migrated column with it."""
        eps = 1e-9
        assert self.C.phi1(eps) == pytest.approx(self.C.phi1_base, rel=1e-6)
        # the discontinuity equals phi1_base
        assert self.C.phi1(eps) - self.C.phi1(0.0) \
            == pytest.approx(self.C.phi1_base, rel=1e-6)

    @given(n=st.floats(0.0, 1e4), m=st.floats(0.0, 1e4))
    @settings(max_examples=50, deadline=None)
    def test_monotone_nondecreasing(self, n, m):
        lo, hi = sorted((n, m))
        assert self.C.phi1(lo) <= self.C.phi1(hi) + 1e-12


# ---------------------------------------------------------------------------
# Eq.(2): β ∈ [0, 1], monotone in the straggler's γ
# ---------------------------------------------------------------------------


class TestEq2Properties:
    @given(lg=st.floats(1e-3, 1e5), e=st.integers(2, 64),
           omega1=st.floats(0, 1e-2), omega2=st.floats(1e-9, 1e-3),
           phi1b=st.floats(0, 1e-2), phi1s=st.floats(1e-9, 1e-3),
           phi2s=st.floats(1e-9, 1e-3))
    @settings(max_examples=100, deadline=None)
    def test_beta_in_unit_interval(self, lg, e, omega1, omega2, phi1b,
                                   phi1s, phi2s):
        b = eq2_beta(lg, _costs(omega1, omega2, phi1b, phi1s, phi2s), e)
        assert 0.0 <= b <= 1.0

    @given(L=st.floats(8, 512), e=st.integers(2, 32),
           omega1=st.floats(0, 1e-2), omega2=st.floats(1e-9, 1e-3),
           phi1b=st.floats(0, 1e-2), phi1s=st.floats(1e-9, 1e-3),
           phi2s=st.floats(1e-9, 1e-3))
    @settings(max_examples=100, deadline=None)
    def test_beta_monotone_in_gamma(self, L, e, omega1, omega2, phi1b,
                                    phi1s, phi2s):
        """β(γ) is monotone, direction fixed by the cost balance:
        dβ/dγ ∝ (Φ1_base − Ω1) before clipping — a larger straggler
        workload tilts toward migration iff the collective launch cost
        dominates the static realloc cost (and clipping to [0,1]
        preserves monotonicity)."""
        costs = _costs(omega1, omega2, phi1b, phi1s, phi2s)
        gammas = np.linspace(0.01, 0.875, 32)
        betas = np.array([eq2_beta(g * L, costs, e) for g in gammas])
        d = np.diff(betas)
        sign = 1.0 if phi1b >= omega1 else -1.0
        assert np.all(sign * d >= -1e-9)


# ---------------------------------------------------------------------------
# Eq.(3): the selected prefix is genuinely cost-effective, and the choice
# depends only on the multiset of rank times
# ---------------------------------------------------------------------------


def _f_of(x, times_desc, workloads, costs, e):
    """Independent recomputation of f(x) from the paper's definition."""
    t_min = float(times_desc.min())
    gamma_x = sum(workloads[k] * (times_desc[k] - t_min) / times_desc[k]
                  for k in range(x) if times_desc[k] > 0)
    recv = max((gamma_x / max(e - x, 1))
               * (times_desc[y] / max(workloads[y], 1e-12))
               for y in range(x, len(times_desc)))
    return (times_desc[x - 1] - t_min) - costs.phi1(gamma_x) - recv


class TestEq3Properties:
    @given(e=st.integers(2, 16), w=st.integers(8, 128),
           phi1b=st.floats(0, 0.5), phi1s=st.floats(0, 0.05),
           seed=st.integers(0, 10_000))
    @settings(max_examples=100, deadline=None)
    def test_prefix_members_have_positive_f(self, e, w, phi1b, phi1s, seed):
        """Every rank inside the returned migration prefix satisfies
        f(k) > 0 (recomputed independently): migration is never selected
        for a rank where it is not cost-effective."""
        rng = np.random.default_rng(seed)
        chis = rng.choice([1.0, 1.0, 2.0, 4.0, 8.0], size=e)
        times = np.sort(chis * rng.uniform(0.9, 1.1, e))[::-1]
        workloads = np.full(e, float(w))
        costs = _costs(0.0, 0.0, phi1b, phi1s, 0.0)
        x = eq3_migration_prefix(times, workloads, costs, e)
        assert 0 <= x < e
        for k in range(1, x + 1):
            assert _f_of(k, times, workloads, costs, e) > 0

    @given(e=st.integers(3, 12), seed=st.integers(0, 10_000),
           phi1b=st.floats(0, 0.3), phi1s=st.floats(0, 0.05))
    @settings(max_examples=100, deadline=None)
    def test_invariant_to_permutation_of_equal_time_ranks(self, e, seed,
                                                          phi1b, phi1s):
        """With equal per-rank workloads the prefix choice depends only on
        the MULTISET of times: permuting ranks (including within tie
        groups — the draw set forces ties) never changes x."""
        rng = np.random.default_rng(seed)
        times = rng.choice([1.0, 1.0, 2.0, 4.0], size=e)  # ties guaranteed
        workloads = np.full(e, 64.0)
        costs = _costs(0.0, 0.0, phi1b, phi1s, 0.0)
        ref = eq3_migration_prefix(np.sort(times)[::-1], workloads, costs, e)
        for _ in range(4):
            perm = rng.permutation(e)
            x = eq3_migration_prefix(np.sort(times[perm])[::-1],
                                     workloads, costs, e)
            assert x == ref
