"""Serve-engine accounting bugfix pins (ISSUE 8).

Three regressions, each with the failure mode it pins:

* occupancy / ``attn_bound_s`` billed empty slots (pos=0 read as a
  resident length-1 sequence) — now masked by the active set;
* the TTFT eligibility clock was keyed by ``id(req)``, so CPython
  address reuse could hand a new request a stale (earlier) clock — now
  keyed by ``req.uid`` and dropped on completion;
* ``DecodeOverheadModel.overhead_s`` subtracted the full psum-chunking
  credit unconditionally, going NEGATIVE at tiny occupancy — now clamped
  so modeled latency never drops below the IterationModel floor.
"""
import numpy as np
import pytest

from repro.control import ControlConfig
from repro.core.hetero import DecodeOverheadModel
from repro.launch.serve import Request, ServeEngine


def _req(vocab, uid, p, g, arrival=0, seed=0):
    rng = np.random.default_rng(seed + uid)
    return Request(uid=uid,
                   prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
                   max_new_tokens=g, arrival_step=arrival)


class TestOccupancyMasking:
    def test_attn_s_masks_empty_slots(self):
        m = DecodeOverheadModel(kv_bytes_per_pos=1.0, score_bytes_per_pos=0.0,
                                num_slots=4, max_len=64, tile=16,
                                hbm_bw=1.0, comm_time=0.0)
        pos = np.zeros(4, np.int32)              # engine vector: empty = 0
        active = np.array([1.0, 0.0, 0.0, 0.0])
        # one occupied slot reads ONE 16-row tile; the raw-pos bug billed
        # all four (the pinned pre-fix value: 64.0)
        assert m.attn_s(pos, fused=True, active=active) == 16.0
        assert m.attn_s(pos, fused=True) == 64.0
        # the unfused path physically reads every row either way
        assert m.attn_s(pos, fused=False, active=active) \
            == m.attn_s(pos, fused=False)

    def test_engine_occupancy_excludes_idle_slots(self):
        """4 slots, ONE short request: the occupancy report must track
        only the occupied slot's positions, not credit the 3 idle slots
        with a row each."""
        ctl = ControlConfig(mode="zero", hetero_kind="contention", chi=4.0,
                            contention_p=0.15, sim_ranks=8,
                            model_decode_overheads=True, seed=0)
        eng = ServeEngine("yi-6b", num_slots=4, max_len=16, seed=0,
                          control=ctl)
        eng.run([_req(eng.cfg.vocab_size, 0, 3, 3)])
        eng.close()
        denom = 4 * 16.0
        # first step: the lone slot feeds position 0 -> exactly one row
        assert eng.history[0]["occupancy"] == pytest.approx(1.0 / denom)
        # occupancy grows with the slot's position, one row per step
        occ = [h["occupancy"] for h in eng.history]
        np.testing.assert_allclose(
            occ, [(i + 1) / denom for i in range(len(occ))])
        # attn_bound_s prices ONE slot's tile, not four
        one_tile = min(eng.overhead.tile, 16) * eng.overhead.kv_bytes_per_pos
        assert eng.history[0]["attn_bound_s"] == pytest.approx(
            one_tile / eng.overhead.hbm_bw)


class TestTTFTUidKeying:
    def test_ttft_survives_id_reuse(self):
        """Force CPython to hand a new Request the SAME address as a
        completed one: its TTFT clock must start at ITS OWN eligibility
        (keyed by uid), not inherit anything tied to the recycled id."""
        eng = ServeEngine("yi-6b", num_slots=1, max_len=16, seed=0)
        ra = _req(eng.cfg.vocab_size, 0, 4, 8)
        addr = id(ra)
        eng.submit(ra)
        while any(s is not None for s in eng.slots) or eng.queue:
            eng.step()
        t1 = eng.clock                           # wall so far >> one step
        assert t1 > 0
        del ra                                   # free the address
        rb = None
        for uid in range(1, 4097):               # same-shape dataclass:
            cand = _req(eng.cfg.vocab_size, uid, 4, 4)   # address recycles
            if id(cand) == addr:
                rb = cand
                break
        if rb is None:
            pytest.skip("allocator never reused the address")
        rb.arrival_step = eng.step_count
        eng.submit(rb)
        # the clock entry is keyed by uid and starts NOW, not at t=0
        assert eng._eligible_clock[rb.uid] == pytest.approx(t1)
        while any(s is not None for s in eng.slots) or eng.queue:
            eng.step()
        eng.close()
        comp = [c for c in eng.completions if c.uid == rb.uid][0]
        # a stale clock would fold the FIRST request's entire service
        # time into rb's TTFT (>= t1); the real TTFT is its own prefill
        assert 0 < comp.token_latencies[0] < t1
        # entries are dropped on completion — no unbounded growth
        assert eng._eligible_clock == {}


class TestOverheadClamp:
    def test_overhead_never_negative(self):
        m = DecodeOverheadModel(kv_bytes_per_pos=1.0, score_bytes_per_pos=0.0,
                                num_slots=4, max_len=64, tile=16,
                                hbm_bw=1.0, comm_time=100.0)
        pos = np.zeros(4, np.int32)
        active = np.array([1.0, 0.0, 0.0, 0.0])
        # attn_s = 16, chunking credit = 100 - 25 = 75: the un-clamped
        # model returned 16 - 75 = -59, dragging modeled latency BELOW
        # the IterationModel floor
        assert m.overhead_s(pos, fused=True, psum_chunks=4,
                            active=active) == 0.0
        m2 = DecodeOverheadModel(kv_bytes_per_pos=1.0,
                                 score_bytes_per_pos=0.0,
                                 num_slots=4, max_len=64, tile=16,
                                 hbm_bw=1.0, comm_time=16.0)
        # credit = 16 - 16/k; at k=1 credit is 0 -> full attn_s survives
        assert m2.overhead_s(pos, fused=True, psum_chunks=1,
                             active=active) == 16.0
        # exact boundary: attn_s == credit + exposed remainder
        assert m2.overhead_s(pos, fused=True, psum_chunks=2,
                             active=active) == pytest.approx(8.0)

    def test_engine_latency_keeps_iteration_floor(self):
        """With overhead modeling ON and aggressive psum chunking, every
        step's modeled latency stays >= the plain IterationModel step
        time (the pre-fix engine dipped below it at low occupancy because
        the over-subtracted chunking credit went negative)."""
        ctl = ControlConfig(mode="off", hetero_kind="contention", chi=4.0,
                            contention_p=0.15, sim_ranks=8,
                            model_decode_overheads=True,
                            fused_attention=True, psum_chunks=64, seed=0)
        eng = ServeEngine("yi-6b", num_slots=4, max_len=16, seed=0,
                          control=ctl)
        eng.run([_req(eng.cfg.vocab_size, 0, 3, 4)])
        eng.close()
        for h in eng.history:
            assert h["overhead_s"] >= 0.0
            assert h["latency_s"] >= h["dense_latency_s"] - 1e-12
