"""Checkpoint-store correctness: crash-safe writes, manifest validation,
separator-safe flat keys, and the full-train-state layout helpers.
"""
import json
import os

import numpy as np
import pytest

from repro.checkpoint import store


def _tree():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "nested": {"b": np.ones((4,), np.int32)}}


class TestRoundTrip:
    def test_basic_round_trip(self, tmp_path):
        d = str(tmp_path)
        tree = _tree()
        store.save(d, 3, tree)
        assert store.latest_step(d) == 3
        like = {"w": np.zeros((2, 3), np.float32),
                "nested": {"b": np.zeros((4,), np.int32)}}
        out = store.restore(d, 3, like)
        np.testing.assert_array_equal(out["w"], tree["w"])
        np.testing.assert_array_equal(out["nested"]["b"], tree["nested"]["b"])

    def test_slash_in_dict_key_round_trips(self, tmp_path):
        """A dict key containing the path separator must survive save →
        restore bit-exactly (the flat key escapes it)."""
        d = str(tmp_path)
        tree = {"scan/layer": {"w/down": np.full((3,), 7.0, np.float32)},
                "back\\slash": np.full((2,), 3.0, np.float32)}
        store.save(d, 1, tree)
        like = {"scan/layer": {"w/down": np.zeros((3,), np.float32)},
                "back\\slash": np.zeros((2,), np.float32)}
        out = store.restore(d, 1, like)
        np.testing.assert_array_equal(out["scan/layer"]["w/down"],
                                      tree["scan/layer"]["w/down"])
        np.testing.assert_array_equal(out["back\\slash"], tree["back\\slash"])

    def test_slash_keys_do_not_collide(self, tmp_path):
        """{"a": {"b/c": x}} and {"a/b": {"c": y}} are DIFFERENT pytrees:
        unescaped joining would flatten both to the key "a/b/c" and one
        leaf would silently overwrite the other."""
        d = str(tmp_path)
        tree = {"a": {"b/c": np.asarray([1.0], np.float32)},
                "a/b": {"c": np.asarray([2.0], np.float32)}}
        store.save(d, 1, tree)
        man = store.read_manifest(d, 1)
        assert len(man["keys"]) == 2           # no collision
        out = store.restore(d, 1, {"a": {"b/c": np.zeros(1, np.float32)},
                                   "a/b": {"c": np.zeros(1, np.float32)}})
        assert float(out["a"]["b/c"][0]) == 1.0
        assert float(out["a/b"]["c"][0]) == 2.0

    def test_load_arrays_nested(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"plane": {"est": {"chi": np.ones(4)}},
                          "params": {"w": np.zeros(2)}})
        out = store.load_arrays(d, 1, prefix="plane")
        np.testing.assert_array_equal(out["est"]["chi"], np.ones(4))
        assert "params" not in out


class TestCrashSafety:
    def test_latest_step_skips_manifestless_npz(self, tmp_path):
        """An npz whose manifest never landed is a torn write — it must
        not be selected as the resume point."""
        d = str(tmp_path)
        store.save(d, 1, _tree())
        store.save(d, 5, _tree())
        os.unlink(os.path.join(d, "ckpt_00000005.json"))  # simulate crash
        assert store.latest_step(d) == 1

    def test_no_tmp_litter_and_no_partial_files(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 2, _tree())
        names = sorted(os.listdir(d))
        assert names == ["ckpt_00000002.json", "ckpt_00000002.npz"]

    def test_overwrite_crash_cannot_pair_new_npz_with_old_manifest(
            self, tmp_path, monkeypatch):
        """Re-saving an existing step retracts the old commit marker
        FIRST: a crash after the new npz lands but before its manifest
        must leave a skipped orphan, never run B's arrays silently paired
        with run A's manifest/extra state."""
        d = str(tmp_path)
        store.save(d, 1, {"w": np.zeros((2,), np.float32)},
                   extra={"run": "A"})
        orig = store._atomic_write

        def crash_on_manifest(path, fn):
            if path.endswith(".json"):
                raise RuntimeError("crash before manifest commit")
            return orig(path, fn)

        monkeypatch.setattr(store, "_atomic_write", crash_on_manifest)
        with pytest.raises(RuntimeError, match="crash"):
            store.save(d, 1, {"w": np.ones((2,), np.float32)},
                       extra={"run": "B"})
        assert store.latest_step(d) is None     # torn write, not run A's

    def test_restore_closes_npz_handle(self, tmp_path):
        """restore() must not leak the npz file handle."""
        d = str(tmp_path)
        store.save(d, 1, _tree())
        like = {"w": np.zeros((2, 3), np.float32),
                "nested": {"b": np.zeros((4,), np.int32)}}
        fd_dir = "/proc/self/fd"
        if not os.path.isdir(fd_dir):
            pytest.skip("no /proc fd introspection on this platform")
        before = len(os.listdir(fd_dir))
        for _ in range(5):
            store.restore(d, 1, like)
        assert len(os.listdir(fd_dir)) <= before + 1


class TestValidation:
    def test_missing_leaf_is_actionable(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"w": np.zeros((2,), np.float32)})
        with pytest.raises(KeyError, match="missing leaf"):
            store.restore(d, 1, {"w": np.zeros((2,), np.float32),
                                 "extra": np.zeros((1,), np.float32)})

    def test_shape_mismatch_is_actionable(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"w": np.zeros((2, 3), np.float32)})
        with pytest.raises(ValueError, match="shape mismatch"):
            store.restore(d, 1, {"w": np.zeros((3, 2), np.float32)})

    def test_manifest_npz_dtype_disagreement(self, tmp_path):
        """A checkpoint pair whose manifest and npz disagree is corrupt
        and must be rejected, not silently cast."""
        d = str(tmp_path)
        store.save(d, 1, {"w": np.zeros((2,), np.float32)})
        mpath = os.path.join(d, "ckpt_00000001.json")
        man = json.load(open(mpath))
        man["dtypes"]["w"] = "float64"
        with open(mpath, "w") as f:
            json.dump(man, f)
        with pytest.raises(ValueError, match="dtype mismatch"):
            store.restore(d, 1, {"w": np.zeros((2,), np.float32)})

    def test_missing_manifest_is_actionable(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, _tree())
        os.unlink(os.path.join(d, "ckpt_00000001.json"))
        with pytest.raises(FileNotFoundError, match="no manifest"):
            store.restore(d, 1, _tree())


class TestConcurrentReaders:
    """The warm-spare promotion path: a cluster manager reading the
    checkpoint directory while a trainer is mid-save must get the newest
    COMMITTED state or a clean miss — never a crash, never torn data."""

    def _like(self):
        return {"w": np.zeros((2,), np.float32)}

    def test_load_latest_params_picks_newest_committed(self, tmp_path):
        d = str(tmp_path)
        store.save(d, 1, {"w": np.full((2,), 1.0, np.float32)})
        store.save(d, 5, {"w": np.full((2,), 5.0, np.float32)})
        step, params = store.load_latest_params(d, self._like())
        assert step == 5
        np.testing.assert_array_equal(params["w"], np.full((2,), 5.0))

    def test_empty_or_missing_directory_is_a_clean_miss(self, tmp_path):
        assert store.load_latest_params(str(tmp_path), self._like()) \
            == (None, None)
        assert store.load_latest_params(
            os.path.join(str(tmp_path), "never_made"), self._like()) \
            == (None, None)

    def test_orphan_npz_is_skipped_mid_save(self, tmp_path):
        """The npz of step 9 landed but its manifest hasn't yet (the
        writer is between the two atomic writes): readers must resolve
        to the previous committed step."""
        d = str(tmp_path)
        store.save(d, 2, {"w": np.full((2,), 2.0, np.float32)})
        store.save(d, 9, {"w": np.full((2,), 9.0, np.float32)})
        os.unlink(os.path.join(d, "ckpt_00000009.json"))  # not committed
        assert store.latest_step(d) == 2
        step, params = store.load_latest_params(d, self._like())
        assert step == 2
        np.testing.assert_array_equal(params["w"], np.full((2,), 2.0))

    def test_manifest_retracted_between_scan_and_read(self, tmp_path,
                                                      monkeypatch):
        """The benign race: the scan saw step 7 committed, but the
        trainer retracted its manifest (overwrite-in-progress) before the
        reader opened it — fall back to the previous committed step."""
        d = str(tmp_path)
        store.save(d, 3, {"w": np.full((2,), 3.0, np.float32)})
        store.save(d, 7, {"w": np.full((2,), 7.0, np.float32)})
        orig = store.read_manifest

        def retracted(directory, step):
            if step == 7:
                raise FileNotFoundError(
                    f"checkpoint step {step} in {directory} has no "
                    "manifest")
            return orig(directory, step)

        monkeypatch.setattr(store, "read_manifest", retracted)
        step, params = store.load_latest_params(d, self._like())
        assert step == 3
        np.testing.assert_array_equal(params["w"], np.full((2,), 3.0))

    def test_reader_gives_up_on_a_churning_directory(self, tmp_path,
                                                     monkeypatch):
        """Every scan loses the race (a writer looping over the same
        steps): after the retry budget the reader raises instead of
        spinning forever."""
        d = str(tmp_path)
        for s in range(1, 5):
            store.save(d, s, {"w": np.full((2,), float(s), np.float32)})
        monkeypatch.setattr(
            store, "read_manifest",
            lambda directory, step: (_ for _ in ()).throw(
                FileNotFoundError("no manifest")))
        with pytest.raises(RuntimeError, match="kept changing"):
            store.load_latest_params(d, self._like(), retries=2)


class TestTrainStateLayout:
    def test_prefix_restore_and_load_params(self, tmp_path):
        d = str(tmp_path)
        params = {"w": np.full((2,), 5.0, np.float32)}
        opt = {"mu": {"w": np.full((2,), 0.5, np.float32)}}
        store.save(d, 7, {"params": params, "opt": opt},
                   extra={"layout": store.TRAIN_STATE_LAYOUT,
                          "train_step": 7})
        like = {"w": np.zeros((2,), np.float32)}
        out = store.restore(d, 7, like, prefix="params")
        np.testing.assert_array_equal(out["w"], params["w"])
        # load_params dispatches on the manifest layout tag
        out2 = store.load_params(d, 7, like)
        np.testing.assert_array_equal(out2["w"], params["w"])

    def test_load_params_legacy_layout(self, tmp_path):
        d = str(tmp_path)
        params = {"w": np.full((3,), 2.0, np.float32)}
        store.save(d, 2, params)                 # params-only, no layout tag
        out = store.load_params(d, 2, {"w": np.zeros((3,), np.float32)})
        np.testing.assert_array_equal(out["w"], params["w"])
