"""End-to-end system behaviour tests.

Single-device: the trainer driver must reduce loss on real (synthetic)
data. Multi-device control-loop behaviour (SEMI balancing) runs in a
subprocess with 4 host devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_trainer_reduces_loss_vit():
    from repro.launch.train import run_training
    hist = run_training("vit-1b", steps=16, tp=1, dp=1, batch=8,
                        control_mode="off", quiet=True, log_every=1000)
    first = np.mean(hist["loss"][:4])
    last = np.mean(hist["loss"][-4:])
    assert np.isfinite(last)
    assert last < first, (first, last)


def test_trainer_reduces_loss_lm():
    from repro.launch.train import run_training
    hist = run_training("yi-6b", steps=50, tp=1, dp=1, batch=8, seq=32,
                        lr=1e-3, control_mode="off", quiet=True,
                        log_every=1000)
    assert np.isfinite(hist["final_loss"])
    assert np.mean(hist["loss"][-5:]) < np.mean(hist["loss"][:5]) - 0.2


def test_trainer_checkpoint_resume(tmp_path):
    from repro.launch.train import run_training
    d = str(tmp_path / "ck")
    run_training("yi-6b", steps=4, tp=1, batch=2, seq=16, ckpt_dir=d,
                 control_mode="off", quiet=True, log_every=1000)
    from repro.checkpoint import store
    assert store.latest_step(d) == 4
    hist = run_training("yi-6b", steps=6, tp=1, batch=2, seq=16, ckpt_dir=d,
                        resume=True, control_mode="off", quiet=True,
                        log_every=1000)
    assert len(hist["loss"]) == 2    # resumed from step 4


def test_trainer_resume_equivalence(tmp_path):
    """Crash-safe full-state checkpointing: train N steps uninterrupted
    vs. train k, 'crash', restore, train N−k — the resumed run must be
    BIT-IDENTICAL (loss trajectory, plan signatures, estimator state),
    which requires params + AdamW moments/step + controller/estimator
    state + the data-pipeline position to all round-trip."""
    from repro.launch.train import run_training
    kw = dict(tp=1, batch=2, seq=16, control_mode="zero",
              hetero_kind="static", chi=4.0, times="measured",
              quiet=True, log_every=1000)
    d = str(tmp_path / "ck")
    full = run_training("yi-6b", steps=8, **kw)
    run_training("yi-6b", steps=4, ckpt_dir=d, **kw)
    resumed = run_training("yi-6b", steps=8, ckpt_dir=d, resume=True, **kw)
    assert len(resumed["loss"]) == 4
    assert resumed["loss"] == full["loss"][4:]           # bit-identical
    assert resumed["signatures"] == full["signatures"][4:]
    # the estimator's χ̂ stream continued exactly where it left off
    assert resumed["chi_hat"] == full["chi_hat"]


def test_trainer_legacy_params_only_checkpoint_still_loads(tmp_path):
    """A pre-full-state checkpoint (params only, no layout tag) must keep
    restoring: params load, optimizer restarts fresh."""
    import numpy as np
    from repro.checkpoint import store
    from repro.launch.train import run_training
    d = str(tmp_path / "ck")
    h1 = run_training("yi-6b", steps=3, tp=1, batch=2, seq=16, ckpt_dir=d,
                      control_mode="off", quiet=True, log_every=1000)
    # rewrite the checkpoint as the LEGACY layout (params subtree, no tag)
    params = store.load_arrays(d, 3, prefix="params")
    store.save(d, 3, params)
    h2 = run_training("yi-6b", steps=5, tp=1, batch=2, seq=16, ckpt_dir=d,
                      resume=True, control_mode="off", quiet=True,
                      log_every=1000)
    assert len(h2["loss"]) == 2
    assert np.isfinite(h2["loss"]).all()


def test_semi_control_balances_modeled_time():
    """The core paper claim, end-to-end: with a χ=4 straggler, ZERO keeps
    the modeled bulk-synchronous step time well under the uncontrolled run
    (Fig. 9/10 behaviour), while training still converges."""
    code = """
from repro.launch.train import run_training
import numpy as np
base = run_training("vit-1b", steps=12, tp=4, control_mode="off",
                    hetero_kind="static", chi=4.0, quiet=True, log_every=1000)
ctrl = run_training("vit-1b", steps=12, tp=4, control_mode="zero",
                    hetero_kind="static", chi=4.0, quiet=True, log_every=1000)
t_base = np.mean(base["modeled_step_s"][2:])
t_ctrl = np.mean(ctrl["modeled_step_s"][2:])
assert np.isfinite(ctrl["final_loss"])
assert t_ctrl < 0.6 * t_base, (t_base, t_ctrl)
print("speedup:", t_base / t_ctrl)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
