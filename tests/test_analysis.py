"""Tests for the static analyzer itself (ISSUE 10, repro.analysis):
known-good/known-bad fixtures per rule R1-R5, registry completeness,
the mutate-mode smoke, and the banned-API source scans that back the
ruff TID251 rules for environments without ruff."""
import os
import re
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import rules
from repro.analysis.engine import lint
from repro.analysis.registry import Artifact, TraceCase

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _case(**kw):
    kw.setdefault("step", "t")
    kw.setdefault("name", "c")
    kw.setdefault("fn", lambda: None)
    kw.setdefault("args", ())
    return TraceCase(**kw)


def _rules_fired(arts, rule_id):
    return [v for v in lint(arts, [rule_id]) if v.rule == rule_id]


# ---------------------------------------------------------------------------
# R1 — retrace audit
# ---------------------------------------------------------------------------


def test_r1_clean_when_hashes_agree():
    a = Artifact(case=_case(signature="sig"), jaxpr_hash="aaaa",
                 retrace_hashes=(("double-trace", "aaaa"),))
    b = Artifact(case=_case(name="c2", signature="sig"), jaxpr_hash="aaaa")
    assert _rules_fired([a, b], "R1") == []


def test_r1_fires_on_forked_retrace():
    a = Artifact(case=_case(), jaxpr_hash="aaaa",
                 retrace_hashes=(("alias-build", "bbbb"),))
    assert _rules_fired([a], "R1")


def test_r1_fires_on_signature_bucket_split():
    a = Artifact(case=_case(name="c1", signature="sig"), jaxpr_hash="aaaa")
    b = Artifact(case=_case(name="c2", signature="sig"), jaxpr_hash="bbbb")
    assert _rules_fired([a, b], "R1")


# ---------------------------------------------------------------------------
# R2 — host-sync / donation
# ---------------------------------------------------------------------------

_ALIASED_HLO = """
HloModule jit_step, input_output_alias={ {1}: (1, {}, may-alias) }
ENTRY main {
  %p0 = f32[4]{0} parameter(0)
  %p1 = f32[4,8]{1,0} parameter(1)
  ROOT %out = f32[4,8]{1,0} add(%p1, %p1)
}
"""

_INFEED_HLO = """
HloModule jit_step
ENTRY main {
  %tok = token[] after-all()
  %in = ((f32[4]{0}), token[]) infeed(%tok)
  ROOT %out = f32[4]{0} get-tuple-element(%in), index=0
}
"""


def test_r2_clean_on_donated_and_aliased_state():
    a = Artifact(case=_case(state_argnums=(1,), donate_argnums=(1,)),
                 hlo_text=_ALIASED_HLO)
    assert _rules_fired([a], "R2") == []


def test_r2_fires_on_undonated_state():
    a = Artifact(case=_case(state_argnums=(1,), donate_argnums=()))
    hits = _rules_fired([a], "R2")
    assert hits and "not donated" in hits[0].message


def test_r2_fires_when_declared_donation_did_not_alias():
    a = Artifact(case=_case(state_argnums=(1,), donate_argnums=(1,)),
                 hlo_text=_INFEED_HLO.replace("infeed", "add2"))
    hits = _rules_fired([a], "R2")
    assert hits and "input_output_alias" in hits[0].message


def test_r2_fires_on_hlo_host_transfer():
    a = Artifact(case=_case(), hlo_text=_INFEED_HLO)
    hits = _rules_fired([a], "R2")
    assert hits and "infeed" in hits[0].message


def test_r2_fires_on_callback_primitive():
    import jax
    import numpy as np

    def fn(x):
        return jax.pure_callback(
            lambda a: np.asarray(a),
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    jx = jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((4,), "float32"))
    a = Artifact(case=_case(), jaxpr=jx)
    hits = _rules_fired([a], "R2")
    assert hits and "pure_callback" in hits[0].message


# ---------------------------------------------------------------------------
# R3 — collective audit
# ---------------------------------------------------------------------------

_CHUNKED_HLO = """
ENTRY main {
  %a1 = f32[2,8,64]{2,1,0} all-reduce(%x0), to_apply=%add
  %a2 = f32[2,8,64]{2,1,0} all-reduce(%x1), to_apply=%add
  %a3 = f32[2,8,64]{2,1,0} all-reduce(%x2), to_apply=%add
  %a4 = f32[2,8,64]{2,1,0} all-reduce(%x3), to_apply=%add
}
"""

_FAT_HLO = """
ENTRY main {
  %a1 = f32[2,8,256]{2,1,0} all-reduce(%x0), to_apply=%add
}
"""


def test_r3_chunked_audit_good_and_bad():
    ok, observed = rules.audit_chunked_all_reduce(
        _CHUNKED_HLO, 4, "2,8,256", "2,8,64")
    assert ok == [] and observed == ["2,8,64"] * 4
    bad, _ = rules.audit_chunked_all_reduce(
        _FAT_HLO, 4, "2,8,256", "2,8,64")
    assert len(bad) == 2          # missing chunks AND a surviving fat one
    ok1, _ = rules.audit_chunked_all_reduce(
        _FAT_HLO, 1, "2,8,256", "2,8,64")
    assert ok1 == []


def test_r3_rule_reads_expectations_from_case():
    exp = {"chunked_all_reduce": {
        "chunks": 4, "full_dims": "2,8,256", "chunk_dims": "2,8,64"}}
    good = Artifact(case=_case(expect=exp), hlo_text=_CHUNKED_HLO)
    bad = Artifact(case=_case(expect=exp), hlo_text=_FAT_HLO)
    assert _rules_fired([good], "R3") == []
    assert _rules_fired([bad], "R3")


def test_r3_grouped_psum_jaxpr_counting():
    import jax
    import jax.numpy as jnp

    sds = jax.ShapeDtypeStruct((4, 4), jnp.float32)

    def grouped(a, b):
        return jax.lax.psum((a, b), "i")

    def split(a, b):
        return jax.lax.psum(a, "i"), jax.lax.psum(b, "i")

    def trace(fn):
        mesh = jax.sharding.Mesh(
            __import__("numpy").array(jax.devices()[:1]), ("i",))
        from repro.sharding import shard_map
        from jax.sharding import PartitionSpec as P
        return jax.make_jaxpr(shard_map(
            fn, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
            check_vma=False))(sds, sds)

    assert rules.grouped_psum_count_jaxpr(trace(grouped)) == 1
    assert rules.grouped_psum_count_jaxpr(trace(split)) == 0
    exp = {"grouped_psum": {"count": 1}}
    good = Artifact(case=_case(expect=exp), jaxpr=trace(grouped))
    bad = Artifact(case=_case(expect=exp), jaxpr=trace(split))
    assert _rules_fired([good], "R3") == []
    assert _rules_fired([bad], "R3")


# ---------------------------------------------------------------------------
# R4 — Pallas VMEM budget
# ---------------------------------------------------------------------------


def _matmul_jaxpr():
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops
    sds = jax.ShapeDtypeStruct
    return jax.make_jaxpr(lambda x, w, k: ops.block_pruned_matmul(
        x, w, k, 32, 16, 32))(
        sds((16, 128), jnp.float32), sds((128, 64), jnp.float32),
        sds((2,), jnp.int32))


def test_r4_clean_within_budget_fires_when_budget_shrunk():
    jx = _matmul_jaxpr()
    good = Artifact(case=_case(), jaxpr=jx)
    assert _rules_fired([good], "R4") == []
    bad = Artifact(case=_case(expect={"vmem_budget": 1024}), jaxpr=jx)
    hits = _rules_fired([bad], "R4")
    assert hits and "VMEM" in hits[0].message


def test_r4_assert_fits_raises_named_error():
    import jax
    import jax.numpy as jnp
    from repro.analysis.vmem import VmemBudgetError, assert_fits
    from repro.kernels import ops
    sds = jax.ShapeDtypeStruct
    args = (sds((16, 128), jnp.float32), sds((128, 64), jnp.float32),
            sds((2,), jnp.int32))
    assert_fits(lambda x, w, k: ops.block_pruned_matmul(x, w, k, 32, 16, 32),
                *args)                                    # default budget ok
    with pytest.raises(VmemBudgetError):
        assert_fits(
            lambda x, w, k: ops.block_pruned_matmul(x, w, k, 32, 16, 32),
            *args, budget=1024)


# ---------------------------------------------------------------------------
# R5 — dtype leak
# ---------------------------------------------------------------------------


def test_r5_fires_on_f64_in_hlo_and_respects_allowance():
    hlo = "ENTRY main {\n  %c = f64[8]{0} convert(%p0)\n}\n"
    bad = Artifact(case=_case(), hlo_text=hlo)
    assert _rules_fired([bad], "R5")
    allowed = Artifact(case=_case(expect={"allow_f64": True}),
                       hlo_text=hlo)
    assert _rules_fired([allowed], "R5") == []
    clean = Artifact(case=_case(),
                     hlo_text="ENTRY main {\n  %c = f32[8]{0} convert(%p0)\n}\n")
    assert _rules_fired([clean], "R5") == []


def test_r5_fires_on_f64_jaxpr():
    import jax
    from jax.experimental import enable_x64
    with enable_x64():
        jx = jax.make_jaxpr(lambda x: x.astype("float64") * 2)(
            jax.ShapeDtypeStruct((4,), "float32"))
    assert _rules_fired([Artifact(case=_case(), jaxpr=jx)], "R5")


# ---------------------------------------------------------------------------
# engine-level behavior
# ---------------------------------------------------------------------------


def test_engine_surfaces_trace_failures_as_violations():
    broken = _case(fn=lambda: (_ for _ in ()).throw(RuntimeError("boom")),
                   args=())
    from repro.analysis.engine import trace_artifact
    from repro.analysis.registry import CaseEnv
    art = trace_artifact(broken, CaseEnv())
    assert art.error
    hits = [v for v in lint([art]) if v.rule == "engine"]
    assert hits and "boom" in hits[0].message


def test_registry_completeness_every_cli_step_registered():
    from repro.analysis.registry import REQUIRED_STEPS, load_providers
    names = load_providers()
    missing = set(REQUIRED_STEPS) - set(names)
    assert not missing, (
        f"step builders missing analysis registration: {sorted(missing)} — "
        "register them via repro.analysis.registry (DESIGN_ANALYSIS.md)")


def test_unknown_rule_id_rejected():
    with pytest.raises(ValueError):
        rules.rules_by_id(["R9"])


def test_hlo_shim_modules_warn_and_forward():
    import importlib
    import warnings
    from repro.analysis import hlo as canonical
    for shim_name, attr in (("repro.launch.hlo_analysis",
                             "parse_collectives"),
                            ("repro.launch.hlo_inspect", "op_histogram")):
        shim = importlib.import_module(shim_name)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fn = getattr(shim, attr)
        assert any(issubclass(x.category, DeprecationWarning) for x in w), \
            shim_name
        assert fn is getattr(canonical, attr)


# ---------------------------------------------------------------------------
# mutate-mode smoke (subprocess: forced host devices, real CLI)
# ---------------------------------------------------------------------------


def test_mutate_mode_every_rule_fires():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--mutate",
         "--devices", "8"],
        capture_output=True, text=True, timeout=420,
        env={**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")})
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    assert "0 silent" in out.stdout


# ---------------------------------------------------------------------------
# banned-API source scans (TID251 backstop for ruff-less environments)
# ---------------------------------------------------------------------------


def _source_files():
    for base in ("src", "benchmarks"):
        for dirpath, _, files in os.walk(os.path.join(ROOT, base)):
            for f in files:
                if f.endswith(".py"):
                    yield os.path.join(dirpath, f)


def _code_lines(path):
    """Source lines with #-comments stripped (coarse, string-safe enough
    for a banned-pattern scan)."""
    for line in open(path, encoding="utf-8"):
        yield line.split("#", 1)[0]


def test_no_id_calls_on_request_objects():
    """PR 8 regression class: ``id(req)`` as a request key aliases
    recycled objects (the TTFT clock bug). Request identity is
    ``req.uid``, always."""
    pat = re.compile(r"\bid\(\s*(?:req|request)\b")
    bad = [p for p in _source_files()
           if any(pat.search(ln) for ln in _code_lines(p))]
    assert not bad, f"id() called on request objects in: {bad}"


def test_no_direct_hlo_analysis_imports_outside_analysis_package():
    pat = re.compile(r"(?:from\s+repro\.launch\s+import\s+[^\n]*"
                     r"\bhlo_analysis\b|"
                     r"(?:from|import)\s+repro\.launch\.hlo_analysis\b)")
    allowed = {os.path.join(ROOT, "src", "repro", "launch",
                            "hlo_analysis.py")}
    bad = [p for p in _source_files()
           if p not in allowed
           and pat.search(open(p, encoding="utf-8").read())]
    assert not bad, (
        f"direct repro.launch.hlo_analysis imports (use "
        f"repro.analysis.hlo): {bad}")
