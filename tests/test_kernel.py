"""Pallas block-pruned-matmul kernel vs the pure-jnp oracle (interpret mode).

Shape/dtype sweep per the brief: every kernel asserts allclose against
ref.py across matrix sizes, block sizes, keep counts and dtypes.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _mk(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


@pytest.mark.parametrize("M,K,N,block,tm,tn", [
    (32, 128, 64, 32, 16, 32),
    (64, 256, 128, 64, 32, 64),
    (128, 512, 256, 128, 64, 128),
    (48, 96, 80, 32, 16, 16),        # ragged M/N vs tiles (padding path)
    (8, 64, 8, 32, 8, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref(M, K, N, block, tm, tn, dtype):
    rng = np.random.default_rng(M + K + N)
    x, w = _mk(rng, (M, K), dtype), _mk(rng, (K, N), dtype)
    nb = K // block
    kb = max(1, nb // 2)
    keep = jnp.asarray(np.sort(rng.choice(nb, kb, replace=False)), jnp.int32)
    y = ops.block_pruned_matmul(x, w, keep, block, tm, tn)
    y_ref = ref.block_pruned_matmul_ref(x, w, keep, block=block)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=tol, rtol=tol)


def test_kernel_all_blocks_is_dense():
    rng = np.random.default_rng(7)
    x, w = _mk(rng, (32, 128), jnp.float32), _mk(rng, (128, 64), jnp.float32)
    keep = jnp.arange(4, dtype=jnp.int32)
    y = ops.block_pruned_matmul(x, w, keep, 32, 16, 32)
    np.testing.assert_allclose(y, x @ w, atol=1e-4)


def test_kernel_batched_leading_dims():
    rng = np.random.default_rng(8)
    x = _mk(rng, (2, 3, 128), jnp.float32)
    w = _mk(rng, (128, 32), jnp.float32)
    keep = jnp.array([0, 3], jnp.int32)
    y = ops.block_pruned_matmul(x, w, keep, 32, 8, 16)
    assert y.shape == (2, 3, 32)
    y_ref = ref.block_pruned_matmul_ref(
        x.reshape(-1, 128), w, keep, block=32).reshape(2, 3, 32)
    np.testing.assert_allclose(y, y_ref, atol=1e-4)


def test_kernel_custom_vjp_matches_xla_path():
    rng = np.random.default_rng(9)
    x = _mk(rng, (16, 128), jnp.float32)
    w = _mk(rng, (128, 48), jnp.float32)
    keep = jnp.array([1, 2], jnp.int32)

    def loss_k(x_, w_):
        return jnp.sum(ops.block_pruned_matmul(x_, w_, keep, 32, 8, 16) ** 2)

    from repro.core import resizing

    def loss_x(x_, w_):
        return jnp.sum(resizing.resized_matmul(x_, w_, keep, block=32) ** 2)

    gk = jax.grad(loss_k, (0, 1))(x, w)
    gx = jax.grad(loss_x, (0, 1))(x, w)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(a, b, atol=1e-3)
