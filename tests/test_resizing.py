"""ZERO-resizing unit + property tests (paper Sec. III)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import resizing, workload


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


class TestResizedMatmul:
    def test_forward_matches_masked_oracle(self):
        rng = np.random.default_rng(0)
        K, N, B = 128, 48, 16
        x, w = _rand(rng, 6, K), _rand(rng, K, N)
        keep = jnp.array([0, 2, 5], jnp.int32)
        y = resizing.resized_matmul(x, w, keep, block=B)
        mask = np.repeat(np.isin(np.arange(K // B), np.array(keep)), B)
        np.testing.assert_allclose(y, (x * mask) @ w, atol=1e-4)

    def test_output_shape_equals_unpruned(self):
        """Consistency constraint: output dims match the unpruned matmul."""
        rng = np.random.default_rng(1)
        x, w = _rand(rng, 4, 7, 64), _rand(rng, 64, 32)
        y = resizing.resized_matmul(x, w, jnp.array([1], jnp.int32), block=16)
        assert y.shape == (4, 7, 32)

    def test_gradients_zero_imputed_with_lineage(self):
        """VJP scatters grads to exactly the kept rows/cols, zeros elsewhere
        (the paper's lineage + Zero imputation, Fig. 2 right)."""
        rng = np.random.default_rng(2)
        K, N, B = 96, 24, 16
        x, w = _rand(rng, 8, K), _rand(rng, K, N)
        keep = jnp.array([1, 3, 4], jnp.int32)
        gx, gw = jax.grad(
            lambda x_, w_: jnp.sum(
                resizing.resized_matmul(x_, w_, keep, block=B) ** 2),
            argnums=(0, 1))(x, w)
        mask = np.repeat(np.isin(np.arange(K // B), np.array(keep)), B)
        assert np.all(np.asarray(gw)[~mask] == 0.0)
        assert np.all(np.asarray(gx)[:, ~mask] == 0.0)
        # kept entries match the masked-dense oracle exactly
        gx_r, gw_r = jax.grad(
            lambda x_, w_: jnp.sum(((x_ * mask) @ w_) ** 2),
            argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gw, np.asarray(gw_r) * mask[:, None], atol=1e-3)
        np.testing.assert_allclose(gx, np.asarray(gx_r) * mask, atol=1e-3)

    @given(nb=st.integers(2, 8), bucket=st.integers(0, 3),
           seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_switched_matmul_bucket0_is_dense(self, nb, bucket, seed):
        rng = np.random.default_rng(seed)
        B = 8
        K, N = nb * B, 16
        x, w = _rand(rng, 4, K), _rand(rng, K, N)
        pri = jnp.asarray(rng.permutation(nb).astype(np.int32))
        buckets = (0.0, 0.25, 0.5, 0.75)
        y = resizing.switched_matmul(x, w, pri, jnp.array(bucket),
                                     buckets=buckets, block=B)
        assert y.shape == (4, N)
        if bucket == 0:
            np.testing.assert_allclose(y, x @ w, atol=1e-4)
        else:
            kc = workload.keep_blocks_for_bucket(buckets[bucket], nb)
            keep = np.sort(np.asarray(pri)[:kc])
            mask = np.repeat(np.isin(np.arange(nb), keep), B)
            np.testing.assert_allclose(y, (x * mask) @ w, atol=1e-4)


class TestImputation:
    def test_zero_is_identity(self):
        g = jnp.ones((8, 4))
        kept = jnp.array([True] * 4 + [False] * 4)
        np.testing.assert_array_equal(
            resizing.impute_rows(g, kept, "zero"), g)

    def test_average_fills_pruned_rows(self):
        g = jnp.concatenate([jnp.full((2, 3), 4.0), jnp.zeros((2, 3))])
        kept = jnp.array([True, True, False, False])
        out = resizing.impute_rows(g, kept, "average")
        np.testing.assert_allclose(out[2:], 4.0)
        np.testing.assert_allclose(out[:2], 4.0)

    def test_same_uses_previous(self):
        g = jnp.zeros((4, 2))
        prev = jnp.full((4, 2), 7.0)
        kept = jnp.array([True, False, True, False])
        out = resizing.impute_rows(g, kept, "same", prev)
        np.testing.assert_allclose(np.asarray(out)[1], 7.0)
        np.testing.assert_allclose(np.asarray(out)[0], 0.0)


class TestWorkload:
    @given(gamma=st.floats(0.0, 0.875), nb=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_bucket_rounds_up(self, gamma, nb):
        """Eq.(1)'s γ is rounded UP so the runtime gap is fully offset."""
        b = workload.bucket_for_gamma(gamma)
        assert workload.DEFAULT_BUCKETS[b] >= gamma - 1e-9

    @given(nb=st.integers(1, 64), gamma=st.floats(0.0, 1.0))
    @settings(max_examples=50, deadline=None)
    def test_keep_blocks_bounds(self, nb, gamma):
        kc = workload.keep_blocks_for_bucket(gamma, nb)
        assert 1 <= kc <= nb

    def test_adapt_block_size(self):
        assert workload.adapt_block_size(1024) == 128
        assert workload.adapt_block_size(704) == 64    # 704 = 11·64
        assert workload.adapt_block_size(96) == 32     # 96 = 3·32
        assert workload.adapt_block_size(176) == 0     # 176 = 11·16: exempt

    def test_neutral_plan(self):
        plan = workload.WorkloadPlan.neutral(4)
        assert plan.is_neutral()
