"""Unit tests for the telemetry subsystem (DESIGN_TELEMETRY.md).

Covers the satellite checklist: the contention RNG de-aliasing with
pinned trajectories, estimator behavior (EWMA convergence under ±5%
multiplicative noise, single-spike rejection, regime-change re-lock,
warmup gating, mitigation-blindness), and the trace write→read round
trip including the schema-version check.
"""
import json
import os

import numpy as np
import pytest

from repro.core.hetero import HeteroSchedule, IterationModel
from repro.telemetry import (EstimatorConfig, StepSample, StragglerEstimator,
                             TraceFormatError, TraceReader, TraceWriter,
                             schedule_from_trace)

TRACES_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "..", "examples", "traces")


# ---------------------------------------------------------------------------
# HeteroSchedule contention RNG (satellite: SeedSequence de-aliasing)
# ---------------------------------------------------------------------------


class TestContentionRng:
    def _hits(self, seed, steps, p=0.5):
        s = HeteroSchedule(num_ranks=8, kind="contention", seed=seed,
                           contention_p=p, contention_chi=4.0)
        return np.stack([(s.chi(t) > 1).astype(int) for t in steps])

    def test_seed_step_streams_do_not_alias(self):
        """default_rng(seed + step) made (seed=0, step=5) replay
        (seed=5, step=0) exactly; SeedSequence((seed, step)) keys the
        stream on the PAIR, so shifted schedules diverge."""
        a = self._hits(0, range(5, 37))
        b = self._hits(5, range(0, 32))
        assert not np.array_equal(a, b)
        # and distinct seeds produce distinct trajectories at equal steps
        assert not np.array_equal(self._hits(0, range(32)),
                                  self._hits(1, range(32)))

    def test_pinned_trajectories(self):
        """The new per-step streams are part of the trace/replay contract:
        pin them so an RNG change cannot silently invalidate committed
        fixtures and benchmark trajectories."""
        expect0 = np.array([[0, 1, 1, 1, 0, 0, 0, 0],
                            [0, 0, 0, 0, 1, 1, 0, 1],
                            [1, 1, 0, 1, 1, 1, 1, 1],
                            [0, 0, 1, 1, 1, 0, 1, 0]])
        expect5 = np.array([[0, 0, 0, 1, 1, 1, 1, 1],
                            [0, 1, 0, 0, 0, 0, 1, 1],
                            [1, 1, 0, 0, 1, 1, 1, 1],
                            [0, 1, 0, 1, 1, 1, 0, 0]])
        np.testing.assert_array_equal(self._hits(0, range(4)), expect0)
        np.testing.assert_array_equal(self._hits(5, range(4)), expect5)

    def test_determinism_per_step(self):
        s = HeteroSchedule(num_ranks=8, kind="contention", seed=3)
        np.testing.assert_array_equal(s.chi(7), s.chi(7))


# ---------------------------------------------------------------------------
# StragglerEstimator
# ---------------------------------------------------------------------------


MODEL = IterationModel(matmul_time=0.010, other_time=0.0015)


def _feed(est, chi, frac, steps, noise=0.0, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        t = MODEL.times(np.asarray(chi), np.asarray(frac))
        if noise:
            t = t * (1.0 + rng.uniform(-noise, noise, len(chi)))
        est.update(t, np.asarray(frac))


class TestEstimator:
    def test_ewma_converges_under_noise(self):
        """±5% multiplicative noise on the measured times: χ̂ converges to
        the true χ within a few percent and stays there."""
        est = StragglerEstimator(MODEL, 4)
        chi = [4.0, 2.0, 1.0, 1.0]
        _feed(est, chi, [0.25, 0.5, 1.0, 1.0], steps=80, noise=0.05)
        np.testing.assert_allclose(est.chi_hat, chi, rtol=0.05)
        # full-workload-equivalent reconstruction matches the oracle
        np.testing.assert_allclose(est.full_times(),
                                   MODEL.times(np.asarray(chi), np.ones(4)),
                                   rtol=0.05)

    def test_not_fooled_by_mitigation(self):
        """The closed-loop property: a rank the plan already pruned to
        1/8 of its workload still reports its FULL χ — the estimator
        divides the mitigation back out, so the controller keeps seeing
        the heterogeneity degree (paper Eq. 1), not the mitigated time."""
        est = StragglerEstimator(MODEL, 2)
        _feed(est, [4.0, 1.0], [0.125, 1.0], steps=20)
        # measured time of the pruned straggler is BELOW the helper's ...
        t_mitigated = MODEL.times(np.array([4.0, 1.0]), np.array([0.125, 1.0]))
        assert t_mitigated[0] < t_mitigated[1]
        # ... yet the reconstruction still ranks it 4x slower
        np.testing.assert_allclose(est.chi_hat, [4.0, 1.0], rtol=1e-6)

    def test_single_spike_rejected(self):
        """One spiked sample (GC pause / page fault) is dropped by the
        median/MAD gate: χ̂ of the spiked rank does not move."""
        est = StragglerEstimator(MODEL, 4)
        chi = [2.0, 1.0, 1.0, 1.0]
        frac = [0.5, 1.0, 1.0, 1.0]
        _feed(est, chi, frac, steps=30, noise=0.03)
        before = est.chi_hat.copy()
        spiked = MODEL.times(np.asarray(chi), np.asarray(frac))
        spiked[0] *= 10.0
        est.update(spiked, np.asarray(frac))
        assert est.chi_hat[0] == pytest.approx(before[0])
        assert est.rejected_total >= 1
        # the stream recovers: the next clean sample is accepted again
        rej = est.rejected_total
        _feed(est, chi, frac, steps=1)
        assert est.rejected_total == rej
        np.testing.assert_allclose(est.chi_hat, chi, rtol=0.05)

    def test_regime_change_relocks(self):
        """`regime_steps` consecutive out-of-band samples are a burst
        start, not noise: the window flushes and χ̂ re-locks immediately."""
        cfg = EstimatorConfig(regime_steps=2)
        est = StragglerEstimator(MODEL, 2, cfg)
        _feed(est, [1.0, 1.0], [1.0, 1.0], steps=20, noise=0.03)
        assert est.chi_hat[0] == pytest.approx(1.0, rel=0.03)
        _feed(est, [4.0, 1.0], [1.0, 1.0], steps=cfg.regime_steps)
        assert est.relocks == 1
        assert est.chi_hat[0] == pytest.approx(4.0, rel=1e-6)
        # hold the burst long enough for the flushed window to mature
        # (shorter than warmup_steps and the MAD gate cannot re-arm),
        # then release: the estimator re-locks back to χ=1
        _feed(est, [4.0, 1.0], [1.0, 1.0], steps=cfg.warmup_steps + 2)
        _feed(est, [1.0, 1.0], [1.0, 1.0], steps=cfg.regime_steps)
        assert est.relocks == 2
        assert est.chi_hat[0] == pytest.approx(1.0, rel=1e-6)

    def test_warmup_gate(self):
        cfg = EstimatorConfig(warmup_steps=5)
        est = StragglerEstimator(MODEL, 2, cfg)
        for k in range(cfg.warmup_steps):
            assert not est.ready
            est.update(MODEL.times(np.array([2.0, 1.0]), np.ones(2)))
        assert est.ready
        # nominal_times (the warmup fallback) is homogeneous -> the
        # controller's deadband keeps the plan neutral
        nom = est.nominal_times()
        assert np.all(nom == nom[0])


# ---------------------------------------------------------------------------
# Trace write -> read round trip + replay
# ---------------------------------------------------------------------------


class TestTraceRoundTrip:
    def _write(self, path, n=6):
        frac = np.array([0.25, 1.0, 1.0, 1.0])
        with TraceWriter(path, 4, matmul_time=MODEL.matmul_time,
                         other_time=MODEL.other_time,
                         meta={"fixture": "unit"}) as w:
            for t in range(n):
                w.append(StepSample(
                    step=t,
                    rank_times=MODEL.times(np.array([4.0, 1.0, 1.0, 1.0]),
                                           frac),
                    plan_signature="tp4b8shed[]", work_frac=frac,
                    wall_s=0.001 * t))
        return frac

    def test_round_trip(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        frac = self._write(p)
        r = TraceReader(p)
        assert r.num_ranks == 4
        assert r.matmul_time == MODEL.matmul_time
        assert r.meta["fixture"] == "unit"
        ss = r.samples()
        assert [s.step for s in ss] == list(range(6))
        np.testing.assert_allclose(ss[0].work_frac, frac)
        assert ss[0].plan_signature == "tp4b8shed[]"
        assert ss[3].wall_s == pytest.approx(0.003)

    def test_schema_version_check(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        self._write(p)
        lines = open(p).read().splitlines()
        hdr = json.loads(lines[0])
        hdr["version"] = 99
        bad = str(tmp_path / "bad.jsonl")
        with open(bad, "w") as f:
            f.write("\n".join([json.dumps(hdr)] + lines[1:]))
        with pytest.raises(TraceFormatError, match="version"):
            TraceReader(bad)
        hdr["version"] = 1
        hdr["schema"] = "something.else"
        with open(bad, "w") as f:
            f.write("\n".join([json.dumps(hdr)] + lines[1:]))
        with pytest.raises(TraceFormatError, match="schema"):
            TraceReader(bad)

    def test_rank_count_mismatch_rejected(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        self._write(p)
        with open(p, "a") as f:
            f.write(json.dumps({"kind": "sample", "step": 6,
                                "rank_times": [0.1, 0.2]}) + "\n")
        with pytest.raises(TraceFormatError, match="rank times"):
            TraceReader(p).samples()

    def test_replay_reconstructs_full_chi(self, tmp_path):
        """kind="trace" replay: recorded MITIGATED times come back as
        full-workload-equivalent χ (the recorded work_frac divides out)."""
        p = str(tmp_path / "t.jsonl")
        self._write(p)
        sched = schedule_from_trace(p)
        assert sched.kind == "trace"
        np.testing.assert_allclose(sched.chi(0), [4.0, 1.0, 1.0, 1.0],
                                   rtol=1e-9)
        # wrap-around past the end
        np.testing.assert_allclose(sched.chi(6), sched.chi(0))
        # rank-count override pads with 1.0
        wide = schedule_from_trace(p, num_ranks=6)
        assert wide.chi(0).shape == (6,)
        assert wide.chi(0)[4] == 1.0

    def test_committed_fixtures_load(self):
        """The committed fixture library replays (header constants pinned
        by make_fixtures.py)."""
        for name, steps in (("static_skew", 60), ("round_robin", 120),
                            ("bursty_contention", 200)):
            path = os.path.join(TRACES_DIR, f"{name}.jsonl")
            r = TraceReader(path)
            assert r.num_ranks == 8
            assert len(r.samples()) == steps
            sched = schedule_from_trace(path)
            chis = np.stack([sched.chi(t) for t in range(steps)])
            # every fixture contains real straggling episodes (χ≈4 after
            # noise) and quiet ranks near χ=1
            assert chis.max() > 3.5
            assert np.percentile(chis, 10) < 1.2
