"""First-class ragged shard geometry (core/geometry.py, DESIGN_SHARDING.md).

Property tests for the geometry object and its χ-seeding, the padded
param expansion, the plan-layer composition (PlanStatic signatures,
per-rank priority rows, residual controller planning) and — in
subprocesses with forced host devices — the numerical contracts:

* an all-EQUAL geometry is normalized away and bit-matches the
  geometry-free equal-shard baseline (forward AND grads);
* any valid UNEVEN geometry (including a min-slice rank) matches the
  canonical dense oracle to float tolerance, neutral / resized /
  migrated alike, with migration lossless in forward and backward;
* serve decode under an uneven geometry + the lossless β-policy is
  token-exact vs the same-geometry dense engine.

Runs under real `hypothesis` when installed (CI) and under the seeded
deterministic fallback otherwise (tests/_hypothesis_fallback.py).
"""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import geometry as geom
from repro.core.geometry import (ShardGeometry, equal_geometry,
                                 geometry_from_chi, parse_geometry_arg)
from repro.core.workload import PlanStatic

from test_multidevice import run_py


# ---------------------------------------------------------------------------
# the geometry object
# ---------------------------------------------------------------------------


class TestShardGeometry:
    def test_basic_invariants(self):
        g = ShardGeometry(sizes=(4, 10, 9, 9), block=8)
        assert g.tp == 4
        assert g.total_blocks == 32
        assert g.max_blocks == 10 and g.min_blocks == 4
        assert g.offsets == (0, 4, 14, 23)
        assert g.width == 256
        assert g.padded_blocks == 40 and g.padded_width == 320
        assert not g.is_equal
        assert equal_geometry(32, 4, 8).is_equal

    def test_rank_of_block_partitions(self):
        g = ShardGeometry(sizes=(2, 14, 8, 8), block=8)
        owners = [g.rank_of_block(b) for b in range(g.total_blocks)]
        for r in range(g.tp):
            assert owners.count(r) == g.sizes[r]
        assert owners == sorted(owners)          # contiguous canonical spans

    def test_rejects_empty_rank(self):
        with pytest.raises(ValueError):
            ShardGeometry(sizes=(0, 16, 8, 8), block=8)

    @given(tp=st.sampled_from([1, 2, 4]),
           data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_random_partition_invariants(self, tp, data):
        """Any random uneven partition (min-slice ranks included) keeps
        the layout algebra consistent."""
        total = data.draw(st.integers(tp, 48))
        cuts = sorted(data.draw(
            st.lists(st.integers(1, total - 1), min_size=tp - 1,
                     max_size=tp - 1)))
        sizes, prev = [], 0
        for c in cuts + [total]:
            sizes.append(max(c - prev, 1))
            prev = c
        # repair: force the sum back to total (draws may collide)
        sizes[-1] += total - sum(sizes)
        if sizes[-1] < 1:
            return
        g = ShardGeometry(sizes=tuple(sizes), block=8)
        assert sum(g.sizes) == g.total_blocks == total
        assert g.offsets[0] == 0
        assert all(g.offsets[r + 1] - g.offsets[r] == g.sizes[r]
                   for r in range(tp - 1))
        assert g.padded_blocks == tp * max(sizes)
        assert g.padded_width % tp == 0


class TestGeometryFromChi:
    def test_two_x_straggler_gets_half_share(self):
        g = geometry_from_chi([2.0, 1.0, 1.0, 1.0], 32, 8)
        assert g.sizes == (5, 9, 9, 9)
        assert sum(g.sizes) == 32

    @given(tp=st.sampled_from([2, 4]), data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_sum_min_and_monotonicity(self, tp, data):
        chis = [data.draw(st.floats(0.5, 8.0)) for _ in range(tp)]
        total = data.draw(st.integers(tp, 64))
        g = geometry_from_chi(chis, total, 8)
        assert sum(g.sizes) == total
        assert min(g.sizes) >= 1
        # a strictly slower rank never gets MORE blocks (after the χ snap)
        q = [max(round(c / 0.25) * 0.25, 1.0) for c in chis]
        for i in range(tp):
            for j in range(tp):
                if q[i] > q[j]:
                    assert g.sizes[i] <= g.sizes[j]

    def test_uniform_chi_is_equal(self):
        assert geometry_from_chi([3.0] * 4, 32, 8).is_equal


class TestParseArg:
    def test_none_forms(self):
        assert parse_geometry_arg(None, 4) is None
        assert parse_geometry_arg("", 4) is None
        assert parse_geometry_arg("none", 4) is None

    def test_explicit_counts(self):
        assert parse_geometry_arg("12,12,4,4", 4) == (12, 12, 4, 4)

    def test_wrong_rank_count(self):
        with pytest.raises(ValueError):
            parse_geometry_arg("12,20", 4)


# ---------------------------------------------------------------------------
# padded param expansion
# ---------------------------------------------------------------------------


class TestParamExpansion:
    def _params(self, d=6, width=256, layers=2):
        rng = np.random.default_rng(7)
        return {"stack": {"scan": {"ffn": {
            "w_up": rng.standard_normal((layers, d, width)),
            "w_gate": rng.standard_normal((layers, d, width)),
            "w_down": rng.standard_normal((layers, width, d)),
        }}}}

    @given(data=st.data())
    @settings(max_examples=20, deadline=None)
    def test_roundtrip_exact(self, data):
        tp = data.draw(st.sampled_from([2, 4]))
        chis = [data.draw(st.floats(1.0, 4.0)) for _ in range(tp)]
        g = geometry_from_chi(chis, 32, 8)
        p = self._params()
        q = geom.restrict_ffn_params(geom.expand_ffn_params(p, g), g)
        for k in ("w_up", "w_gate", "w_down"):
            np.testing.assert_array_equal(
                q["stack"]["scan"]["ffn"][k], p["stack"]["scan"]["ffn"][k])

    def test_padding_is_zero_and_real_blocks_land_in_rank_slices(self):
        g = ShardGeometry(sizes=(2, 14, 8, 8), block=8)
        p = self._params()
        e = geom.expand_ffn_params(p, g)["stack"]["scan"]["ffn"]
        wu = e["w_up"]
        assert wu.shape[-1] == g.padded_width
        loc = g.max_blocks * g.block
        for r, (L, off) in enumerate(zip(g.sizes, g.offsets)):
            sl = wu[..., r * loc:(r + 1) * loc]
            np.testing.assert_array_equal(
                sl[..., :L * g.block],
                p["stack"]["scan"]["ffn"]["w_up"][
                    ..., off * g.block:(off + L) * g.block])
            assert not sl[..., L * g.block:].any()
        wd = e["w_down"]
        assert not wd[:, 2 * g.block:loc, :].any()   # rank 0 pad rows zero

    def test_no_ffn_pair_raises(self):
        with pytest.raises(ValueError):
            geom.expand_ffn_params({"w": np.zeros((4, 4))},
                                   ShardGeometry(sizes=(1, 3), block=8))


# ---------------------------------------------------------------------------
# plan-layer composition
# ---------------------------------------------------------------------------


class TestPlanStaticGeometry:
    def test_equal_geometry_normalizes_to_baseline_signature(self):
        base = PlanStatic(tp_size=4, block_size=8)
        geo = PlanStatic(tp_size=4, block_size=8, geometry=(8, 8, 8, 8))
        assert geo.canonical().geometry == ()
        assert geo.signature_str() == base.signature_str()

    def test_uneven_geometry_tags_signature(self):
        a = PlanStatic(tp_size=4, block_size=8, geometry=(10, 10, 6, 6))
        b = PlanStatic(tp_size=4, block_size=8, geometry=(6, 6, 10, 10))
        assert "geo[10,10,6,6]" in a.signature_str()
        assert a.signature_str() != b.signature_str()

    def test_geometry_rank_mismatch_raises(self):
        with pytest.raises(ValueError):
            PlanStatic(tp_size=4, block_size=8, geometry=(10, 22))


class TestPerRankPriGeometry:
    def test_identity_rows_real_then_padding(self):
        from repro.control.scopes import per_rank_pri
        sizes = (4, 10, 9, 9)
        rows = per_rank_pri(np.arange(32), 4, 10, geometry=sizes)
        for r, L in enumerate(sizes):
            assert list(rows[r][:L]) == list(range(L))      # real, keep-first
            assert list(rows[r][L:]) == list(range(L, 10))  # padding last

    def test_missing_block_raises(self):
        from repro.control.scopes import per_rank_pri
        with pytest.raises(ValueError):
            per_rank_pri(np.arange(31), 4, 10, geometry=(4, 10, 9, 9))


class TestResidualController:
    """χ-seeded static geometry absorbs a persistent straggler: the
    controller, planning RELATIVE to the geometry, sees no residual."""

    def _controller(self, workloads):
        from repro.config import WorkloadControlConfig
        from repro.core.controller import SemiController
        from repro.core.hetero import IterationModel
        wc = WorkloadControlConfig(enabled=True, mode="semi", block_size=8,
                                   max_migration_sources=3)
        model = IterationModel(matmul_time=1.0, other_time=0.1)
        return SemiController(wc, len(workloads), model,
                              int(round(float(np.mean(workloads)))),
                              workloads=np.asarray(workloads, np.float64))

    def test_absorbed_straggler_plans_nothing(self):
        chis = np.array([2.0, 1.0, 1.0, 1.0])
        g = geometry_from_chi(chis, 32, 8)          # (5, 9, 9, 9)
        ctl = self._controller(g.sizes)
        base = np.asarray(g.sizes) / np.mean(g.sizes)
        times = 1.0 * base * chis + 0.1             # residual-only view
        plan, report = ctl.plan(times)
        assert not report.stragglers
        assert plan.static.mig_sheds == ()
        assert int(plan.dynamic.bucket_by_rank.max()) == 0

    def test_unabsorbed_residual_still_mitigated(self):
        # geometry sized for χ=2 but the rank actually runs at χ=4:
        # the residual (≈2×) must still be detected and mitigated
        g = geometry_from_chi([2.0, 1.0, 1.0, 1.0], 32, 8)
        ctl = self._controller(g.sizes)
        chis = np.array([4.0, 1.0, 1.0, 1.0])
        base = np.asarray(g.sizes) / np.mean(g.sizes)
        plan, report = ctl.plan(1.0 * base * chis + 0.1)
        assert 0 in report.stragglers
        assert plan.static.geometry == g.sizes
        # sheds stay inside the smallest rank's real blocks
        assert all(m < min(g.sizes) for m in plan.static.mig_sheds)


# ---------------------------------------------------------------------------
# numerical contracts (subprocess, forced host devices)
# ---------------------------------------------------------------------------

GEO_PREAMBLE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.layers.tp_linear import ControlContext, controlled_ffn
from repro.core.workload import PlanStatic
from repro.core.geometry import ShardGeometry
from repro.control.scopes import per_rank_pri
from repro.core import geometry as geom

e, B, S, d, block = 4, 2, 8, 16, 8
geo = ShardGeometry(sizes=GEO_SIZES, block=block)
H = geo.width                       # canonical FFN width
Hp = geo.padded_width
nb_loc = geo.max_blocks
mesh = Mesh(np.array(jax.devices()[:e]).reshape(1, e), ("data", "model"))
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
wg = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wu = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wd = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
act = jax.nn.silu
ref = (act(x @ wg) * (x @ wu)) @ wd
pp = geom.expand_ffn_params(
    {"w_up": np.asarray(wu), "w_gate": np.asarray(wg),
     "w_down": np.asarray(wd)}, geo)
wup, wgp, wdp = (jnp.asarray(pp["w_up"]), jnp.asarray(pp["w_gate"]),
                 jnp.asarray(pp["w_down"]))
buckets = (0.0, 0.25, 0.5)

def make_ctx(m, bucket_vec, src, sizes=None):
    st = PlanStatic(buckets=buckets, block_size=block, mig_blocks=m,
                    tp_size=e, geometry=sizes or ())
    pri = jnp.asarray(per_rank_pri(np.arange(e * nb_loc), e, nb_loc,
                                   geometry=sizes))
    return ControlContext(mesh=mesh, axis="model", static=st,
        bucket_by_rank=jnp.array(bucket_vec, jnp.int32),
        mig_src=jnp.array(src, jnp.int32), pri={"ffn": pri})
"""


def geo_py(sizes, body):
    return GEO_PREAMBLE.replace("GEO_SIZES", repr(tuple(sizes))) + body


class TestEqualGeometryBitMatch:
    def test_forward_and_grads_bit_identical(self):
        """geometry=(L,L,L,L) must trace the SAME program as no geometry:
        outputs and grads are bit-equal, not just close."""
        run_py(geo_py((8, 8, 8, 8), """
assert Hp == H
ctx_eq = make_ctx(2, [0, 2, 0, 0], 1, sizes=(8, 8, 8, 8))
ctx_no = make_ctx(2, [0, 2, 0, 0], 1, sizes=None)
def loss(ctx, wu_, wd_, wg_):
    return jnp.sum(controlled_ffn(x, wu_, wd_, ctx, "ffn", act,
                                  w_gate=wg_)**2)
for ctx in (ctx_eq, ctx_no):
    assert ctx.static.canonical().geometry == ()
y_eq = controlled_ffn(x, wu, wd, ctx_eq, "ffn", act, w_gate=wg)
y_no = controlled_ffn(x, wu, wd, ctx_no, "ffn", act, w_gate=wg)
assert np.array_equal(np.asarray(y_eq), np.asarray(y_no))
g_eq = jax.grad(loss, (1, 2, 3))(ctx_eq, wu, wd, wg)
g_no = jax.grad(loss, (1, 2, 3))(ctx_no, wu, wd, wg)
for a, b in zip(g_eq, g_no):
    assert np.array_equal(np.asarray(a), np.asarray(b))
print("ok")
"""), devices=4)


class TestUnevenGeometryOracle:
    SIZES = (2, 6, 4, 4)          # min-slice rank 0, canonical H = 128

    def test_neutral_matches_dense_oracle(self):
        run_py(geo_py(self.SIZES, """
ctx = make_ctx(0, [0]*e, -1, sizes=geo.sizes)
y = controlled_ffn(x, wup, wdp, ctx, "ffn", act, w_gate=wgp)
assert np.allclose(y, ref, atol=1e-4), np.abs(np.array(y)-ref).max()
print("ok")
"""), devices=4)

    def test_resize_matches_masked_oracle_in_canonical_space(self):
        run_py(geo_py(self.SIZES, """
# rank 1 (6 real blocks) resizes at gamma=0.5: keep count comes from
# the SAME helper the branch tables use, sized to ITS real blocks
from repro.core.workload import keep_blocks_for_bucket
ctx = make_ctx(0, [0, 2, 0, 0], -1, sizes=geo.sizes)
y = controlled_ffn(x, wup, wdp, ctx, "ffn", act, w_gate=wgp)
kc = keep_blocks_for_bucket(0.5, geo.sizes[1])
mask = np.ones(geo.total_blocks, bool)
mask[geo.offsets[1] + kc:geo.offsets[1] + geo.sizes[1]] = False
ref_p = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
assert np.allclose(y, ref_p, atol=1e-4), np.abs(np.array(y)-ref_p).max()
print("ok")
"""), devices=4)

    def test_migration_lossless_fwd_and_bwd(self):
        """Migration from the min-slice rank (1 of its 2 real blocks)
        changes nothing: forward and canonical-space grads match dense."""
        run_py(geo_py(self.SIZES, """
ctx = make_ctx(1, [0]*e, 0, sizes=geo.sizes)
y = controlled_ffn(x, wup, wdp, ctx, "ffn", act, w_gate=wgp)
assert np.allclose(y, ref, atol=1e-4)
def loss(wu_, wd_, wg_):
    return jnp.sum(controlled_ffn(x, wu_, wd_, ctx, "ffn", act,
                                  w_gate=wg_)**2)
gu, gdn, gg = jax.grad(loss, (0, 1, 2))(wup, wdp, wgp)
canon = geom.restrict_ffn_params(
    {"w_up": np.asarray(gu), "w_gate": np.asarray(gg),
     "w_down": np.asarray(gdn)}, geo)
gr = jax.grad(lambda wu_, wd_, wg_: jnp.sum(
    (((act(x@wg_))*(x@wu_))@wd_)**2), (0, 1, 2))(wu, wd, wg)
for a, b in ((canon["w_up"], gr[0]), (canon["w_down"], gr[1]),
             (canon["w_gate"], gr[2])):
    assert np.allclose(a, np.asarray(b), atol=1e-3), \
        np.abs(np.asarray(a) - np.asarray(b)).max()
print("ok")
"""), devices=4)


class TestServeTokenExact:
    def test_uneven_geometry_lossless_semi_is_token_exact(self):
        """Serve decode under an uneven geometry + lossless β-policy
        emits the SAME tokens as the same-geometry dense engine."""
        run_py("""
import numpy as np
from repro.control import ControlConfig
from repro.launch.serve import Request, ServeEngine

def run(mode):
    cc = ControlConfig(mode=mode, hetero_kind="static", chi=3.0,
                       geometry=(40, 24))
    eng = ServeEngine("yi-6b", num_slots=2, max_len=10, tp=2, control=cc)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, eng.cfg.vocab_size,
                                        (4,)).astype(np.int32),
                    max_new_tokens=5, arrival_step=i * 2)
            for i in range(3)]
    comps = eng.run(reqs)
    eng.close()
    return {c.uid: c.tokens.tolist() for c in comps}

assert run("off") == run("semi")
print("ok")
""", devices=2)


# ---------------------------------------------------------------------------
# config collapse + deprecation shims (satellites)
# ---------------------------------------------------------------------------


class TestControlConfigShims:
    def test_to_workload_matches_legacy_serve_mapping(self):
        from repro.config import WorkloadControlConfig
        from repro.control import ControlConfig
        c = ControlConfig(mode="semi", block_size=8, max_sources=2,
                          beta_policy="lossless", use_kernel=True,
                          times="measured")
        legacy = WorkloadControlConfig(
            enabled=True, mode="semi", block_size=8,
            max_migration_sources=2, beta_policy="lossless",
            use_kernel=True, times="measured")
        assert c.to_workload() == legacy

    def test_to_workload_trainer_overrides(self):
        from repro.control import ControlConfig
        wc = ControlConfig(mode="off", beta_policy="eq2",
                           shed_cap=2).to_workload(
            enabled=True, migration_sources=0)
        assert wc.enabled and wc.mode == "zero"
        assert wc.max_migration_sources == 0
        assert wc.migration_shed_cap == 2

    def test_serve_control_config_warns(self):
        from repro.launch.serve import ServeControlConfig
        with pytest.warns(DeprecationWarning, match="ControlConfig"):
            c = ServeControlConfig(mode="zero")
        assert c.mode == "zero"

    def test_bad_mode_rejected(self):
        from repro.control import ControlConfig
        with pytest.raises(ValueError):
            ControlConfig(mode="resize")


class TestStepsAliasShim:
    def test_deprecated_reexports_warn_and_resolve(self):
        import importlib
        steps = importlib.import_module("repro.launch.steps")
        from repro.control import scopes as scopes_lib
        with pytest.warns(DeprecationWarning, match="repro.control.scopes"):
            fn = steps.per_rank_pri
        assert fn is scopes_lib.per_rank_pri
        with pytest.warns(DeprecationWarning):
            assert steps.SCOPE_LAYOUT is scopes_lib.SCOPE_LAYOUT

    def test_unknown_attribute_still_raises(self):
        from repro.launch import steps
        with pytest.raises(AttributeError):
            steps.definitely_not_here


class TestInterpretCache:
    def test_cached_resolution_and_reset(self):
        import os
        from repro.kernels import ops
        old = os.environ.get("REPRO_PALLAS_INTERPRET")
        try:
            ops.reset_interpret_cache()
            os.environ["REPRO_PALLAS_INTERPRET"] = "1"
            ops.reset_interpret_cache()
            assert ops.interpret_mode() is True
            # cached: flipping the env WITHOUT reset does not change it
            os.environ["REPRO_PALLAS_INTERPRET"] = "0"
            assert ops.interpret_mode() is True
            ops.reset_interpret_cache()
            assert ops.interpret_mode() is False
            # the live module override still wins over the cache
            ops.INTERPRET = True
            assert ops.interpret_mode() is True
        finally:
            ops.INTERPRET = None
            if old is None:
                os.environ.pop("REPRO_PALLAS_INTERPRET", None)
            else:
                os.environ["REPRO_PALLAS_INTERPRET"] = old
            ops.reset_interpret_cache()
