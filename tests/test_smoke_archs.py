"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward/train step on CPU; output shapes and
finiteness asserted. The FULL configs are exercised only via the dry-run."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.config import INPUT_SHAPES, get_config, list_configs, smoke_variant
from repro.models import get_api

ASSIGNED = [
    "qwen2-vl-7b", "recurrentgemma-2b", "deepseek-7b", "deepseek-v2-lite-16b",
    "mixtral-8x7b", "falcon-mamba-7b", "yi-6b", "granite-3-8b",
    "whisper-small", "qwen2.5-32b",
]


def make_smoke_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.num_classes:
        return {"patches": jnp.asarray(
                    rng.standard_normal((B, cfg.frontend.num_tokens - 1, 48)),
                    jnp.float32),
                "labels": jnp.asarray(rng.integers(0, cfg.num_classes, B),
                                      jnp.int32)}
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.encdec is not None:
        b["frame_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.encdec.encoder_seq_len, cfg.d_model))
            * 0.02, jnp.float32)
    elif cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend.num_tokens, cfg.d_model))
            * 0.02, jnp.float32)
    return b


@pytest.mark.parametrize("arch", ASSIGNED)
def test_assigned_config_exists_with_exact_dims(arch):
    cfg = get_config(arch)
    assert cfg.source, "every config must cite its source"
    expected = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    api = get_api(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    batch = make_smoke_batch(cfg)

    loss, metrics = api.loss_fn(params, cfg, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"

    # one SGD-flavored step must change params and keep loss finite
    grads = jax.grad(lambda p: api.loss_fn(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), \
        f"{arch}: non-finite grads"
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                              params, grads)
    loss2, _ = api.loss_fn(new_params, cfg, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if a != "whisper-small"])
def test_smoke_decode_step(arch):
    cfg = smoke_variant(get_config(arch))
    api = get_api(cfg)
    if not api.has_decode:
        pytest.skip("no decode for this family")
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    cache = api.init_cache(cfg, B, S)
    tokens = jnp.zeros((B,), jnp.int32)
    cur = jnp.full((B,), 3, jnp.int32)
    logits, new_cache = api.decode_step(params, cfg, cache, tokens, cur)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode"


def test_whisper_decode_step():
    cfg = smoke_variant(get_config("whisper-small"))
    api = get_api(cfg)
    params, _ = api.init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    rng = np.random.default_rng(0)
    enc = api.encode(params, cfg, jnp.asarray(
        rng.standard_normal((B, cfg.encdec.encoder_seq_len, cfg.d_model))
        * 0.02, jnp.float32))
    cache = api.init_cache(cfg, B, S)
    logits, _ = api.decode_step(params, cfg, cache,
                                jnp.zeros((B,), jnp.int32),
                                jnp.full((B,), 2, jnp.int32), enc)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_matches_prefill_logits():
    """Decode with a prefilled cache must reproduce the teacher-forced
    forward's next-token logits (KV-cache correctness)."""
    cfg = smoke_variant(get_config("yi-6b"))
    api = get_api(cfg)
    params, _ = api.init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    B, S = 2, 12
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)

    # full forward logits at position S-1
    logits_full, _, _ = api.forward(params, cfg, jnp.asarray(toks))
    want = np.asarray(logits_full[:, -1])

    # decode token-by-token
    cache = api.init_cache(cfg, B, S)
    out = None
    for t in range(S):
        out, cache = api.decode_step(
            params, cfg, cache, jnp.asarray(toks[:, t]),
            jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), want, atol=2e-2, rtol=2e-2)


def test_mamba_decode_matches_forward():
    """State-based decode must match the chunked-scan forward (SSM path)."""
    cfg = smoke_variant(get_config("falcon-mamba-7b"))
    api = get_api(cfg)
    params, _ = api.init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    B, S = 1, 10
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    logits_full, _, _ = api.forward(params, cfg, jnp.asarray(toks))
    want = np.asarray(logits_full[:, -1])

    cache = api.init_cache(cfg, B, S)
    out = None
    for t in range(S):
        out, cache = api.decode_step(
            params, cfg, cache, jnp.asarray(toks[:, t]),
            jnp.full((B,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), want, atol=2e-2, rtol=2e-2)
