"""Kernel ↔ controlled-path integration: the Pallas block-pruned matmul
must be a drop-in replacement inside switched_matmul (fwd + bwd)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import resizing


def test_switched_matmul_kernel_path_matches_xla():
    rng = np.random.default_rng(0)
    K, N, B = 256, 128, 32
    x = jnp.asarray(rng.standard_normal((16, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    pri = jnp.asarray(rng.permutation(K // B).astype(np.int32))
    buckets = (0.0, 0.5)
    for bucket in (0, 1):
        y_xla = resizing.switched_matmul(x, w, pri, jnp.array(bucket),
                                         buckets=buckets, block=B,
                                         use_kernel=False)
        y_k = resizing.switched_matmul(x, w, pri, jnp.array(bucket),
                                       buckets=buckets, block=B,
                                       use_kernel=True)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_xla),
                                   atol=1e-3, rtol=1e-3)


def test_switched_matmul_kernel_gradients():
    rng = np.random.default_rng(1)
    K, N, B = 128, 64, 32
    x = jnp.asarray(rng.standard_normal((8, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    pri = jnp.asarray(rng.permutation(K // B).astype(np.int32))

    def loss(w_, kernel):
        y = resizing.switched_matmul(x, w_, pri, jnp.array(1),
                                     buckets=(0.0, 0.5), block=B,
                                     use_kernel=kernel)
        return jnp.sum(y ** 2)

    g_xla = jax.grad(lambda w_: loss(w_, False))(w)
    g_k = jax.grad(lambda w_: loss(w_, True))(w)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_xla),
                               atol=1e-2, rtol=1e-2)
