"""HLO inspector unit tests (string-level, no compile), now against the
canonical parser in repro.analysis.hlo (the launch/hlo_inspect and
launch/hlo_analysis modules are deprecation shims)."""
from repro.analysis.hlo import (collective_histogram, collective_payload_bytes,
                                find_redundant_collectives, parse_collectives,
                                reshape_churn)

FAKE_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag1 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ag2 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  %t = f32[256,128]{0,1} transpose(%p0), dimensions={1,0}
  %r = f32[32768]{0} reshape(%p0)
  ROOT %out = f32[128,256]{1,0} copy(%ar)
}
"""


def test_histogram_counts_and_bytes():
    rows = collective_histogram(FAKE_HLO)
    kinds = {r[0]: (r[2], r[3]) for r in rows}
    assert kinds["all-gather"][0] == 2
    assert kinds["all-gather"][1] == 2 * 128 * 4096 * 4
    assert kinds["all-reduce"][0] == 1


def test_redundant_detection():
    red = find_redundant_collectives(FAKE_HLO)
    assert len(red) == 1
    assert red[0][0] == "all-gather" and red[0][2] == 2


def test_reshape_churn():
    churn = reshape_churn(FAKE_HLO)
    assert churn["transpose"] == 1
    assert churn["reshape"] == 1
    assert churn["copy"] == 1


# ---- ISSUE 10 satellite: tuple-shaped collective outputs & -done lines ----

# async all-reduce in the canonical tuple form: (operand alias, result).
# The payload crosses the wire ONCE — byte accounting must not double it.
TUPLE_ASYNC_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ars = (f32[128,256]{1,0}, f32[128,256]{1,0}) all-reduce-start(%p0), to_apply=%add
  %ard = f32[128,256]{1,0} all-reduce-done(%ars)
  ROOT %out = f32[128,256]{1,0} copy(%ard)
}
"""

# grouped (fused multi-operand) SYNC all-reduce: every element is a
# distinct payload and every one counts.
GROUPED_SYNC_HLO = """
HloModule jit_step
ENTRY main {
  %a = f32[8,8]{1,0} parameter(0)
  %b = f32[4,4]{1,0} parameter(1)
  %g = (f32[8,8]{1,0}, f32[4,4]{1,0}) all-reduce(%a, %b), to_apply=%add
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%g), index=0
}
"""


def test_async_tuple_start_counted_once():
    got = parse_collectives(TUPLE_ASYNC_HLO)
    assert got["all-reduce"] == 128 * 256 * 4          # NOT 2x
    assert got["total"] == 128 * 256 * 4


def test_done_lines_contribute_zero_even_with_result_tuples():
    assert collective_payload_bytes(
        "f32[128,256]{1,0}", "all-reduce-done") == 0
    # a -done whose result is itself a tuple (grouped async) still
    # contributes nothing — the pair was priced at -start
    assert collective_payload_bytes(
        "(f32[64,56]{1,0}, f32[56,64]{1,0})", "all-reduce-done") == 0


def test_grouped_sync_tuple_sums_all_elements():
    got = parse_collectives(GROUPED_SYNC_HLO)
    assert got["all-reduce"] == (8 * 8 + 4 * 4) * 4


def test_asymmetric_start_tuple_counts_every_element():
    # halves don't mirror -> not the canonical (operand, result) aliasing
    # form; count everything rather than guess
    assert collective_payload_bytes(
        "(f32[8,8]{1,0}, f32[4,4]{1,0})", "all-reduce-start") \
        == (8 * 8 + 4 * 4) * 4


# ---- ISSUE 7: collective-overlap report & occupancy-aware decode bytes ----

ASYNC_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ars = f32[128,256]{1,0} all-reduce-start(%p0), to_apply=%add
  %m1 = f32[128,256]{1,0} multiply(%p0, %p0)
  %m2 = f32[128,256]{1,0} add(%m1, %p0)
  %ard = f32[128,256]{1,0} all-reduce-done(%ars)
  %ags = f32[128,512]{1,0} all-gather-start(%p0), dimensions={1}
  %agd = f32[128,512]{1,0} all-gather-done(%ags)
  %sync = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[128,256]{1,0} copy(%ard)
}
"""


def test_collective_overlap_report():
    from repro.analysis.hlo import collective_overlap_report
    rep = collective_overlap_report(ASYNC_HLO)
    assert rep["async_pairs"] == 2
    assert rep["sync_collectives"] == 1
    # the all-reduce pair hides 2 compute ops; the all-gather pair and
    # the sync collective hide 0
    by_overlap = sorted(p["intervening_compute_ops"] for p in rep["pairs"])
    assert by_overlap == [0, 0, 2]
    # overlapped = only the pair with compute in its window
    assert rep["overlapped_bytes"] == 128 * 256 * 4
    assert 0.0 < rep["fraction_overlapped"] < 1.0


def test_decode_bytes_scale_with_occupancy():
    from repro.analysis.hlo import analytic_step_bytes
    from repro.config import INPUT_SHAPES, get_config
    from repro.launch.specs import effective_model_cfg
    shape = next(s for s in INPUT_SHAPES.values() if s.kind == "decode")
    cfg = effective_model_cfg(get_config("yi-6b"), shape)
    full = analytic_step_bytes(cfg, shape, decode_occupancy=1.0)
    half = analytic_step_bytes(cfg, shape, decode_occupancy=0.5)
    params = float(cfg.param_count()) * 2.0
    # cache term halves exactly; param traffic is occupancy-independent
    assert abs((full - params) * 0.5 - (half - params)) < 1e-6 * full
    # default argument reproduces the old full-rows bound
    assert analytic_step_bytes(cfg, shape) == full
