"""HLO inspector unit tests (string-level, no compile)."""
from repro.launch.hlo_inspect import (collective_histogram,
                                      find_redundant_collectives,
                                      reshape_churn)

FAKE_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag1 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ag2 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  %t = f32[256,128]{0,1} transpose(%p0), dimensions={1,0}
  %r = f32[32768]{0} reshape(%p0)
  ROOT %out = f32[128,256]{1,0} copy(%ar)
}
"""


def test_histogram_counts_and_bytes():
    rows = collective_histogram(FAKE_HLO)
    kinds = {r[0]: (r[2], r[3]) for r in rows}
    assert kinds["all-gather"][0] == 2
    assert kinds["all-gather"][1] == 2 * 128 * 4096 * 4
    assert kinds["all-reduce"][0] == 1


def test_redundant_detection():
    red = find_redundant_collectives(FAKE_HLO)
    assert len(red) == 1
    assert red[0][0] == "all-gather" and red[0][2] == 2


def test_reshape_churn():
    churn = reshape_churn(FAKE_HLO)
    assert churn["transpose"] == 1
    assert churn["reshape"] == 1
    assert churn["copy"] == 1


# ---- ISSUE 7: collective-overlap report & occupancy-aware decode bytes ----

ASYNC_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ars = f32[128,256]{1,0} all-reduce-start(%p0), to_apply=%add
  %m1 = f32[128,256]{1,0} multiply(%p0, %p0)
  %m2 = f32[128,256]{1,0} add(%m1, %p0)
  %ard = f32[128,256]{1,0} all-reduce-done(%ars)
  %ags = f32[128,512]{1,0} all-gather-start(%p0), dimensions={1}
  %agd = f32[128,512]{1,0} all-gather-done(%ags)
  %sync = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[128,256]{1,0} copy(%ard)
}
"""


def test_collective_overlap_report():
    from repro.launch.hlo_analysis import collective_overlap_report
    rep = collective_overlap_report(ASYNC_HLO)
    assert rep["async_pairs"] == 2
    assert rep["sync_collectives"] == 1
    # the all-reduce pair hides 2 compute ops; the all-gather pair and
    # the sync collective hide 0
    by_overlap = sorted(p["intervening_compute_ops"] for p in rep["pairs"])
    assert by_overlap == [0, 0, 2]
    # overlapped = only the pair with compute in its window
    assert rep["overlapped_bytes"] == 128 * 256 * 4
    assert 0.0 < rep["fraction_overlapped"] < 1.0


def test_decode_bytes_scale_with_occupancy():
    from repro.config import INPUT_SHAPES, get_config
    from repro.launch.hlo_analysis import analytic_step_bytes
    from repro.launch.specs import effective_model_cfg
    shape = next(s for s in INPUT_SHAPES.values() if s.kind == "decode")
    cfg = effective_model_cfg(get_config("yi-6b"), shape)
    full = analytic_step_bytes(cfg, shape, decode_occupancy=1.0)
    half = analytic_step_bytes(cfg, shape, decode_occupancy=0.5)
    params = float(cfg.param_count()) * 2.0
    # cache term halves exactly; param traffic is occupancy-independent
    assert abs((full - params) * 0.5 - (half - params)) < 1e-6 * full
    # default argument reproduces the old full-rows bound
    assert analytic_step_bytes(cfg, shape) == full
