"""HLO inspector unit tests (string-level, no compile)."""
from repro.launch.hlo_inspect import (collective_histogram,
                                      find_redundant_collectives,
                                      reshape_churn)

FAKE_HLO = """
HloModule jit_step
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag1 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ag2 = f32[128,4096]{1,0} all-gather(%p0), dimensions={1}
  %ar = f32[128,256]{1,0} all-reduce(%p0), to_apply=%add
  %t = f32[256,128]{0,1} transpose(%p0), dimensions={1,0}
  %r = f32[32768]{0} reshape(%p0)
  ROOT %out = f32[128,256]{1,0} copy(%ar)
}
"""


def test_histogram_counts_and_bytes():
    rows = collective_histogram(FAKE_HLO)
    kinds = {r[0]: (r[2], r[3]) for r in rows}
    assert kinds["all-gather"][0] == 2
    assert kinds["all-gather"][1] == 2 * 128 * 4096 * 4
    assert kinds["all-reduce"][0] == 1


def test_redundant_detection():
    red = find_redundant_collectives(FAKE_HLO)
    assert len(red) == 1
    assert red[0][0] == "all-gather" and red[0][2] == 2


def test_reshape_churn():
    churn = reshape_churn(FAKE_HLO)
    assert churn["transpose"] == 1
    assert churn["reshape"] == 1
    assert churn["copy"] == 1
