"""Scenario regression tests: the controller driven through hundreds of
simulated steps must produce STABLE plans (no flip-flop on measurement
noise) and a bounded signature set that the PlanCompileCache compiles at
most once each.
"""
import numpy as np
import pytest

from repro.config import WorkloadControlConfig
from repro.core.controller import SemiController
from repro.core.hetero import HeteroSchedule, IterationModel
from repro.core.workload import PlanCompileCache


def drive(kind, *, mode="semi", steps=200, noise=0.05, tp=8, chi=4.0,
          period=20, seed=0):
    """Run `steps` iterations of schedule -> noisy times -> controller ->
    compile cache; returns (signatures, compiled-signature list, cache)."""
    cfg = WorkloadControlConfig(enabled=True, mode=mode, block_size=8,
                                max_migration_sources=3)
    model = IterationModel(matmul_time=1.0, other_time=0.15)
    ctl = SemiController(cfg, tp, model, num_blocks=64, seed=seed)
    sched = HeteroSchedule(num_ranks=tp, kind=kind, chis=(chi,),
                           period=period, contention_chi=chi,
                           contention_p=0.15, seed=seed)
    cache = PlanCompileCache(lambda s: object())
    compiled = []
    cache.on_compile = compiled.append
    rng = np.random.default_rng(seed + 99)
    sigs, plans = [], []
    for t in range(steps):
        times = model.times(sched.chi(t), np.ones(tp))
        times = times * (1.0 + rng.uniform(-noise, noise, tp))
        plan, _ = ctl.plan(times)
        sig = plan.static.signature()
        cache.get(sig)
        sigs.append(sig)
        plans.append(plan)
    return sigs, plans, compiled, cache


class TestScenarioStability:
    def test_noise_only_no_flip_flop(self):
        """±5% multiplicative time noise on a homogeneous group is NOT
        heterogeneity: the deadband keeps every plan neutral, so 200
        steps produce exactly one signature and zero churn."""
        sigs, plans, compiled, cache = drive("none", steps=200, noise=0.05)
        assert all(p.is_neutral() for p in plans)
        assert len(set(sigs)) == 1
        assert cache.compile_count == 1
        assert cache.hit_count == 199

    def test_round_robin_bounded_churn(self):
        """A rotating straggler retargets via the DYNAMIC mig_src vector;
        the static signature stays constant under ±5% noise, so the whole
        200-step run compiles at most two executables and plan changes
        stay bounded by the schedule, not the noise."""
        sigs, plans, compiled, cache = drive("round_robin", steps=200,
                                             noise=0.05, period=20)
        changes = sum(1 for a, b in zip(sigs, sigs[1:]) if a != b)
        assert changes <= 4                      # schedule-driven only
        assert cache.compile_count <= 2
        # noise must not leak into bucket flip-flop either: count dynamic
        # re-bucketings of NON-straggler ranks
        spurious = sum(int((np.asarray(p.dynamic.bucket_by_rank) > 0).sum() > 1)
                       for p in plans)
        assert spurious == 0

    @pytest.mark.parametrize("mode", ["semi", "zero"])
    def test_contention_compiles_each_signature_once(self, mode):
        """Random contention churns WHICH ranks straggle every step, but
        shed quantization keeps the signature set tiny and the cache
        builds each signature exactly once across the whole run."""
        sigs, plans, compiled, cache = drive("contention", mode=mode,
                                             steps=200, noise=0.05)
        distinct = set(sigs)
        assert len(distinct) <= 8                # quantized grid, bounded
        assert cache.compile_count == len(distinct)
        # at-most-once: no signature was ever built twice
        assert len(compiled) == len(set(compiled)) == cache.compile_count
        assert cache.hit_count == 200 - cache.compile_count

    def test_static_straggler_plan_converges(self):
        """A constant χ=4 straggler yields one stable non-neutral plan."""
        sigs, plans, compiled, cache = drive("static", steps=100, noise=0.05)
        assert not plans[-1].is_neutral()
        assert len(set(sigs)) == 1
        assert cache.compile_count == 1
