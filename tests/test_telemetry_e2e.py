"""End-to-end closed-loop scenario tests (DESIGN_TELEMETRY.md §4).

The acceptance claim: a 200-step contention run in MEASURED mode — the
controller fed only StragglerEstimator reconstructions of mitigated
measured times — converges to the same plan signatures as MODELED mode
(the χ-oracle), within the straggler_threshold deadband, with no extra
recompiles (compile-cache size pinned equal).

The fast tier drives the controller directly over the committed
bursty-contention fixture; the slow tier runs the REAL train driver
(`run_training`, tp=4 subprocess) in both modes on the same replayed
trace and compares histories.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config import WorkloadControlConfig
from repro.core.controller import (SemiController, decision_key,
                                   reports_agree, work_fraction)
from repro.core.hetero import IterationModel
from repro.core.workload import PlanCompileCache
from repro.telemetry import (EstimatorConfig, StragglerEstimator, TraceReader,
                             schedule_from_trace)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURE = os.path.join(ROOT, "examples", "traces", "bursty_contention.jsonl")


def drive_mode(measured: bool, mode: str = "semi", steps: int = 200):
    """Closed control loop over the replayed fixture: schedule -> (oracle
    | measurement->estimator) -> controller -> plan -> next measurement."""
    reader = TraceReader(FIXTURE)
    model = IterationModel(reader.matmul_time, reader.other_time)
    sched = schedule_from_trace(FIXTURE)
    e = reader.num_ranks
    cfg = WorkloadControlConfig(enabled=True, mode=mode, block_size=8,
                                max_migration_sources=3,
                                times="measured" if measured else "modeled")
    ctl = SemiController(cfg, e, model, num_blocks=64, seed=0)
    est = (StragglerEstimator(model, e, EstimatorConfig.from_control(cfg))
           if measured else None)
    cache = PlanCompileCache(lambda s: object())
    reports, sigs = [], []
    for t in range(steps):
        chi = sched.chi(t)
        if measured:
            times = est.full_times() if est.ready else est.nominal_times()
        else:
            times = model.times(chi, np.ones(e))
        plan, rep = ctl.plan(times)
        cache.get(plan.static.signature())
        frac = work_fraction(plan, 64)
        if measured:
            # the closed loop only ever observes the MITIGATED runtime
            est.update(model.times(chi, frac), frac)
        reports.append(rep)
        sigs.append(plan.static.signature_str())
    return reports, sigs, cache


class TestClosedLoop200:
    @pytest.mark.parametrize("mode", ["semi", "zero"])
    def test_measured_converges_to_modeled_plans(self, mode):
        rm, sm, cm = drive_mode(False, mode)
        re_, se, ce = drive_mode(True, mode)
        # same plan-signature set: the measured loop discovers exactly the
        # plans the oracle picks — no phantom signatures from estimation
        # transients
        assert set(se) == set(sm)
        # no extra recompiles: compile-cache size pinned equal
        assert ce.compile_count == cm.compile_count
        assert len(ce) == len(cm)
        # per-step decisions agree on >= 80% of steps (disagreements are
        # the 1-2 step estimation lag at each burst start/end — 16 bursts
        # in the fixture), and within the deadband everywhere they agree
        exact = sum(1 for a, b in zip(rm, re_)
                    if decision_key(a) == decision_key(b))
        band = sum(1 for a, b in zip(rm, re_) if reports_agree(a, b))
        assert exact >= 160, f"only {exact}/200 steps agree exactly"
        assert band >= exact
        # steady state: the fixture's last burst ends by step 187; in the
        # quiet tail both modes settle on the identical neutral plan
        for a, b in zip(rm[-8:], re_[-8:]):
            assert decision_key(a) == decision_key(b)

    def test_warmup_holds_plan_neutral(self):
        """Until the warmup gate opens the measured loop must not react,
        even though the fixture starts mid-burst."""
        re_, se, _ = drive_mode(True, "semi", steps=3)
        assert all(not r.stragglers for r in re_)
        assert all(s.endswith("shed[]") for s in se)


@pytest.mark.slow
class TestTrainDriverClosedLoop:
    def test_train_measured_matches_modeled_on_replay(self, tmp_path):
        """The real trainer (jitted steps, PlanCompileCache, tp=4) in both
        modes on the replayed contention fixture: same signature set,
        same number of plan-signature compiles, >= 75% per-step bucket
        agreement (tp=4 truncates the 8-rank fixture to its first 4
        ranks; the lag steps at burst edges are the only divergence)."""
        code = textwrap.dedent(f"""
            import json
            from repro.launch.train import run_training
            out = {{}}
            for times in ("modeled", "measured"):
                h = run_training("vit-1b", steps=40, tp=4, batch=4, seq=16,
                                 quiet=True, control_mode="semi",
                                 hetero_kind="trace",
                                 trace_in={FIXTURE!r},
                                 mig_blocks=8, max_sources=2, times=times)
                out[times] = {{"buckets": h["buckets"],
                              "signatures": h["signatures"],
                              "plan_compiles": h["plan_compiles"]}}
            print("RESULT" + json.dumps(out))
        """)
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
        res = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=1200)
        assert res.returncode == 0, res.stderr[-2000:]
        out = json.loads(res.stdout.split("RESULT", 1)[1])
        mod, mea = out["modeled"], out["measured"]
        assert set(mea["signatures"]) == set(mod["signatures"])
        assert mea["plan_compiles"] == mod["plan_compiles"]
        agree = sum(1 for a, b in zip(mod["buckets"], mea["buckets"])
                    if a == b)
        assert agree >= int(0.75 * len(mod["buckets"]))
