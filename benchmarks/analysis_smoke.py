"""Static-analysis smoke leg (ISSUE 10): runs the invariant linter as a
benchmark job so the smoke set exercises the same gate CI's ``analyze``
job does — ``python -m repro.analysis --check --mutate`` over the full
registered step matrix, in a subprocess with forced host devices and
Pallas interpret mode.

Reported numbers: wall time of the check, case count, and mutant
coverage (every R1–R5 mutant must FIRE). Raises — failing the bench
run — on any HEAD violation or silent mutant.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

from benchmarks.common import ROOT, csv_row, is_dry_run, save_bench_json

DEVICES = 8


def _run_cli(*flags: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["REPRO_PALLAS_INTERPRET"] = "1"
    env.pop("XLA_FLAGS", None)  # the CLI forces its own device count
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", *flags,
         "--devices", str(DEVICES), "--json"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(
            f"repro.analysis {' '.join(flags)} failed:\n"
            f"{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout)


def main():
    t0 = time.perf_counter()
    check = _run_cli("--check")["check"]
    t_check = time.perf_counter() - t0
    if check["violations"]:
        raise RuntimeError(f"HEAD violates invariants: {check['violations']}")

    t0 = time.perf_counter()
    mutate = _run_cli("--mutate")["mutate"]
    t_mutate = time.perf_counter() - t0
    silent = sorted(n for n, r in mutate.items() if not r["fired"])
    if silent:
        raise RuntimeError(f"mutants stayed silent (dead rules): {silent}")

    save_bench_json(
        "analysis_smoke",
        {"devices": DEVICES, "dry_run": is_dry_run()},
        {"cases": len(check["cases"]),
         "violations": 0,
         "mutants": len(mutate),
         "silent_mutants": 0,
         "check_s": t_check,
         "mutate_s": t_mutate})
    yield csv_row("analysis_check", t_check * 1e6,
                  f"cases={len(check['cases'])} violations=0")
    yield csv_row("analysis_mutate", t_mutate * 1e6,
                  f"mutants={len(mutate)} silent=0")


if __name__ == "__main__":
    for row in main():
        print(row)
