"""Benchmark harness — one function per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,tab1,...] [--fast]
                                            [--dry-run]

Prints ``name,us_per_call,derived`` CSV rows. JSON artifacts land in
experiments/bench/ (stable schema: {"name", "config", "metrics"});
``--dry-run`` is the CI smoke mode — tiny shapes, seconds not minutes,
covering the pruned-matmul kernel path and the multi-straggler migration
dataflow so perf regressions are visible per-PR.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


# key -> (module, slow real-training job, part of the --dry-run smoke set)
JOBS = [
    ("fig3", "benchmarks.imputation", False, False),
    ("kernel", "benchmarks.kernel_bench", False, True),
    ("roofline", "benchmarks.roofline", False, False),
    ("tab1", "benchmarks.migration_policies", False, False),
    ("fig9", "benchmarks.hetero_resizing", True, False),
    ("fig56", "benchmarks.homo_resizing", True, False),
    ("fig10", "benchmarks.single_straggler", True, False),
    ("fig11", "benchmarks.multi_straggler", False, True),
    ("serve", "benchmarks.serve_bench", False, True),
    ("cluster", "benchmarks.cluster_bench", False, True),
    ("xla_flags", "benchmarks.xla_flags_sweep", False, True),
    ("telemetry", "benchmarks.telemetry_bench", False, True),
    ("analyze", "benchmarks.analysis_smoke", False, True),
    ("ablate", "benchmarks.ablations", True, False),
]


# named job subsets for --suite (CI entry points)
SUITES = {
    "kernels": {"kernel", "xla_flags"},
    "migration": {"fig11", "tab1"},
    "serve": {"serve"},
    "cluster": {"cluster"},
    "telemetry": {"telemetry"},
    "analysis": {"analyze"},
    "smoke": {key for key, _, _, smoke in JOBS if smoke},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig56,fig9,tab1,fig10,fig11,"
                         "kernel,roofline,serve,cluster,telemetry,analyze")
    ap.add_argument("--suite", default=None, choices=sorted(SUITES),
                    help="named subset (CI): kernels | migration | serve "
                         "| cluster | telemetry | analysis | smoke")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow real-training ACC benchmarks")
    ap.add_argument("--dry-run", action="store_true",
                    help="CI smoke: tiny shapes on the smoke job subset")
    args = ap.parse_args()
    if args.dry_run:
        os.environ["REPRO_BENCH_DRY"] = "1"

    only = set(args.only.split(",")) if args.only else None
    if args.suite:
        only = SUITES[args.suite] | (only or set())

    print("name,us_per_call,derived")
    failed = []
    ran = []
    for key, module, slow, smoke in JOBS:
        if only and key not in only:
            continue
        if args.dry_run and not smoke and only is None:
            # dry-run default = smoke subset; explicit --only/--suite wins
            continue
        if args.fast and slow:
            continue
        try:
            mod = __import__(module, fromlist=["main"])
            for row in mod.main():
                print(row, flush=True)
            ran.append(key)
        except Exception as e:                              # noqa: BLE001
            failed.append((key, repr(e)))
            print(f"{key}_FAILED,0.0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)

    if args.dry_run:
        from benchmarks.common import OUT_DIR
        os.makedirs(OUT_DIR, exist_ok=True)
        with open(os.path.join(OUT_DIR, "smoke_summary.json"), "w") as f:
            json.dump({"name": "smoke_summary",
                       "config": {"dry_run": True},
                       "metrics": {"ran": ran,
                                   "failed": [k for k, _ in failed]}},
                      f, indent=1)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
