"""Benchmark harness — one function per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,tab1,...] [--fast]

Prints ``name,us_per_call,derived`` CSV rows. JSON artifacts land in
experiments/bench/.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig56,fig9,tab1,fig10,fig11,"
                         "kernel,roofline")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow real-training ACC benchmarks")
    args = ap.parse_args()

    jobs = [
        ("fig3", "benchmarks.imputation", False),
        ("kernel", "benchmarks.kernel_bench", False),
        ("roofline", "benchmarks.roofline", False),
        ("tab1", "benchmarks.migration_policies", False),
        ("fig9", "benchmarks.hetero_resizing", True),
        ("fig56", "benchmarks.homo_resizing", True),
        ("fig10", "benchmarks.single_straggler", True),
        ("fig11", "benchmarks.multi_straggler", False),
        ("ablate", "benchmarks.ablations", True),
    ]
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for key, module, slow in jobs:
        if only and key not in only:
            continue
        if args.fast and slow:
            continue
        try:
            mod = __import__(module, fromlist=["main"])
            for row in mod.main():
                print(row, flush=True)
        except Exception as e:                              # noqa: BLE001
            failed.append((key, repr(e)))
            print(f"{key}_FAILED,0.0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
