"""Multi-replica cluster serving benchmark: routing policies under
persistent per-replica skew, with mid-run drain + warm-spare promotion.

Replays the committed ``examples/traces/replica_skew.jsonl`` fixture —
3 replicas x 4 TP ranks, replica 1 carrying a PERSISTENT χ=4 rank,
replica 2 periodic transient bursts, plus the bursty request-arrival
trace shipped in the fixture header — through a
:class:`repro.cluster.ReplicaManager` once per routing policy:

* ``round_robin`` — load-blind rotation (the naive baseline);
* ``least_queue`` — queue-depth greedy (χ-blind: it only avoids the slow
  replica after requests have already piled up on it);
* ``chi_aware``   — the headline policy: prices each request against
  every replica's PLAN-ADJUSTED residual capacity
  (``ControlPlane.capacity``), so the outer routing loop sees exactly
  the residual slowdown the inner SEMI loop could not migrate away —
  the paper's workload control nested at cluster scope.

Every replica runs ``mode="semi"`` (nested control: the inner loop
mitigates within the replica while the router steers across replicas),
and every leg executes the SAME mid-run lifecycle event: the uncontended
replica 0 is drained at the midpoint and a warm spare (replaying the
same χ lanes) is promoted in its place — so the comparison includes the
drain/promotion machinery and the zero-drop reassignment path.

Emits stable-schema ``BENCH_cluster.json`` (trajectory point) and FAILS
unless:

* chi_aware beats round_robin on cluster p95 per-token latency AND mean
  TTFT;
* every leg completes EVERY request exactly once (zero dropped, zero
  duplicated) through the drain + promotion;
* every completion is token-exact against a single-replica UNCONTENDED
  baseline (routing/reassignment must never change a token);
* the chi_aware leg's recorded cluster trace splits back into R
  per-replica replay schedules (one-JSONL cluster replay).
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import OUT_DIR, csv_row, is_dry_run, save_bench_json
from repro.cluster import ReplicaHandle, ReplicaManager, Router
from repro.control import ControlConfig
from repro.launch.serve import Request, ServeEngine
from repro.telemetry import replica_schedules

ARCH = "yi-6b"
NUM_SLOTS = 4                   # wide enough that bursts decode together:
# occupancy-dependent attention makes steps on the contended replica
# visibly slower, which is exactly the residual the router must price
MAX_LEN = 16                    # fixture lengths: prompt 3..8 + gen 3..8
PREFILL_CHUNK = 2
FIXTURE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "examples", "traces", "replica_skew.jsonl")
POLICIES = ("round_robin", "least_queue", "chi_aware")


def load_fixture_header() -> dict:
    with open(FIXTURE) as f:
        return json.loads(f.readline())


def make_requests(arrivals, vocab: int, limit=None):
    """Materialize the fixture's arrival trace as Requests (prompt token
    CONTENT is generated sequentially, so a dry-run prefix sees the same
    prompts as the full run)."""
    rng = np.random.default_rng(np.random.SeedSequence((0xC1, 5)))
    reqs = []
    for uid, step, p, g in arrivals:
        prompt = rng.integers(0, vocab, (p,)).astype(np.int32)
        if limit is None or len(reqs) < limit:
            reqs.append(Request(uid=int(uid), prompt=prompt,
                                max_new_tokens=int(g),
                                arrival_step=int(step)))
    return reqs


def replica_factory(lane: int, W: int):
    """Engine factory for the replica replaying χ-lane block ``lane``
    of the shared fixture, running the full inner SEMI loop."""
    def build():
        control = ControlConfig(
            mode="semi", hetero_kind="trace", sim_ranks=W,
            trace_in=FIXTURE, trace_rank_offset=lane * W)
        return ServeEngine(ARCH, num_slots=NUM_SLOTS, max_len=MAX_LEN,
                           control=control, prefill_chunk=PREFILL_CHUNK,
                           trace_tag={"replica_lane": lane})
    return build


def run_baseline(reqs):
    """Single UNCONTENDED replica: the token-exactness reference (and
    the no-cluster latency floor)."""
    eng = ServeEngine(ARCH, num_slots=NUM_SLOTS, max_len=MAX_LEN,
                      control=ControlConfig(mode="off"),
                      prefill_chunk=PREFILL_CHUNK)
    comps = eng.run(reqs)
    eng.close()
    return {c.uid: c.tokens for c in comps}


def run_policy(policy: str, reqs, R: int, W: int, drain_step: int,
               record_trace=None):
    handles = [ReplicaHandle(f"r{i}", replica_factory(i, W))
               for i in range(R)]
    # warm spare replays replica 0's (uncontended) lanes: promotion is
    # capacity-neutral, so the legs compare ROUTING, not fleet size
    handles.append(ReplicaHandle("spare", replica_factory(0, W),
                                 spare=True))
    mgr = ReplicaManager(handles, Router(policy),
                         record_trace=record_trace)

    def hook(m):
        if m.cluster_step == drain_step:
            m.drain("r0")                 # promotes the spare

    comps = mgr.run(reqs, on_step=hook)
    stats = mgr.stats()
    stats["routes"] = sum(1 for e in mgr.events if e["kind"] == "route")
    stats["events"] = [e["kind"] for e in mgr.events
                       if e["kind"] != "route"]
    tokens = {c.uid: c.tokens for c in comps}
    mgr.close()
    return tokens, stats


def main() -> list:
    dry = is_dry_run()
    hdr = load_fixture_header()
    R, W = int(hdr["replicas"]), int(hdr["ranks_per_replica"])
    reqs = make_requests(hdr["arrivals"], 100,
                         limit=8 if dry else None)
    drain_step = max(4, max(r.arrival_step for r in reqs) // 2)

    baseline = run_baseline(reqs)
    want = set(baseline)
    assert want == {r.uid for r in reqs}, "baseline dropped requests"

    rows = []
    results = {}
    exact = {}
    trace_out = os.path.join(OUT_DIR, "traces", "cluster_chi_aware.jsonl")
    for policy in POLICIES:
        tokens, stats = run_policy(
            policy, reqs, R, W, drain_step,
            record_trace=trace_out if policy == "chi_aware" else None)
        results[policy] = stats
        exact[policy] = (set(tokens) == want and all(
            np.array_equal(tokens[uid], baseline[uid]) for uid in want))
        rows.append(csv_row(
            f"cluster_{policy}", stats["p95_ms"] * 1e3,
            f"p95={stats['p95_ms']:.3f}ms,ttft={stats['ttft_mean_ms']:.3f}"
            f"ms,tok_s={stats['tok_per_s']:.1f},"
            f"reassigned={stats['reassigned']},"
            f"dupes={stats['duplicates']},exact={exact[policy]}"))

    rr, cq = results["round_robin"], results["chi_aware"]
    p95_speedup = rr["p95_ms"] / max(cq["p95_ms"], 1e-12)
    ttft_speedup = rr["ttft_mean_ms"] / max(cq["ttft_mean_ms"], 1e-12)
    rows.append(csv_row(
        "cluster_speedup", 0.0,
        f"p95_speedup={p95_speedup:.2f}x,ttft_speedup={ttft_speedup:.2f}x,"
        f"vs=round_robin,replicas={R}x{W}"))

    n_sched = len(replica_schedules(trace_out))

    config = {"arch": ARCH, "replicas": R, "ranks_per_replica": W,
              "num_slots": NUM_SLOTS, "max_len": MAX_LEN,
              "prefill_chunk": PREFILL_CHUNK,
              "n_requests": len(reqs), "drain_step": drain_step,
              "fixture": os.path.basename(FIXTURE), "dry_run": dry}
    metrics = {policy: results[policy] for policy in POLICIES}
    metrics.update({
        "token_exact": {p: bool(exact[p]) for p in POLICIES},
        "p95_speedup": p95_speedup, "ttft_speedup": ttft_speedup,
        "replayable_schedules": n_sched})
    save_bench_json("cluster", config, metrics, trajectory=True)

    # regression gates — the cluster acceptance criteria
    for policy in POLICIES:
        s = results[policy]
        if s["requests"] != len(reqs) or s["duplicates"]:
            raise RuntimeError(
                f"cluster bench regression: {policy} completed "
                f"{s['requests']}/{len(reqs)} requests with "
                f"{s['duplicates']} duplicates through drain+promotion "
                "(zero-drop invariant broken)")
        if not exact[policy]:
            raise RuntimeError(
                f"cluster bench regression: {policy} completions diverged "
                "from the single-replica uncontended baseline — routing/"
                "reassignment must never change a token")
        if "drain" not in s["events"] or "promote" not in s["events"]:
            raise RuntimeError(
                f"cluster bench regression: {policy} leg skipped the "
                f"mid-run drain/promotion (events: {s['events']})")
    if cq["p95_ms"] >= rr["p95_ms"]:
        raise RuntimeError(
            f"cluster bench regression: chi_aware p95 {cq['p95_ms']:.3f}ms "
            f"did not beat round_robin p95 {rr['p95_ms']:.3f}ms under "
            "persistent replica skew")
    if cq["ttft_mean_ms"] >= rr["ttft_mean_ms"]:
        raise RuntimeError(
            f"cluster bench regression: chi_aware mean TTFT "
            f"{cq['ttft_mean_ms']:.3f}ms did not beat round_robin "
            f"{rr['ttft_mean_ms']:.3f}ms under persistent replica skew")
    if n_sched != R + 1:                  # R actives + the spare
        raise RuntimeError(
            f"cluster bench regression: recorded cluster trace split into "
            f"{n_sched} replica schedules, expected {R + 1}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
