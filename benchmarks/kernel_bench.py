"""Kernel-level benchmark: block-pruned matmul FLOP savings.

Wall-clock on the XLA gather path (the CPU-executable realization of the
kernel's dataflow; the Pallas kernel itself targets TPU and runs here in
interpret mode for correctness only), plus analytic FLOP counts per γ.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, is_dry_run, save_bench_json
from repro.core import resizing


def timeit(f, *args, n=20):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else \
        f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / n


def main() -> list:
    rows = []
    if is_dry_run():
        M, K, N, block, iters = 128, 512, 512, 128, 5
    else:
        M, K, N, block, iters = 512, 2048, 2048, 128, 20
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    nb = K // block

    dense = jax.jit(lambda x, w: x @ w)
    t_dense = timeit(dense, x, w, n=iters)
    rows.append(csv_row("kernel_dense_matmul", t_dense * 1e6,
                        f"gflops={2 * M * K * N / t_dense / 1e9:.1f}"))

    results = {"dense_us": t_dense * 1e6}
    for gamma in (0.25, 0.5, 0.75):
        kc = nb - int(gamma * nb)
        keep = jnp.asarray(np.sort(rng.choice(nb, kc, replace=False)),
                           jnp.int32)
        pruned = jax.jit(
            lambda x, w, k: resizing.resized_matmul(x, w, k, block=block))
        t = timeit(pruned, x, w, keep, n=iters)
        speedup = t_dense / t
        results[f"gamma{gamma}_us"] = t * 1e6
        results[f"gamma{gamma}_speedup"] = speedup
        rows.append(csv_row(f"kernel_pruned_matmul_gamma{gamma}", t * 1e6,
                            f"speedup={speedup:.2f},ideal={1/(1-gamma):.2f}"))
    save_bench_json("kernel_bench",
                    {"M": M, "K": K, "N": N, "block": block, "iters": iters,
                     "dry_run": is_dry_run()}, results)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
