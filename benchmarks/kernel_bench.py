"""Kernel-level benchmark sweep: the pruned Pallas family vs its dense
equivalent, END TO END THROUGH THE GRADIENT (ISSUE 2 tentpole gate).

Sweep axes: keep-ratio × M × block. For each point we time forward and
forward+backward of ``block_pruned_matmul`` (custom kernel VJP) and
compare against the SAME kernel family run dense (keep = all blocks) —
the apples-to-apples baseline at matched execution layer. On CPU the
kernels run in interpret mode, which is uniformly slower than native XLA
(recorded alongside as ``xla_dense`` context), so the gated quantity is
the pruned/dense RATIO: algorithmically the pruned path must win at any
keep-ratio ≤ 7/8, on TPU and CPU-interpret alike. A fused-FFN section
times the one-pallas_call FFN pair the same way.

The keep=1/2 fwd+bwd ratio is regression-gated against
``benchmarks/kernel_threshold.json`` (CI smoke job): a kernel change that
erodes the pruning advantage past the recorded threshold fails the run.

Emits the stable schema {"name","config","metrics"} to
experiments/bench/kernels.json and (full runs) BENCH_kernels.json.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import ROOT, csv_row, is_dry_run, save_bench_json
from repro.kernels import ops
from repro.layers import attention as attention_lib

THRESHOLD_PATH = os.path.join(ROOT, "benchmarks", "kernel_threshold.json")
DECODE_ATTN_THRESHOLD_PATH = os.path.join(
    ROOT, "benchmarks", "decode_attn_threshold.json")


def _timed_once(f, args, n):
    r = f(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
    (r[0] if isinstance(r, tuple) else r).block_until_ready()
    return (time.perf_counter() - t0) / n


def interleaved_min(cases, n=3, repeats=5):
    """Min-of-repeats with INTERLEAVED sampling: every repeat sweeps all
    cases back-to-back, so slow drift of the host (allocator growth,
    thermal, background load) hits every case equally instead of
    inflating whichever config happens to be measured last — the
    pruned/dense ratios stay honest. ``cases``: {key: (fn, args)}.
    Returns {key: best_seconds}."""
    for f, args in cases.values():            # compile/warm everything first
        r = f(*args)
        (r[0] if isinstance(r, tuple) else r).block_until_ready()
    best = {k: np.inf for k in cases}
    for _ in range(repeats):
        for k, (f, args) in cases.items():
            best[k] = min(best[k], _timed_once(f, args, n))
    return best


def _bench_matmul_group(M, K, N, block, keep_ratios, iters, repeats):
    """Interleaved fwd / fwd+bwd sweep over keep ratios (1.0 = dense
    kernel baseline) for one (M, block) point. Returns
    {ratio: {"fwd": s, "bwd": s, "kb": int, "nb": int}}."""
    rng = np.random.default_rng(M + block)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    nb = K // block

    fwd = jax.jit(lambda x_, w_, k_: ops.block_pruned_matmul(
        x_, w_, k_, block))
    grad = jax.jit(jax.grad(lambda x_, w_, k_: jnp.sum(
        ops.block_pruned_matmul(x_, w_, k_, block) ** 2), (0, 1)))

    cases, kbs = {}, {}
    for r in (1.0,) + tuple(keep_ratios):
        kb = max(1, int(round(r * nb)))
        keep = jnp.asarray(np.sort(rng.choice(nb, kb, replace=False)),
                           jnp.int32)
        kbs[r] = kb
        cases[("fwd", r)] = (fwd, (x, w, keep))
        cases[("bwd", r)] = (grad, (x, w, keep))
    times = interleaved_min(cases, n=iters, repeats=repeats)
    return {r: {"fwd": times[("fwd", r)], "bwd": times[("bwd", r)],
                "kb": kbs[r], "nb": nb} for r in (1.0,) + tuple(keep_ratios)}


def _bench_ffn_group(M, d, H, D2, block, iters, repeats):
    rng = np.random.default_rng(H + block)
    x = jnp.asarray(rng.standard_normal((M, d)), jnp.float32)
    wu = jnp.asarray(rng.standard_normal((d, H)) * 0.1, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((H, D2)) * 0.1, jnp.float32)
    nb = H // block
    act = jax.nn.silu

    fwd = jax.jit(lambda x_, u_, d_, k_: ops.fused_pruned_ffn(
        x_, u_, d_, k_, None, act, block))
    grad = jax.jit(jax.grad(lambda x_, u_, d_, k_: jnp.sum(
        ops.fused_pruned_ffn(x_, u_, d_, k_, None, act, block) ** 2),
        (0, 1, 2)))

    cases, kbs = {}, {}
    for r in (1.0, 0.5):
        kb = max(1, int(round(r * nb)))
        keep = jnp.asarray(np.sort(rng.choice(nb, kb, replace=False)),
                           jnp.int32)
        kbs[r] = kb
        cases[("fwd", r)] = (fwd, (x, wu, wd, keep))
        cases[("bwd", r)] = (grad, (x, wu, wd, keep))
    times = interleaved_min(cases, n=iters, repeats=repeats)
    return {r: {"fwd": times[("fwd", r)], "bwd": times[("bwd", r)],
                "kb": kbs[r], "nb": nb} for r in (1.0, 0.5)}


def _occupancy_cur_pos(name, num_slots, max_len):
    """Ragged per-slot cur_pos patterns (ISSUE 7): the fused kernel's
    advantage scales with how empty the cache is, so the sweep covers
    the serve-realistic spread from all-full to one-hot."""
    if name == "full":
        return np.full((num_slots,), max_len - 1, np.int32)
    if name == "half":
        return np.full((num_slots,), max_len // 2 - 1, np.int32)
    if name == "ragged":
        return np.linspace(0, max_len - 1, num_slots).astype(np.int32)
    if name == "sparse":
        cur = np.zeros((num_slots,), np.int32)   # near-empty slots + one full
        cur[-1] = max_len - 1
        return cur
    raise ValueError(f"unknown occupancy pattern {name!r}")


def _bench_decode_attn_group(num_slots, max_len, occ_patterns, iters,
                             repeats):
    """Fused single-pallas_call decode attention vs the matched 3-kernel
    unfused pipeline (scores->HBM, softmax, weighted sum) at one
    (num_slots, max_len) point, across cur_pos occupancy patterns.
    Native-XLA decode_attention is recorded as context only — same
    caveat as ``xla_dense``: interpret-mode kernels on CPU lose to
    native XLA across the board, so the gated quantity is the
    fused/unfused RATIO at matched execution layer."""
    Hkv, G, D = 2, 4, 64
    rng = np.random.default_rng(num_slots * 1000 + max_len)
    q = jnp.asarray(rng.standard_normal((num_slots, Hkv * G, 1, D)),
                    jnp.float32)
    k = jnp.asarray(rng.standard_normal((num_slots, Hkv, max_len, D)),
                    jnp.float32)
    v = jnp.asarray(rng.standard_normal((num_slots, Hkv, max_len, D)),
                    jnp.float32)

    fused = jax.jit(lambda q_, k_, v_, c_: ops.fused_decode_attention(
        q_, k_, v_, cur_pos=c_))
    unfused = jax.jit(lambda q_, k_, v_, c_: ops.unfused_decode_attention(
        q_, k_, v_, cur_pos=c_))
    xla = jax.jit(lambda q_, k_, v_, c_: attention_lib.decode_attention(
        q_, k_, v_, cur_pos=c_))

    cases, curs = {}, {}
    for name in occ_patterns:
        cur = _occupancy_cur_pos(name, num_slots, max_len)
        curs[name] = cur
        c = jnp.asarray(cur, jnp.int32)
        cases[("fused", name)] = (fused, (q, k, v, c))
        cases[("unfused", name)] = (unfused, (q, k, v, c))
        cases[("xla", name)] = (xla, (q, k, v, c))
    times = interleaved_min(cases, n=iters, repeats=repeats)
    return {name: {"fused": times[("fused", name)],
                   "unfused": times[("unfused", name)],
                   "xla": times[("xla", name)],
                   "occupancy": float((curs[name] + 1).mean() / max_len)}
            for name in occ_patterns}


def timeit(f, *args, n=3, repeats=5):
    """Min-of-repeats for standalone references (xla_dense)."""
    return interleaved_min({"_": (f, args)}, n=n, repeats=repeats)["_"]


def _xla_dense(M, K, N, iters):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    fwd = jax.jit(lambda x_, w_: x_ @ w_)
    grad = jax.jit(jax.grad(lambda x_, w_: jnp.sum((x_ @ w_) ** 2), (0, 1)))
    return timeit(fwd, x, w, n=iters), timeit(grad, x, w, n=iters)


def main() -> list:
    rows = []
    dry = is_dry_run()
    if dry:
        # smoke shapes: deep enough in K that the grid-step savings (not
        # fixed pallas_call overhead) dominate; keep=7/8 is only gated on
        # the full run, where the signal is clean
        Ms, blocks, K, N, iters = (64,), (32,), 512, 128, 2
        ffn_shapes = (64, 64, 128, 64)          # M, d, H, D2
        keep_ratios = (0.25, 0.5, 0.75)
    else:
        Ms, blocks, K, N, iters = (128, 256), (64, 128), 1024, 512, 4
        ffn_shapes = (256, 256, 1024, 256)
        keep_ratios = (0.25, 0.5, 0.75, 0.875)

    repeats = 3 if dry else 6
    sweep = []
    gate_ratios = {}
    for M in Ms:
        for block in blocks:
            g = _bench_matmul_group(M, K, N, block, keep_ratios, iters,
                                    repeats)
            d_fwd, d_bwd = g[1.0]["fwd"], g[1.0]["bwd"]
            for r in (1.0,) + keep_ratios:
                f, b = g[r]["fwd"], g[r]["bwd"]
                sweep.append({"M": M, "K": K, "N": N, "block": block,
                              "keep_ratio": r, "kb": g[r]["kb"],
                              "nb": g[r]["nb"],
                              "fwd_us": f * 1e6, "fwdbwd_us": b * 1e6,
                              "ratio_fwd": f / d_fwd,
                              "ratio_fwdbwd": b / d_bwd})
                if r < 1.0:
                    gate_ratios.setdefault(r, []).append(b / d_bwd)
                    rows.append(csv_row(
                        f"kernel_pruned_M{M}_b{block}_keep{r}", b * 1e6,
                        f"ratio_fwdbwd={b/d_bwd:.2f},"
                        f"ratio_fwd={f/d_fwd:.2f}"))

    xla_f, xla_b = _xla_dense(max(Ms), K, N, iters)
    rows.append(csv_row("kernel_xla_dense_ref", xla_b * 1e6,
                        f"fwd_us={xla_f*1e6:.1f}"))

    # fused FFN pair: pruned vs dense at the same (kernel) execution layer
    Mf, d, H, D2 = ffn_shapes
    gf = _bench_ffn_group(Mf, d, H, D2, blocks[-1], iters, repeats)
    ffn = {}
    for r in (0.5, 1.0):
        b = gf[r]["bwd"]
        ratio = b / gf[1.0]["bwd"]
        ffn[f"keep{r}"] = {"fwd_us": gf[r]["fwd"] * 1e6, "fwdbwd_us": b * 1e6,
                           "kb": gf[r]["kb"], "nb": gf[r]["nb"],
                           "ratio_fwdbwd": ratio}
        rows.append(csv_row(f"kernel_fused_ffn_keep{r}", b * 1e6,
                            f"ratio_fwdbwd={ratio:.2f}"))

    # decode attention (ISSUE 7): fused single-kernel vs matched 3-kernel
    # unfused pipeline across num_slots x max_len x cur_pos occupancy
    # cache lengths start at 256 (2+ tiles): at a single 128-row tile the
    # online-softmax bookkeeping ~cancels the fused win and the signal is
    # noise — same reasoning as gating keep=7/8 on the full run only
    if dry:
        da_slots, da_lens, da_iters = (4,), (256,), 2
    else:
        da_slots, da_lens, da_iters = (4, 8), (256, 512), 3
    occ_patterns = ("full", "half", "ragged", "sparse")
    da_sweep, da_ratios = [], []
    for ns in da_slots:
        for ml in da_lens:
            g = _bench_decode_attn_group(ns, ml, occ_patterns, da_iters,
                                         repeats)
            for name in occ_patterns:
                e = g[name]
                ratio = e["fused"] / e["unfused"]
                da_sweep.append({
                    "num_slots": ns, "max_len": ml, "pattern": name,
                    "occupancy": e["occupancy"],
                    "fused_us": e["fused"] * 1e6,
                    "unfused_us": e["unfused"] * 1e6,
                    "xla_us": e["xla"] * 1e6,
                    "ratio_fused_unfused": ratio})
                da_ratios.append(ratio)
                rows.append(csv_row(
                    f"kernel_decode_attn_s{ns}_l{ml}_{name}",
                    e["fused"] * 1e6,
                    f"ratio_fused_unfused={ratio:.2f},"
                    f"occ={e['occupancy']:.2f}"))

    # ---- gates ----------------------------------------------------------
    worst = {r: max(v) for r, v in gate_ratios.items()}
    max_at_or_below_78 = max(worst.values())
    gate_pass = max_at_or_below_78 < 1.0
    threshold = None
    if os.path.exists(THRESHOLD_PATH):
        threshold = json.load(open(THRESHOLD_PATH))
    reg_ratio = worst.get(0.5)
    reg_max = (threshold or {}).get("ratio_fwdbwd_keep_half_max")
    reg_pass = reg_max is None or reg_ratio <= reg_max

    # fused must beat unfused at EVERY measured point (ISSUE 7 acceptance),
    # and the worst ratio is regression-gated against the committed file
    da_worst = max(da_ratios)
    da_pass = da_worst < 1.0
    da_threshold = None
    if os.path.exists(DECODE_ATTN_THRESHOLD_PATH):
        da_threshold = json.load(open(DECODE_ATTN_THRESHOLD_PATH))
    da_reg_max = (da_threshold or {}).get("ratio_fused_unfused_max")
    da_reg_pass = da_reg_max is None or da_worst <= da_reg_max

    metrics = {
        "sweep": sweep,
        "ffn": ffn,
        "xla_dense": {"fwd_us": xla_f * 1e6, "fwdbwd_us": xla_b * 1e6,
                      "note": "native XLA context; interpret-mode kernels "
                              "are gated on the pruned/dense ratio, not "
                              "absolute CPU time"},
        "decode_attn": {
            "sweep": da_sweep,
            "gate": {"worst_ratio_fused_unfused": da_worst,
                     "fused_beats_unfused_everywhere": da_pass,
                     "regression_threshold": da_reg_max,
                     "regression_pass": da_reg_pass}},
        "gate": {"worst_ratio_by_keep": {str(k): v for k, v in worst.items()},
                 "max_ratio_fwdbwd_at_or_below_7_8": max_at_or_below_78,
                 "pruned_beats_dense": gate_pass,
                 "regression_threshold": reg_max,
                 "ratio_fwdbwd_keep_half": reg_ratio,
                 "regression_pass": reg_pass},
    }
    config = {"Ms": list(Ms), "blocks": list(blocks), "K": K, "N": N,
              "keep_ratios": list(keep_ratios), "iters": iters,
              "ffn_shapes": list(ffn_shapes),
              "decode_attn_slots": list(da_slots),
              "decode_attn_max_lens": list(da_lens),
              "decode_attn_patterns": list(occ_patterns), "dry_run": dry,
              "interpret": ops.interpret_mode()}
    save_bench_json("kernels", config, metrics, trajectory=True)
    rows.append(csv_row("kernel_gate", 0.0,
                        f"max_ratio@<=7/8={max_at_or_below_78:.2f},"
                        f"pass={gate_pass},regression_pass={reg_pass}"))
    rows.append(csv_row("kernel_decode_attn_gate", 0.0,
                        f"worst_ratio={da_worst:.2f},pass={da_pass},"
                        f"regression_pass={da_reg_pass}"))
    if not gate_pass:
        raise RuntimeError(
            f"pruned fwd+bwd not faster than dense kernel at keep<=7/8 "
            f"(worst ratio {max_at_or_below_78:.3f})")
    if not reg_pass:
        raise RuntimeError(
            f"keep=1/2 fwd+bwd ratio {reg_ratio:.3f} regressed past the "
            f"recorded threshold {reg_max} ({THRESHOLD_PATH})")
    if not da_pass:
        raise RuntimeError(
            f"fused decode attention not faster than the unfused pipeline "
            f"at every point (worst ratio {da_worst:.3f})")
    if not da_reg_pass:
        raise RuntimeError(
            f"fused/unfused decode-attn ratio {da_worst:.3f} regressed "
            f"past the recorded threshold {da_reg_max} "
            f"({DECODE_ATTN_THRESHOLD_PATH})")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
