"""Shared benchmark helpers.

Every benchmark reproduces one paper table/figure (DESIGN.md §6) and
reports two kinds of numbers:

* RT — modeled runtime at PAPER SCALE (ViT-1B, e=8 V100-class ranks),
  from the analytic iteration model. The paper itself simulates
  heterogeneity by sleep injection, so modeled bulk-synchronous times are
  the same epistemics (DESIGN.md §7.4). V100: 112 TFLOP/s tensor peak.
* ACC — REAL training accuracy of the reduced model on CPU with the
  actual ZERO/SEMI machinery in the jitted step.

Output convention (benchmarks/run.py): ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Optional

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(ROOT, "experiments", "bench")

# paper-scale constants (Sec. V-A): 8x V100 (112 TFLOPS), ViT-1B
PAPER_E = 8
V100_FLOPS = 112e12
V100_MFU = 0.35


# Non-matmul fraction C/M of the paper's testbed, CALIBRATED from the
# paper's own headline ((8M+C)/(M+C) = 3.5 at χ=8 ⇒ C = 1.8·M): V100s on
# PCIe 3.0 with 1D-TP all-reduces every layer are communication-heavy.
PAPER_COMM_FRAC = 1.8


def paper_scale_model(arch: str = "vit-1b", batch: int = 64, seq: int = 65):
    """IterationModel for the paper's testbed (ViT-1B, bs=64, sql=65)."""
    from repro.config import ShapeConfig, get_config
    from repro.core.hetero import iteration_model
    cfg = get_config(arch)
    shape = ShapeConfig("paper", seq, batch, "train")
    return iteration_model(cfg, shape, PAPER_E, peak_flops=V100_FLOPS,
                           mfu=V100_MFU, comm_frac=PAPER_COMM_FRAC)


def is_dry_run() -> bool:
    """Tiny-shapes smoke mode (CI): set by `benchmarks/run.py --dry-run`.

    Benchmarks consult this to shrink device counts / shapes / iteration
    counts so the whole sweep finishes in seconds, not minutes."""
    return os.environ.get("REPRO_BENCH_DRY", "") == "1"


def save_json(name: str, payload: dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def save_bench_json(name: str, config: dict, metrics: dict,
                    trajectory: bool = False) -> str:
    """Write bench output in the STABLE schema shared by the CI smoke job
    and the per-PR trajectory files:

        {"name": <bench id>, "config": {...}, "metrics": {...}}

    Always lands in experiments/bench/<name>.json; with trajectory=True it
    is ALSO written to the repo root as BENCH_<name>.json (committed, so
    perf regressions are visible in per-PR diffs). Dry-run smoke never
    touches trajectory files — tiny-shape numbers must not clobber the
    committed full-scale points."""
    payload = {"name": name, "config": config, "metrics": metrics}
    path = save_json(name, payload)
    if trajectory and not is_dry_run():
        with open(os.path.join(ROOT, f"BENCH_{name}.json"), "w") as f:
            json.dump(payload, f, indent=1, default=float, sort_keys=True)
    return path


def run_subprocess_py(code: str, devices: int = 8, timeout: int = 1200,
                      with_bench_path: bool = False) -> str:
    """Run a snippet under N host devices; returns stdout.

    ``with_bench_path`` adds the repo root to PYTHONPATH so the snippet
    can import the ``benchmarks`` package itself."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    path = [os.path.join(ROOT, "src")] + ([ROOT] if with_bench_path else [])
    env["PYTHONPATH"] = os.pathsep.join(path)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, cwd=ROOT,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
