"""Serving benchmark: continuous-batching decode under contention,
dense vs. straggler-aware (ZERO-resized) — per-token latency percentiles
and throughput.

Replays ONE staggered request trace through the :class:`ServeEngine`
twice under the SAME contention schedule (χ = 4, p = 0.15 — the paper's
contention-driven straggling regime at serve time):

* ``dense``   — control off: every decode step takes as long as the
  slowest simulated rank (bulk-synchronous TP);
* ``resized`` — the SemiController ZERO-resizes the contended rank's TP
  decode matmuls each step (plan-signature compile caching keeps the
  executable set tiny), and the REAL controlled step executes the pruned
  branch.

Latency epistemics match the rest of the bench suite: per-step times come
from the calibrated iteration model over the simulated rank group (the
paper itself simulates heterogeneity), while the decode dataflow runs for
real — slots, recycling, prefill-on-admit, plan dispatch.

Emits stable-schema ``BENCH_serve.json`` (trajectory point) and FAILS if
resized decode does not beat dense p95 per-token latency — the serving
analogue of the kernel-bench regression gate.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import OUT_DIR, csv_row, is_dry_run, save_bench_json
from repro.launch.serve import (Request, ServeControlConfig, ServeEngine,
                                latency_percentiles)

ARCH = "yi-6b"
SIM_RANKS = 8                     # paper-scale TP group for the χ schedule
CHI = 4.0
CONTENTION_P = 0.15


def make_trace(vocab: int, n_requests: int, prompt_len: int, gen_len: int,
               arrival_every: int, seed: int = 0):
    """Deterministic staggered trace with unequal prompt/gen lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        p = prompt_len + int(rng.integers(0, max(prompt_len // 2, 1)))
        g = gen_len + int(rng.integers(0, max(gen_len // 2, 1)))
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
            max_new_tokens=g, arrival_step=i * arrival_every))
    return reqs


def run_engine(mode: str, *, num_slots: int, max_len: int, trace_args,
               use_kernel: bool = False, seed: int = 0,
               trace_out: str = None):
    control = ServeControlConfig(
        mode=mode, hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS,
        use_kernel=use_kernel, seed=seed, trace_out=trace_out)
    eng = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                      control=control, seed=seed)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *trace_args))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    stats["steps"] = len(eng.history)
    stats["wall_us_per_step"] = float(
        np.mean([h["wall_s"] for h in eng.history]) * 1e6)
    stats["straggler_steps"] = sum(
        1 for h in eng.history if h.get("stragglers"))
    stats.update(eng.trace_counts())
    return eng, comps, stats


def main() -> list:
    dry = is_dry_run()
    num_slots = 2 if dry else 4
    n_requests = 4 if dry else 12
    prompt_len = 4 if dry else 8
    gen_len = 4 if dry else 16
    arrival_every = 2
    max_len = prompt_len * 2 + gen_len * 2      # headroom for jittered lens
    trace_args = (n_requests, prompt_len, gen_len, arrival_every)

    rows = []
    results = {}
    for key, mode in (("dense", "off"), ("resized", "zero")):
        # every bench run emits a replayable telemetry trace: a recorded
        # contention episode is a deterministic regression scenario
        trace_out = os.path.join(OUT_DIR, "traces", f"serve_{key}.jsonl")
        eng, comps, stats = run_engine(mode, num_slots=num_slots,
                                       max_len=max_len,
                                       trace_args=trace_args,
                                       trace_out=trace_out)
        results[key] = stats
        stats["trace_out"] = os.path.relpath(trace_out, OUT_DIR)
        rows.append(csv_row(
            f"serve_{key}", stats["p95_ms"] * 1e3,
            f"p50={stats['p50_ms']:.3f}ms,p95={stats['p95_ms']:.3f}ms,"
            f"p99={stats['p99_ms']:.3f}ms,tok_s={stats['tok_per_s']:.1f},"
            f"compiles={stats['plan_compiles']}"))

    d, r = results["dense"], results["resized"]
    speedup_p95 = d["p95_ms"] / max(r["p95_ms"], 1e-12)
    speedup_tput = r["tok_per_s"] / max(d["tok_per_s"], 1e-12)
    rows.append(csv_row(
        "serve_speedup", 0.0,
        f"p95_speedup={speedup_p95:.2f}x,tput_speedup={speedup_tput:.2f}x,"
        f"chi={CHI},p={CONTENTION_P}"))

    config = {"arch": ARCH, "sim_ranks": SIM_RANKS, "chi": CHI,
              "contention_p": CONTENTION_P, "num_slots": num_slots,
              "n_requests": n_requests, "prompt_len": prompt_len,
              "gen_len": gen_len, "arrival_every": arrival_every,
              "dry_run": dry}
    metrics = {"dense": results["dense"], "resized": results["resized"],
               "p95_speedup": speedup_p95, "tput_speedup": speedup_tput}
    save_bench_json("serve", config, metrics, trajectory=True)

    # regression gate (serving analogue of the kernel-bench ratio gate):
    # under χ=4 / p=0.15 contention, resized decode must beat dense p95
    if r["p95_ms"] >= d["p95_ms"]:
        raise RuntimeError(
            f"serve bench regression: resized p95 {r['p95_ms']:.3f}ms did "
            f"not beat dense p95 {d['p95_ms']:.3f}ms under contention")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
