"""Serving benchmark: continuous-batching decode under contention —
dense vs. ZERO-resized vs. full SEMI (lossless migration) — per-token
latency percentiles and throughput.

Replays ONE staggered request trace through the :class:`ServeEngine`
under the SAME contention schedule (χ = 4, p = 0.15 — the paper's
contention-driven straggling regime at serve time):

* ``dense``   — control off: every decode step takes as long as the
  slowest simulated rank (bulk-synchronous TP);
* ``resized`` — the SemiController ZERO-resizes the contended rank's TP
  decode matmuls each step (plan-signature compile caching keeps the
  executable set tiny), and the REAL controlled step executes the pruned
  branch (fast but LOSSY: pruned weights change logits);
* ``semi``    — the paper's adaptive solution through the unified control
  plane: Eq.(3)-selected stragglers MIGRATE their shed blocks to helper
  ranks (multi-source, reduce-merged, β-policy "lossless"). Runs in a
  4-device subprocess (real TP migration dataflow, sim_ranks = 8 folded
  onto the mesh via the plan projection) and is gated on BOTH latency
  (beats contended dense p95) and losslessness (token-exact vs. the
  uncontended dense baseline at the same tp).

Latency epistemics match the rest of the bench suite: per-step times come
from the calibrated iteration model over the simulated rank group (the
paper itself simulates heterogeneity), while the decode dataflow runs for
real — slots, recycling, prefill-on-admit, plan dispatch, migration
collectives.

Emits stable-schema ``BENCH_serve.json`` (trajectory point) and FAILS if
resized decode does not beat dense p95, if SEMI decode does not beat
dense p95, or if SEMI decode is not token-exact.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (OUT_DIR, csv_row, is_dry_run,
                               run_subprocess_py, save_bench_json)
from repro.control import ControlConfig
from repro.launch.serve import (Request, ServeEngine,
                                latency_percentiles)

ARCH = "yi-6b"
SIM_RANKS = 8                     # paper-scale TP group for the χ schedule
SEMI_TP = 4                       # real mesh for the semi-migration run
CHI = 4.0
CONTENTION_P = 0.15
PAGE_SIZE = 8                     # paged-KV legs (multiple of 8: fused-ready)
PREFILL_CHUNK = 4                 # chunked-prefill substeps per engine step
TRACE_FIXTURE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "examples", "traces", "bursty_contention.jsonl")


def make_trace(vocab: int, n_requests: int, prompt_len: int, gen_len: int,
               arrival_every: int, seed: int = 0):
    """Deterministic staggered trace with unequal prompt/gen lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        p = prompt_len + int(rng.integers(0, max(prompt_len // 2, 1)))
        g = gen_len + int(rng.integers(0, max(gen_len // 2, 1)))
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
            max_new_tokens=g, arrival_step=i * arrival_every))
    return reqs


def make_mixed_trace(vocab: int, n_requests: int, max_len: int,
                     seed: int = 0):
    """Bursty mixed-length trace for the paged-capacity leg.

    Arrival bursts reuse the ``bursty_contention`` fixture's burst
    geometry (requests land in groups, not a steady drip), and lengths
    follow a short-heavy mix with a long tail — the regime where a fixed
    ``num_slots x max_len`` cache strands most of its HBM."""
    with open(TRACE_FIXTURE) as f:
        hdr = json.loads(f.readline())
    burst = max(2, int(hdr["burst_len"]) // 3)     # requests per burst
    gap = max(2, int(hdr["burst_every"]) // 5)     # steps between bursts
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        if rng.random() < 0.3:                     # long tail
            p = int(rng.integers(max_len // 3, max_len // 2 + 1))
            g = int(rng.integers(max_len // 4, max_len // 2 + 1))
        else:                                      # short-heavy bulk
            p = int(rng.integers(2, max(max_len // 6, 3)))
            g = int(rng.integers(2, max(max_len // 6, 3)))
        g = min(g, max_len - p)
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
            max_new_tokens=g, arrival_step=(i // burst) * gap))
    return reqs


def run_engine(mode: str, *, num_slots: int, max_len: int, trace_args,
               use_kernel: bool = False, seed: int = 0,
               trace_out: str = None):
    control = ControlConfig(
        mode=mode, hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS,
        use_kernel=use_kernel, seed=seed, trace_out=trace_out)
    eng = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                      control=control, seed=seed)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *trace_args))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    stats["steps"] = len(eng.history)
    stats["wall_us_per_step"] = float(
        np.mean([h["wall_s"] for h in eng.history]) * 1e6)
    stats["straggler_steps"] = sum(
        1 for h in eng.history if h.get("stragglers"))
    stats.update(eng.trace_counts())
    return eng, comps, stats


DECODE_PSUM_CHUNKS = 4


def run_decode_path_engine(leg: str, *, num_slots: int, max_len: int,
                           trace_args, seed: int = 0):
    """One decode-path leg (ISSUE 7) under the SAME contention schedule
    as the classic legs, with the decode-overhead model ON — attention
    cache reads and collective exposure priced per step from the actual
    per-slot positions:

    * ``unfused``       — oracle attention, one fat epilogue psum
                          (PR 6 behavior, honestly priced);
    * ``fused``         — fused Pallas decode attention, fat psum;
    * ``fused_overlap`` — fused attention + chunked epilogue psum.

    All three run mode="zero" so the fused path is exercised COMPOSED
    with ZERO-resized decode (the tentpole composition requirement)."""
    fused = leg != "unfused"
    chunks = DECODE_PSUM_CHUNKS if leg == "fused_overlap" else 1
    control = ControlConfig(
        mode="zero", hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS,
        fused_attention=fused, psum_chunks=chunks,
        model_decode_overheads=True, seed=seed)
    eng = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                      control=control, seed=seed)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *trace_args))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    hist = [h for h in eng.history if "overhead_s" in h]
    stats["mean_step_latency_s"] = float(
        np.mean([h["latency_s"] for h in hist]))
    stats["mean_overhead_s"] = float(
        np.mean([h["overhead_s"] for h in hist]))
    stats["mean_occupancy"] = float(
        np.mean([h["occupancy"] for h in hist]))
    # roofline floor for each step: χ=1 full-workload matmul + the
    # occupied-tiles-only attention read, zero exposed collective
    stats["mean_roofline_s"] = float(np.mean(
        [eng.it_model.matmul_time + h["attn_bound_s"] for h in hist]))
    stats["roofline_distance_s"] = (stats["mean_step_latency_s"]
                                    - stats["mean_roofline_s"])
    return comps, stats


def _ttft_ms(comps) -> float:
    """Mean time-to-first-token (first per-request token latency, which
    includes queue wait + prefill) in ms — the shared stats field, so an
    empty completion list reads 0.0 rather than a nan mean."""
    return latency_percentiles(comps)["ttft_mean_ms"]


def run_mixed_lengths_leg(*, num_slots: int, max_len: int, n_requests: int,
                          seed: int = 0) -> dict:
    """Paged-KV capacity leg (ISSUE 8): 2N slots over a page pool sized
    to the FIXED engine's N-slot HBM budget, against the fixed 2N-slot
    engine on the same bursty mixed-length trace.

    All engines run 2N decode lanes, so per-step compute pricing is
    identical — the paging win is pure HBM capacity: the fixed cache at
    this budget holds N resident requests; the paged pool holds 2N
    because short requests only occupy the pages they use. Two paged
    variants run:

    * ``paged`` (prefill_chunk=1) — paging alone must be FREE: gated on
      exact p50 per-token parity with the fixed engine;
    * ``paged_chunked`` (prefill_chunk=PREFILL_CHUNK) — chunked prefill
      trades a small priced p50 cost (decode tokens share steps with
      prefill chunks) for a large tail win: gated on token-exactness and
      beating the fixed engine's p95 and mean TTFT.

    Equal-HBM and >= 2x resident-capacity gates apply to the shipping
    (chunked) configuration."""
    slots2 = 2 * num_slots
    pps = -(-max_len // PAGE_SIZE)
    ctl = lambda: ControlConfig(
        mode="off", hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS, seed=seed)

    # the equal-HBM yardstick: the fixed cache's bytes at N slots
    fixed_n = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                          control=ctl(), seed=seed)
    budget_bytes = fixed_n.kv_cache_bytes()
    fixed_n.close()

    def run_one(**eng_kw):
        eng = ServeEngine(ARCH, num_slots=slots2, max_len=max_len,
                          control=ctl(), seed=seed, **eng_kw)
        comps = eng.run(make_mixed_trace(eng.cfg.vocab_size, n_requests,
                                         max_len, seed=seed))
        eng.close()
        stats = latency_percentiles(comps, total_time_s=eng.clock)
        stats["ttft_ms"] = _ttft_ms(comps)
        stats["steps"] = len(eng.history)
        stats["peak_resident"] = max(h["active"] for h in eng.history)
        return eng, comps, stats

    paged_kw = dict(page_size=PAGE_SIZE, num_pages=num_slots * pps)
    ref, ref_comps, ref_stats = run_one()
    _, p1_comps, p1_stats = run_one(prefill_chunk=1, **paged_kw)
    eng, pc_comps, pc_stats = run_one(prefill_chunk=PREFILL_CHUNK,
                                      **paged_kw)

    tok_ref = {c.uid: c.tokens for c in ref_comps}
    exact = lambda comps: bool(all(
        np.array_equal(c.tokens, tok_ref[c.uid]) for c in comps))
    return {
        "fixed": ref_stats, "paged": p1_stats, "paged_chunked": pc_stats,
        "kv_cache_bytes": eng.kv_cache_bytes(),
        "fixed_kv_cache_bytes": budget_bytes,
        "fixed_2n_kv_cache_bytes": ref.kv_cache_bytes(),
        "peak_resident": pc_stats["peak_resident"],
        "fixed_slot_capacity": num_slots,
        "preemptions": eng.preemptions,
        "page_size": PAGE_SIZE, "prefill_chunk": PREFILL_CHUNK,
        "num_pages": num_slots * pps,
        "token_exact": exact(p1_comps),
        "chunked_token_exact": exact(pc_comps),
    }


_SEMI_CHILD = """
import json
import numpy as np
from repro.control import ControlConfig
from repro.launch.serve import (Request, ServeEngine,
                                latency_percentiles)
from benchmarks.serve_bench import (ARCH, CHI, CONTENTION_P, SEMI_TP,
                                    SIM_RANKS, make_trace)

p = json.loads(__SEMI_PARAMS__)

def run(mode, hetero, **eng_kw):
    control = ControlConfig(
        mode=mode, hetero_kind=hetero, chi=CHI, contention_p=CONTENTION_P,
        sim_ranks=SIM_RANKS, max_sources=SIM_RANKS - 1, seed=p["seed"])
    eng = ServeEngine(ARCH, num_slots=p["num_slots"], max_len=p["max_len"],
                      tp=SEMI_TP, control=control, seed=p["seed"], **eng_kw)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *p["trace_args"]))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    stats.update(eng.trace_counts())
    return eng, comps, stats

# uncontended dense baseline at the SAME tp: the token-exactness reference
ref_eng, ref, ref_stats = run("off", "none")
eng, comps, stats = run("semi", "contention")
tok_ref = {c.uid: c.tokens for c in ref}
exact = all(np.array_equal(c.tokens, tok_ref[c.uid]) for c in comps)
# paged KV under SEMI on the real mesh: the block-paged pool must be
# invisible to the control plane — token-exact vs the SAME dense ref
peng, pcomps, pstats = run("semi", "contention",
                           page_size=p["page_size"])
paged_exact = all(np.array_equal(c.tokens, tok_ref[c.uid])
                  for c in pcomps)
out = {
    "semi": stats,
    "dense_ref": ref_stats,
    "token_exact": bool(exact),
    "semi_paged": pstats,
    "paged_token_exact": bool(paged_exact),
    "migrated_steps": sum(1 for h in eng.history if h.get("mig_srcs")),
    "paged_migrated_steps": sum(1 for h in peng.history
                                if h.get("mig_srcs")),
    "resize_steps": sum(1 for h in eng.history
                        if h.get("max_bucket", 0) > 0),
    "straggler_steps": sum(1 for h in eng.history if h.get("stragglers")),
}
print("SEMI_JSON:" + json.dumps(out))
"""


def run_semi_subprocess(*, num_slots, max_len, trace_args, seed=0) -> dict:
    """Run the SEMI-migration leg on a real SEMI_TP-rank host mesh.

    A subprocess (the shared bench harness) is required because the XLA
    host-device-count flag must be set before jax initializes — the
    parent process is already running single-device legs."""
    params = json.dumps({"num_slots": num_slots, "max_len": max_len,
                         "trace_args": list(trace_args), "seed": seed,
                         "page_size": PAGE_SIZE})
    code = _SEMI_CHILD.replace("__SEMI_PARAMS__", repr(params))
    stdout = run_subprocess_py(code, devices=SEMI_TP, timeout=1800,
                               with_bench_path=True)
    for line in stdout.splitlines():
        if line.startswith("SEMI_JSON:"):
            return json.loads(line[len("SEMI_JSON:"):])
    raise RuntimeError(f"semi serve subprocess emitted no result:\n{stdout}")


def main() -> list:
    dry = is_dry_run()
    num_slots = 2 if dry else 4
    n_requests = 4 if dry else 12
    prompt_len = 4 if dry else 8
    gen_len = 4 if dry else 16
    arrival_every = 2
    max_len = prompt_len * 2 + gen_len * 2      # headroom for jittered lens
    trace_args = (n_requests, prompt_len, gen_len, arrival_every)

    rows = []
    results = {}
    for key, mode in (("dense", "off"), ("resized", "zero")):
        # every bench run emits a replayable telemetry trace: a recorded
        # contention episode is a deterministic regression scenario
        trace_out = os.path.join(OUT_DIR, "traces", f"serve_{key}.jsonl")
        eng, comps, stats = run_engine(mode, num_slots=num_slots,
                                       max_len=max_len,
                                       trace_args=trace_args,
                                       trace_out=trace_out)
        results[key] = stats
        stats["trace_out"] = os.path.relpath(trace_out, OUT_DIR)
        rows.append(csv_row(
            f"serve_{key}", stats["p95_ms"] * 1e3,
            f"p50={stats['p50_ms']:.3f}ms,p95={stats['p95_ms']:.3f}ms,"
            f"p99={stats['p99_ms']:.3f}ms,tok_s={stats['tok_per_s']:.1f},"
            f"compiles={stats['plan_compiles']}"))

    # -- SEMI leg: lossless migration on a real 4-rank mesh ---------------
    semi = run_semi_subprocess(num_slots=num_slots, max_len=max_len,
                               trace_args=trace_args)
    s = semi["semi"]
    rows.append(csv_row(
        "serve_semi", s["p95_ms"] * 1e3,
        f"p50={s['p50_ms']:.3f}ms,p95={s['p95_ms']:.3f}ms,"
        f"tok_s={s['tok_per_s']:.1f},mig_steps={semi['migrated_steps']},"
        f"token_exact={semi['token_exact']}"))

    # -- decode-path legs (ISSUE 7): fused attention + chunked psum -------
    decode_path = {}
    decode_tokens = {}
    for leg in ("unfused", "fused", "fused_overlap"):
        comps, stats = run_decode_path_engine(
            leg, num_slots=num_slots, max_len=max_len,
            trace_args=trace_args)
        decode_path[leg] = stats
        decode_tokens[leg] = {c.uid: c.tokens for c in comps}
        rows.append(csv_row(
            f"serve_decode_{leg}", stats["p50_ms"] * 1e3,
            f"p50={stats['p50_ms']:.3f}ms,p95={stats['p95_ms']:.3f}ms,"
            f"occ={stats['mean_occupancy']:.2f},"
            f"roof_dist={stats['roofline_distance_s']*1e3:.3f}ms"))

    u, f, fo = (decode_path["unfused"], decode_path["fused"],
                decode_path["fused_overlap"])
    decode_exact = all(
        np.array_equal(decode_tokens["unfused"][uid], toks)
        for leg in ("fused", "fused_overlap")
        for uid, toks in decode_tokens[leg].items())
    decode_p50_speedup = u["p50_ms"] / max(fo["p50_ms"], 1e-12)
    rows.append(csv_row(
        "serve_decode_speedup", 0.0,
        f"p50_speedup={decode_p50_speedup:.2f}x,"
        f"token_exact={decode_exact},"
        f"roof_dist_unfused={u['roofline_distance_s']*1e3:.3f}ms,"
        f"roof_dist_both={fo['roofline_distance_s']*1e3:.3f}ms"))

    # -- mixed-length paged-capacity leg (ISSUE 8) ------------------------
    mixed = run_mixed_lengths_leg(num_slots=num_slots, max_len=max_len,
                                  n_requests=n_requests * 2)
    mf, mp, mc = mixed["fixed"], mixed["paged"], mixed["paged_chunked"]
    rows.append(csv_row(
        "serve_mixed_lengths", mc["p50_ms"] * 1e3,
        f"p50={mc['p50_ms']:.3f}ms(fixed={mf['p50_ms']:.3f}),"
        f"p95={mc['p95_ms']:.3f}ms(fixed={mf['p95_ms']:.3f}),"
        f"ttft={mc['ttft_ms']:.3f}ms(fixed={mf['ttft_ms']:.3f}),"
        f"resident={mixed['peak_resident']}"
        f"/{mixed['fixed_slot_capacity']}fixed,"
        f"kv_kb={mixed['kv_cache_bytes']/1024:.0f},"
        f"preempt={mixed['preemptions']},"
        f"token_exact={mixed['token_exact'] and mixed['chunked_token_exact']}"))

    d, r = results["dense"], results["resized"]
    speedup_p95 = d["p95_ms"] / max(r["p95_ms"], 1e-12)
    speedup_tput = r["tok_per_s"] / max(d["tok_per_s"], 1e-12)
    semi_speedup_p95 = d["p95_ms"] / max(s["p95_ms"], 1e-12)
    rows.append(csv_row(
        "serve_speedup", 0.0,
        f"p95_speedup={speedup_p95:.2f}x,tput_speedup={speedup_tput:.2f}x,"
        f"semi_p95_speedup={semi_speedup_p95:.2f}x,"
        f"chi={CHI},p={CONTENTION_P}"))

    config = {"arch": ARCH, "sim_ranks": SIM_RANKS, "chi": CHI,
              "contention_p": CONTENTION_P, "num_slots": num_slots,
              "n_requests": n_requests, "prompt_len": prompt_len,
              "gen_len": gen_len, "arrival_every": arrival_every,
              "semi_tp": SEMI_TP, "dry_run": dry}
    metrics = {"dense": results["dense"], "resized": results["resized"],
               "semi": s, "semi_dense_ref": semi["dense_ref"],
               "semi_token_exact": semi["token_exact"],
               "semi_paged": semi["semi_paged"],
               "semi_paged_token_exact": semi["paged_token_exact"],
               "semi_paged_migrated_steps": semi["paged_migrated_steps"],
               "semi_migrated_steps": semi["migrated_steps"],
               "semi_resize_steps": semi["resize_steps"],
               "mixed_lengths": mixed,
               "p95_speedup": speedup_p95, "tput_speedup": speedup_tput,
               "semi_p95_speedup": semi_speedup_p95,
               "decode_path": {
                   "unfused": u, "fused": f, "fused_overlap": fo,
                   "psum_chunks": DECODE_PSUM_CHUNKS,
                   "p50_speedup": decode_p50_speedup,
                   "token_exact": decode_exact,
                   "mean_occupancy": fo["mean_occupancy"]}}
    save_bench_json("serve", config, metrics, trajectory=True)

    # regression gates (serving analogue of the kernel-bench ratio gate):
    # under χ=4 / p=0.15 contention, resized decode must beat dense p95
    if r["p95_ms"] >= d["p95_ms"]:
        raise RuntimeError(
            f"serve bench regression: resized p95 {r['p95_ms']:.3f}ms did "
            f"not beat dense p95 {d['p95_ms']:.3f}ms under contention")
    # ... SEMI must ALSO beat it while staying lossless (migration only
    # redistributes the shed blocks; it must not change a single token)
    if not semi["token_exact"]:
        raise RuntimeError(
            "serve bench regression: semi-mode decode under contention "
            "diverged from the uncontended dense baseline — migration is "
            "supposed to be lossless")
    if s["p95_ms"] >= d["p95_ms"]:
        raise RuntimeError(
            f"serve bench regression: semi p95 {s['p95_ms']:.3f}ms did "
            f"not beat dense p95 {d['p95_ms']:.3f}ms under contention")
    # decode-path gates (ISSUE 7): fused+overlap must beat the honestly
    # priced unfused path on p50, token-for-token, and land measurably
    # closer to the occupancy roofline
    if fo["p50_ms"] >= u["p50_ms"]:
        raise RuntimeError(
            f"serve bench regression: fused+overlap decode p50 "
            f"{fo['p50_ms']:.3f}ms did not beat unfused p50 "
            f"{u['p50_ms']:.3f}ms")
    if not decode_exact:
        raise RuntimeError(
            "serve bench regression: fused decode path diverged from the "
            "unfused oracle path — the kernel must be token-exact")
    if fo["roofline_distance_s"] >= u["roofline_distance_s"]:
        raise RuntimeError(
            f"serve bench regression: fused+overlap decode is not closer "
            f"to the roofline bound ({fo['roofline_distance_s']:.6f}s vs "
            f"unfused {u['roofline_distance_s']:.6f}s)")
    # paged-KV gates (ISSUE 8): the paged engine must be invisible to the
    # control plane (token-exact under SEMI migration on the real mesh)...
    if not semi["paged_token_exact"]:
        raise RuntimeError(
            "serve bench regression: paged-KV semi decode diverged from "
            "the uncontended dense baseline — paging must not change a "
            "single token")
    # ... and on the mixed-length leg it must hold >= 2x the fixed
    # cache's resident requests at the SAME HBM budget, token-for-token,
    # without regressing p50 per-token latency
    if mixed["kv_cache_bytes"] > mixed["fixed_kv_cache_bytes"]:
        raise RuntimeError(
            f"serve bench regression: paged pool "
            f"{mixed['kv_cache_bytes']}B exceeds the fixed "
            f"{mixed['fixed_slot_capacity']}-slot cache budget "
            f"{mixed['fixed_kv_cache_bytes']}B")
    if mixed["peak_resident"] < 2 * mixed["fixed_slot_capacity"]:
        raise RuntimeError(
            f"serve bench regression: paged engine peaked at "
            f"{mixed['peak_resident']} resident requests — expected >= 2x "
            f"the fixed cache's {mixed['fixed_slot_capacity']} at equal "
            "HBM on the mixed-length trace")
    if not (mixed["token_exact"] and mixed["chunked_token_exact"]):
        raise RuntimeError(
            "serve bench regression: paged/chunked engine diverged from "
            "the fixed-slot engine on the mixed-length trace")
    # paging alone must be latency-FREE (p50 per-token parity) ...
    if mp["p50_ms"] > mf["p50_ms"] * 1.001:
        raise RuntimeError(
            f"serve bench regression: paged p50 {mp['p50_ms']:.3f}ms "
            f"regressed vs fixed p50 {mf['p50_ms']:.3f}ms")
    # ... and chunked prefill must buy its priced p50 cost back in the
    # tail: better p95 AND better mean TTFT than single-token prefill
    if mc["p95_ms"] >= mf["p95_ms"] or mc["ttft_ms"] >= mf["ttft_ms"]:
        raise RuntimeError(
            f"serve bench regression: chunked prefill did not improve the "
            f"tail (p95 {mc['p95_ms']:.3f} vs {mf['p95_ms']:.3f}ms, ttft "
            f"{mc['ttft_ms']:.3f} vs {mf['ttft_ms']:.3f}ms)")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
