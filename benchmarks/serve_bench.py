"""Serving benchmark: continuous-batching decode under contention —
dense vs. ZERO-resized vs. full SEMI (lossless migration) — per-token
latency percentiles and throughput.

Replays ONE staggered request trace through the :class:`ServeEngine`
under the SAME contention schedule (χ = 4, p = 0.15 — the paper's
contention-driven straggling regime at serve time):

* ``dense``   — control off: every decode step takes as long as the
  slowest simulated rank (bulk-synchronous TP);
* ``resized`` — the SemiController ZERO-resizes the contended rank's TP
  decode matmuls each step (plan-signature compile caching keeps the
  executable set tiny), and the REAL controlled step executes the pruned
  branch (fast but LOSSY: pruned weights change logits);
* ``semi``    — the paper's adaptive solution through the unified control
  plane: Eq.(3)-selected stragglers MIGRATE their shed blocks to helper
  ranks (multi-source, reduce-merged, β-policy "lossless"). Runs in a
  4-device subprocess (real TP migration dataflow, sim_ranks = 8 folded
  onto the mesh via the plan projection) and is gated on BOTH latency
  (beats contended dense p95) and losslessness (token-exact vs. the
  uncontended dense baseline at the same tp).

Latency epistemics match the rest of the bench suite: per-step times come
from the calibrated iteration model over the simulated rank group (the
paper itself simulates heterogeneity), while the decode dataflow runs for
real — slots, recycling, prefill-on-admit, plan dispatch, migration
collectives.

Emits stable-schema ``BENCH_serve.json`` (trajectory point) and FAILS if
resized decode does not beat dense p95, if SEMI decode does not beat
dense p95, or if SEMI decode is not token-exact.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (OUT_DIR, csv_row, is_dry_run,
                               run_subprocess_py, save_bench_json)
from repro.control import ControlConfig
from repro.launch.serve import (Request, ServeEngine,
                                latency_percentiles)

ARCH = "yi-6b"
SIM_RANKS = 8                     # paper-scale TP group for the χ schedule
SEMI_TP = 4                       # real mesh for the semi-migration run
CHI = 4.0
CONTENTION_P = 0.15


def make_trace(vocab: int, n_requests: int, prompt_len: int, gen_len: int,
               arrival_every: int, seed: int = 0):
    """Deterministic staggered trace with unequal prompt/gen lengths."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        p = prompt_len + int(rng.integers(0, max(prompt_len // 2, 1)))
        g = gen_len + int(rng.integers(0, max(gen_len // 2, 1)))
        reqs.append(Request(
            uid=i, prompt=rng.integers(0, vocab, (p,)).astype(np.int32),
            max_new_tokens=g, arrival_step=i * arrival_every))
    return reqs


def run_engine(mode: str, *, num_slots: int, max_len: int, trace_args,
               use_kernel: bool = False, seed: int = 0,
               trace_out: str = None):
    control = ControlConfig(
        mode=mode, hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS,
        use_kernel=use_kernel, seed=seed, trace_out=trace_out)
    eng = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                      control=control, seed=seed)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *trace_args))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    stats["steps"] = len(eng.history)
    stats["wall_us_per_step"] = float(
        np.mean([h["wall_s"] for h in eng.history]) * 1e6)
    stats["straggler_steps"] = sum(
        1 for h in eng.history if h.get("stragglers"))
    stats.update(eng.trace_counts())
    return eng, comps, stats


DECODE_PSUM_CHUNKS = 4


def run_decode_path_engine(leg: str, *, num_slots: int, max_len: int,
                           trace_args, seed: int = 0):
    """One decode-path leg (ISSUE 7) under the SAME contention schedule
    as the classic legs, with the decode-overhead model ON — attention
    cache reads and collective exposure priced per step from the actual
    per-slot positions:

    * ``unfused``       — oracle attention, one fat epilogue psum
                          (PR 6 behavior, honestly priced);
    * ``fused``         — fused Pallas decode attention, fat psum;
    * ``fused_overlap`` — fused attention + chunked epilogue psum.

    All three run mode="zero" so the fused path is exercised COMPOSED
    with ZERO-resized decode (the tentpole composition requirement)."""
    fused = leg != "unfused"
    chunks = DECODE_PSUM_CHUNKS if leg == "fused_overlap" else 1
    control = ControlConfig(
        mode="zero", hetero_kind="contention", chi=CHI,
        contention_p=CONTENTION_P, sim_ranks=SIM_RANKS,
        fused_attention=fused, psum_chunks=chunks,
        model_decode_overheads=True, seed=seed)
    eng = ServeEngine(ARCH, num_slots=num_slots, max_len=max_len,
                      control=control, seed=seed)
    comps = eng.run(make_trace(eng.cfg.vocab_size, *trace_args))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    hist = [h for h in eng.history if "overhead_s" in h]
    stats["mean_step_latency_s"] = float(
        np.mean([h["latency_s"] for h in hist]))
    stats["mean_overhead_s"] = float(
        np.mean([h["overhead_s"] for h in hist]))
    stats["mean_occupancy"] = float(
        np.mean([h["occupancy"] for h in hist]))
    # roofline floor for each step: χ=1 full-workload matmul + the
    # occupied-tiles-only attention read, zero exposed collective
    stats["mean_roofline_s"] = float(np.mean(
        [eng.it_model.matmul_time + h["attn_bound_s"] for h in hist]))
    stats["roofline_distance_s"] = (stats["mean_step_latency_s"]
                                    - stats["mean_roofline_s"])
    return comps, stats


_SEMI_CHILD = """
import json
import numpy as np
from repro.control import ControlConfig
from repro.launch.serve import (Request, ServeEngine,
                                latency_percentiles)
from benchmarks.serve_bench import (ARCH, CHI, CONTENTION_P, SEMI_TP,
                                    SIM_RANKS, make_trace)

p = json.loads(__SEMI_PARAMS__)

def run(mode, hetero):
    control = ControlConfig(
        mode=mode, hetero_kind=hetero, chi=CHI, contention_p=CONTENTION_P,
        sim_ranks=SIM_RANKS, max_sources=SIM_RANKS - 1, seed=p["seed"])
    eng = ServeEngine(ARCH, num_slots=p["num_slots"], max_len=p["max_len"],
                      tp=SEMI_TP, control=control, seed=p["seed"])
    comps = eng.run(make_trace(eng.cfg.vocab_size, *p["trace_args"]))
    eng.close()
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    stats.update(eng.trace_counts())
    return eng, comps, stats

# uncontended dense baseline at the SAME tp: the token-exactness reference
ref_eng, ref, ref_stats = run("off", "none")
eng, comps, stats = run("semi", "contention")
tok_ref = {c.uid: c.tokens for c in ref}
exact = all(np.array_equal(c.tokens, tok_ref[c.uid]) for c in comps)
out = {
    "semi": stats,
    "dense_ref": ref_stats,
    "token_exact": bool(exact),
    "migrated_steps": sum(1 for h in eng.history if h.get("mig_srcs")),
    "resize_steps": sum(1 for h in eng.history
                        if h.get("max_bucket", 0) > 0),
    "straggler_steps": sum(1 for h in eng.history if h.get("stragglers")),
}
print("SEMI_JSON:" + json.dumps(out))
"""


def run_semi_subprocess(*, num_slots, max_len, trace_args, seed=0) -> dict:
    """Run the SEMI-migration leg on a real SEMI_TP-rank host mesh.

    A subprocess (the shared bench harness) is required because the XLA
    host-device-count flag must be set before jax initializes — the
    parent process is already running single-device legs."""
    params = json.dumps({"num_slots": num_slots, "max_len": max_len,
                         "trace_args": list(trace_args), "seed": seed})
    code = _SEMI_CHILD.replace("__SEMI_PARAMS__", repr(params))
    stdout = run_subprocess_py(code, devices=SEMI_TP, timeout=1800,
                               with_bench_path=True)
    for line in stdout.splitlines():
        if line.startswith("SEMI_JSON:"):
            return json.loads(line[len("SEMI_JSON:"):])
    raise RuntimeError(f"semi serve subprocess emitted no result:\n{stdout}")


def main() -> list:
    dry = is_dry_run()
    num_slots = 2 if dry else 4
    n_requests = 4 if dry else 12
    prompt_len = 4 if dry else 8
    gen_len = 4 if dry else 16
    arrival_every = 2
    max_len = prompt_len * 2 + gen_len * 2      # headroom for jittered lens
    trace_args = (n_requests, prompt_len, gen_len, arrival_every)

    rows = []
    results = {}
    for key, mode in (("dense", "off"), ("resized", "zero")):
        # every bench run emits a replayable telemetry trace: a recorded
        # contention episode is a deterministic regression scenario
        trace_out = os.path.join(OUT_DIR, "traces", f"serve_{key}.jsonl")
        eng, comps, stats = run_engine(mode, num_slots=num_slots,
                                       max_len=max_len,
                                       trace_args=trace_args,
                                       trace_out=trace_out)
        results[key] = stats
        stats["trace_out"] = os.path.relpath(trace_out, OUT_DIR)
        rows.append(csv_row(
            f"serve_{key}", stats["p95_ms"] * 1e3,
            f"p50={stats['p50_ms']:.3f}ms,p95={stats['p95_ms']:.3f}ms,"
            f"p99={stats['p99_ms']:.3f}ms,tok_s={stats['tok_per_s']:.1f},"
            f"compiles={stats['plan_compiles']}"))

    # -- SEMI leg: lossless migration on a real 4-rank mesh ---------------
    semi = run_semi_subprocess(num_slots=num_slots, max_len=max_len,
                               trace_args=trace_args)
    s = semi["semi"]
    rows.append(csv_row(
        "serve_semi", s["p95_ms"] * 1e3,
        f"p50={s['p50_ms']:.3f}ms,p95={s['p95_ms']:.3f}ms,"
        f"tok_s={s['tok_per_s']:.1f},mig_steps={semi['migrated_steps']},"
        f"token_exact={semi['token_exact']}"))

    # -- decode-path legs (ISSUE 7): fused attention + chunked psum -------
    decode_path = {}
    decode_tokens = {}
    for leg in ("unfused", "fused", "fused_overlap"):
        comps, stats = run_decode_path_engine(
            leg, num_slots=num_slots, max_len=max_len,
            trace_args=trace_args)
        decode_path[leg] = stats
        decode_tokens[leg] = {c.uid: c.tokens for c in comps}
        rows.append(csv_row(
            f"serve_decode_{leg}", stats["p50_ms"] * 1e3,
            f"p50={stats['p50_ms']:.3f}ms,p95={stats['p95_ms']:.3f}ms,"
            f"occ={stats['mean_occupancy']:.2f},"
            f"roof_dist={stats['roofline_distance_s']*1e3:.3f}ms"))

    u, f, fo = (decode_path["unfused"], decode_path["fused"],
                decode_path["fused_overlap"])
    decode_exact = all(
        np.array_equal(decode_tokens["unfused"][uid], toks)
        for leg in ("fused", "fused_overlap")
        for uid, toks in decode_tokens[leg].items())
    decode_p50_speedup = u["p50_ms"] / max(fo["p50_ms"], 1e-12)
    rows.append(csv_row(
        "serve_decode_speedup", 0.0,
        f"p50_speedup={decode_p50_speedup:.2f}x,"
        f"token_exact={decode_exact},"
        f"roof_dist_unfused={u['roofline_distance_s']*1e3:.3f}ms,"
        f"roof_dist_both={fo['roofline_distance_s']*1e3:.3f}ms"))

    d, r = results["dense"], results["resized"]
    speedup_p95 = d["p95_ms"] / max(r["p95_ms"], 1e-12)
    speedup_tput = r["tok_per_s"] / max(d["tok_per_s"], 1e-12)
    semi_speedup_p95 = d["p95_ms"] / max(s["p95_ms"], 1e-12)
    rows.append(csv_row(
        "serve_speedup", 0.0,
        f"p95_speedup={speedup_p95:.2f}x,tput_speedup={speedup_tput:.2f}x,"
        f"semi_p95_speedup={semi_speedup_p95:.2f}x,"
        f"chi={CHI},p={CONTENTION_P}"))

    config = {"arch": ARCH, "sim_ranks": SIM_RANKS, "chi": CHI,
              "contention_p": CONTENTION_P, "num_slots": num_slots,
              "n_requests": n_requests, "prompt_len": prompt_len,
              "gen_len": gen_len, "arrival_every": arrival_every,
              "semi_tp": SEMI_TP, "dry_run": dry}
    metrics = {"dense": results["dense"], "resized": results["resized"],
               "semi": s, "semi_dense_ref": semi["dense_ref"],
               "semi_token_exact": semi["token_exact"],
               "semi_migrated_steps": semi["migrated_steps"],
               "semi_resize_steps": semi["resize_steps"],
               "p95_speedup": speedup_p95, "tput_speedup": speedup_tput,
               "semi_p95_speedup": semi_speedup_p95,
               "decode_path": {
                   "unfused": u, "fused": f, "fused_overlap": fo,
                   "psum_chunks": DECODE_PSUM_CHUNKS,
                   "p50_speedup": decode_p50_speedup,
                   "token_exact": decode_exact,
                   "mean_occupancy": fo["mean_occupancy"]}}
    save_bench_json("serve", config, metrics, trajectory=True)

    # regression gates (serving analogue of the kernel-bench ratio gate):
    # under χ=4 / p=0.15 contention, resized decode must beat dense p95
    if r["p95_ms"] >= d["p95_ms"]:
        raise RuntimeError(
            f"serve bench regression: resized p95 {r['p95_ms']:.3f}ms did "
            f"not beat dense p95 {d['p95_ms']:.3f}ms under contention")
    # ... SEMI must ALSO beat it while staying lossless (migration only
    # redistributes the shed blocks; it must not change a single token)
    if not semi["token_exact"]:
        raise RuntimeError(
            "serve bench regression: semi-mode decode under contention "
            "diverged from the uncontended dense baseline — migration is "
            "supposed to be lossless")
    if s["p95_ms"] >= d["p95_ms"]:
        raise RuntimeError(
            f"serve bench regression: semi p95 {s['p95_ms']:.3f}ms did "
            f"not beat dense p95 {d['p95_ms']:.3f}ms under contention")
    # decode-path gates (ISSUE 7): fused+overlap must beat the honestly
    # priced unfused path on p50, token-for-token, and land measurably
    # closer to the occupancy roofline
    if fo["p50_ms"] >= u["p50_ms"]:
        raise RuntimeError(
            f"serve bench regression: fused+overlap decode p50 "
            f"{fo['p50_ms']:.3f}ms did not beat unfused p50 "
            f"{u['p50_ms']:.3f}ms")
    if not decode_exact:
        raise RuntimeError(
            "serve bench regression: fused decode path diverged from the "
            "unfused oracle path — the kernel must be token-exact")
    if fo["roofline_distance_s"] >= u["roofline_distance_s"]:
        raise RuntimeError(
            f"serve bench regression: fused+overlap decode is not closer "
            f"to the roofline bound ({fo['roofline_distance_s']:.6f}s vs "
            f"unfused {u['roofline_distance_s']:.6f}s)")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
