"""Figs. 7/8/9 reproduction: heterogeneous χ sweep.

A round-robin straggler (χ ∈ {0,2,4,8}) hits one of e=8 paper-scale ranks.
Variants: Baseline (no control), ZERO-Pri (Eq.1 ratio), ZERO-PriDiffE
(empirical γ=1/2), ZERO-PriDiffR (Eq.1 ratio + per-layer differentiation).

RT comes from the paper-scale workload model (the same epistemics as the
paper's sleep-injection testbed): the bulk-synchronous step takes
max_i(M·w_i·χ_i + C); the controller chooses w_i. ACC comes from REAL
reduced-scale training with the actual jitted control path (subprocess,
4 host devices).

Headline paper claims validated here: χ=8 → ZERO-Pri speedup ≈ 3.5×
over Baseline; accuracy loss small (≈1.3% paper).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (PAPER_E, csv_row, paper_scale_model,
                               run_subprocess_py, save_json)
from repro.config import WorkloadControlConfig
from repro.core.controller import SemiController, work_fraction
from repro.core.hetero import HeteroSchedule

CHIS = (2.0, 4.0, 8.0)
NUM_BLOCKS = 64


def modeled_rt(chi: float, mode: str, gamma_override=None) -> float:
    """Mean modeled step time over a straggler rotation period."""
    m = paper_scale_model()
    cfg = WorkloadControlConfig(enabled=mode != "off", mode="zero",
                                block_size=128)
    controller = SemiController(cfg, PAPER_E, m, NUM_BLOCKS) \
        if mode != "off" else None
    sched = HeteroSchedule(num_ranks=PAPER_E, kind="round_robin",
                           chis=(chi,), period=1)
    work = np.ones(PAPER_E)
    total = 0.0
    steps = PAPER_E
    for t in range(steps):
        x = sched.chi(t)
        if controller is not None:
            times = m.times(x, np.ones(PAPER_E))
            plan, rep = controller.plan(times)
            if gamma_override is not None:
                from repro.core.workload import bucket_for_gamma
                b = plan.dynamic.bucket_by_rank
                b[b > 0] = bucket_for_gamma(gamma_override, cfg.gamma_buckets)
            work = work_fraction(plan, NUM_BLOCKS)
        total += m.step_time(x, work)
    return total / steps


ACC_SNIPPET = """
from repro.launch.train import run_training
import json
res = {}
for name, kw in {
    "baseline": dict(control_mode="off"),
    "pri": dict(control_mode="zero", selection="priority"),
    "pridiffE": dict(control_mode="zero", selection="priority",
                     force_gamma=None, imputation="zero"),
}.items():
    h = run_training("vit-1b", steps=40, tp=4, batch=16, data_noise=1.3,
                     hetero_kind="round_robin", chi=4.0, hetero_period=8,
                     eval_every=40, quiet=True, log_every=1000, **kw)
    res[name] = h["acc"][-1] if h["acc"] else None
print("RESULT" + json.dumps(res))
"""


def main() -> list:
    rows = []
    table = {}
    base_homo = modeled_rt(1.0, "off")
    for chi in CHIS:
        rt_base = modeled_rt(chi, "off")
        rt_pri = modeled_rt(chi, "zero")
        rt_diffE = modeled_rt(chi, "zero", gamma_override=0.5)
        table[chi] = {"baseline": rt_base, "pri": rt_pri, "pridiffE": rt_diffE}
        rows.append(csv_row(f"fig9_rt_chi{int(chi)}_baseline",
                            rt_base * 1e6, f"x_homo={rt_base/base_homo:.2f}"))
        rows.append(csv_row(f"fig9_rt_chi{int(chi)}_zero_pri",
                            rt_pri * 1e6,
                            f"speedup_vs_baseline={rt_base/rt_pri:.2f}"))
        rows.append(csv_row(f"fig9_rt_chi{int(chi)}_zero_pridiffE",
                            rt_diffE * 1e6,
                            f"speedup_vs_baseline={rt_base/rt_diffE:.2f}"))
    # headline: chi=8 speedup ~3.5x (paper)
    sp8 = table[8.0]["baseline"] / table[8.0]["pri"]
    rows.append(csv_row("fig9_headline_chi8_speedup", 0.0,
                        f"speedup={sp8:.2f},paper=3.5,within_25pct="
                        f"{abs(sp8 - 3.5) / 3.5 < 0.25}"))

    out = run_subprocess_py(ACC_SNIPPET, devices=4, timeout=3600)
    res = json.loads(out.split("RESULT")[1].strip())
    for k, v in res.items():
        if v is not None:
            rows.append(csv_row(f"fig9_acc_{k}", 0.0, f"acc={v:.3f}"))
    if res.get("baseline") and res.get("pri"):
        loss = res["baseline"] - res["pri"]
        rows.append(csv_row("fig9_acc_loss_pri_vs_baseline", 0.0,
                            f"acc_loss={loss:.3f},paper=0.013"))
    save_json("fig9_hetero", {"rt": table, "acc": res})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
