"""Fig. 3 reproduction: imputation policy (Same / Average / Zero) vs ACC.

A controlled classifier is trained with γ=0.5 resizing on every step; the
pruned gradient rows are imputed by each policy via
``repro.core.resizing.impute_gradients``. The paper's finding to validate:
Same best, Zero beats Average, all below the unpruned baseline.

The model is a 2-layer MLP classifier on the pattern-image task (the
controlled matmul is exactly the paper's Fig. 2 dataflow, explicit and
imperative so each policy is applied literally).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, save_json
from repro.core import resizing
from repro.data.pipeline import PatternImageStream, patchify


def train_mlp(imputation: str, *, gamma: float = 0.5, steps: int = 150,
              hidden: int = 256, block: int = 16, lr: float = 5e-2,
              seed: int = 0, rotate_every: int = 10) -> float:
    rng = np.random.default_rng(seed)
    d_in, n_cls = 64 * 48, 10
    w1 = jnp.asarray(rng.standard_normal((d_in, hidden)) * (d_in ** -0.5),
                     jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((hidden, n_cls)) * (hidden ** -0.5),
                     jnp.float32)
    nb = hidden // block
    kc = max(1, nb - int(round(gamma * nb)))
    stream = iter(PatternImageStream(batch_size=64, seed=seed))
    test = iter(PatternImageStream(batch_size=64, seed=seed + 999))
    prev_g2 = jnp.zeros_like(w2)

    @jax.jit
    def step(w1, w2, keep, x, y, prev_g2):
        def loss_fn(w1, w2):
            h = jax.nn.relu(x @ w1)
            # the paper's pruned second matmul: prune hidden (contraction)
            logits = resizing.resized_matmul(h, w2, keep, block=block)
            lp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))
        loss, (g1, g2) = jax.value_and_grad(loss_fn, (0, 1))(w1, w2)
        kept = resizing.keep_mask(keep, nb, block)
        g2_imp = resizing.impute_rows(g2, kept, imputation, prev_g2)
        # "Same" keeps each row's most recent REAL gradient (paper Fig. 3)
        new_prev = jnp.where(kept[:, None], g2, prev_g2)
        return w1 - lr * g1, w2 - lr * g2_imp, loss, new_prev

    keep = jnp.arange(nb, dtype=jnp.int32)[:kc]
    for i in range(steps):
        b = next(stream)
        x = jnp.asarray(patchify(b["images"]).reshape(64, -1))
        y = jnp.asarray(b["labels"])
        # keep set rotates every few steps (priority-style slow rotation,
        # so a pruned row's "previous" gradient is recent — Sec. III-B)
        if gamma > 0.0 and i % rotate_every == 0:
            keep = jnp.asarray(np.sort(rng.choice(nb, kc, replace=False)),
                               jnp.int32)
        elif gamma == 0.0:
            keep = jnp.arange(nb, dtype=jnp.int32)
        w1, w2, loss, prev_g2 = step(w1, w2, keep, x, y, prev_g2)

    # eval
    correct = total = 0
    for _ in range(8):
        b = next(test)
        x = jnp.asarray(patchify(b["images"]).reshape(64, -1))
        logits = jax.nn.relu(x @ w1) @ w2
        correct += int((np.asarray(logits.argmax(-1)) == b["labels"]).sum())
        total += 64
    return correct / total


def main(steps: int = 40) -> list:
    rows = []
    accs = {}
    for policy in ("baseline", "same", "zero", "average"):
        if policy == "baseline":
            acc = np.mean([train_mlp("zero", gamma=0.0, steps=steps, seed=s)
                           for s in (0, 1)])
        else:
            acc = np.mean([train_mlp(policy, gamma=0.75, steps=steps, seed=s)
                           for s in (0, 1)])
        accs[policy] = float(acc)
        rows.append(csv_row(f"fig3_imputation_{policy}", 0.0,
                            f"acc={acc:.3f}"))
    # The decision-relevant claim (Zero beats Average; Zero is the paper's
    # final choice). Note: the paper found Same best at full ViT scale; at
    # our reduced scale stale gradients hurt more than zeros — recorded as
    # a refuted sub-hypothesis in EXPERIMENTS.md §Paper-validation.
    ok = accs["zero"] >= accs["average"] and accs["baseline"] >= accs["zero"]
    rows.append(csv_row("fig3_ordering_zero>=average", 0.0, f"holds={ok}"))
    save_json("fig3_imputation", accs)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
