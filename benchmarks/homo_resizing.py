"""Figs. 5/6 reproduction: homogeneous γ sweep — ACC + RT vs pruning ratio.

Every rank prunes γ of its FFN blocks each step (ZERO-Rd random selection
vs ZERO-Pri priority selection). ACC from REAL reduced-ViT training
through the controlled jitted step; RT from the paper-scale workload
model: RT(γ)/RT(0) = ((1−γ)·M + C) / (M + C).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (csv_row, paper_scale_model, run_subprocess_py,
                               save_json)

GAMMAS = (0.25, 0.5, 0.875)      # the paper's 1/4, 1/2, 9/10 (bucketized)


def modeled_rt_ratio(gamma: float, arch: str = "vit-1b") -> float:
    m = paper_scale_model(arch)
    full = m.matmul_time + m.other_time
    return ((1 - gamma) * m.matmul_time + m.other_time) / full


TRAIN_SNIPPET = """
from repro.launch.train import run_training
import json, numpy as np
res = {{}}
for gamma in {gammas}:
    for sel in ("random", "priority"):
        h = run_training("vit-1b", steps=40, tp=4, batch=16, data_noise=1.3,
                         control_mode="zero", hetero_kind="static",
                         chi=1e9, force_gamma=gamma, selection=sel,
                         eval_every=40, quiet=True, log_every=1000)
        res[f"{{gamma}}/{{sel}}"] = h["acc"][-1] if h["acc"] else None
h = run_training("vit-1b", steps=40, tp=4, batch=16, data_noise=1.3, control_mode="off",
                 eval_every=40, quiet=True, log_every=1000)
res["0.0/baseline"] = h["acc"][-1] if h["acc"] else None
print("RESULT" + json.dumps(res))
"""


def main(quick: bool = False) -> list:
    rows = []
    rt = {g: modeled_rt_ratio(g) for g in GAMMAS}
    for g in GAMMAS:
        rows.append(csv_row(f"fig5_rt_ratio_gamma{g}", 0.0,
                            f"modeled_rt_frac={rt[g]:.3f}"))
    for arch in ("vit-1b", "vit-3b"):
        m = paper_scale_model(arch)
        rows.append(csv_row(f"fig56_epoch_time_{arch}",
                            (m.matmul_time + m.other_time) * 1e6,
                            f"paper_scale_step_s={m.matmul_time + m.other_time:.3f}"))

    out = run_subprocess_py(TRAIN_SNIPPET.format(gammas=GAMMAS), devices=4,
                            timeout=3600)
    import json
    res = json.loads(out.split("RESULT")[1].strip())
    base = res.get("0.0/baseline") or 1.0
    for key, acc in sorted(res.items()):
        if acc is None:
            continue
        rows.append(csv_row(f"fig5_acc_{key.replace('/', '_')}", 0.0,
                            f"acc={acc:.3f},loss_vs_base={base - acc:.3f}"))
    # Pri should lose less accuracy than Rd at the big γ
    big = max(GAMMAS)
    pri = res.get(f"{big}/priority")
    rd = res.get(f"{big}/random")
    if pri is not None and rd is not None:
        rows.append(csv_row("fig5_pri_beats_rd_at_max_gamma", 0.0,
                            f"pri={pri:.3f},rd={rd:.3f},holds={pri >= rd - 0.02}"))
    save_json("fig56_homo_resizing", {"rt_ratio": rt, "acc": res})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
