"""Telemetry suite: trace-replay smoke + closed-loop agreement + overhead.

Three checks, all gated (the job FAILS on regression):

* **closed-loop agreement** — the controller driven over the committed
  bursty-contention fixture in measured mode (estimator reconstructions
  of mitigated times) must produce the SAME plan-signature set and the
  SAME number of compiled signatures as modeled mode (the χ-oracle), and
  agree on >= 80% of per-step decisions (the remainder is the 1-2 step
  estimation lag at burst edges).
* **replay determinism** — replaying the fixture twice yields identical
  decision streams (traces are regression scenarios, so replay must be
  bit-stable).
* **telemetry overhead** — the measured per-step host cost of the whole
  telemetry path (simulated measurement + estimator update + trace
  append) must stay under 2% of the dense baseline step at paper scale
  (the deployment claim: closing the loop is free relative to a real
  training step).

Emits stable-schema ``telemetry.json`` (experiments/bench/).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import (ROOT, csv_row, is_dry_run, paper_scale_model,
                               save_bench_json)
from repro.config import WorkloadControlConfig
from repro.core.controller import SemiController, decision_key, work_fraction
from repro.core.hetero import IterationModel
from repro.core.workload import PlanCompileCache
from repro.telemetry import (EstimatorConfig, StepSample, StragglerEstimator,
                             TraceReader, TraceWriter, schedule_from_trace)

FIXTURE = os.path.join(ROOT, "examples", "traces", "bursty_contention.jsonl")
NUM_BLOCKS = 64


def drive(measured: bool, steps: int, trace_out: str = None):
    """One closed control loop over the replayed fixture."""
    reader = TraceReader(FIXTURE)
    model = IterationModel(reader.matmul_time, reader.other_time)
    sched = schedule_from_trace(FIXTURE)
    e = reader.num_ranks
    cfg = WorkloadControlConfig(enabled=True, mode="semi", block_size=8,
                                max_migration_sources=3,
                                times="measured" if measured else "modeled")
    ctl = SemiController(cfg, e, model, num_blocks=NUM_BLOCKS, seed=0)
    est = (StragglerEstimator(model, e, EstimatorConfig.from_control(cfg))
           if measured else None)
    cache = PlanCompileCache(lambda s: object())
    writer = (TraceWriter(trace_out, e, matmul_time=model.matmul_time,
                          other_time=model.other_time,
                          meta={"bench": "telemetry", "measured": measured})
              if trace_out else None)
    keys, sigs = [], []
    for t in range(steps):
        chi = sched.chi(t)
        if measured:
            times = est.full_times() if est.ready else est.nominal_times()
        else:
            times = model.times(chi, np.ones(e))
        plan, rep = ctl.plan(times)
        cache.get(plan.static.signature())
        frac = work_fraction(plan, NUM_BLOCKS)
        meas = model.times(chi, frac)
        if measured:
            est.update(meas, frac)
        if writer:
            writer.append(StepSample(step=t, rank_times=meas,
                                     plan_signature=plan.static.signature_str(),
                                     work_frac=frac))
        keys.append(decision_key(rep))
        sigs.append(plan.static.signature_str())
    if writer:
        writer.close()
    return keys, sigs, cache


def overhead_us_per_step(steps: int = 200) -> float:
    """Host cost of the full per-step telemetry path, min-of-repeats."""
    reader = TraceReader(FIXTURE)
    model = IterationModel(reader.matmul_time, reader.other_time)
    e = reader.num_ranks
    chi = np.ones(e)
    chi[0] = 4.0
    frac = np.ones(e)
    best = float("inf")
    with tempfile.TemporaryDirectory() as d:
        for _ in range(3):
            est = StragglerEstimator(model, e)
            writer = TraceWriter(os.path.join(d, "t.jsonl"), e,
                                 matmul_time=model.matmul_time,
                                 other_time=model.other_time)
            t0 = time.perf_counter()
            for t in range(steps):
                meas = model.times(chi, frac)
                est.update(meas, frac)
                writer.append(StepSample(step=t, rank_times=meas,
                                         plan_signature="tp8b8shed[]",
                                         work_frac=frac))
            dt = time.perf_counter() - t0
            writer.close()
            best = min(best, dt / steps * 1e6)
    return best


def main() -> list:
    dry = is_dry_run()
    steps = 60 if dry else 200
    rows = []

    # -- closed-loop agreement: measured vs modeled plan decisions --------
    out_dir = os.path.join(ROOT, "experiments", "bench", "traces")
    km, sm, cm = drive(False, steps,
                       trace_out=os.path.join(out_dir, "telemetry_modeled.jsonl"))
    ke, se, ce = drive(True, steps,
                       trace_out=os.path.join(out_dir, "telemetry_measured.jsonl"))
    exact = sum(1 for a, b in zip(km, ke) if a == b)
    agree_frac = exact / steps
    rows.append(csv_row(
        "telemetry_agreement", 0.0,
        f"exact={exact}/{steps},sigs_modeled={len(set(sm))},"
        f"sigs_measured={len(set(se))},compiles={cm.compile_count}/"
        f"{ce.compile_count}"))
    if set(se) != set(sm):
        raise RuntimeError(
            f"telemetry regression: measured-mode signature set {set(se)} "
            f"!= modeled {set(sm)}")
    if ce.compile_count != cm.compile_count:
        raise RuntimeError(
            f"telemetry regression: measured mode compiled "
            f"{ce.compile_count} signatures, modeled {cm.compile_count} — "
            "the closed loop must not cause extra recompiles")
    if agree_frac < 0.8:
        raise RuntimeError(
            f"telemetry regression: measured-mode decisions agree with "
            f"modeled on only {agree_frac:.0%} of steps (< 80%)")

    # -- replay determinism ----------------------------------------------
    ke2, se2, _ = drive(True, steps)
    if ke2 != ke:
        raise RuntimeError("telemetry regression: fixture replay is not "
                           "deterministic")
    rows.append(csv_row("telemetry_replay_deterministic", 0.0, "ok=True"))

    # -- overhead vs the dense baseline step ------------------------------
    oh_us = overhead_us_per_step(steps=60 if dry else 200)
    dense_us = paper_scale_model().step_time(np.ones(8), np.ones(8)) * 1e6
    ratio = oh_us / dense_us
    rows.append(csv_row("telemetry_overhead", oh_us,
                        f"dense_step_us={dense_us:.0f},ratio={ratio:.4f}"))
    if ratio >= 0.02:
        raise RuntimeError(
            f"telemetry regression: per-step telemetry cost {oh_us:.0f}us "
            f"is {ratio:.1%} of the dense baseline step ({dense_us:.0f}us) "
            "— must stay under 2%")

    config = {"fixture": os.path.relpath(FIXTURE, ROOT), "steps": steps,
              "num_blocks": NUM_BLOCKS, "dry_run": dry}
    metrics = {"exact_agreement": exact, "steps": steps,
               "agreement_frac": agree_frac,
               "signatures_modeled": sorted(set(sm)),
               "signatures_measured": sorted(set(se)),
               "compiles_modeled": cm.compile_count,
               "compiles_measured": ce.compile_count,
               "overhead_us_per_step": oh_us,
               "dense_step_us": dense_us, "overhead_ratio": ratio}
    save_bench_json("telemetry", config, metrics)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
