"""Ablations for the two TPU-adaptation assumptions (DESIGN.md §7.1/7.2).

1. γ-bucket quantization (continuous γ → 8 buckets, rounded UP): how much
   work is over-pruned, and does the waiting cost stay fully offset?
2. Pruning granularity (single columns → 128-lane blocks): accuracy cost
   of block-mean priority selection, measured on the Fig. 3 MLP setup.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, paper_scale_model, save_json
from benchmarks.imputation import train_mlp
from repro.core.workload import DEFAULT_BUCKETS, bucket_for_gamma


def bucket_waste(n_buckets: int, samples: int = 2000) -> tuple:
    """Mean over-pruning (bucketγ − exactγ) and max residual wait for a
    uniform γ* distribution, under round-up bucketing."""
    buckets = tuple(i / n_buckets for i in range(n_buckets))
    rng = np.random.default_rng(0)
    gammas = rng.uniform(0, buckets[-1], samples)
    over = []
    residual_wait = []
    for g in gammas:
        b = buckets[bucket_for_gamma(g, buckets)]
        over.append(b - g)
        residual_wait.append(max(0.0, g - b))   # >0 would mean waiting remains
    return float(np.mean(over)), float(np.max(residual_wait))


def main() -> list:
    rows = []
    m = paper_scale_model()
    waste = {}
    for n in (2, 4, 8, 16):
        over, resid = bucket_waste(n)
        # over-pruned work costs accuracy, not time; residual wait must be 0
        waste[n] = {"mean_overprune": over, "max_residual_wait": resid}
        rows.append(csv_row(
            f"ablate_buckets_n{n}", 0.0,
            f"mean_overpruned_gamma={over:.4f},max_residual_wait={resid:.4f}"))
    rows.append(csv_row(
        "ablate_buckets_roundup_offsets_all_wait", 0.0,
        f"holds={all(v['max_residual_wait'] == 0.0 for v in waste.values())}"))

    # granularity: per-column (block=1 equivalent via block=2 lanes... we
    # compare 2 vs 16 vs 64-lane blocks at fixed gamma on the MLP task)
    acc = {}
    for block in (2, 16, 64):
        acc[block] = float(np.mean(
            [train_mlp("zero", gamma=0.5, steps=40, block=block, seed=s)
             for s in (0, 1)]))
        rows.append(csv_row(f"ablate_granularity_block{block}", 0.0,
                            f"acc={acc[block]:.3f}"))
    spread = max(acc.values()) - min(acc.values())
    rows.append(csv_row("ablate_granularity_cost", 0.0,
                        f"acc_spread={spread:.3f},"
                        f"block_pruning_cheap={spread < 0.05}"))
    save_json("ablations", {"bucket_waste": waste, "granularity_acc": acc})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
