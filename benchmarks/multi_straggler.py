"""Fig. 11 reproduction: multi-straggler λ sweep.

4 of 8 ranks straggle with χ = {8, 6, 4, 2}. λ = how many of them (from
the slowest down) run MIGRATION; the rest run resizing to T_min (Alg. 2).
RT modeled at paper scale with Φ1 comm costs; ACC modeled from the real
per-γ accuracy curve measured in the Fig. 5 benchmark (resizing is the
only lossy component; migration is exact). The controller's own Eq. (3)
prediction of the sweet spot is reported against the sweep's argmin.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (OUT_DIR, PAPER_E, csv_row, paper_scale_model,
                               save_json)
from repro.config import WorkloadControlConfig
from repro.core.controller import (SemiController, eq3_migration_prefix,
                                   pretest_cost_functions)

NUM_BLOCKS = 64
STRAGGLER_CHIS = (8.0, 6.0, 4.0, 2.0)


def sweep_lambda(lam: int):
    """Returns (modeled step time, mean resize γ over the resizing group)."""
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    chi = np.ones(PAPER_E)
    chi[: len(STRAGGLER_CHIS)] = STRAGGLER_CHIS
    t_min = m.matmul_time + m.other_time
    work = np.ones(PAPER_E)
    mig_volume = 0.0
    gammas = []
    for i, c in enumerate(chi):
        if c <= 1.0:
            continue
        excess = 1.0 - 1.0 / c          # work fraction to shed to hit t_min
        if i < lam:                      # migration group (lossless)
            work[i] = 1.0 - excess
            mig_volume += excess * NUM_BLOCKS
        else:                            # resizing group (lossy)
            work[i] = 1.0 - excess
            gammas.append(excess)
    # helpers absorb migrated work
    helpers = [i for i in range(PAPER_E) if chi[i] <= 1.0]
    for i in helpers:
        work[i] += (mig_volume / NUM_BLOCKS) / max(len(helpers), 1)
    t = m.step_time(chi, work) + (costs.phi1(mig_volume) if mig_volume else 0)
    return t, (float(np.mean(gammas)) if gammas else 0.0)


def acc_model(mean_gamma: float) -> float:
    """Interpolate the REAL γ→ACC curve measured by benchmarks/homo_resizing
    (falls back to a linear model if that benchmark hasn't run yet)."""
    path = os.path.join(OUT_DIR, "fig56_homo_resizing.json")
    pts = {0.0: None}
    if os.path.exists(path):
        data = json.load(open(path))
        base = data["acc"].get("0.0/baseline")
        if base:
            pts = {0.0: base}
            for k, v in data["acc"].items():
                if v is None or "priority" not in k:
                    continue
                g = float(k.split("/")[0])
                pts[g] = v
    if len(pts) > 1 and None not in pts.values():
        gs = np.array(sorted(pts))
        accs = np.array([pts[g] for g in gs])
        return float(np.interp(mean_gamma, gs, accs))
    return 1.0 - 0.25 * mean_gamma       # fallback linear loss model


def main() -> list:
    rows = []
    table = {}
    best_lam, best_t = None, np.inf
    for lam in range(0, 5):
        t, g = sweep_lambda(lam)
        # Fig. 7 observation: pruning on a straggler SUBSET dilutes the
        # homogeneous-γ accuracy loss by the resizing-rank fraction
        n_resize = 4 - lam
        a = acc_model(g * n_resize / PAPER_E)
        table[lam] = {"rt": t, "mean_gamma": g, "acc": a}
        rows.append(csv_row(f"fig11_lambda{lam}", t * 1e6,
                            f"step_s={t:.3f},mean_resize_gamma={g:.2f},"
                            f"acc={a:.3f}"))
        # "sweet spot": fastest λ whose modeled loss vs the lossless
        # λ=4 stays under 2% (the paper's "small accuracy penalty")
        pass
    lossless = table[4]["acc"]
    for lam in range(0, 5):
        if lossless - table[lam]["acc"] < 0.02 + 1e-9 \
                and table[lam]["rt"] < best_t:
            best_lam, best_t = lam, table[lam]["rt"]

    # what does the controller's Eq.(3) pick?
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    chi = np.ones(PAPER_E)
    chi[:4] = STRAGGLER_CHIS
    times = m.times(chi, np.ones(PAPER_E))
    x = eq3_migration_prefix(np.sort(times)[::-1], np.full(PAPER_E, NUM_BLOCKS),
                             costs, PAPER_E)
    rows.append(csv_row("fig11_sweet_spot", 0.0,
                        f"sweep_best_lambda={best_lam},eq3_pick={x},"
                        f"paper_spot=3"))
    save_json("fig11_multi_straggler",
              {"sweep": table, "eq3_pick": x, "best": best_lam})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
