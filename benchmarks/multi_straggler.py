"""Fig. 11 reproduction: multi-straggler λ sweep on the REAL dataflow.

4 of 8 ranks straggle with χ = {8, 6, 4, 2}. λ = how many of them (from
the slowest down) run CONCURRENT MIGRATION; the rest run resizing to
T_min (Alg. 2). The seed version approximated the multi-straggler case by
diluting single-straggler pruning; this one drives the real multi-source
plan machinery end to end:

* the λ sweep builds genuine :class:`WorkloadPlan`s — per-source sheds
  quantized by :func:`quantize_shed` into the canonical ``PlanStatic``
  signature — and models RT through :func:`work_fraction`, the same
  function the trainer uses;
* one configuration per λ ∈ {2, 3} is EXECUTED with ``controlled_ffn``
  on a host-device mesh (subprocess): verifies the lossless claim
  numerically (max |y − oracle|) and times the fused multi-source
  broadcast against dense and single-source baselines;
* ACC modeled from the real per-γ accuracy curve measured in the Fig. 5
  benchmark (resizing is the only lossy component; migration is exact).

The controller's own Eq. (3) prediction of the sweet spot is reported
against the sweep's argmin, and the whole result lands in the
stable-schema ``BENCH_multi_straggler.json`` trajectory point.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import (OUT_DIR, PAPER_E, csv_row, is_dry_run,
                               paper_scale_model, run_subprocess_py,
                               save_bench_json)
from repro.telemetry import StepSample, TraceWriter
from repro.config import WorkloadControlConfig
from repro.core.controller import (SemiController, eq3_migration_prefix,
                                   pretest_cost_functions, work_fraction)
from repro.core.geometry import geometry_from_chi
from repro.core.workload import (DEFAULT_BUCKETS, PlanDynamic, PlanStatic,
                                 WorkloadPlan, bucket_for_gamma,
                                 quantize_shed)

NUM_BLOCKS = 64
STRAGGLER_CHIS = (8.0, 6.0, 4.0, 2.0)

# the geometry leg's scenario: a PERSISTENT 2x speed ratio on two ranks
# (the static-geometry sweet case — the imbalance never moves, so a
# χ-seeded uneven split absorbs it once instead of re-migrating per step)
GEO_CHIS = (2.0, 2.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0)


def plan_for_lambda(lam: int) -> "tuple[WorkloadPlan, list]":
    """Real multi-source plan: slowest λ stragglers migrate (quantized
    sheds), the rest resize to T_min. Returns (plan, resize γ list)."""
    chi = np.ones(PAPER_E)
    chi[: len(STRAGGLER_CHIS)] = STRAGGLER_CHIS
    srcs, sheds, gammas = [], [], []
    bucket_by_rank = np.zeros((PAPER_E,), np.int32)
    for i, c in enumerate(chi):
        if c <= 1.0:
            continue
        excess = 1.0 - 1.0 / c           # work fraction to shed to hit t_min
        if i < lam:                      # migration group (lossless)
            m_q = quantize_shed(int(round(excess * NUM_BLOCKS)), NUM_BLOCKS)
            if m_q > 0:                  # zero-shed slots are not emitted
                srcs.append(i)
                sheds.append(m_q)
        else:                            # resizing group (lossy)
            bucket_by_rank[i] = bucket_for_gamma(excess)
            gammas.append(excess)
    pairs = sorted(zip(sheds, srcs), key=lambda p: -p[0])
    static = PlanStatic(buckets=DEFAULT_BUCKETS,
                        mig_shed=tuple(p[0] for p in pairs),
                        tp_size=PAPER_E).canonical()
    dynamic = PlanDynamic(
        bucket_by_rank=bucket_by_rank,
        mig_src=(np.asarray([p[1] for p in pairs], np.int32)
                 if pairs else np.array(-1, np.int32)))
    return WorkloadPlan(static, dynamic), gammas


def sweep_lambda(lam: int):
    """Returns (modeled step time, mean resize γ over the resizing group)
    via the trainer's own work_fraction on the real plan."""
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    chi = np.ones(PAPER_E)
    chi[: len(STRAGGLER_CHIS)] = STRAGGLER_CHIS
    plan, gammas = plan_for_lambda(lam)
    frac = work_fraction(plan, NUM_BLOCKS)
    mig_volume = float(sum(plan.static.mig_sheds))
    t = m.step_time(chi, frac) + (costs.phi1(mig_volume) if mig_volume else 0)
    return t, (float(np.mean(gammas)) if gammas else 0.0)


def acc_model(mean_gamma: float) -> float:
    """Interpolate the REAL γ→ACC curve measured by benchmarks/homo_resizing
    (falls back to a linear model if that benchmark hasn't run yet)."""
    path = os.path.join(OUT_DIR, "fig56_homo_resizing.json")
    pts = {0.0: None}
    if os.path.exists(path):
        data = json.load(open(path))
        base = data["acc"].get("0.0/baseline")
        if base:
            pts = {0.0: base}
            for k, v in data["acc"].items():
                if v is None or "priority" not in k:
                    continue
                g = float(k.split("/")[0])
                pts[g] = v
    if len(pts) > 1 and None not in pts.values():
        gs = np.array(sorted(pts))
        accs = np.array([pts[g] for g in gs])
        return float(np.interp(mean_gamma, gs, accs))
    return 1.0 - 0.25 * mean_gamma       # fallback linear loss model


def geometry_leg() -> dict:
    """Uneven-STATIC + SEMI-residual vs equal-static + full-dynamic SEMI.

    Both configs run the same lossless SEMI controller on the same
    persistent 2x schedule (GEO_CHIS). Config A (equal shards) must
    re-migrate the stragglers' excess EVERY step and pays the Φ1
    collective cost each time; config B seeds the static split from χ
    (geometry_from_chi), the controller plans only the residual — which
    the deadband absorbs — so steady-state steps carry no migration
    traffic. The modeled step times come from the SAME work_fraction /
    step_time path the trainer uses; a regression gate in main() requires
    B < A.
    """
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    chi = np.asarray(GEO_CHIS)
    wc = WorkloadControlConfig(enabled=True, mode="semi", block_size=8,
                               max_migration_sources=3,
                               beta_policy="lossless")

    # -- A: equal static shards, full-dynamic SEMI every step -------------
    ctl_a = SemiController(wc, PAPER_E, m, NUM_BLOCKS)
    plan_a, _ = ctl_a.plan(m.times(chi, np.ones(PAPER_E)))
    vol_a = float(sum(plan_a.static.mig_sheds))
    t_a = m.step_time(chi, work_fraction(plan_a, NUM_BLOCKS)) \
        + (costs.phi1(vol_a) if vol_a else 0.0)

    # -- B: χ-seeded uneven static shards, SEMI plans the residual --------
    geo = geometry_from_chi(chi, NUM_BLOCKS * PAPER_E, 8)
    base = np.asarray(geo.sizes) / np.mean(geo.sizes)
    ctl_b = SemiController(wc, PAPER_E, m,
                           int(round(float(np.mean(geo.sizes)))),
                           workloads=np.asarray(geo.sizes, np.float64))
    plan_b, report_b = ctl_b.plan(m.times(chi, base))
    vol_b = float(sum(plan_b.static.mig_sheds))
    t_b = m.step_time(chi, work_fraction(plan_b, NUM_BLOCKS)) \
        + (costs.phi1(vol_b) if vol_b else 0.0)

    return {"chis": list(GEO_CHIS),
            "geometry": list(geo.sizes),
            "equal_dynamic": {"step_s": t_a, "mig_volume": vol_a,
                              "signature": plan_a.static.signature_str()},
            "geometry_residual": {"step_s": t_b, "mig_volume": vol_b,
                                  "residual_stragglers":
                                      list(report_b.stragglers),
                                  "signature": plan_b.static.signature_str()},
            "speedup": t_a / t_b if t_b else 0.0}


GEO_DATAFLOW_CODE = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.layers.tp_linear import ControlContext, controlled_ffn
from repro.core.workload import PlanStatic
from repro.core.geometry import ShardGeometry
from repro.core import geometry as geom
from repro.control.scopes import per_rank_pri
e, B, S, d, block = {e}, 2, 8, {d}, 8
geo = ShardGeometry(sizes={sizes}, block=block)
H = geo.width
mesh = Mesh(np.array(jax.devices()).reshape(1, e), ("data", "model"))
act = jax.nn.silu
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
wg = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wu = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wd = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
pp = geom.expand_ffn_params({{"w_up": np.asarray(wu),
                              "w_gate": np.asarray(wg),
                              "w_down": np.asarray(wd)}}, geo)
st = PlanStatic(buckets=(0.0, 0.25, 0.5), block_size=block, mig_blocks=1,
                tp_size=e, geometry=geo.sizes)
pri = jnp.asarray(per_rank_pri(np.arange(geo.total_blocks), e,
                               geo.max_blocks, geometry=geo.sizes))
ref = (act(x @ wg) * (x @ wu)) @ wd
out = {{}}
for tag, src in (("neutral", -1), ("migrating", int(np.argmin(geo.sizes)))):
    ctx = ControlContext(mesh=mesh, axis="model", static=st,
                         bucket_by_rank=jnp.zeros((e,), jnp.int32),
                         mig_src=jnp.array(src, jnp.int32),
                         pri={{"ffn": pri}})
    y = controlled_ffn(x, jnp.asarray(pp["w_up"]), jnp.asarray(pp["w_down"]),
                       ctx, "ffn", act, w_gate=jnp.asarray(pp["w_gate"]))
    out[tag] = float(np.abs(np.asarray(y) - ref).max())
import json
print("RESULT" + json.dumps(out))
"""


def geometry_dataflow_check() -> dict:
    """Execute an uneven geometry (min-slice rank included) on a host
    mesh: padded ragged layout must match the canonical dense oracle,
    neutral and under lossless migration from the smallest rank."""
    dry = is_dry_run()
    e = 4
    sizes = (2, 6, 4, 4) if dry else (4, 12, 8, 8)
    code = GEO_DATAFLOW_CODE.format(e=e, d=16 if dry else 32,
                                    sizes=repr(sizes))
    outp = run_subprocess_py(code, devices=e, timeout=300 if dry else 600)
    payload = json.loads(outp.split("RESULT", 1)[1])
    payload["sizes"] = list(sizes)
    return payload


REAL_DATAFLOW_CODE = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.layers.tp_linear import ControlContext, controlled_ffn
from repro.core.workload import PlanStatic, keep_blocks_for_bucket
e, B, S, d, H, block = {e}, {B}, {S}, {d}, {H}, 8
nb_loc = (H // e) // block
mesh = Mesh(np.array(jax.devices()).reshape(1, e), ("data", "model"))
act = jax.nn.silu
rng = np.random.default_rng(0)
x = jnp.array(rng.standard_normal((B, S, d)), jnp.float32)
wg = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wu = jnp.array(rng.standard_normal((d, H))*.1, jnp.float32)
wd = jnp.array(rng.standard_normal((H, d))*.1, jnp.float32)
buckets = (0.0, 0.25, 0.5)
pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))

def make_fn(sheds):
    # which ranks straggle is a runtime input of the jitted fn; only the
    # shed counts are baked into the compiled signature
    st = PlanStatic(buckets=buckets, block_size=block,
                    mig_shed=tuple(sheds), tp_size=e)
    def f(bucket_vec, src_vec):
        ctx = ControlContext(mesh=mesh, axis="model", static=st,
                             bucket_by_rank=bucket_vec, mig_src=src_vec,
                             pri={{"ffn": pri}})
        return controlled_ffn(x, wu, wd, ctx, "ffn", act, w_gate=wg)
    return jax.jit(f)

def timed(f, *args, iters={iters}, repeats=3):
    y = f(*args); y.block_until_ready()          # compile
    best = float("inf")                          # min-of-repeats: least
    for _ in range(repeats):                     # noise on a shared host
        t0 = time.perf_counter()
        for _ in range(iters):
            y = f(*args)
        y.block_until_ready()
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)
    return best, y

ref = (act(x @ wg) * (x @ wu)) @ wd
out = {{}}
b0 = jnp.zeros((e,), jnp.int32)
us_dense, _ = timed(make_fn(()), b0, jnp.array([-1], jnp.int32))
out["us_dense"] = us_dense
for lam, (sheds, srcs, bucket_vec) in json.loads('{cases}').items():
    f = make_fn(tuple(sheds))
    us, y = timed(f, jnp.array(bucket_vec, jnp.int32),
                  jnp.array(srcs, jnp.int32))
    mask = np.ones(H // block, bool)
    for r, b in enumerate(bucket_vec):
        kc = keep_blocks_for_bucket(buckets[b], nb_loc)
        mask[r * nb_loc + kc : (r + 1) * nb_loc] = False
    oracle = ((act(x @ wg) * (x @ wu)) * np.repeat(mask, block)) @ wd
    out[lam] = {{"us_per_call": us,
                "max_err_vs_oracle": float(np.abs(np.array(y) - oracle).max()),
                "pure_migration_lossless": bool(
                    max(bucket_vec) == 0
                    and np.allclose(y, ref, atol=2e-4))}}
print("RESULT" + json.dumps(out))
"""


def real_dataflow_check():
    """Execute concurrent migration on a host mesh; returns metrics."""
    dry = is_dry_run()
    e = 4 if dry else 8
    cases = {}
    for lam in ((2,) if dry else (2, 3)):
        # small-mesh renorm of the paper scenario: lam sources with distinct
        # sheds, everyone else dense (pure-migration => lossless check) —
        # plus one mixed case exercising resize+migrate together
        srcs = list(range(lam))
        sheds = [max(1, 3 - s) for s in range(lam)]
        cases[f"lam{lam}_pure"] = (sheds, srcs, [0] * e)
        mixed = [0] * e
        mixed[-1] = 1
        cases[f"lam{lam}_mixed"] = (sheds, srcs, mixed)
    code = REAL_DATAFLOW_CODE.format(
        e=e, B=2, S=8, d=32 if dry else 64, H=e * 32,
        iters=3 if dry else 10, cases=json.dumps(cases))
    outp = run_subprocess_py(code, devices=e,
                             timeout=300 if dry else 900)
    payload = json.loads(outp.split("RESULT", 1)[1])
    payload["mesh_devices"] = e
    return payload


def emit_trace(table: dict) -> str:
    """Record the λ-sweep's modeled per-rank times as a replayable
    telemetry trace (one sample per λ, under that λ's plan)."""
    m = paper_scale_model()
    chi = np.ones(PAPER_E)
    chi[: len(STRAGGLER_CHIS)] = STRAGGLER_CHIS
    path = os.path.join(OUT_DIR, "traces", "multi_straggler.jsonl")
    with TraceWriter(path, PAPER_E, matmul_time=m.matmul_time,
                     other_time=m.other_time,
                     meta={"bench": "fig11",
                           "chis": list(STRAGGLER_CHIS)}) as w:
        for lam in sorted(table):
            plan, _ = plan_for_lambda(lam)
            frac = work_fraction(plan, NUM_BLOCKS)
            w.append(StepSample(step=lam, rank_times=m.times(chi, frac),
                                plan_signature=plan.static.signature_str(),
                                work_frac=frac))
    return path


def main() -> list:
    rows = []
    table = {}
    best_lam, best_t = None, np.inf
    for lam in range(0, 5):
        t, g = sweep_lambda(lam)
        # Fig. 7 observation: pruning on a straggler SUBSET dilutes the
        # homogeneous-γ accuracy loss by the resizing-rank fraction
        n_resize = 4 - lam
        a = acc_model(g * n_resize / PAPER_E)
        plan, _ = plan_for_lambda(lam)
        table[lam] = {"rt": t, "mean_gamma": g, "acc": a,
                      "mig_shed": list(plan.static.mig_sheds),
                      "signature": str(plan.static.signature().mig_shed)}
        rows.append(csv_row(f"fig11_lambda{lam}", t * 1e6,
                            f"step_s={t:.3f},mean_resize_gamma={g:.2f},"
                            f"acc={a:.3f},sheds={plan.static.mig_sheds}"))
    # "sweet spot": fastest λ whose modeled loss vs the lossless
    # λ=4 stays under 2% (the paper's "small accuracy penalty")
    lossless = table[4]["acc"]
    for lam in range(0, 5):
        if lossless - table[lam]["acc"] < 0.02 + 1e-9 \
                and table[lam]["rt"] < best_t:
            best_lam, best_t = lam, table[lam]["rt"]

    # what does the controller's Eq.(3) pick?
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    chi = np.ones(PAPER_E)
    chi[:4] = STRAGGLER_CHIS
    times = m.times(chi, np.ones(PAPER_E))
    x = eq3_migration_prefix(np.sort(times)[::-1], np.full(PAPER_E, NUM_BLOCKS),
                             costs, PAPER_E)
    rows.append(csv_row("fig11_sweet_spot", 0.0,
                        f"sweep_best_lambda={best_lam},eq3_pick={x},"
                        f"paper_spot=3"))

    # the real thing: concurrent multi-source migration on a device mesh
    real = real_dataflow_check()
    for key, v in real.items():
        if not isinstance(v, dict):
            continue
        rows.append(csv_row(f"fig11_real_{key}", v["us_per_call"],
                            f"max_err={v['max_err_vs_oracle']:.2e},"
                            f"lossless={v.get('pure_migration_lossless')}"))

    trace_path = emit_trace(table)
    rows.append(csv_row("fig11_trace", 0.0,
                        f"trace={os.path.relpath(trace_path, OUT_DIR)}"))

    # -- ragged shard geometry leg (DESIGN_SHARDING.md) -------------------
    geo_leg = geometry_leg()
    t_a = geo_leg["equal_dynamic"]["step_s"]
    t_b = geo_leg["geometry_residual"]["step_s"]
    rows.append(csv_row("fig11_geometry_equal_dynamic", t_a * 1e6,
                        f"mig_volume={geo_leg['equal_dynamic']['mig_volume']}"))
    rows.append(csv_row(
        "fig11_geometry_residual", t_b * 1e6,
        f"geometry={geo_leg['geometry']},"
        f"mig_volume={geo_leg['geometry_residual']['mig_volume']},"
        f"speedup={geo_leg['speedup']:.3f}"))
    geo_real = geometry_dataflow_check()
    rows.append(csv_row(
        "fig11_geometry_dataflow", 0.0,
        f"sizes={geo_real['sizes']},neutral_err={geo_real['neutral']:.2e},"
        f"migrating_err={geo_real['migrating']:.2e}"))
    # regression gates: the χ-seeded static split must beat per-step
    # dynamic migration under the persistent schedule, and the padded
    # ragged dataflow must match the canonical dense oracle
    if not t_b < t_a:
        raise RuntimeError(
            f"geometry leg regression: uneven-static+residual step "
            f"{t_b:.6f}s is not faster than equal+full-dynamic {t_a:.6f}s")
    if max(geo_real["neutral"], geo_real["migrating"]) > 2e-4:
        raise RuntimeError(
            f"geometry dataflow regression: max err vs dense oracle "
            f"{geo_real} exceeds 2e-4")

    config = {"e": PAPER_E, "chis": list(STRAGGLER_CHIS),
              "num_blocks": NUM_BLOCKS, "lambdas": list(range(5)),
              "geo_chis": list(GEO_CHIS), "dry_run": is_dry_run()}
    metrics = {"sweep": table, "eq3_pick": x, "best_lambda": best_lam,
               "real_dataflow": real,
               "geometry_leg": geo_leg, "geometry_dataflow": geo_real,
               "trace": os.path.relpath(trace_path, OUT_DIR)}
    save_bench_json("multi_straggler", config, metrics, trajectory=True)
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
