"""Table I reproduction: broadcast-reduce vs scatter-gather migration.

Two artifacts:
1. MEASURED per-device HLO collective bytes + op counts of the two
   shard_map implementations (repro.core.migration) on an 8-rank mesh —
   broadcast-reduce's reduce-merging removes the result-return hop, so its
   collective volume is structurally lower.
2. MODELED epoch times at paper scale: t_comm(SG) ≈ 2·V/BW + (e−1)·t_su
   (serial sends + gather-back), t_comm(BR) ≈ V/BW + t_su (tree broadcast;
   reduce merged into the existing all-reduce). Reproduces the table's
   shape: BR < SG everywhere, gap narrowing as ν grows.
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import csv_row, run_subprocess_py, save_json

HLO_SNIPPET = """
import numpy as np, jax, jax.numpy as jnp, json
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import migration
from repro.sharding import shard_map
from repro.analysis.hlo import parse_collectives
e, T, d, H, block = 8, 64, 128, 512, 16
mesh = Mesh(np.array(jax.devices()).reshape(e), ("model",))
x = jnp.zeros((T, d), jnp.float32)
w1 = jnp.zeros((d, H), jnp.float32)
w2 = jnp.zeros((H, d), jnp.float32)
ids = jnp.arange(8, dtype=jnp.int32)   # migrate 8 of 32 local blocks
kw = dict(axis="model", mig_src=jnp.array(0, jnp.int32),
          mig_block_ids=ids, block=block, act_fn=jax.nn.silu)
out = {}
for name, fn in [("broadcast_reduce", migration.migrated_pair_matmul),
                 ("scatter_gather", migration.scatter_gather_pair_matmul)]:
    f = shard_map(lambda x, a, b: fn(x, a, b, **kw), mesh=mesh,
        in_specs=(P(), P(None, "model"), P("model", None)),
        out_specs=P(), check_vma=False)
    txt = jax.jit(f).lower(x, w1, w2).compile().as_text()
    out[name] = parse_collectives(txt)
print("RESULT" + json.dumps(out))
"""

# paper testbed epoch structure: 373 s compute-only epoch (Table I, γ=0)
BASE_EPOCH_S = 373.0
PCIE_BW = 12e9          # effective PCIe 3.0 x16
T_SETUP = 0.8           # per-connection setup+serialization cost (s/epoch)


def modeled_epoch(policy: str, gamma: float, nu: int, e: int = 8,
                  vol_full: float = 80e9) -> float:
    """vol_full: bytes a fully-migrated (γ=1) straggler ships per epoch."""
    v = gamma * vol_full * nu
    helpers = e - nu
    if v == 0:
        return BASE_EPOCH_S
    if policy == "broadcast_reduce":
        comm = v / PCIE_BW + nu * T_SETUP * max(np.log2(max(helpers, 2)), 1)
    else:
        comm = 2 * v / PCIE_BW + nu * helpers * T_SETUP
    return BASE_EPOCH_S + comm


def main() -> list:
    rows = []
    out = run_subprocess_py(HLO_SNIPPET, devices=8, timeout=900)
    hlo = json.loads(out.split("RESULT")[1].strip())
    br, sg = hlo["broadcast_reduce"]["total"], hlo["scatter_gather"]["total"]
    rows.append(csv_row("tab1_hlo_coll_bytes_broadcast_reduce", 0.0,
                        f"bytes={br}"))
    rows.append(csv_row("tab1_hlo_coll_bytes_scatter_gather", 0.0,
                        f"bytes={sg}"))
    rows.append(csv_row("tab1_hlo_br_lt_sg", 0.0,
                        f"ratio={sg / max(br, 1):.2f},holds={br < sg}"))

    table = {}
    for nu in (1, 4):
        for g in (0.0, 0.25, 0.5, 0.75, 1.0):
            for pol in ("broadcast_reduce", "scatter_gather"):
                t = modeled_epoch(pol, g, nu)
                table[f"{pol}({nu})/{g}"] = t
        # the paper's observation: the gap narrows as nu grows
    g1 = (table["scatter_gather(1)/1.0"] - BASE_EPOCH_S) / \
         (table["broadcast_reduce(1)/1.0"] - BASE_EPOCH_S)
    g4 = (table["scatter_gather(4)/1.0"] - BASE_EPOCH_S) / \
         (table["broadcast_reduce(4)/1.0"] - BASE_EPOCH_S)
    for k in ("broadcast_reduce(1)/1.0", "scatter_gather(1)/1.0",
              "broadcast_reduce(4)/1.0", "scatter_gather(4)/1.0"):
        rows.append(csv_row(f"tab1_epoch_{k.replace('/', '_g')}",
                            table[k] * 1e6, f"epoch_s={table[k]:.0f}"))
    rows.append(csv_row("tab1_gap_narrows_with_nu", 0.0,
                        f"gap_nu1={g1:.2f},gap_nu4={g4:.2f},holds={g4 < g1}"))
    save_json("tab1_migration_policies", {"hlo": hlo, "epochs": table})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
