"""§Roofline report generator: reads the dry-run JSONs and emits the
per-(arch × shape) three-term roofline table (also consumed by
EXPERIMENTS.md).

Correction applied here (validated empirically, see EXPERIMENTS.md
§Dry-run): XLA's ``cost_analysis()`` and our HLO parse count a ``while``
body ONCE, not × trip count, so scan-over-layers programs under-report all
three terms by up to L×. Each term therefore uses
max(HLO-derived, analytic floor); both values are retained in the JSON.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ROOT, csv_row
from repro.config import INPUT_SHAPES, get_config
from repro.analysis import hlo as H

DRYRUN_DIR = os.path.join(ROOT, "experiments", "dryrun")

MESH_SHAPES = {"pod16x16": {"data": 16, "model": 16},
               "pod2x16x16": {"pod": 2, "data": 16, "model": 16}}


def corrected_terms(r: dict, mesh: str,
                    decode_occupancy: float = 1.0) -> dict:
    """``decode_occupancy`` — mean((cur_pos+1)/max_len) over serve slots
    (ISSUE 7): decode cache reads scale with how full the slots ARE, not
    with max_len. 1.0 reproduces the old full-rows bound (and is correct
    for the unfused path, which really does read every row)."""
    from repro.launch.specs import effective_model_cfg
    cfg = effective_model_cfg(get_config(r["arch"]), INPUT_SHAPES[r["shape"]])
    shape = INPUT_SHAPES[r["shape"]]
    chips = r["chips"]
    roof = r["roofline"]
    hlo_flops = roof["flops_per_device"] * chips
    hlo_bytes = roof["bytes_per_device"] * chips
    hlo_coll = roof["coll_bytes_per_device"] * chips
    an_flops = H.analytic_step_flops(cfg, shape)
    an_bytes = H.analytic_step_bytes(cfg, shape,
                                     decode_occupancy=decode_occupancy)
    an_coll = H.analytic_step_collective_bytes(cfg, shape, MESH_SHAPES[mesh])
    flops = max(hlo_flops, an_flops)
    nbytes = max(hlo_bytes, an_bytes)
    coll = max(hlo_coll, an_coll)
    terms = {
        "compute_s": flops / (chips * H.PEAK_FLOPS),
        "memory_s": nbytes / (chips * H.HBM_BW),
        "collective_s": coll / (chips * H.LINK_BW),
        "hlo": {"flops": hlo_flops, "bytes": hlo_bytes, "coll": hlo_coll},
        "analytic": {"flops": an_flops, "bytes": an_bytes, "coll": an_coll},
        "model_flops": H.model_flops(cfg, shape),
    }
    terms["useful_flops_ratio"] = terms["model_flops"] / max(flops, 1.0)
    terms["dominant"] = max(
        ("compute", "memory", "collective"),
        key=lambda k: terms[f"{k}_s"])
    terms["bound_s"] = terms[f"{terms['dominant']}_s"]
    return terms


def measured_occupancy(default: float = 1.0) -> float:
    """Mean serve-slot occupancy from the last serve bench run
    (experiments/bench/serve.json, decode_path section), else
    ``default``. Keeps roofline artifacts reproducible without the serve
    bench while letting a full run use the MEASURED occupancy."""
    path = os.path.join(ROOT, "experiments", "bench", "serve.json")
    try:
        d = json.load(open(path))
        occ = d["metrics"]["decode_path"]["mean_occupancy"]
        return float(occ)
    except (OSError, KeyError, TypeError, ValueError):
        return default


def load_all(mesh: str = "pod16x16"):
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        r = json.load(open(path))
        out[(r["arch"], r["shape"])] = r
    return out


def improvement_hint(arch: str, shape: str, dom: str) -> str:
    """One sentence on what would move the dominant term down."""
    if dom == "collective":
        return ("reduce TP all-reduce volume: overlap with compute, "
                "reduce-scatter+all-gather decomposition, or shrink the "
                "dispatched token buffers (MoE)")
    if dom == "memory":
        if shape.startswith("decode") or shape == "long_500k":
            return "shrink KV reads: GQA head dedup, bf16->int8 cache, window"
        return ("cut activation traffic: remat policy, fused xent (skip "
                "materialized logits), bf16 activations")
    return "raise MXU utilization: larger per-core tiles, fused matmuls"


def table_markdown(mesh: str = "pod16x16") -> str:
    rows = [
        "| arch | shape | kind | compute s | memory s | collective s | "
        "dominant | MODEL/HLO | HBM GiB/dev | what would move it |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), r in sorted(load_all(mesh).items()):
        t = corrected_terms(r, mesh)
        mem = r.get("memory_analysis", {})
        hbm = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0)) / 2 ** 30
        rows.append(
            f"| {arch} | {shape} | {r['kind']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"**{t['dominant']}** | {t['useful_flops_ratio']:.2f} | "
            f"{hbm:.1f} | {improvement_hint(arch, shape, t['dominant'])} |")
    return "\n".join(rows)


def main() -> list:
    rows = []
    data = load_all()
    if not data:
        rows.append(csv_row("roofline_missing", 0.0, "run dryrun first"))
        return rows
    dominant_counts = {}
    occ = measured_occupancy()
    for (arch, shape), r in sorted(data.items()):
        t = corrected_terms(r, "pod16x16")
        rows.append(csv_row(
            f"roofline_{arch}__{shape}", t["bound_s"] * 1e6,
            f"dominant={t['dominant']},compute={t['compute_s']:.4f},"
            f"memory={t['memory_s']:.4f},collective={t['collective_s']:.4f},"
            f"useful_flops={t['useful_flops_ratio']:.2f}"))
        dominant_counts[t["dominant"]] = dominant_counts.get(t["dominant"], 0) + 1
        if INPUT_SHAPES[shape].kind == "decode" and occ < 1.0:
            # occupancy-corrected decode bound: what the fused kernel's
            # occupied-rows-only traffic makes of the memory term
            to = corrected_terms(r, "pod16x16", decode_occupancy=occ)
            rows.append(csv_row(
                f"roofline_{arch}__{shape}__occ", to["bound_s"] * 1e6,
                f"occupancy={occ:.2f},memory={to['memory_s']:.4f},"
                f"memory_full={t['memory_s']:.4f},"
                f"dominant={to['dominant']}"))
    rows.append(csv_row("roofline_pairs_covered", 0.0,
                        f"n={len(data)},dominants={dominant_counts}"))
    # multi-pod coverage
    data2 = load_all("pod2x16x16")
    rows.append(csv_row("roofline_multipod_pairs", 0.0, f"n={len(data2)}"))
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
    print()
    print(table_markdown())
