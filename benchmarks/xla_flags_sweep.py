"""XLA latency-hiding flag sweep (ISSUE 7): the compiler-side baseline
for hiding the decode-step TP all-reduce, next to the chunked-psum
epilogue (the kernel-side measure) — so the bench reports
kernel-vs-flags-vs-both instead of conflating the two.

Each leg is a fresh subprocess (XLA flags only apply before jax
initializes): ``repro.launch._bootstrap.apply_xla_preset`` — the exact
production path the serve CLI uses — is called pre-jax, then a TP
matmul + epilogue all-reduce step runs under shard_map on host devices,
with the all-reduce either one fat ``lax.psum`` (chunks=1) or the
``repro.layers.tp_linear.chunked_psum`` split the serve engine uses.

    baseline  preset=none            chunks=1
    flags     preset=latency-hiding  chunks=1
    chunked   preset=none            chunks=4
    both      preset=latency-hiding  chunks=4

Report-only (no gate): on CPU the latency-hiding scheduler is largely
inert — the value of this sweep is the committed MECHANISM (flags are
plumbed, both axes measurable) and the TPU numbers when run there.
A leg whose subprocess fails degrades to {"supported": false} so the
smoke job stays green on backends without these flags.
"""
from __future__ import annotations

import json

from benchmarks.common import csv_row, is_dry_run, run_subprocess_py, \
    save_bench_json

_CHILD = """
import json, time
from repro.launch._bootstrap import apply_xla_preset
applied = apply_xla_preset({preset!r})           # pre-jax, production path
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.layers.tp_linear import chunked_psum
from repro.sharding import shard_map

devs = jax.devices()
mesh = jax.sharding.Mesh(np.array(devs), ("x",))
M, K, N = {M}, {K}, {N}
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
w = jnp.asarray(rng.standard_normal((K, N)) * 0.02, jnp.float32)

def step(x_, w_):
    # local partial matmul + epilogue all-reduce: the decode-step TP
    # pattern whose exposure the chunked psum / scheduler flags target
    y = x_ @ w_
    y = y + jax.nn.silu(y)                      # compute to overlap with
    return chunked_psum(y, "x", {chunks})

f = jax.jit(shard_map(step, mesh=mesh,
                      in_specs=(P(None, "x"), P("x", None)),
                      out_specs=P()))
r = f(x, w); r.block_until_ready()
ts = []
for _ in range({iters}):
    t0 = time.perf_counter()
    r = f(x, w); r.block_until_ready()
    ts.append(time.perf_counter() - t0)
print(json.dumps({{"step_us": min(ts) * 1e6, "flags_applied": applied}}))
"""

LEGS = [
    ("baseline", "none", 1),
    ("flags", "latency-hiding", 1),
    ("chunked", "none", 4),
    ("both", "latency-hiding", 4),
]


def main() -> list:
    dry = is_dry_run()
    devices = 2 if dry else 4
    M, K, N = (64, 256, 256) if dry else (256, 2048, 2048)
    iters = 5 if dry else 20

    rows, legs = [], {}
    for name, preset, chunks in LEGS:
        code = _CHILD.format(preset=preset, chunks=chunks, M=M, K=K, N=N,
                             iters=iters)
        try:
            out = run_subprocess_py(code, devices=devices, timeout=600,
                                    with_bench_path=False)
            rep = json.loads(out.strip().splitlines()[-1])
            legs[name] = {"supported": True, "preset": preset,
                          "psum_chunks": chunks,
                          "step_us": rep["step_us"],
                          "flags_applied": rep["flags_applied"]}
        except Exception as e:                                # noqa: BLE001
            legs[name] = {"supported": False, "preset": preset,
                          "psum_chunks": chunks, "error": repr(e)[:200]}
        d = legs[name]
        rows.append(csv_row(f"xla_flags_{name}",
                            d.get("step_us", 0.0),
                            f"preset={preset},chunks={chunks},"
                            f"supported={d['supported']}"))

    base = legs.get("baseline", {})
    speedups = {}
    if base.get("supported"):
        for name in ("flags", "chunked", "both"):
            if legs.get(name, {}).get("supported"):
                speedups[name] = base["step_us"] / legs[name]["step_us"]
    metrics = {"legs": legs, "speedup_vs_baseline": speedups}
    config = {"devices": devices, "M": M, "K": K, "N": N, "iters": iters,
              "dry_run": dry}
    save_bench_json("xla_flags", config, metrics)
    return rows


if __name__ == "__main__":
    import argparse
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--dry-run", action="store_true",
                    help="tiny shapes, 2 devices (CI smoke)")
    if ap.parse_args().dry_run:
        os.environ["REPRO_BENCH_DRY"] = "1"
    print("\n".join(main()))
