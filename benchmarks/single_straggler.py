"""Fig. 10 reproduction: a single straggler with growing skewness χ.

Solutions: Baseline, MIG (migration only), ZERO-PriDiffR (resize only),
SEMI (Eq. 2 hybrid). RT from the paper-scale model with migration comm
costs from the pre-test cost functions; ACC deltas from real reduced-scale
runs (zero lossy, migration lossless by construction — property-tested in
tests/test_multidevice.py).
"""
from __future__ import annotations

import json

import numpy as np

from benchmarks.common import (PAPER_E, csv_row, paper_scale_model,
                               run_subprocess_py, save_json)
from repro.config import WorkloadControlConfig
from repro.core.controller import (SemiController, pretest_cost_functions,
                                   work_fraction)

NUM_BLOCKS = 64
CHIS = (2.0, 4.0, 6.0, 8.0)


def modeled_rt(chi: float, mode: str) -> float:
    m = paper_scale_model()
    costs = pretest_cost_functions(m, NUM_BLOCKS, e=PAPER_E)
    x = np.ones(PAPER_E)
    x[0] = chi
    if mode == "off":
        return m.step_time(x, np.ones(PAPER_E))
    cfg = WorkloadControlConfig(enabled=True, mode=mode, block_size=128)
    ctl = SemiController(cfg, PAPER_E, m, NUM_BLOCKS, costs=costs)
    times = m.times(x, np.ones(PAPER_E))
    plan, rep = ctl.plan(times)
    frac = work_fraction(plan, NUM_BLOCKS)
    t = m.step_time(x, frac)
    # migration communication overhead (Φ1) + helper compute ripple
    if rep.mig_blocks > 0:
        t += costs.phi1(rep.mig_blocks)
    return t


ACC_SNIPPET = """
from repro.launch.train import run_training
import json
res = {}
for name, kw in {
    "baseline": dict(control_mode="off", hetero_kind="none"),
    "zero": dict(control_mode="zero"),
    "mig": dict(control_mode="mig", mig_blocks=4),
    "semi": dict(control_mode="semi", mig_blocks=4),
}.items():
    h = run_training("vit-1b", steps=40, tp=4, batch=16, data_noise=1.3,
                     hetero_kind=kw.pop("hetero_kind", "static"), chi=6.0,
                     eval_every=40, quiet=True, log_every=1000, **kw)
    res[name] = h["acc"][-1] if h["acc"] else None
print("RESULT" + json.dumps(res))
"""


def main() -> list:
    rows = []
    rt = {}
    for chi in CHIS:
        for mode in ("off", "mig", "zero", "semi"):
            t = modeled_rt(chi, mode)
            rt[f"{mode}/{chi}"] = t
            rows.append(csv_row(f"fig10_rt_{mode}_chi{int(chi)}", t * 1e6,
                                f"step_s={t:.3f}"))
    # paper shape: baseline grows linearly; ZERO & SEMI stay ~flat; MIG in
    # between (comm cost grows with chi)
    flat = rt["semi/8.0"] / rt["semi/2.0"]
    lin = rt["off/8.0"] / rt["off/2.0"]
    rows.append(csv_row("fig10_semi_flat_vs_baseline_linear", 0.0,
                        f"semi_growth={flat:.2f},baseline_growth={lin:.2f},"
                        f"holds={flat < 0.5 * lin}"))

    out = run_subprocess_py(ACC_SNIPPET, devices=4, timeout=3600)
    acc = json.loads(out.split("RESULT")[1].strip())
    for k, v in acc.items():
        if v is not None:
            rows.append(csv_row(f"fig10_acc_{k}", 0.0, f"acc={v:.3f}"))
    if acc.get("baseline") and acc.get("zero") and acc.get("semi"):
        rows.append(csv_row(
            "fig10_semi_acc_beats_zero", 0.0,
            f"semi_loss={acc['baseline'] - acc['semi']:.3f},"
            f"zero_loss={acc['baseline'] - acc['zero']:.3f}"))
    save_json("fig10_single_straggler", {"rt": rt, "acc": acc})
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
