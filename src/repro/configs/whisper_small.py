"""Whisper-small [arXiv:2212.04356]. Encoder-decoder; conv/mel frontend is a
STUB per the brief — input_specs provides precomputed frame embeddings."""
from repro.config import EncDecConfig, FrontendStub, ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,               # decoder layers
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    pos_embedding="sinusoid",   # computed on the fly (no learned table)
    act="gelu",                  # plain GELU MLP (not gated)
    encdec=EncDecConfig(num_encoder_layers=12, encoder_seq_len=1500),
    frontend=FrontendStub(kind="audio", embed_dim=768, num_tokens=1500),
    tie_embeddings=True,
    source="arXiv:2212.04356",
))
