"""Falcon-Mamba-7B [arXiv:2410.05355]. Pure Mamba-1 SSM, attention-free."""
from repro.config import ModelConfig, SSMConfig, register_config

CONFIG = register_config(ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    num_layers=64,
    d_model=4096,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=65024,
    pos_embedding="none",
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2410.05355",
))
