"""RecurrentGemma-2B [arXiv:2402.19427] (Griffin architecture).

Hybrid: RG-LRU recurrent blocks and local (sliding-window) attention in a
2:1 pattern. MQA (1 KV head), head_dim 256, GeGLU FFN.
"""
from repro.config import ModelConfig, RGLRUConfig, register_config

CONFIG = register_config(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,               # 26 blocks in the 2:1 pattern
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pos_embedding="rope",
    act="gelu_glu",              # GeGLU
    rglru=RGLRUConfig(
        lru_width=2560,
        conv1d_width=4,
        block_pattern=("rglru", "rglru", "attn"),
        local_window=2048,
    ),
    tie_embeddings=True,
    source="arXiv:2402.19427",
))
