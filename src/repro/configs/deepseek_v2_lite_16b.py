"""DeepSeek-V2-Lite (16B total / 2.4B active) [arXiv:2405.04434].

MLA attention (kv_lora_rank=512) + fine-grained MoE: 64 routed experts
top-6 with 2 shared experts (assignment header values; the full V2 model
uses 160 routed — we follow the assigned header: 64e top-6), expert hidden
1408, first layer dense FFN.
"""
from repro.config import MLAConfig, MoEConfig, ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,             # MLA: heads share the latent; kept for bookkeeping
    d_ff=1408,                   # routed-expert hidden size
    vocab_size=102400,
    pos_embedding="rope",
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,           # V2-Lite uses full-rank Q
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_expert=1408,
        num_shared_experts=2,
        d_shared=1408,
        first_dense_layers=1,
        d_ff_dense=10944,
    ),
    source="arXiv:2405.04434",
))
