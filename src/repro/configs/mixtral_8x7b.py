"""Mixtral-8x7B [arXiv:2401.04088]. 8 experts top-2 MoE, GQA, SWA."""
from repro.config import MoEConfig, ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_expert=14336,
        expert_sharding="tp",   # 8 big experts: split d_expert over TP
    ),
    source="arXiv:2401.04088",
))
