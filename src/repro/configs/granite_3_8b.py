"""Granite-3.0-8B [hf:ibm-granite/granite-3.0-2b-base family]. Dense GQA LM."""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="granite-3-8b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
))
