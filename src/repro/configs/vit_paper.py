"""The paper's own benchmark models: ViT-1B and ViT-3B (Sec. V-A).

ViT-1B: hs=2048, depth=24 (paper, Sec. II-B) ~= 1.2B params.
ViT-3B: hs=2560, depth=32 ~= 2.7B params (paper customizes layer count and
hidden size; exact values are not printed — chosen to hit the stated 2.7B).
Classification over 10 classes (CIFAR-10-like), patch-embedding frontend
is implemented as a linear patchifier inside the model (images are small).
"""
from repro.config import FrontendStub, ModelConfig, register_config

VIT_1B = register_config(ModelConfig(
    name="vit-1b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=0,
    pos_embedding="learned",
    act="gelu",
    num_classes=10,
    frontend=FrontendStub(kind="vision", embed_dim=2048, num_tokens=65),
    source="paper Sec. V-A (ViT-1B, hs=2048, depth=24)",
))

VIT_3B = register_config(ModelConfig(
    name="vit-3b",
    family="vlm",
    num_layers=32,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=10240,
    vocab_size=0,
    pos_embedding="learned",
    act="gelu",
    num_classes=10,
    frontend=FrontendStub(kind="vision", embed_dim=2560, num_tokens=65),
    source="paper Sec. V-A (ViT-3B)",
))
