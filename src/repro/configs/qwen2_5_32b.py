"""Qwen2.5-32B [hf:Qwen/Qwen2.5 family]. Dense GQA LM with QKV bias."""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B",
))
