"""Yi-6B [arXiv:2403.04652]. Llama-architecture dense LM with GQA (4 KV heads)."""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652",
))
