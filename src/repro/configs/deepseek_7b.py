"""DeepSeek-7B (base) [arXiv:2401.02954]. Llama-architecture dense LM (MHA)."""
from repro.config import ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=10_000.0,
    source="arXiv:2401.02954",
))
