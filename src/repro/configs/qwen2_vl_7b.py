"""Qwen2-VL-7B language backbone [arXiv:2409.12191].

VLM: M-RoPE positional encoding, dynamic-resolution vision encoder.
The vision encoder (ViT + merger) is a STUB per the brief — input_specs
provides precomputed patch embeddings; we implement the 28-layer decoder.
"""
from repro.config import FrontendStub, ModelConfig, register_config

CONFIG = register_config(ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pos_embedding="mrope",
    frontend=FrontendStub(kind="vision", embed_dim=3584, num_tokens=256),
    source="arXiv:2409.12191",
))
