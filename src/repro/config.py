"""Configuration system for the repro framework.

Dataclass-based, hashable (frozen) configs so they can key jit caches.
Architecture configs live in ``repro.configs.<arch>`` and register
themselves into a global registry via :func:`register_config`.
"""
from __future__ import annotations

import dataclasses
import importlib
import pkgutil
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs for architecture families
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration."""

    num_experts: int
    top_k: int
    d_expert: int                 # hidden dim of each expert FFN
    num_shared_experts: int = 0   # DeepSeek-V2 style always-on experts
    d_shared: int = 0             # hidden dim of the shared expert(s)
    first_dense_layers: int = 0   # leading layers that use a dense FFN
    d_ff_dense: int = 0           # hidden dim of those dense FFNs
    router_aux_coef: float = 0.01  # load-balance auxiliary loss weight
    capacity_factor: float = 1.25  # expert capacity for dropless-ish dispatch
    expert_sharding: str = "expert"  # "expert" (expert-parallel) | "tp"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention configuration."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 => full-rank Q projection (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM configuration."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 => ceil(d_model / 16)


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block configuration."""

    lru_width: int = 0            # 0 => d_model
    conv1d_width: int = 4
    block_pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")  # 2:1 recurrent:attn
    local_window: int = 2048


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Whisper-style) configuration."""

    num_encoder_layers: int = 12
    encoder_seq_len: int = 1500   # post-conv frame count (stub frontend)


@dataclass(frozen=True)
class FrontendStub:
    """Modality frontend stub (vision patches / audio frames).

    Per the brief the ViT/conv encoder is NOT implemented; ``input_specs``
    provides precomputed embeddings of shape [batch, num_tokens, embed_dim].
    """

    kind: str                     # "vision" | "audio"
    embed_dim: int
    num_tokens: int


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"   # rope | mrope | learned | none
    sliding_window: int = 0       # 0 => full attention
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"             # silu (SwiGLU) | gelu (plain MLP)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    frontend: Optional[FrontendStub] = None
    # classification head (the paper's ViT); 0 => LM head over vocab
    num_classes: int = 0
    # decode hot path: route GQA/MLA decode attention through the fused
    # Pallas kernel (kernels/decode_attn.py; interpret-mode off-TPU).
    # Model-level (not ControlContext) because the dense serve path runs
    # with ctx=None — set via ControlConfig.fused_attention, which the
    # step builders apply with dataclasses.replace.
    fused_decode_attn: bool = False
    source: str = ""              # citation

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True if sub-quadratic attention is native (SSM / hybrid / SWA)."""
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for reporting."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            s = self.ssm or SSMConfig()
            d_in = s.expand * d
            dt_rank = s.dt_rank or -(-d // 16)
            per_layer = (
                d * 2 * d_in            # in_proj
                + d_in * s.d_conv       # conv
                + d_in * (dt_rank + 2 * s.d_state)  # x_proj
                + dt_rank * d_in        # dt_proj
                + d_in * s.d_state      # A
                + d_in * 2              # D, dt bias
                + d_in * d              # out_proj
            )
        else:
            if self.mla is not None:
                m = self.mla
                qdim = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                q = d * qdim if not m.q_lora_rank else d * m.q_lora_rank + m.q_lora_rank * qdim
                kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + m.kv_lora_rank * self.num_heads * (
                    m.qk_nope_head_dim + m.v_head_dim)
                o = self.num_heads * m.v_head_dim * d
                attn = q + kv + o
            else:
                attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                    + self.num_heads * hd * d
            ff_mult = 3 if self.act == "silu" else 2
            if self.moe is not None:
                mo = self.moe
                moe_ff = mo.num_experts * ff_mult * d * mo.d_expert \
                    + mo.num_shared_experts * ff_mult * d * (mo.d_shared or mo.d_expert) \
                    + d * mo.num_experts
                n_moe = L - mo.first_dense_layers
                dense_ff = mo.first_dense_layers * ff_mult * d * (mo.d_ff_dense or self.d_ff)
                per_layer = attn + (moe_ff * n_moe + dense_ff) / L
            else:
                per_layer = attn + ff_mult * d * self.d_ff
        total = emb + int(L * per_layer)
        if self.encdec is not None:
            total += int(self.encdec.num_encoder_layers * per_layer)
        return total

    def active_param_count(self) -> int:
        """Parameters active per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.num_layers
        ff_mult = 3 if self.act == "silu" else 2
        full = self.param_count()
        all_experts = (L - mo.first_dense_layers) * mo.num_experts * ff_mult * d * mo.d_expert
        active = (L - mo.first_dense_layers) * mo.top_k * ff_mult * d * mo.d_expert
        return full - all_experts + active


# ---------------------------------------------------------------------------
# Input shapes (assigned), mesh and run configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pod: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pod


@dataclass(frozen=True)
class WorkloadControlConfig:
    """The paper's technique knobs (Sec. III/IV)."""

    enabled: bool = False
    mode: str = "semi"            # zero | mig | semi | off
    # ZERO-resizing
    gamma_buckets: Tuple[float, ...] = (0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875)
    block_size: int = 128         # pruning granularity (TPU-aligned), adapts down
    imputation: str = "zero"      # zero | average | same
    selection: str = "priority"   # random | priority | priority_diff
    alpha: float = 0.8            # decay factor for per-layer ratio floor (Sec. III-B)
    theta_iter: float = 1e-3      # micro-threshold for per-layer candidates
    # migration
    migration_block: int = 128    # migrated-column granularity
    max_migration_sources: int = 3   # concurrent straggler slots (0 = no mig)
    migration_shed_cap: int = 0      # per-source shed-block cap (0 = uncapped)
    # β source for SEMI's per-source mission split (Eq. 2): "eq2" balances
    # migration vs. resize cost (training default); "lossless" forces
    # β = 1 for every Eq.(3)-selected source — the whole offset volume
    # migrates, so the plan changes no outputs (the serve engine's
    # default: decode quality must not silently degrade under contention)
    beta_policy: str = "eq2"         # eq2 | lossless
    # controller
    tavg_refresh_threshold: float = 0.10   # passive T_avg refresh on >10% change
    # straggler-detection deadband: ranks within this relative margin of
    # T_ref are NOT stragglers. ±5% multiplicative measurement noise gives
    # a worst-case min-to-max spread of 1.05/0.95 ≈ 1.11, so 0.12 absorbs
    # it — plans stop flip-flopping on noise while real stragglers
    # (χ ≥ 2 in every paper scenario) sit far above the band.
    straggler_threshold: float = 0.12
    # execution: route controlled matmuls through the Pallas pruned-kernel
    # family (fused FFN + kernel-level backward; interpret-mode off-TPU)
    use_kernel: bool = False
    # decode raw-speed pass (ISSUE 7): fused decode-attention kernel and
    # chunked TP all-reduce epilogues. fused_attention flips
    # ModelConfig.fused_decode_attn in the step builders; psum_chunks > 1
    # splits the controlled-layer epilogue psum into that many
    # independent per-chunk all-reduces so the latency-hiding scheduler
    # can overlap them with the remaining compute.
    fused_attention: bool = False
    psum_chunks: int = 1
    # telemetry / closed-loop measured mode (DESIGN_TELEMETRY.md):
    # where the controller's per-rank times come from. "modeled" reads the
    # χ-oracle straight from the simulated schedule; "measured" consumes
    # StragglerEstimator reconstructions of measured (mitigated) times.
    times: str = "modeled"           # modeled | measured
    ewma_alpha: float = 0.4          # estimator EWMA weight (newest sample)
    estimator_warmup: int = 3        # samples before the warmup gate opens
    outlier_nmad: float = 4.0        # median/MAD spike-rejection threshold
    measure_interval: int = 1        # steps between in-graph rank gathers

    def __post_init__(self):
        # a typo'd beta_policy would silently fall through to the LOSSY
        # eq2 split — the exact silent quality degradation the lossless
        # policy exists to prevent — so reject unknown values loudly
        if self.beta_policy not in ("eq2", "lossless"):
            raise ValueError(
                f"beta_policy {self.beta_policy!r} is not one of "
                "('eq2', 'lossless')")
        if self.psum_chunks < 1:
            raise ValueError(
                f"psum_chunks must be >= 1, got {self.psum_chunks}")


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    learning_rate: float = 3e-3
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 10
    seed: int = 0
    microbatch: int = 0           # 0 => no gradient accumulation
    remat: str = "none"           # none | block | full
    fsdp_layers: bool = False     # shard the stacked-layer dim over data
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    train: TrainConfig = TrainConfig()
    control: WorkloadControlConfig = WorkloadControlConfig()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register_config(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def _load_all() -> None:
    from repro import configs as cfg_pkg

    for mod in pkgutil.iter_modules(cfg_pkg.__path__):
        if not mod.name.startswith("_"):
            importlib.import_module(f"repro.configs.{mod.name}")


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _load_all()
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4) or 1
    kv = max(1, min(cfg.num_kv_heads, heads))
    # keep the GQA ratio flavor: if original had kv < heads, keep kv < heads
    if cfg.num_kv_heads < cfg.num_heads and kv == heads:
        kv = max(1, heads // 2)
    updates = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d // heads if cfg.family != "moe" or True else 0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.moe is not None:
        updates["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 256),
            num_shared_experts=min(cfg.moe.num_shared_experts, 1),
            d_shared=min(cfg.moe.d_shared, 256) if cfg.moe.d_shared else 0,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            d_ff_dense=min(cfg.moe.d_ff_dense, 256) if cfg.moe.d_ff_dense else 0,
        )
    if cfg.mla is not None:
        updates["mla"] = MLAConfig(
            kv_lora_rank=64, q_lora_rank=0, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32)
        updates["head_dim"] = 0
    if cfg.ssm is not None:
        updates["ssm"] = dataclasses.replace(cfg.ssm, d_state=8)
    if cfg.rglru is not None:
        updates["rglru"] = dataclasses.replace(
            cfg.rglru, lru_width=0, local_window=64)
    if cfg.encdec is not None:
        updates["encdec"] = EncDecConfig(num_encoder_layers=2, encoder_seq_len=32)
    if cfg.frontend is not None:
        # classifiers keep their token count (image geometry fixes it)
        ntok = cfg.frontend.num_tokens if cfg.num_classes else 16
        updates["frontend"] = FrontendStub(
            kind=cfg.frontend.kind, embed_dim=d, num_tokens=ntok)
    if cfg.sliding_window:
        updates["sliding_window"] = 32
    return dataclasses.replace(cfg, **updates)
