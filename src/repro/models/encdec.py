"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv frontend is a STUB (per the brief):
``frame_embeds`` [B, F, d] arrive precomputed. The encoder is a
bidirectional transformer over frames; the decoder is a causal
transformer with cross-attention. Positions are sinusoidal (computed on
the fly — no 500k learned-position table).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import blocks
from repro.layers.blocks import _normal, rms_norm
from repro.models import lm as lm_lib
from repro.sharding import shard

Params = Dict[str, Any]


def sinusoid_positions(S: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(S)[:, None] + offset
    dim = jnp.arange(d // 2)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 6)
    enc_cfg = cfg  # same dims for encoder/decoder (Whisper)
    enc, enc_ax = blocks.init_stack(
        jax.random.fold_in(ks[0], 0), _enc_cfg(cfg), dtype,
        kind_override="attn_bidir")
    dec, dec_ax = blocks.init_stack(
        jax.random.fold_in(ks[0], 1), cfg, dtype, kind_override="attn_cross")
    p = {
        "embed": _normal(ks[1], (cfg.vocab_size, cfg.d_model), dtype=dtype),
        "encoder": enc,
        "decoder": dec,
        "norm_enc": jnp.zeros((cfg.d_model,), jnp.float32),
        "norm_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    ax = {
        "embed": ("vocab", "embed"),
        "encoder": enc_ax,
        "decoder": dec_ax,
        "norm_enc": ("embed",),
        "norm_f": ("embed",),
    }
    return p, ax


def _enc_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(cfg, num_layers=cfg.encdec.num_encoder_layers)


def encode(p: Params, cfg: ModelConfig, frame_embeds: jax.Array, *,
           ctx=None) -> jax.Array:
    mesh = ctx.mesh if ctx else None
    B, F, d = frame_embeds.shape
    x = frame_embeds + sinusoid_positions(F, d).astype(frame_embeds.dtype)[None]
    x = shard(x, ("batch", None, "embed"), mesh=mesh)
    x, _, _ = blocks.apply_stack(
        p["encoder"], x, _enc_cfg(cfg), ctx=ctx, positions=jnp.arange(F),
        causal=False, kind_override="attn_bidir")
    return rms_norm(x, p["norm_enc"], cfg.norm_eps)


def forward(p: Params, cfg: ModelConfig, tokens: jax.Array,
            frame_embeds: jax.Array, *, ctx=None, remat: str = "none"):
    """Teacher-forced decoder over encoder output. Returns logits."""
    mesh = ctx.mesh if ctx else None
    enc = encode(p, cfg, frame_embeds, ctx=ctx)
    B, S = tokens.shape
    x = jnp.take(p["embed"], tokens, axis=0)
    x = x + sinusoid_positions(S, cfg.d_model).astype(x.dtype)[None]
    x = shard(x, ("batch", None, "embed"), mesh=mesh)
    x, _, _ = blocks.apply_stack(
        p["decoder"], x, cfg, ctx=ctx, positions=jnp.arange(S),
        encoder_out=enc, remat=remat, kind_override="attn_cross")
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("...d,vd->...v", x, p["embed"])
    return shard(logits, ("batch", None, "vocab"), mesh=mesh)


def loss_fn(p: Params, cfg: ModelConfig, batch, *, ctx=None, remat="none"):
    logits = forward(p, cfg, batch["tokens"], batch["frame_embeds"],
                     ctx=ctx, remat=remat)
    loss = lm_lib.sharded_xent(logits, batch["labels"],
                               mesh=ctx.mesh if ctx else None)
    return loss, {"xent": loss}


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    one = {"attn": {"k": jnp.zeros((B, cfg.num_kv_heads, S, hd), dtype),
                    "v": jnp.zeros((B, cfg.num_kv_heads, S, hd), dtype)}}
    return {"scan": (jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (cfg.num_layers,) + t.shape), one),)}


def cache_axes(cfg: ModelConfig):
    one = {"attn": {"k": (None, "batch", "kv_heads", "decode_seq", None),
                    "v": (None, "batch", "kv_heads", "decode_seq", None)}}
    return {"scan": (one,)}


def decode_step(p: Params, cfg: ModelConfig, cache, tokens: jax.Array,
                cur_pos: jax.Array, encoder_out: jax.Array, *, ctx=None):
    """One decoder token against the self-cache + fixed encoder output."""
    mesh = ctx.mesh if ctx else None
    B = tokens.shape[0]
    x = jnp.take(p["embed"], tokens[:, None], axis=0)
    # per-row position offset (continuous batching decodes slots at ragged
    # positions): [B, 1] offset broadcasts through sinusoid_positions
    pe = sinusoid_positions(1, cfg.d_model, offset=cur_pos[:, None])
    x = x + pe.astype(x.dtype)[:, None, :]
    x = shard(x, ("batch", None, "embed"), mesh=mesh)
    x, new_cache, _ = blocks.apply_stack(
        p["decoder"], x, cfg, ctx=ctx, positions=cur_pos[:, None],
        caches=cache, cur_pos=cur_pos, encoder_out=encoder_out,
        kind_override="attn_cross")
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], p["embed"])
    return shard(logits, ("batch", "vocab"), mesh=mesh), new_cache
