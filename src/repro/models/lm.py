"""Decoder-only language models (dense / MoE / SSM / hybrid / VLM).

Pure-functional: ``init`` builds the param pytree + logical-axes pytree;
``forward`` / ``loss_fn`` / ``prefill`` / ``decode_step`` are jit-able.
The VLM variant consumes precomputed patch embeddings (frontend stub) and
M-RoPE positions.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import blocks
from repro.layers.blocks import _normal, rms_norm
from repro.sharding import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 4)
    stack, stack_ax = blocks.init_stack(ks[0], cfg, dtype)
    p: Params = {
        "embed": _normal(ks[1], (cfg.vocab_size, cfg.d_model), dtype=dtype),
        "stack": stack,
        "norm_f": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    ax: Params = {
        "embed": ("vocab", "embed"),
        "stack": stack_ax,
        "norm_f": ("embed",),
    }
    if not cfg.tie_embeddings:
        p["head"] = _normal(ks[2], (cfg.d_model, cfg.vocab_size), dtype=dtype)
        ax["head"] = ("embed", "vocab")
    return p, ax


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _embed(p: Params, tokens: jax.Array, cfg: ModelConfig, mesh) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return shard(x, ("batch", None, "embed"), mesh=mesh)


def _logits(p: Params, x: jax.Array, cfg: ModelConfig, mesh) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["head"])
    axes = ("batch", None, "vocab") if logits.ndim == 3 else ("batch", "vocab")
    return shard(logits, axes, mesh=mesh)


def mrope_positions_for(cfg: ModelConfig, B: int, S: int,
                        num_patches: int) -> jax.Array:
    """[B, S, 3] (t, h, w) position streams: a √P×√P patch grid followed by
    sequential text positions (Qwen2-VL layout)."""
    g = max(int(math.sqrt(max(num_patches, 1))), 1)
    i = jnp.arange(S)
    is_patch = i < num_patches
    t = jnp.where(is_patch, 0, i - num_patches + g)
    h = jnp.where(is_patch, i // g, i - num_patches + g)
    w = jnp.where(is_patch, i % g, i - num_patches + g)
    pos = jnp.stack([t, h, w], axis=-1)
    return jnp.broadcast_to(pos[None], (B, S, 3)).astype(jnp.int32)


def sharded_xent(logits: jax.Array, labels: jax.Array,
                 mesh=None) -> jax.Array:
    """Token-mean cross entropy; the vocab dim stays model-sharded (GSPMD
    inserts the max/sum reductions)."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(p: Params, cfg: ModelConfig, tokens: jax.Array, *,
            ctx=None, patch_embeds: Optional[jax.Array] = None,
            remat: str = "none", collect: bool = False):
    """tokens [B, St]; patch_embeds [B, P, d] for VLM (prepended).

    Returns (logits [B, S, V], caches-or-None, aux)."""
    mesh = ctx.mesh if ctx else None
    x = _embed(p, tokens, cfg, mesh)
    B = x.shape[0]
    mpos = None
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
        x = shard(x, ("batch", None, "embed"), mesh=mesh)
    S = x.shape[1]
    if cfg.pos_embedding == "mrope":
        P = 0 if patch_embeds is None else patch_embeds.shape[1]
        mpos = mrope_positions_for(cfg, B, S, P)
    positions = jnp.arange(S)
    caches = _empty_caches(cfg, B, S, x.dtype) if collect else None
    x, new_caches, aux = blocks.apply_stack(
        p["stack"], x, cfg, ctx=ctx, positions=positions,
        caches=caches, cur_pos=jnp.zeros((B,), jnp.int32) if collect else None,
        mrope_positions=mpos, remat=remat)
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    return _logits(p, x, cfg, mesh), new_caches, aux


def loss_fn(p: Params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            ctx=None, remat: str = "none"):
    logits, _, aux = forward(p, cfg, batch["tokens"], ctx=ctx,
                             patch_embeds=batch.get("patch_embeds"),
                             remat=remat)
    St = batch["labels"].shape[1]
    loss = sharded_xent(logits[:, -St:], batch["labels"],
                        mesh=ctx.mesh if ctx else None)
    return loss + aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, kind: str, B: int, S: int, dtype,
                 paging=None):
    """``paging`` (core.paging.PagedLayout): attention leaves trade the
    per-slot ``[B, ..., S, ...]`` seq axis for the shared
    ``[num_pages, page_size, ...]`` pool; recurrent state leaves have no
    seq axis and keep their slot-batch layout either way."""
    d = cfg.d_model
    if kind == "mamba":
        s = cfg.ssm
        d_in = s.expand * d
        return {"h": jnp.zeros((B, d_in, s.d_state), jnp.float32),
                "conv": jnp.zeros((B, s.d_conv - 1, d_in), dtype)}
    if kind == "rglru":
        g = cfg.rglru
        W = g.lru_width or d
        return {"h": jnp.zeros((B, W), jnp.float32),
                "conv": jnp.zeros((B, g.conv1d_width - 1, W), dtype)}
    if cfg.mla is not None:
        m = cfg.mla
        if paging is not None:
            if paging.kv_int8:
                raise ValueError(
                    "kv_int8 paging covers the GQA K/V pools only — the "
                    "MLA latent is already compressed")
            return {"attn": {
                "latent": jnp.zeros(
                    (paging.num_pages, paging.page_size, m.kv_lora_rank),
                    dtype),
                "k_rope": jnp.zeros(
                    (paging.num_pages, paging.page_size,
                     m.qk_rope_head_dim), dtype)}}
        return {"attn": {
            "latent": jnp.zeros((B, S, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((B, S, m.qk_rope_head_dim), dtype)}}
    hd = cfg.resolved_head_dim
    if paging is not None:
        shape = (paging.num_pages, cfg.num_kv_heads, paging.page_size, hd)
        if paging.kv_int8:
            return {"attn": {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:3], jnp.float32),
                "v_scale": jnp.zeros(shape[:3], jnp.float32)}}
        return {"attn": {"k": jnp.zeros(shape, dtype),
                         "v": jnp.zeros(shape, dtype)}}
    return {"attn": {
        "k": jnp.zeros((B, cfg.num_kv_heads, S, hd), dtype),
        "v": jnp.zeros((B, cfg.num_kv_heads, S, hd), dtype)}}


def _cache_axes(cfg: ModelConfig, kind: str, paging=None):
    if kind == "mamba":
        return {"h": ("batch", "lru", None), "conv": ("batch", None, "lru")}
    if kind == "rglru":
        return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}
    if cfg.mla is not None:
        if paging is not None:
            # the pool axis is NOT the slot batch: pages from different
            # slots interleave freely, so it must stay unsharded
            return {"attn": {"latent": (None, None, None),
                             "k_rope": (None, None, None)}}
        return {"attn": {"latent": ("batch", "decode_seq", None),
                         "k_rope": ("batch", "decode_seq", None)}}
    if paging is not None:
        ax = {"k": (None, "kv_heads", None, None),
              "v": (None, "kv_heads", None, None)}
        if paging.kv_int8:
            ax["k_scale"] = (None, "kv_heads", None)
            ax["v_scale"] = (None, "kv_heads", None)
        return {"attn": ax}
    return {"attn": {"k": ("batch", "kv_heads", "decode_seq", None),
                     "v": ("batch", "kv_heads", "decode_seq", None)}}


def _fix_rglru_cache(c):
    # apply_block returns {"h","conv"} for rglru; drop the placeholder
    return c


def _empty_caches(cfg: ModelConfig, B: int, S: int, dtype, paging=None):
    prefix, pattern, repeat, suffix = blocks.split_layers(cfg)
    out: Params = {}
    if prefix:
        out["prefix"] = [_layer_cache(cfg, k, B, S, dtype, paging)
                         for k in prefix]
    group = tuple(_layer_cache(cfg, k, B, S, dtype, paging) for k in pattern)
    out["scan"] = jax.tree.map(
        lambda t: jnp.broadcast_to(t[None], (repeat,) + t.shape), group)
    if suffix:
        out["suffix"] = [_layer_cache(cfg, k, B, S, dtype, paging)
                         for k in suffix]
    return out


def init_cache(cfg: ModelConfig, B: int, S: int, dtype=jnp.float32,
               paging=None) -> Params:
    return _empty_caches(cfg, B, S, dtype, paging)


def cache_axes(cfg: ModelConfig, paging=None) -> Params:
    prefix, pattern, repeat, suffix = blocks.split_layers(cfg)
    out: Params = {}
    lift = lambda ax: jax.tree.map(
        lambda t: (None,) + tuple(t), ax,
        is_leaf=lambda t: isinstance(t, tuple)
        and all(e is None or isinstance(e, str) for e in t))
    if prefix:
        out["prefix"] = [_cache_axes(cfg, k, paging) for k in prefix]
    out["scan"] = tuple(lift(_cache_axes(cfg, k, paging)) for k in pattern)
    if suffix:
        out["suffix"] = [_cache_axes(cfg, k, paging) for k in suffix]
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def decode_step(p: Params, cfg: ModelConfig, cache: Params,
                tokens: jax.Array, cur_pos: jax.Array, *, ctx=None,
                pages: Optional[jax.Array] = None):
    """One-token decode. tokens [B]; cur_pos [B] (uniform). ``pages``
    [B, pages_per_slot] int32 routes cache reads/writes through the
    block-paged pool (cache leaves must be paged-shape). Returns
    (logits [B, V], new_cache)."""
    mesh = ctx.mesh if ctx else None
    x = _embed(p, tokens[:, None], cfg, mesh)
    B = x.shape[0]
    positions = cur_pos[:, None]
    mpos = None
    if cfg.pos_embedding == "mrope":
        mpos = jnp.broadcast_to(cur_pos[:, None, None], (B, 1, 3)).astype(jnp.int32)
    x, new_cache, _ = blocks.apply_stack(
        p["stack"], x, cfg, ctx=ctx, positions=positions, caches=cache,
        cur_pos=cur_pos, mrope_positions=mpos, pages=pages)
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    return _logits(p, x[:, 0], cfg, mesh), new_cache
