"""The paper's benchmark model: ViT for image classification (Sec. V-A).

Encoder-only transformer over patch embeddings + [CLS], learned positions,
GELU MLP, classification head. This is the model the paper trains on
Colossal-AI (ViT-1B: hs=2048, depth=24, sql=65 for 32x32 CIFAR images with
patch 4). The FFN/QKV linears run through the controlled TP path — this
model is the primary vehicle for the accuracy experiments (Figs. 3-11).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.layers import blocks
from repro.layers.blocks import _normal, rms_norm
from repro.sharding import shard

Params = Dict[str, Any]

PATCH_DIM = 4 * 4 * 3   # 32x32x3 images, patch 4


def init(rng, cfg: ModelConfig, dtype=jnp.float32) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 6)
    stack, stack_ax = blocks.init_stack(ks[0], cfg, dtype,
                                        kind_override="attn_bidir")
    S = cfg.frontend.num_tokens           # patches + CLS
    p = {
        "patch_proj": _normal(ks[1], (PATCH_DIM, cfg.d_model), dtype=dtype),
        "cls": _normal(ks[2], (1, 1, cfg.d_model), dtype=dtype),
        "pos": _normal(ks[3], (S, cfg.d_model), std=0.01, dtype=dtype),
        "stack": stack,
        "norm_f": jnp.zeros((cfg.d_model,), jnp.float32),
        "head": _normal(ks[4], (cfg.d_model, cfg.num_classes), dtype=dtype),
    }
    ax = {
        "patch_proj": (None, "embed"),
        "cls": (None, None, "embed"),
        "pos": (None, "embed"),
        "stack": stack_ax,
        "norm_f": ("embed",),
        "head": ("embed", "classes"),
    }
    return p, ax


def forward(p: Params, cfg: ModelConfig, patches: jax.Array, *,
            ctx=None, remat: str = "none") -> jax.Array:
    """patches [B, P, PATCH_DIM] -> logits [B, num_classes]."""
    mesh = ctx.mesh if ctx else None
    B = patches.shape[0]
    x = jnp.einsum("bpk,kd->bpd", patches.astype(p["patch_proj"].dtype),
                   p["patch_proj"])
    cls = jnp.broadcast_to(p["cls"], (B, 1, cfg.d_model)).astype(x.dtype)
    x = jnp.concatenate([cls, x], axis=1) + p["pos"][None].astype(x.dtype)
    x = shard(x, ("batch", None, "embed"), mesh=mesh)
    x, _, _ = blocks.apply_stack(
        p["stack"], x, cfg, ctx=ctx, positions=jnp.arange(x.shape[1]),
        causal=False, remat=remat, kind_override="attn_bidir")
    x = rms_norm(x, p["norm_f"], cfg.norm_eps)
    return jnp.einsum("bd,dc->bc", x[:, 0], p["head"])


def loss_fn(p: Params, cfg: ModelConfig, batch, *, ctx=None, remat="none"):
    logits = forward(p, cfg, batch["patches"], ctx=ctx, remat=remat)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))
    acc = jnp.mean((logits.argmax(-1) == labels).astype(jnp.float32))
    return loss, {"xent": loss, "acc": acc}
