"""Model registry: family -> functional API."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    init: Callable
    loss_fn: Callable
    forward: Callable
    init_cache: Optional[Callable] = None
    cache_axes: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    encode: Optional[Callable] = None      # enc-dec only

    @property
    def has_decode(self) -> bool:
        return self.decode_step is not None


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.num_classes:                     # the paper's ViT classifier
        from repro.models import vit
        return ModelAPI(init=vit.init, loss_fn=vit.loss_fn, forward=vit.forward)
    if cfg.encdec is not None:
        from repro.models import encdec
        return ModelAPI(init=encdec.init, loss_fn=encdec.loss_fn,
                        forward=encdec.forward, init_cache=encdec.init_cache,
                        cache_axes=encdec.cache_axes,
                        decode_step=encdec.decode_step, encode=encdec.encode)
    from repro.models import lm
    return ModelAPI(init=lm.init, loss_fn=lm.loss_fn, forward=lm.forward,
                    init_cache=lm.init_cache, cache_axes=lm.cache_axes,
                    decode_step=lm.decode_step)
