"""Input/param/state specs for every (architecture × input shape).

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for each model input, plus the matching
NamedShardings — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.config import ModelConfig, ShapeConfig
from repro.models import ModelAPI, get_api

SDS = jax.ShapeDtypeStruct


def rules_for(shape: ShapeConfig, mesh: Mesh,
              cfg: Optional[ModelConfig] = None, fsdp: bool = False):
    """Logical rules, adapted per (arch, shape):

    * a global batch smaller than the data axis cannot shard over it
      (long_500k B=1 → batch replicated, the KV-cache *sequence* shards
      over `data` instead — flash-decoding style);
    * KV-head counts that don't divide the model axis (GQA with 4/8/12 KV
      heads on 16-way TP) shard the cache's `head_dim` over `model`
      instead (scores become psum'd partials — GSPMD inserts them).
    """
    data = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    model = mesh.shape.get("model", 1)
    overrides = {}
    if shape.global_batch < data:
        overrides["batch"] = None
    else:
        overrides["decode_seq"] = None
    if cfg is not None and cfg.num_kv_heads and cfg.num_kv_heads % model != 0:
        overrides["kv_heads"] = None
        overrides["head_dim"] = ("model",)
    if fsdp:
        # beyond-paper: shard the stacked-layer dim of params/opt states
        # over data (ZeRO-3-over-layers); GSPMD all-gathers each layer's
        # slice at its scan step and reduce-scatters its grads.
        overrides["layers"] = ("data",)
    return sh.make_rules(**overrides)


def _ns(mesh, rules, *axes, shape=None):
    spec = sh.filter_spec_for_mesh(sh.logical_to_spec(axes, rules), mesh)
    if shape is not None:
        spec = sh.fit_spec_to_shape(spec, shape, mesh)
    return NamedSharding(mesh, spec)


def effective_model_cfg(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent model adaptations (DESIGN.md §5): pure full-attention
    archs get an explicit sliding-window VARIANT for long_500k (window 8192)
    so sub-quadratic decode lowers; natively windowed/recurrent archs are
    untouched."""
    if (shape.name == "long_500k" and cfg.sliding_window == 0
            and cfg.family not in ("ssm", "hybrid")):
        return dataclasses.replace(cfg, sliding_window=8192)
    return cfg


def batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                dtype=jnp.bfloat16) -> Tuple[Dict[str, SDS], Dict[str, Any]]:
    """(SDS dict, sharding dict) for a TRAIN/PREFILL batch."""
    rules = rules_for(shape, mesh, cfg)
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, SDS] = {}
    shards: Dict[str, Any] = {}
    tok = _ns(mesh, rules, "batch", None)

    if cfg.num_classes:                      # paper ViT: patches + labels
        P_ = cfg.frontend.num_tokens - 1
        specs["patches"] = SDS((B, P_, 48), dtype)
        shards["patches"] = _ns(mesh, rules, "batch", None, None)
        specs["labels"] = SDS((B,), jnp.int32)
        shards["labels"] = _ns(mesh, rules, "batch")
        return specs, shards

    if cfg.frontend is not None and cfg.family == "vlm":
        Pn = cfg.frontend.num_tokens
        St = S - Pn
        specs["patch_embeds"] = SDS((B, Pn, cfg.d_model), dtype)
        shards["patch_embeds"] = _ns(mesh, rules, "batch", None, "embed")
        specs["tokens"] = SDS((B, St), jnp.int32)
        specs["labels"] = SDS((B, St), jnp.int32)
        shards["tokens"] = shards["labels"] = tok
        return specs, shards

    if cfg.encdec is not None:
        specs["frame_embeds"] = SDS((B, cfg.encdec.encoder_seq_len, cfg.d_model), dtype)
        shards["frame_embeds"] = _ns(mesh, rules, "batch", None, "embed")

    specs["tokens"] = SDS((B, S), jnp.int32)
    specs["labels"] = SDS((B, S), jnp.int32)
    shards["tokens"] = shards["labels"] = tok
    return specs, shards


def decode_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                 dtype=jnp.bfloat16, paging=None):
    """(SDS dict, sharding dict) for one SERVE step: token + cache at
    seq_len, writing position seq_len-1. ``paging``
    (core.paging.PagedLayout) swaps the attention cache leaves to the
    block-paged pool layout and adds a per-step page-table input."""
    rules = rules_for(shape, mesh, cfg)
    api = get_api(cfg)
    B, S = shape.global_batch, shape.seq_len
    if paging is not None:
        cache_sds = jax.eval_shape(
            lambda: api.init_cache(cfg, B, S, dtype, paging=paging))
        cache_ax = api.cache_axes(cfg, paging=paging)
    else:
        cache_sds = jax.eval_shape(lambda: api.init_cache(cfg, B, S, dtype))
        cache_ax = api.cache_axes(cfg)
    # pad missing leading dims (scan-stacked) with None
    cache_shards = jax.tree.map(
        lambda sds, ax: _ns(mesh, rules,
                            *((None,) * (len(sds.shape) - len(ax)) + tuple(ax)),
                            shape=sds.shape),
        cache_sds, cache_ax)

    specs = {"cache": cache_sds,
             "tokens": SDS((B,), jnp.int32),
             "cur_pos": SDS((B,), jnp.int32)}
    shards = {"cache": cache_shards,
              "tokens": _ns(mesh, rules, "batch"),
              "cur_pos": _ns(mesh, rules, "batch")}
    if paging is not None:
        specs["pages"] = SDS((B, paging.pages_per_slot), jnp.int32)
        shards["pages"] = _ns(mesh, rules, "batch", None)
    if cfg.encdec is not None:
        specs["encoder_out"] = SDS(
            (B, cfg.encdec.encoder_seq_len, cfg.d_model), dtype)
        shards["encoder_out"] = _ns(mesh, rules, "batch", None, "embed")
    return specs, shards


def param_specs(cfg: ModelConfig, mesh: Mesh, rules=None, dtype=jnp.bfloat16):
    """(params SDS tree, logical axes tree, NamedSharding tree) without
    allocating anything (init traced under eval_shape)."""
    api = get_api(cfg)
    box = {}

    def f():
        p, ax = api.init(jax.random.PRNGKey(0), cfg, dtype)
        box["ax"] = ax
        return p

    p_sds = jax.eval_shape(f)
    ax = box["ax"]
    rules = rules or sh.DEFAULT_RULES
    is_ax_leaf = lambda t: (isinstance(t, tuple) and all(
        e is None or isinstance(e, str) for e in t)) or t is None

    def one(sds, a):
        a = a or ()
        a = ((None,) * (len(sds.shape) - len(a)) + tuple(a))[: len(sds.shape)]
        spec = sh.filter_spec_for_mesh(sh.logical_to_spec(a, rules), mesh)
        return NamedSharding(mesh, sh.fit_spec_to_shape(spec, sds.shape, mesh))

    shards = jax.tree.map(one, p_sds, _align(ax, p_sds, is_ax_leaf))
    return p_sds, ax, shards


def _align(ax_tree, sds_tree, is_leaf):
    """Return an axes tree with the same treedef as sds_tree (axes leaves
    may sit one level up when params were vmap-stacked)."""
    flat_sds, treedef = jax.tree.flatten(sds_tree)
    try:
        flat_ax = treedef.flatten_up_to(ax_tree)
        return ax_tree
    except Exception:
        pass
    # fall back: walk both trees and broadcast tuple-leaves over dict subtrees
    def walk(ax, sds):
        if is_leaf(ax) or ax is None:
            if isinstance(sds, dict):
                return {k: walk(ax, v) for k, v in sds.items()}
            if isinstance(sds, (list, tuple)):
                return type(sds)(walk(ax, v) for v in sds)
            return ax
        if isinstance(sds, dict):
            return {k: walk(ax[k] if isinstance(ax, dict) else ax, v)
                    for k, v in sds.items()}
        if isinstance(sds, (list, tuple)):
            if isinstance(ax, (list, tuple)) and len(ax) == len(sds) \
                    and not is_leaf(ax):
                return type(sds)(walk(a, v) for a, v in zip(ax, sds))
            return type(sds)(walk(ax, v) for v in sds)
        return ax
    return walk(ax_tree, sds_tree)
