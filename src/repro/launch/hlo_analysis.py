"""Post-compile HLO analysis: collective-byte accounting + roofline terms.

The roofline (EXPERIMENTS.md §Roofline) is derived from the compiled
artifact, not from wall time (no TPU in the container):

  compute    = FLOPs_global  / (chips × peak)
  memory     = bytes_global  / (chips × HBM_bw)
  collective = coll_bytes_global / (chips × link_bw)

``cost_analysis()`` reports the per-device module; collective bytes are
parsed from the per-device HLO text and scaled by the chip count.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum output-tensor bytes of every collective op in (per-device) HLO.

    Returns {kind: bytes} + {"total": ...}. `-start`/`-done` async pairs are
    counted once (on `-start`).
    """
    out = {k: 0 for k in COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s*"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", s)
        if not m:
            continue
        shape_str, kind, _ = m.group(1), m.group(2), m.group(3)
        if re.search(rf"{kind}-done", s.split("=")[1].split("(")[0]):
            continue
        out[kind] += _shape_bytes(shape_str)
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    return out


_START_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|collective-permute)-start\(")
_DONE_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|collective-permute)-done\("
    r"\s*%?([\w.\-]+)")
_SYNC_RE = re.compile(
    r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|collective-permute)\(")
_OPCODE_RE = re.compile(r"=\s*(?:\([^)]*\)|[\w\[\],{}\s/]*?)\s*([\w\-]+)\(")

# instruction kinds that are bookkeeping, not schedulable compute
_NON_COMPUTE = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "opt-barrier"}


def collective_overlap_report(hlo_text: str) -> dict:
    """Per-step report of how much collective traffic overlaps compute
    (ISSUE 7 satellite): walks the scheduled HLO, pairs every
    ``-start`` with its ``-done``, and counts the compute instructions
    the scheduler placed BETWEEN them. A pair with no intervening
    compute is async in name only — its bytes are fully exposed.
    Synchronous collectives (no -start form) are exposed by definition.

    Returns {"pairs": [...], "total_bytes", "overlapped_bytes",
    "fraction_overlapped", "async_pairs", "sync_collectives"}."""
    open_pairs: Dict[str, dict] = {}
    pairs = []
    sync_count = 0
    total = overlapped = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s and "=" not in s:
            continue
        m = _START_RE.match(s)
        if m:
            name, shape_str, kind = m.groups()
            open_pairs[name] = {"kind": kind,
                                "bytes": _shape_bytes(shape_str),
                                "intervening_compute_ops": 0}
            continue
        md = _DONE_RE.search(s)
        if md:
            kind, operand = md.groups()
            p = open_pairs.pop(operand, None)
            if p is None:       # -done on a name we never saw start
                continue
            p["overlapped"] = p["intervening_compute_ops"] > 0
            pairs.append(p)
            total += p["bytes"]
            if p["overlapped"]:
                overlapped += p["bytes"]
            continue
        ms = _SYNC_RE.match(s)
        if ms:
            b = _shape_bytes(ms.group(1))
            pairs.append({"kind": ms.group(2), "bytes": b,
                          "intervening_compute_ops": 0,
                          "overlapped": False})
            sync_count += 1
            total += b
            continue
        if open_pairs:
            mo = _OPCODE_RE.search(s)
            if mo and mo.group(1) not in _NON_COMPUTE:
                for p in open_pairs.values():
                    p["intervening_compute_ops"] += 1
    return {
        "pairs": pairs,
        "total_bytes": total,
        "overlapped_bytes": overlapped,
        "fraction_overlapped": overlapped / total if total else 0.0,
        "async_pairs": len(pairs) - sync_count,
        "sync_collectives": sync_count,
    }


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    coll_breakdown: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
        }


def roofline_from_compiled(compiled, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    return Roofline(flops_per_device=flops, bytes_per_device=nbytes,
                    coll_bytes_per_device=float(coll["total"]), chips=chips,
                    coll_breakdown=coll)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N_active·B decode."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def analytic_step_flops(cfg, shape) -> float:
    """Analytic FLOOR for the step's global FLOPs: parameter matmuls
    (MODEL_FLOPS) + attention score/value matmuls (which 6·N·D omits).

    Needed because XLA's ``cost_analysis()`` counts a ``while`` body ONCE,
    not × trip-count — scan-over-layers models under-report by ~L×. The
    roofline's compute term uses max(HLO, analytic)."""
    base = model_flops(cfg, shape)
    if cfg.is_attention_free:
        return base
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    L = cfg.num_layers
    window = cfg.sliding_window or 0
    if shape.kind == "decode":
        ctx = min(window, S) if window else S
        attn = 4.0 * B * ctx * H * hd * L          # one query vs the cache
    else:
        eff = (min(window, S) if window else S / 2.0)   # causal halves it
        attn = 4.0 * B * S * eff * H * hd * L
        if shape.kind == "train":
            attn *= 3.0                            # fwd + 2x bwd
    return base + attn


def analytic_step_bytes(cfg, shape, *, decode_occupancy: float = 1.0) -> float:
    """Analytic FLOOR for global HBM traffic of one step (same rationale
    as :func:`analytic_step_flops` — scan bodies are under-counted).

    train:   params f32 × (grad + AdamW moments rw ≈ 10 accesses)
             + activations (fwd write + bwd read) + logits traffic.
    prefill: params bf16 + activations + KV-cache write.
    decode:  params bf16 + KV-cache read (the classic decode bound).

    ``decode_occupancy`` is mean((cur_pos+1)/max_len) over the slots:
    the fused decode kernel reads only the OCCUPIED cache rows, so the
    decode memory term scales with actual occupancy, not max_len
    (ISSUE 7 — the old full-rows assumption overstated the roofline
    bound for mostly-empty slots). Default 1.0 = every row, which is
    both the unfused path's real traffic and the old behavior."""
    P = float(cfg.param_count())
    B, S = shape.global_batch, shape.seq_len
    d, L, V = cfg.d_model, cfg.num_layers, max(cfg.vocab_size, 1)
    tokens = B * (S if shape.kind != "decode" else 1)
    kv = max(cfg.num_kv_heads, 1) * cfg.resolved_head_dim
    if cfg.mla is not None:
        kv = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    if cfg.is_attention_free:
        kv = 2 * (cfg.ssm.expand * d * cfg.ssm.d_state) // max(L, 1) if cfg.ssm else 0
    if shape.kind == "train":
        act = tokens * d * L * 16.0          # fwd write + bwd read, f32-ish
        logits = tokens * V * 4.0 * 3.0
        return P * 4.0 * 10.0 + act + logits
    if shape.kind == "prefill":
        act = tokens * d * L * 8.0
        cache_w = 2.0 * B * S * kv * 2.0
        return P * 2.0 + act + cache_w
    # decode: read the occupied cache rows (or the window for SWA archs)
    ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
    occ = min(max(float(decode_occupancy), 0.0), 1.0)
    cache_r = 2.0 * B * ctx * occ * kv * 2.0 * L
    return P * 2.0 + cache_r


def analytic_step_collective_bytes(cfg, shape, mesh_shape) -> float:
    """Analytic FLOOR for GLOBAL collective traffic of one step under the
    Megatron-1D sharding (same while-body-undercount rationale).

    Per transformer layer: 2 activation all-reduces over TP in fwd
    (attention out + FFN out) and 2 in bwd; ring all-reduce moves
    2·(e−1)/e · size through each device. Training adds the DP gradient
    all-reduce of the TP-sharded params. MoE (expert-parallel) adds the
    dispatch/return all-to-alls."""
    e = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = e * dp
    if e <= 1:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (S if shape.kind != "decode" else 1)
    d, L = cfg.d_model, cfg.num_layers
    bytes_el = 4.0 if shape.kind == "train" else 2.0
    ar_factor = 2.0 * (e - 1) / e
    n_ar = (4.0 if shape.kind == "train" else 2.0)
    if cfg.is_attention_free:
        n_ar /= 2.0                       # single mixer psum per layer
    # activation all-reduces run per TP group on data-local tokens;
    # global volume = per-device volume × chips
    act_coll_global = n_ar * L * ar_factor * (tokens / dp) * d * bytes_el * chips
    total = act_coll_global
    if shape.kind == "train":
        p_local = cfg.param_count() / e
        total += ar_factor * p_local * 4.0 * chips     # DP grad all-reduce
    if cfg.moe is not None and cfg.moe.expert_sharding == "expert":
        # dispatch + combine all-to-alls of the grouped token buffers
        k = cfg.moe.top_k * cfg.moe.capacity_factor
        total += 2.0 * k * tokens * d * bytes_el * (3.0 if shape.kind == "train" else 1.0)
    return total
