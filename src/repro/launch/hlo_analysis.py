"""DEPRECATED shim — the HLO/roofline analysis helpers moved to
:mod:`repro.analysis.hlo` (the static analyzer's canonical parser,
with the tuple-shape and ``-done``-line byte-accounting fixes).

Importing names through this module keeps old callers working but warns;
new code must import from ``repro.analysis.hlo`` — enforced by the ruff
TID251 banned-api rule in pyproject.toml (this path is banned outside
the analysis package).
"""
from __future__ import annotations

_FORWARDED = (
    "PEAK_FLOPS", "HBM_BW", "LINK_BW", "COLLECTIVE_KINDS",
    "_DTYPE_BYTES", "_SHAPE_RE", "_shape_bytes", "shape_bytes",
    "shape_elements", "parse_collectives", "collective_payload_bytes",
    "collective_overlap_report", "Roofline", "roofline_from_compiled",
    "model_flops", "analytic_step_flops", "analytic_step_bytes",
    "analytic_step_collective_bytes")


def __getattr__(name: str):
    if name in _FORWARDED:
        import warnings
        warnings.warn(
            f"repro.launch.hlo_analysis.{name} is deprecated; import it "
            "from repro.analysis.hlo", DeprecationWarning, stacklevel=2)
        from repro.analysis import hlo
        return getattr(hlo, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
