"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; the
`pod` axis extends data parallelism across the inter-pod links.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape} but found {len(devices)}; "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_small_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Mesh over however many (host) devices a test/trainer asked for."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, found {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(data, model), ("data", "model"))
