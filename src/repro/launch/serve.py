"""Batched serving driver: checkpoint -> prefill -> decode loop.

A minimal production-shaped server core: fixed-size request batches,
greedy decode against the jitted serve_step with a donated KV cache, and
per-request completion tracking. (Request transport/HTTP is out of scope;
this is the engine the dry-run's decode shapes lower.)

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --batch 4 \
        --prompt-len 8 --gen-len 24 [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import store as ckpt_store
from repro.config import get_config, smoke_variant
from repro.models import get_api


class DecodeEngine:
    """Holds params + a jitted single-token step; serves fixed batches."""

    def __init__(self, arch: str, batch: int, max_len: int,
                 ckpt_dir: Optional[str] = None, seed: int = 0):
        self.cfg = smoke_variant(get_config(arch))
        self.api = get_api(self.cfg)
        self.batch = batch
        self.max_len = max_len
        params, _ = self.api.init(jax.random.PRNGKey(seed), self.cfg)
        if ckpt_dir:
            last = ckpt_store.latest_step(ckpt_dir)
            if last is not None:
                params = ckpt_store.restore(ckpt_dir, last, params)
        self.params = params
        self._step = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, self.cfg, c, t, pos),
            donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, gen_len: int,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """prompts [B, P] int32 -> [B, P+gen_len] greedy continuations."""
        B, P = prompts.shape
        assert B == self.batch and P + gen_len <= self.max_len
        cache = self.api.init_cache(self.cfg, B, self.max_len)
        out = [prompts[:, 0]]
        done = np.zeros((B,), bool)
        for t in range(P + gen_len - 1):
            logits, cache = self._step(
                self.params, cache, jnp.asarray(out[-1], jnp.int32),
                jnp.full((B,), t, jnp.int32))
            if t + 1 < P:
                nxt = prompts[:, t + 1]
            else:
                nxt = np.asarray(logits.argmax(-1))
                if eos_id is not None:
                    done |= nxt == eos_id
                    nxt = np.where(done, eos_id or 0, nxt)
            out.append(nxt)
            if eos_id is not None and done.all():
                break
        return np.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    eng = DecodeEngine(args.arch, args.batch,
                       args.prompt_len + args.gen_len, args.ckpt_dir)
    rng = np.random.default_rng(0)
    tput = []
    for r in range(args.rounds):
        pat = rng.integers(0, eng.cfg.vocab_size, (args.batch, 4))
        prompts = np.tile(pat, (1, args.prompt_len // 4 + 1))[:, :args.prompt_len]
        t0 = time.time()
        seqs = eng.generate(prompts.astype(np.int32), args.gen_len)
        dt = time.time() - t0
        tok = args.batch * args.gen_len
        tput.append(tok / dt)
        print(f"round {r}: {seqs.shape[1]} positions, "
              f"{tok/dt:.1f} tok/s, sample: {seqs[0][:12]}")
    print(f"mean decode throughput: {np.mean(tput):.1f} tok/s "
          f"(reduced model, 1 CPU device)")


if __name__ == "__main__":
    main()
