"""Continuous-batching serve engine with slot-based KV cache and
straggler-aware decode control.

The seed served fixed batches in lockstep: requests could only enter and
leave together, and none of the paper's workload-control machinery ran at
inference time. This engine is the first path where the balancing
techniques run outside the training loop:

* **Request queue + admission control** — FIFO queue (bounded via
  ``max_queue``); requests are admitted whenever a KV slot is free and
  their arrival step has passed.
* **Slot-based KV cache** — ONE cache pytree padded to a fixed
  ``num_slots`` batch dim, each slot at its own ``cur_pos`` (the decode
  cache write is a per-row scatter, layers/blocks.py). Completed slots
  return to a free list and are zeroed by a jitted reset before reuse
  (semantics-preserving recycling: attention masks by position, recurrent
  SSM/conv state restarts from zeros). Because every array shape is fixed
  at construction, the jitted ``serve_step`` never re-traces on arrivals
  or completions — asserted by tests via the jit cache size.
* **Prefill-on-admit** — prompts are teacher-forced through the same
  jitted decode step (the ``build_serve_step``/``decode_specs`` path), so
  a newly admitted request prefills while other slots keep decoding.
* **Straggler-aware decode** — a χ-schedule (paper Sec. V-A) feeds the
  iteration-time model; measured-style per-rank decode times drive the
  :class:`SemiController` through the unified
  :class:`repro.control.ControlPlane` (the same plan-assembly /
  compile-cache / dispatch implementation the trainer uses —
  DESIGN_CONTROL.md). ``--control zero`` ZERO-resizes a contended rank's
  TP decode matmuls (fast, lossy); ``--control semi`` opens the paper's
  FULL mitigation space at serve time — Eq.(3) picks the straggler prefix
  that migrates (multi-source, reduce-merged, **lossless**: decode
  outputs are token-exact) and only the remainder resizes. Serving
  defaults to the ``lossless`` β-policy, so a SEMI plan that fits entirely
  in migration changes no tokens. Plans sized on a simulated group larger
  than the real mesh are *projected* (``repro.control.projection``):
  migration slots fold onto real ranks, resize buckets keep the
  critical-path branch. Executables are keyed by the full plan signature
  (shed counts included) in a :class:`PlanCompileCache`, so replanning
  swaps between compiled steps instead of recompiling.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --slots 4 \
        --requests 8 --prompt-len 8 --gen-len 24 [--control semi \
        --hetero contention --chi 4 --tp 4]
"""
from __future__ import annotations

# CLI nicety: when invoked as a script with --tp > 1, request that many
# host devices BEFORE jax initializes (shared jax-free helper).
if __name__ == "__main__":
    from repro.launch._bootstrap import (apply_xla_preset, argv_int,
                                         argv_str, ensure_host_devices)
    ensure_host_devices(argv_int("--tp"))
    apply_xla_preset(argv_str("--xla-preset", "none"))

import argparse
import collections
import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import store as ckpt_store
from repro.config import ShapeConfig, get_config, smoke_variant
from repro.control import ControlConfig, ControlPlane
from repro.core import geometry as geom_lib
from repro.core import hetero as hetero_lib
from repro.core import paging as paging_lib
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_small_mesh
from repro.models import get_api
from repro.sharding import ragged_local_width, use_mesh


# ---------------------------------------------------------------------------
# Requests / completions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request.

    arrival_step: engine step at which the request becomes eligible for
    admission (0 = immediately); lets tests/benchmarks replay staggered
    arrival traces deterministically.
    """

    uid: int
    prompt: np.ndarray                 # [P] int32 prompt tokens
    max_new_tokens: int
    arrival_step: int = 0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Completion:
    uid: int
    prompt: np.ndarray
    tokens: np.ndarray                 # generated tokens (<= max_new_tokens)
    admitted_step: int
    finished_step: int
    slot: int
    token_latencies: List[float]       # modeled seconds per emitted token
    # first entry includes queue wait + prefill (time-to-first-token)


@dataclasses.dataclass(frozen=True)
class LoadSnapshot:
    """What the cluster router sees of one engine (repro.cluster).

    ``chi`` is the per-rank χ feed — the estimator's χ̂ once the measured
    loop is locked, else the schedule's current oracle (ones when
    homogeneous). ``step_time_s`` prices one engine step under the
    ACTIVE control plan (``ControlPlane.capacity``), so a straggling
    replica whose SEMI loop already migrated its imbalance reads as
    (nearly) full capacity — the two nested control loops share one
    telemetry vocabulary. ``backlog_steps`` counts the token-steps
    still owed: active slots' remaining prefill chunks + decode tokens,
    plus every queued request's full cost.
    """

    step: int
    clock: float
    queue_depth: int
    active: int
    free_slots: int
    free_pages: Optional[int]          # None = fixed (non-paged) cache
    num_slots: int
    chi: np.ndarray
    work_frac: np.ndarray
    step_time_s: float
    dense_step_time_s: float
    backlog_steps: int


@dataclasses.dataclass
class _Slot:
    req: Request
    admitted_step: int
    pos: int = 0                       # NEXT cache position to feed
    next_token: int = 0                # token to feed this step (decode)
    generated: Optional[list] = None
    t_mark: float = 0.0                # engine clock at last token emission
    t_elig: float = 0.0                # clock at TTFT eligibility (fixed;
    #                                    restored on page-pool preemption)
    latencies: Optional[list] = None


# ---------------------------------------------------------------------------
# Control configuration
# ---------------------------------------------------------------------------


class ServeControlConfig(ControlConfig):
    """Deprecated alias of :class:`repro.control.ControlConfig`.

    The serve engine's knobs were collapsed into the shared
    :class:`ControlConfig` (field names are unchanged); this subclass
    exists only so existing callers keep working, and warns on
    construction. Import ``ControlConfig`` from ``repro.control``.
    """

    def __post_init__(self):
        warnings.warn(
            "ServeControlConfig is deprecated; use "
            "repro.control.ControlConfig (same field names)",
            DeprecationWarning, stacklevel=3)
        super().__post_init__()


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ServeEngine:
    """Continuous-batching decode engine over a fixed slot set."""

    def __init__(self, arch: str, num_slots: int = 4, max_len: int = 64, *,
                 tp: int = 1, ckpt_dir: Optional[str] = None, seed: int = 0,
                 control: Optional[ControlConfig] = None,
                 param_dtype: str = "float32",
                 max_queue: Optional[int] = None,
                 page_size: int = 0, prefill_chunk: int = 1,
                 kv_int8: bool = False,
                 num_pages: Optional[int] = None,
                 trace_tag: Optional[Dict] = None):
        """``page_size`` > 0 switches the KV cache to the block-paged
        pool layout (core/paging.py): attention cache leaves live in a
        shared ``[num_pages, page_size, ...]`` pool (``num_pages``
        defaults to full fixed-cache capacity; pass less to hold more
        resident slots than the pool could serve at max_len — the
        engine preempts on exhaustion). ``prefill_chunk`` teacher-forces
        up to that many prompt tokens per engine step inside ONE jitted
        step (decode slots still advance one token), so a long prompt
        no longer serializes the batch. ``kv_int8`` stores the GQA K/V
        pool in int8 with per-row f32 scales (half the pool HBM; not
        bit-exact, oracle attention path only)."""
        self.cfg = smoke_variant(get_config(arch))
        cfg_canonical = self.cfg
        self.api = get_api(self.cfg)
        if not self.api.has_decode or self.cfg.encdec is not None:
            raise ValueError(f"{arch}: the serve engine drives decoder-only "
                             "models (LM/SSM/hybrid/MoE)")
        self.num_slots = num_slots
        self.max_len = max_len
        self.tp = tp
        self.mesh = make_small_mesh(1, tp)
        self.shape = ShapeConfig("serve", max_len, num_slots, "decode")
        self.control = control or ControlConfig()
        self.max_queue = max_queue
        dtype = jnp.dtype(param_dtype)

        # ---- paged KV layout + chunked prefill --------------------------
        if kv_int8 and not page_size:
            raise ValueError("kv_int8 requires the paged cache "
                             "(--page-size > 0)")
        self.paging = (paging_lib.paged_layout(
            max_len, page_size, num_slots, num_pages=num_pages,
            kv_int8=kv_int8) if page_size else None)
        if self.paging is not None and self.control.fused_attention:
            if kv_int8:
                raise ValueError("kv_int8 has no fused-kernel path; drop "
                                 "--fused-attn (oracle dequant attention)")
            if page_size % 8:
                raise ValueError(f"--page-size {page_size} must be a "
                                 "multiple of 8 for the fused paged "
                                 "kernel (f32 sublane tiling)")
        self.alloc = (paging_lib.PageAllocator(self.paging, num_slots)
                      if self.paging is not None else None)
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.preemptions = 0

        # ---- workload control wiring (the unified control plane) --------
        c = self.control
        # static ragged shard geometry (core/geometry.py): the model
        # config carries the padded d_ff; params are initialized
        # canonically and expanded into the padded ragged layout below
        self.geometry = None
        if c.geometry is not None:
            geo = geom_lib.geometry_for_cfg(cfg_canonical, c.geometry,
                                            c.block_size)
            if not geo.is_equal:
                reason = geom_lib.geometry_unsupported_reason(cfg_canonical)
                if reason:
                    raise ValueError(
                        f"geometry unsupported for {arch}: {reason}")
                self.geometry = geo
                self.cfg = geom_lib.apply_geometry_cfg(cfg_canonical, geo)
                ragged_local_width(geo.padded_width, self.mesh)
        wc = c.to_workload()
        self._wc = wc

        # slot clearing runs INSIDE the jitted step (clear is a regular
        # [num_slots] input, zeros on non-admission steps): recycled
        # SSM/conv state restarts from zeros, and the cache array fed to
        # every step is always a previous step's output — a separate reset
        # executable produces different buffer layouts and costs a
        # spurious one-time retrace (observed on the mamba conv cache).
        cache_ax = (self.api.cache_axes(self.cfg, paging=self.paging)
                    if self.paging is not None
                    else self.api.cache_axes(self.cfg))

        def _clear_slots(cache, clear):
            def one(leaf, ax):
                ax_full = (None,) * (leaf.ndim - len(ax)) + tuple(ax)
                if "batch" not in ax_full:
                    # paged pool leaf: recycling is the allocator's job
                    # (reads mask by position; no zeroing needed)
                    return leaf
                b = ax_full.index("batch")
                shp = [1] * leaf.ndim
                shp[b] = num_slots
                return leaf * (1.0 - clear).reshape(shp).astype(leaf.dtype)
            return jax.tree.map(one, cache, cache_ax)

        # chunked-prefill lane merge: a substep's INVALID lanes (idle
        # slots, decode slots past substep 0, prefill lanes past the
        # prompt chunk) must not advance that slot's state. Attention
        # scatters already drop invalid positions; recurrent SSM/conv
        # leaves update unconditionally, so batch-axis leaves are
        # where-merged back to their pre-substep values.
        def _merge_invalid(old, new, valid):
            def one(o, n, ax):
                ax_full = (None,) * (n.ndim - len(ax)) + tuple(ax)
                if "batch" not in ax_full:
                    return n
                b = ax_full.index("batch")
                shp = [1] * n.ndim
                shp[b] = num_slots
                return jnp.where((valid > 0.0).reshape(shp), n, o)
            return jax.tree.map(one, old, new, cache_ax)

        # plan-signature compile cache over serve-step executables: the
        # controller's static shed counts select the executable; dynamic
        # bucket/src arrays change freely without recompiling.
        from jax.sharding import NamedSharding, PartitionSpec
        replicated = NamedSharding(self.mesh, PartitionSpec())

        invalid_pos = jnp.int32(paging_lib.INVALID_POS)

        def _build(static):
            fn, _, in_sh, out_sh = steps_lib.build_serve_step(
                self.cfg, self.shape, self.mesh, dtype,
                control_static=static, use_kernel=wc.use_kernel,
                fused_attention=wc.fused_attention,
                psum_chunks=wc.psum_chunks, paging=self.paging)

            def stepper(params, cache, tokens, pos, valid, clear, *rest):
                # tokens/pos/valid are [C, num_slots] — C chunked-prefill
                # substeps scanned INSIDE the one jitted step (C=1 is the
                # plain decode step). rest = (pages?, plan?). The
                # full-cache sweep only runs on admission steps; the
                # common decode step skips it (clear is all-zeros).
                cache = jax.lax.cond(jnp.any(clear > 0.0),
                                     lambda c: _clear_slots(c, clear),
                                     lambda c: c, cache)

                def substep(c, xs):
                    tok, p, v = xs
                    p_eff = jnp.where(v > 0.0, p, invalid_pos)
                    logits, nc = fn(params, c, tok, p_eff, *rest)
                    nc = _merge_invalid(c, nc, v)
                    # greedy argmax in-graph: only [C, num_slots] token
                    # ids cross the host boundary, not the full logits
                    return nc, jnp.argmax(logits, -1).astype(jnp.int32)

                cache, toks = jax.lax.scan(substep, cache,
                                           (tokens, pos, valid))
                return toks, cache

            jitted = jax.jit(stepper,
                             in_shardings=(in_sh[0], in_sh[1], replicated,
                                           replicated, replicated,
                                           replicated) + in_sh[4:],
                             out_shardings=(replicated, out_sh[1]),
                             donate_argnums=(1,))
            n_plan_slots = (max(1, static.num_sources)
                            if static is not None else 0)
            return jitted, n_plan_slots, in_sh

        # ---- unified control plane (compile cache + controller +
        # telemetry + sim->real dispatch; shared with launch/train.py) ----
        self.sim_ranks = c.sim_ranks or tp
        # the latency model prices the CANONICAL workload — padded lanes
        # under a ragged geometry are inert zeros, not extra FLOPs
        self.it_model = hetero_lib.iteration_model(
            cfg_canonical, ShapeConfig("serve_model", 1, num_slots, "decode"),
            max(self.sim_ranks, 1), peak_flops=c.peak_flops, mfu=c.mfu)
        # decode-overhead pricing (attention cache reads + collective
        # exposure) — opt-in so the classic legs' modeled trajectories
        # stay bit-identical (tests pin them)
        self.overhead = (hetero_lib.decode_overhead_model(
            cfg_canonical, num_slots, max_len, self.it_model,
            peak_flops=c.peak_flops,
            tile=(self.paging.page_size if self.paging is not None
                  else 128))
            if c.model_decode_overheads else None)
        self.plane = ControlPlane(
            self.cfg, wc, mesh=self.mesh, tp=tp, builder=_build,
            it_model=self.it_model, sim_ranks=self.sim_ranks,
            geometry=(self.geometry.sizes
                      if self.geometry is not None else None),
            # the controller reasons in per-rank shard blocks (the paper's
            # L_i) so migration sheds are sized to FIT a source's local
            # shard; projected sheds are additionally clamped to the real
            # mesh's shard when sim_ranks != tp
            controller_blocks="local", clamp_sheds=True,
            hetero_kind=c.hetero_kind, chi=c.chi, period=c.period,
            contention_p=c.contention_p, seed=c.seed,
            trace_in=c.trace_in, trace_rank_offset=c.trace_rank_offset,
            trace_out=c.trace_out,
            # trace_tag: per-replica tagging (repro.cluster) so traces
            # from one cluster run identify their lane in the shared set
            trace_meta={"arch": arch, "engine": "serve", "mode": c.mode,
                        "hetero": c.hetero_kind, "seed": c.seed,
                        **(trace_tag or {})},
            measure_noise=c.measure_noise)
        self._base_step, self._base_plan_slots, in_sh = self.plane.base
        self.schedule = self.plane.schedule
        self.controller = self.plane.controller

        # ---- params + slot cache ----------------------------------------
        # params (and checkpoints) are CANONICAL; a ragged geometry
        # expands them into the zero-padded layout at load time
        params, _ = self.api.init(jax.random.PRNGKey(seed), cfg_canonical,
                                  dtype)
        if ckpt_dir:
            # race-tolerant latest-committed load: a warm spare may be
            # promoted while a trainer is mid-save in the same directory
            _, loaded = ckpt_store.load_latest_params(ckpt_dir, params)
            if loaded is not None:
                params = loaded
        if self.geometry is not None:
            params = geom_lib.expand_ffn_params(params, self.geometry)
        self.params = jax.device_put(params, in_sh[0])
        self.cache = jax.device_put(
            self.api.init_cache(self.cfg, num_slots, max_len, dtype,
                                paging=self.paging)
            if self.paging is not None
            else self.api.init_cache(self.cfg, num_slots, max_len, dtype),
            in_sh[1])

        # ---- host-side state ---------------------------------------------
        self.queue: collections.deque = collections.deque()
        self._eligible_clock: Dict[int, float] = {}   # req.uid -> TTFT start
        self.slots: List[Optional[_Slot]] = [None] * num_slots
        self.free: List[int] = list(range(num_slots))[::-1]
        self.step_count = 0
        self.clock = 0.0                     # modeled seconds
        self.completions: List[Completion] = []
        self.history: List[Dict] = []

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """FIFO admission control; False = queue full, request rejected.

        Raises on requests that can never fit: prefill past ``max_len``
        would silently drop cache writes (jax scatters clip out-of-bounds
        indices) and break token-exactness without an error.
        """
        need = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0 or need > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + "
                f"max_new_tokens {req.max_new_tokens} exceeds the engine's "
                f"max_len {self.max_len}")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return False
        self.queue.append(req)
        # time-to-first-token starts when the request becomes ELIGIBLE
        # (arrival), not when a slot frees up — queue wait is part of
        # TTFT. Keyed by req.uid: keying by id(req) handed a NEW request
        # a stale clock whenever CPython recycled a completed request's
        # address (ISSUE 8 bugfix).
        if req.arrival_step <= self.step_count:
            self._eligible_clock.setdefault(req.uid, self.clock)
        return True

    def try_submit(self, req: Request) -> bool:
        """Non-blocking admission: ``False`` means NOTHING was enqueued.

        The cluster router needs a clean can't-take-it signal instead of
        an exception — or, worse, a request silently parked behind a
        bound it can never clear. ``False`` when:

        * the bounded queue is already at ``max_queue``;
        * the request can never be served by this engine: prompt +
          ``max_new_tokens`` past ``max_len``, or a paged pool too small
          to EVER hold the request even running alone (without this
          check the admit loop deadlocks on the queue head and the whole
          run times out, or the pool raises mid-decode).

        :meth:`submit` keeps its raising contract for the standalone
        driver, where a never-fits request is a caller bug.
        """
        need = len(req.prompt) + req.max_new_tokens
        if len(req.prompt) == 0 or need > self.max_len:
            return False
        if self.paging is not None \
                and self.paging.pages_for(need) > self.paging.num_pages:
            return False
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            return False
        return self.submit(req)

    def _admit(self):
        """Returns (admitted uids, slot-clear mask for this step's reset).

        Recycled slots are zeroed inside the step so SSM/conv state
        restarts cleanly; attention correctness never depends on stale
        K/V (positions > cur_pos are masked, <= cur_pos are rewritten by
        prefill), but zeroing keeps recycling uniformly exact."""
        clear = np.zeros((self.num_slots,), np.float32)
        admitted = []
        # mark queue members that just became eligible (TTFT clock start)
        for req in self.queue:
            if req.arrival_step <= self.step_count:
                self._eligible_clock.setdefault(req.uid, self.clock)
        while self.free and self.queue \
                and self.queue[0].arrival_step <= self.step_count:
            if self.alloc is not None \
                    and not self.alloc.can_fit(len(self.queue[0].prompt)):
                break          # pool can't hold the prompt; wait for frees
            req = self.queue.popleft()
            slot = self.free.pop()
            t0 = self._eligible_clock.pop(req.uid, self.clock)
            self.slots[slot] = _Slot(
                req=req, admitted_step=self.step_count, pos=0,
                next_token=int(req.prompt[0]), generated=[],
                t_mark=t0, t_elig=t0, latencies=[])
            clear[slot] = 1.0
            admitted.append(req.uid)
        return admitted, clear

    # -- page-pool bookkeeping (paged engine only) ---------------------------
    def _planned_feed(self, s: "_Slot") -> int:
        """Positions this slot writes THIS step: a prefill chunk or one
        decode token."""
        P = len(s.req.prompt)
        return min(self.prefill_chunk, P - s.pos) if s.pos < P else 1

    def _preempt(self, slot: int) -> int:
        """Evict a slot back to the FRONT of the queue, returning its
        pages. Deterministic greedy decode regenerates the identical
        tokens on re-admission, so preemption preserves token-exactness;
        the TTFT clock is restored to the original eligibility time so
        queue-wait (including the preemption) stays in TTFT."""
        s = self.slots[slot]
        self.alloc.free_slot(slot)
        self.slots[slot] = None
        self.free.append(slot)
        self.queue.appendleft(s.req)
        self._eligible_clock[s.req.uid] = s.t_elig
        self.preemptions += 1
        return s.req.uid

    def _ensure_pages(self) -> list:
        """Grow each active slot's page list to cover this step's writes,
        preempting the most recently admitted other slot on exhaustion
        (oldest requests keep their pages — FIFO service order). Returns
        the uids preempted this step."""
        preempted = []
        order = sorted(
            (i for i, s in enumerate(self.slots) if s is not None),
            key=lambda i: (self.slots[i].admitted_step, i))
        for i in order:
            s = self.slots[i]
            if s is None:                      # preempted earlier this pass
                continue
            while not self.alloc.ensure(i, s.pos + self._planned_feed(s) - 1):
                victims = [j for j, v in enumerate(self.slots)
                           if v is not None and j != i]
                if not victims:
                    raise RuntimeError(
                        f"page pool exhausted: slot {i} (uid "
                        f"{s.req.uid}) needs a page and no other slot "
                        "can be preempted — the pool is too small for a "
                        "single request")
                victim = max(victims,
                             key=lambda j: (self.slots[j].admitted_step, j))
                preempted.append(self._preempt(victim))
        return preempted

    def kv_cache_bytes(self) -> int:
        """Total bytes of the engine's cache pytree (KV pools/rows plus
        recurrent state) — the equal-HBM axis of serve_bench's
        mixed_lengths capacity gate."""
        return int(sum(l.size * l.dtype.itemsize
                       for l in jax.tree.leaves(self.cache)))

    # -- one decode step -----------------------------------------------------
    def step(self) -> Dict:
        """Admit, run one jitted step over all slots, harvest.

        Each step feeds every active slot either a CHUNK of its prompt
        (up to ``prefill_chunk`` teacher-forced positions, scanned inside
        the one jitted executable) or one greedy decode token — chunked
        prefill and decode interleave freely across slots with no
        retrace. On the paged engine, page lists are grown to cover this
        step's writes first, preempting the newest-admitted slot when the
        pool runs dry."""
        admitted, clear = self._admit()
        preempted = self._ensure_pages() if self.alloc is not None else []

        C = self.prefill_chunk
        B = self.num_slots
        tokens_cb = np.zeros((C, B), np.int32)
        pos_cb = np.full((C, B), paging_lib.INVALID_POS, np.int32)
        valid_cb = np.zeros((C, B), np.float32)
        feed = np.zeros((B,), np.int32)       # positions fed per slot
        last_pos = np.zeros((B,), np.int32)   # highest position fed
        active = np.zeros((B,), np.float32)
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            active[i] = 1.0
            P = len(s.req.prompt)
            if s.pos < P:                     # teacher-forced prefill chunk
                n = min(C, P - s.pos)
                tokens_cb[:n, i] = np.asarray(s.req.prompt[s.pos:s.pos + n],
                                              np.int32)
                pos_cb[:n, i] = np.arange(s.pos, s.pos + n)
            else:                             # one greedy decode token
                n = 1
                tokens_cb[0, i] = s.next_token
                pos_cb[0, i] = s.pos
            valid_cb[:n, i] = 1.0
            feed[i] = n
            last_pos[i] = s.pos + n - 1

        # chunked prefill feeds MORE than one token per occupied slot;
        # price the extra substep work as extra workload fraction so the
        # modeled clock stays honest (C=1 → scale 1.0, bit-identical to
        # the single-token trajectories the classic legs pin)
        chunk_scale = 1.0 + max(0.0, float(valid_cb.sum())
                                - float(active.sum())) / self.num_slots

        # -- straggler model + plan selection -----------------------------
        step_idx = self.step_count
        chis = self.plane.chis(step_idx)
        dense_latency = self.it_model.step_time(chis, np.ones(self.sim_ranks))
        plan_report = None
        plan = None
        proj = None
        frac = np.ones(self.sim_ranks)
        if self.controller is not None:
            # full-workload-equivalent times (χ-oracle, or the estimator's
            # closed-loop reconstruction in measured mode): Eq.(1)
            # measures the heterogeneity degree, not the mitigated runtime
            times = self.plane.controller_times(chis)
            plan, plan_report = self.plane.decide(times)
            # full SEMI dispatch: the projected plan carries resize
            # buckets AND multi-source migration slots; the executable is
            # keyed on the projected signature in the compile cache
            step_fn, plan_arrays, proj = self.plane.dispatch(plan)
            frac = self.plane.work_frac(plan)
            latency = self.it_model.step_time(chis, frac * chunk_scale)
        else:
            step_fn, plan_arrays = self._base_step, None
            latency = (dense_latency if chunk_scale == 1.0
                       else self.it_model.step_time(
                           chis, np.ones(self.sim_ranks) * chunk_scale))

        self.plane.timer.start()
        with use_mesh(self.mesh):
            args = (self.params, self.cache, jnp.asarray(tokens_cb),
                    jnp.asarray(pos_cb), jnp.asarray(valid_cb),
                    jnp.asarray(clear))
            if self.alloc is not None:
                args = args + (jnp.asarray(self.alloc.table()),)
            if plan_arrays is not None:
                args = args + (plan_arrays,)
            tok_ids, self.cache = step_fn(*args)
        wall = self.plane.timer.stop(tok_ids)
        nxt = np.asarray(jax.device_get(tok_ids))      # [C, num_slots]
        overhead = 0.0
        if self.schedule is None:
            latency = dense_latency = wall       # no simulation: real time
        elif self.overhead is not None:
            # occupancy-priced attention reads + (reduced) collective
            # exposure, from THIS step's actual per-slot positions —
            # masked by `active` so empty slots bill zero tiles
            overhead = self.overhead.overhead_s(
                last_pos, fused=self._wc.fused_attention,
                psum_chunks=self._wc.psum_chunks, active=active)
            latency += overhead

        # -- telemetry: what each simulated rank measured THIS step -------
        self.plane.capture(chis, frac, step=step_idx, plan=plan, wall=wall)

        self.clock += latency
        self.step_count += 1

        # -- harvest per slot ---------------------------------------------
        completed = []
        for i, s in enumerate(self.slots):
            if s is None or feed[i] == 0:
                continue
            n = int(feed[i])
            prev = s.pos
            s.pos = prev + n
            P = len(s.req.prompt)
            if prev < P and s.pos < P:
                continue                         # still mid-prefill
            # the last fed position's logits carry the next token (chunk
            # end == prompt end for the prefill→decode handoff)
            tok = int(nxt[n - 1, i])
            emitted = False
            if len(s.generated) < s.req.max_new_tokens:
                s.generated.append(tok)
                s.latencies.append(self.clock - s.t_mark)
                s.t_mark = self.clock
                emitted = True
            done = (len(s.generated) >= s.req.max_new_tokens
                    or (emitted and s.req.eos_id is not None
                        and tok == s.req.eos_id))
            if done or s.pos >= self.max_len:
                self.completions.append(Completion(
                    uid=s.req.uid, prompt=s.req.prompt,
                    tokens=np.asarray(s.generated, np.int32),
                    admitted_step=s.admitted_step,
                    finished_step=self.step_count, slot=i,
                    token_latencies=list(s.latencies)))
                completed.append(s.req.uid)
                self._eligible_clock.pop(s.req.uid, None)
                self.slots[i] = None
                self.free.append(i)
                if self.alloc is not None:
                    self.alloc.free_slot(i)
            else:
                s.next_token = tok

        report = {"step": self.step_count, "latency_s": latency,
                  "dense_latency_s": dense_latency, "wall_s": wall,
                  "active": sum(s is not None for s in self.slots),
                  "admitted": admitted, "completed": completed,
                  "queued": len(self.queue)}
        if preempted:
            report["preempted"] = preempted
        if self.overhead is not None:
            report["overhead_s"] = overhead
            # slot-cache occupancy + the minimum (fused, occupied-tiles)
            # attention read time: the roofline terms serve_bench gates
            # on. Both are masked by the ACTIVE slots — an empty slot's
            # pos of 0 is vacancy, not a resident length-1 sequence.
            report["occupancy"] = float(
                ((last_pos + 1.0) * active).sum()
                / (self.num_slots * self.max_len))
            report["attn_bound_s"] = self.overhead.attn_s(
                last_pos, fused=True, active=active)
        if plan_report is not None:
            report["stragglers"] = list(plan_report.stragglers)
            report["max_bucket"] = int(plan_report.bucket_by_rank.max())
            # mig_srcs/mig_shed record what EXECUTED on the real mesh
            # (post-projection); the controller's sim-scale intent lands
            # under planned_* — at tp=1 the two legitimately differ
            if proj is not None and proj.mig_srcs:
                report["mig_srcs"] = [int(s) for s in proj.mig_srcs]
                report["mig_shed"] = [int(m) for m in proj.mig_sheds]
            if plan_report.mig_srcs:
                report["planned_mig_srcs"] = [int(s)
                                              for s in plan_report.mig_srcs]
                report["planned_mig_shed"] = [int(m)
                                              for m in plan_report.mig_shed]
        self.history.append(report)
        return report

    # -- cluster-driver API (repro.cluster) ----------------------------------
    @property
    def idle(self) -> bool:
        """No active slots and nothing queued (e.g. a drained replica)."""
        return not self.queue and all(s is None for s in self.slots)

    def tick(self) -> Dict:
        """One cluster-driver step: a full jitted step when any slot is
        occupied or a queued request is admissible, otherwise an IDLE
        tick — the step counter still advances (χ-schedule lanes stay
        aligned with the cluster step across replicas) but the modeled
        clock does not (an idle engine isn't burning time any request
        can observe) and no device work runs. Lets one host loop
        interleave R engines deterministically without paying a jitted
        step per idle replica."""
        admissible = bool(
            self.free and self.queue
            and self.queue[0].arrival_step <= self.step_count
            and (self.alloc is None
                 or self.alloc.can_fit(len(self.queue[0].prompt))))
        if admissible or any(s is not None for s in self.slots):
            return self.step()
        # a queued request blocked from admission still waits: mark its
        # TTFT eligibility so the wait is charged when it lands
        for req in self.queue:
            if req.arrival_step <= self.step_count:
                self._eligible_clock.setdefault(req.uid, self.clock)
        self.step_count += 1
        report = {"step": self.step_count, "idle": True, "latency_s": 0.0,
                  "dense_latency_s": 0.0, "wall_s": 0.0, "active": 0,
                  "admitted": [], "completed": [],
                  "queued": len(self.queue)}
        self.history.append(report)
        return report

    def request_cost_steps(self, prompt_len: int,
                           max_new_tokens: int) -> int:
        """Engine steps a request will occupy a slot for: its prefill
        chunks plus one step per generated token — the cost the
        chi_aware router prices against a replica's capacity."""
        return -(-int(prompt_len) // self.prefill_chunk) \
            + int(max_new_tokens)

    def load_snapshot(self) -> LoadSnapshot:
        """Queue/slot/pool load + plan-adjusted capacity, for routing."""
        backlog = 0
        for s in self.slots:
            if s is None:
                continue
            P = len(s.req.prompt)
            backlog += -(-(P - min(s.pos, P)) // self.prefill_chunk) \
                + (s.req.max_new_tokens - len(s.generated))
        for req in self.queue:
            backlog += self.request_cost_steps(len(req.prompt),
                                               req.max_new_tokens)
        cap = self.plane.capacity(self.step_count)
        return LoadSnapshot(
            step=self.step_count, clock=self.clock,
            queue_depth=len(self.queue),
            active=sum(s is not None for s in self.slots),
            free_slots=len(self.free),
            free_pages=(self.alloc.free_pages if self.alloc is not None
                        else None),
            num_slots=self.num_slots,
            chi=cap.chi, work_frac=cap.work_frac,
            step_time_s=cap.step_time_s,
            dense_step_time_s=cap.dense_step_time_s,
            backlog_steps=backlog)

    def evict_queue(self) -> List[Request]:
        """Pop every queued (not yet admitted) request — the cluster
        manager reassigns them when a replica drains or fails. Their
        TTFT eligibility clocks go with them; the receiving replica
        restarts the wait clock in its own timeline."""
        out = list(self.queue)
        self.queue.clear()
        for req in out:
            self._eligible_clock.pop(req.uid, None)
        return out

    def active_requests(self) -> List[Request]:
        """Requests currently holding a slot, in admission order — what
        a failed replica's manager must re-route (greedy decode is
        deterministic, so a from-scratch re-run is token-identical)."""
        order = sorted((i for i, s in enumerate(self.slots)
                        if s is not None),
                       key=lambda i: (self.slots[i].admitted_step, i))
        return [self.slots[i].req for i in order]

    # -- drivers -------------------------------------------------------------
    def run(self, requests: List[Request],
            max_steps: Optional[int] = None) -> List[Completion]:
        """Replay an arrival trace until every request completes.

        Requests are submitted AT their arrival step (not up front), so a
        bounded queue measures true concurrent occupancy rather than the
        length of the trace."""
        if not requests:
            return []
        pending = collections.deque(sorted(requests,
                                           key=lambda r: r.arrival_step))
        limit = max_steps or (self.max_len * (len(requests) + 1)
                              + pending[-1].arrival_step)
        while (pending or self.queue
               or any(s is not None for s in self.slots)):
            if self.step_count >= limit:
                raise RuntimeError(f"serve loop exceeded {limit} steps")
            while pending and pending[0].arrival_step <= self.step_count:
                r = pending.popleft()
                if not self.submit(r):
                    raise RuntimeError(f"queue full, request {r.uid} "
                                       "rejected")
            self.step()
        return sorted(self.completions, key=lambda c: c.uid)

    def close(self) -> None:
        """Flush/close the telemetry trace (safe to call repeatedly)."""
        self.plane.close()

    # -- introspection (tests / benchmarks) ----------------------------------
    def trace_counts(self) -> Dict[str, int]:
        """Executable-build telemetry: plan signatures compiled vs reused,
        and the base jitted step's trace-cache size (1 = never re-traced
        across arrivals/completions/recycling)."""
        out = dict(self.plane.counts())
        out["base_step_traces"] = (self._base_step._cache_size()
                                   if hasattr(self._base_step, "_cache_size")
                                   else -1)
        return out

    def analysis_cases(self, step: str = "serve_engine_step", *,
                       compile_hlo: bool = True):
        """Static-analysis TraceCases for THIS engine's fused base step
        (repro.analysis): the exact jitted executable ``step()`` drives,
        with the KV cache declared hot state (argnum 1, donated) so R2
        proves the donation actually aliased in the compiled module."""
        from repro.analysis.registry import TraceCase
        sds = jax.ShapeDtypeStruct

        def shape_of(tree):
            return jax.tree.map(lambda a: sds(a.shape, a.dtype), tree)

        B, C = self.num_slots, self.prefill_chunk
        args = (shape_of(self.params), shape_of(self.cache),
                sds((C, B), jnp.int32), sds((C, B), jnp.int32),
                sds((C, B), jnp.float32), sds((B,), jnp.float32))
        if self.paging is not None:
            args += (sds((B, self.paging.pages_per_slot), jnp.int32),)
        if self._base_plan_slots:
            raise NotImplementedError(
                "analysis_cases covers the base (dense) serve step; "
                "controlled plan-slot steps are traced via the "
                "serve_decode_step provider")
        return [TraceCase(
            step=step, name=f"base_tp{self.tp}", fn=self._base_step,
            args=args, mesh=self.mesh, donate_argnums=(1,),
            state_argnums=(1,), compile_hlo=compile_hlo,
            signature=f"serve_base_tp{self.tp}")]


#: The well-defined zero-traffic stats record: what a drained or
#: never-routed replica reports. Every key the non-empty record carries,
#: all-zero — so aggregation code can sum/compare without key checks.
EMPTY_LATENCY_STATS = {"p50_ms": 0.0, "p95_ms": 0.0, "p99_ms": 0.0,
                       "mean_ms": 0.0, "ttft_mean_ms": 0.0, "tokens": 0,
                       "requests": 0, "tok_per_s": 0.0}


def latency_percentiles(completions: List[Completion],
                        total_time_s: Optional[float] = None
                        ) -> Dict[str, float]:
    """p50/p95/p99 per-token latency (ms), mean TTFT + tokens/s.

    Pass the engine's elapsed clock as ``total_time_s`` for true ENGINE
    throughput: concurrently-decoding slots each bill the full step
    latency to their own token, so summing per-token latencies would
    understate throughput by ~the number of active slots. Without it the
    sum-based figure (per-slot serial throughput) is returned.

    A run with no emitted tokens — a drained or zero-traffic replica, or
    completions that are all ``max_new_tokens=0`` — returns a copy of
    :data:`EMPTY_LATENCY_STATS` instead of crashing percentile math on
    an empty vector (pinned by tests/test_serve_engine.py)."""
    lats = np.asarray([l for c in completions for l in c.token_latencies])
    if lats.size == 0:
        return dict(EMPTY_LATENCY_STATS)
    # TTFT = each request's FIRST token latency (queue wait + prefill)
    ttft = [c.token_latencies[0] for c in completions if c.token_latencies]
    span = total_time_s if total_time_s is not None else float(lats.sum())
    return {"p50_ms": float(np.percentile(lats, 50) * 1e3),
            "p95_ms": float(np.percentile(lats, 95) * 1e3),
            "p99_ms": float(np.percentile(lats, 99) * 1e3),
            "mean_ms": float(lats.mean() * 1e3),
            "ttft_mean_ms": float(np.mean(ttft) * 1e3),
            "tokens": int(lats.size),
            "requests": len(completions),
            "tok_per_s": float(lats.size / max(span, 1e-12))}


# ---------------------------------------------------------------------------
# Fixed-batch engine (the seed's lockstep loop, kept as the equivalence
# baseline: tests prove slot recycling is semantics-preserving against it)
# ---------------------------------------------------------------------------


class FixedBatchEngine:
    """Holds params + a jitted single-token step; serves fixed batches."""

    def __init__(self, arch: str, batch: int, max_len: int,
                 ckpt_dir: Optional[str] = None, seed: int = 0):
        self.cfg = smoke_variant(get_config(arch))
        self.api = get_api(self.cfg)
        self.batch = batch
        self.max_len = max_len
        params, _ = self.api.init(jax.random.PRNGKey(seed), self.cfg)
        if ckpt_dir:
            last = ckpt_store.latest_step(ckpt_dir)
            if last is not None:
                params = ckpt_store.load_params(ckpt_dir, last, params)
        self.params = params
        self._step = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, self.cfg, c, t, pos),
            donate_argnums=(1,))

    def generate(self, prompts: np.ndarray, gen_len: int,
                 eos_id: Optional[int] = None) -> np.ndarray:
        """prompts [B, P] int32 -> [B, P+gen_len] greedy continuations."""
        B, P = prompts.shape
        assert B == self.batch and P + gen_len <= self.max_len
        cache = self.api.init_cache(self.cfg, B, self.max_len)
        out = [prompts[:, 0]]
        done = np.zeros((B,), bool)
        for t in range(P + gen_len - 1):
            logits, cache = self._step(
                self.params, cache, jnp.asarray(out[-1], jnp.int32),
                jnp.full((B,), t, jnp.int32))
            if t + 1 < P:
                nxt = prompts[:, t + 1]
            else:
                nxt = np.asarray(logits.argmax(-1))
                if eos_id is not None:
                    done |= nxt == eos_id
                    nxt = np.where(done, eos_id or 0, nxt)
            out.append(nxt)
            if eos_id is not None and done.all():
                break
        return np.stack(out, axis=1)


# Backwards-compatible alias (pre-continuous-batching name).
DecodeEngine = FixedBatchEngine


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen-len", type=int, default=24)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="steps between request arrivals (staggered trace)")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--control", default="off",
                    choices=["off", "zero", "semi"])
    ap.add_argument("--hetero", default="none",
                    choices=["none", "static", "round_robin", "contention",
                             "trace"])
    ap.add_argument("--chi", type=float, default=4.0)
    ap.add_argument("--sim-ranks", type=int, default=0)
    ap.add_argument("--max-sources", type=int, default=3,
                    help="concurrent migration slots (semi mode)")
    ap.add_argument("--beta-policy", default="lossless",
                    choices=["lossless", "eq2"],
                    help="semi mission split: lossless migrates the full "
                         "offset volume (token-exact); eq2 balances "
                         "migration vs resize cost per Eq.(2)")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--fused-attn", action="store_true",
                    help="fused Pallas decode-attention kernel "
                         "(interpret-mode fallback off-TPU)")
    ap.add_argument("--psum-chunks", type=int, default=1,
                    help="chunk-split the controlled epilogue all-reduce "
                         "into this many async-overlappable psums")
    ap.add_argument("--xla-preset", default="none",
                    choices=["none", "latency-hiding"],
                    help="XLA latency-hiding flag preset (applied before "
                         "jax initializes)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--times", default="modeled",
                    choices=["modeled", "measured"],
                    help="controller input: χ-oracle or the online "
                         "StragglerEstimator over measured decode times")
    ap.add_argument("--trace-in", default=None,
                    help="telemetry trace to replay (with --hetero trace)")
    ap.add_argument("--trace-out", default=None,
                    help="record a replayable telemetry trace here (JSONL)")
    ap.add_argument("--geometry", default=None,
                    help="static ragged TP shard geometry: per-rank FFN "
                         "block counts 'a,b,...' (DESIGN_SHARDING.md)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="block-paged KV cache page size in tokens "
                         "(0 = fixed per-slot cache); with --fused-attn "
                         "must be a multiple of 8")
    ap.add_argument("--prefill-chunk", type=int, default=1,
                    help="prompt positions fed per step during prefill "
                         "(scanned inside the one jitted step)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantize the paged K/V pools (per-row "
                         "scales; oracle attention path only)")
    args = ap.parse_args()

    control = ControlConfig(
        mode=args.control, hetero_kind=args.hetero, chi=args.chi,
        sim_ranks=args.sim_ranks, max_sources=args.max_sources,
        beta_policy=args.beta_policy, use_kernel=args.use_kernel,
        fused_attention=args.fused_attn, psum_chunks=args.psum_chunks,
        times=args.times, trace_in=args.trace_in, trace_out=args.trace_out,
        geometry=geom_lib.parse_geometry_arg(args.geometry, args.tp))
    eng = ServeEngine(args.arch, num_slots=args.slots,
                      max_len=args.prompt_len + args.gen_len, tp=args.tp,
                      ckpt_dir=args.ckpt_dir, control=control,
                      page_size=args.page_size,
                      prefill_chunk=args.prefill_chunk,
                      kv_int8=args.kv_int8)
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, eng.cfg.vocab_size,
                                        (args.prompt_len,)).astype(np.int32),
                    max_new_tokens=args.gen_len,
                    arrival_step=i * args.arrival_every)
            for i in range(args.requests)]
    t0 = time.time()
    comps = eng.run(reqs)
    eng.close()
    wall = time.time() - t0
    stats = latency_percentiles(comps, total_time_s=eng.clock)
    for c in comps[:4]:
        print(f"req {c.uid}: slot {c.slot}, steps "
              f"{c.admitted_step}->{c.finished_step}, "
              f"tokens {c.tokens[:8]}...")
    print(f"{len(comps)} requests, {stats['tokens']} tokens in {wall:.1f}s "
          f"wall; modeled p50/p95/p99 per-token "
          f"{stats['p50_ms']:.2f}/{stats['p95_ms']:.2f}/"
          f"{stats['p99_ms']:.2f} ms, {stats['tok_per_s']:.1f} tok/s")
    print(f"trace counts: {eng.trace_counts()}")


# ---------------------------------------------------------------------------
# static-analysis registration (repro.analysis; see DESIGN_ANALYSIS.md)
# ---------------------------------------------------------------------------

from repro.analysis import registry as _analysis  # noqa: E402


def _an_serve_engine_cases(env):
    if not env.heavy:
        return []
    tp = 2 if env.max_devices >= 2 else 1
    eng = ServeEngine("yi-6b", num_slots=2, max_len=8, tp=tp)
    try:
        return eng.analysis_cases(compile_hlo=env.compile_hlo)
    finally:
        eng.close()


_analysis.register("serve_engine_step", _an_serve_engine_cases)


if __name__ == "__main__":
    main()
