"""Pre-jax process bootstrap for CLI entry points.

MUST stay importable before (and without) jax: the train/serve `__main__`
blocks call :func:`ensure_host_devices` before their first jax import so
the XLA host-device-count flag can still take effect.
"""
from __future__ import annotations

import os
import sys


def argv_int(flag: str, default: int = 1) -> int:
    """Parse an int CLI flag from sys.argv, accepting both the
    space-separated (``--tp 4``) and equals (``--tp=4``) forms."""
    for i, a in enumerate(sys.argv):
        try:
            if a == flag:
                return int(sys.argv[i + 1])
            if a.startswith(flag + "="):
                return int(a.split("=", 1)[1])
        except (ValueError, IndexError):
            return default
    return default


def ensure_host_devices(n: int) -> None:
    """Request n XLA host devices if jax has not been initialized yet
    (library users set XLA_FLAGS themselves)."""
    if n > 1 and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")
