"""Pre-jax process bootstrap for CLI entry points.

MUST stay importable before (and without) jax: the train/serve `__main__`
blocks call :func:`ensure_host_devices` before their first jax import so
the XLA host-device-count flag can still take effect.
"""
from __future__ import annotations

import os
import sys


def argv_int(flag: str, default: int = 1) -> int:
    """Parse an int CLI flag from sys.argv, accepting both the
    space-separated (``--tp 4``) and equals (``--tp=4``) forms."""
    for i, a in enumerate(sys.argv):
        try:
            if a == flag:
                return int(sys.argv[i + 1])
            if a.startswith(flag + "="):
                return int(a.split("=", 1)[1])
        except (ValueError, IndexError):
            return default
    return default


def argv_str(flag: str, default: str = "") -> str:
    """Parse a string CLI flag from sys.argv (``--x v`` / ``--x=v``)."""
    for i, a in enumerate(sys.argv):
        if a == flag and i + 1 < len(sys.argv):
            return sys.argv[i + 1]
        if a.startswith(flag + "="):
            return a.split("=", 1)[1]
    return default


def ensure_host_devices(n: int) -> None:
    """Request n XLA host devices if jax has not been initialized yet
    (library users set XLA_FLAGS themselves)."""
    if n > 1 and "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")


# ---------------------------------------------------------------------------
# XLA latency-hiding presets (ISSUE 7): the cheap compiler-side baseline
# for collective/compute overlap, next to the chunked-psum epilogue (the
# kernel-side measure). Must be applied BEFORE jax initializes — flag
# strings only, no jax imports here.
# ---------------------------------------------------------------------------

XLA_PRESETS = {
    "none": (),
    # async collectives + the latency-hiding scheduler: lets all-reduce
    # -start/-done pairs straddle independent compute
    "latency-hiding": (
        "--xla_tpu_enable_latency_hiding_scheduler=true",
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
    ),
}


def _tpu_runtime_present() -> bool:
    # an explicit JAX_PLATFORMS wins over an installed-but-unused libtpu
    # (the common CI case: libtpu on disk, JAX_PLATFORMS=cpu)
    plats = os.environ.get("JAX_PLATFORMS", "")
    if plats:
        return "tpu" in plats.lower()
    import importlib.util
    return (importlib.util.find_spec("libtpu") is not None
            or bool(os.environ.get("TPU_NAME")))


def xla_preset_flags(name: str) -> tuple:
    """Preset flags valid for THIS host. TPU-prefixed XLA flags are
    FATAL on other backends (unknown-flag check in XLA's
    parse_flags_from_env), so they are dropped unless a TPU runtime is
    importable — a preset can legitimately resolve to no flags."""
    if name not in XLA_PRESETS:
        raise ValueError(
            f"unknown XLA preset {name!r}; choose from "
            f"{sorted(XLA_PRESETS)}")
    flags = XLA_PRESETS[name]
    if not _tpu_runtime_present():
        flags = tuple(f for f in flags if not f.startswith("--xla_tpu_"))
    return flags


def apply_xla_preset(name: str) -> bool:
    """Append the preset's flags to XLA_FLAGS; returns False (no-op)
    when jax is already initialized or the preset is empty."""
    flags = xla_preset_flags(name)
    if not flags or "jax" in sys.modules:
        return False
    os.environ["XLA_FLAGS"] = " ".join(
        (os.environ.get("XLA_FLAGS", ""),) + flags).strip()
    return True
