"""jit-able train / serve steps with explicit in/out shardings.

``build_train_step`` / ``build_serve_step`` return (fn, arg-SDS tuple,
in_shardings, out_shardings) ready for ``jax.jit(...).lower(...)`` (the
dry-run) or real execution (the trainer).

Workload control: when a WorkloadPlan is supplied, the step takes an extra
``plan`` dict of device arrays (bucket_by_rank, mig_src, pri lists) and
threads a ControlContext into the model — so the controller can retarget
stragglers every iteration without recompiling.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.config import ModelConfig, RunConfig, ShapeConfig, TrainConfig
from repro.control import scopes as _scopes
from repro.core.workload import PlanStatic
from repro.layers.tp_linear import ControlContext
from repro.models import get_api
from repro.optim import adamw
from repro.launch import specs as specs_lib

SDS = jax.ShapeDtypeStruct

# Scope discovery / plan-array assembly moved to the unified control plane
# (repro.control.scopes) in PR 5; the module-level aliases that kept old
# imports alive are now deprecation shims — import from
# repro.control.scopes instead (enforced for new code by the ruff TID251
# banned-api rule in pyproject.toml).
_DEPRECATED_SCOPE_EXPORTS = (
    "SCOPE_LAYOUT", "control_block_size", "control_scopes", "per_rank_pri",
    "plan_pri_arrays", "plan_specs", "scope_block_table")


def __getattr__(name: str):
    if name in _DEPRECATED_SCOPE_EXPORTS:
        import warnings
        warnings.warn(
            f"repro.launch.steps.{name} is deprecated; import it from "
            "repro.control.scopes", DeprecationWarning, stacklevel=2)
        return getattr(_scopes, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _replicated(mesh):
    return NamedSharding(mesh, P())


def make_ctx(mesh: Mesh, static: PlanStatic, plan: Dict[str, Any],
             use_kernel: bool = False,
             psum_chunks: int = 1) -> ControlContext:
    return ControlContext(
        mesh=mesh, axis="model", static=static,
        bucket_by_rank=plan["bucket_by_rank"], mig_src=plan["mig_src"],
        pri=plan.get("pri", {}), use_kernel=use_kernel,
        per_layer=static.per_layer, psum_chunks=psum_chunks)


def build_rank_time_gather(mesh: Mesh, axis: str = "model"):
    """Jitted all-gather of per-rank local clocks (telemetry measurement).

    Input: [e] float32 sharded over ``axis`` — entry r is rank r's locally
    measured segment time (on the single-host simulator the vector comes
    from the simulated measurement backend; on a real cluster each rank
    contributes its own slice). Output: the replicated [e] vector, so
    EVERY host sees ALL TP ranks' times. Run once per control interval by
    telemetry.RankTimer — not every iteration — per the paper's passive
    T_avg refresh discipline (Sec. III-A).
    """
    e = mesh.shape[axis]

    def local_gather(x):                      # x: [1] this rank's clock
        return jax.lax.all_gather(x, axis, tiled=True)

    gathered = sh.shard_map(local_gather, mesh=mesh, in_specs=P(axis),
                            out_specs=P())
    return jax.jit(gathered,
                   in_shardings=NamedSharding(mesh, P(axis)),
                   out_shardings=_replicated(mesh)) if e > 1 else \
        jax.jit(lambda x: x)


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     train: TrainConfig = TrainConfig(),
                     control_static: Optional[PlanStatic] = None,
                     total_steps: int = 0, use_kernel: bool = False,
                     psum_chunks: int = 1):
    """Returns (train_step, arg_sds, in_shardings, out_shardings)."""
    cfg = specs_lib.effective_model_cfg(cfg, shape)
    api = get_api(cfg)
    dtype = jnp.dtype(train.param_dtype)
    rules = specs_lib.rules_for(shape, mesh, cfg, fsdp=train.fsdp_layers)

    p_sds, _, p_shards = specs_lib.param_specs(cfg, mesh, rules, dtype)
    opt_sds = adamw.AdamWState(
        step=SDS((), jnp.int32),
        mu=jax.tree.map(lambda s: SDS(s.shape, jnp.float32), p_sds),
        nu=jax.tree.map(lambda s: SDS(s.shape, jnp.float32), p_sds))
    opt_shards = adamw.AdamWState(
        step=_replicated(mesh),
        mu=jax.tree.map(lambda s: s, p_shards),
        nu=jax.tree.map(lambda s: s, p_shards))
    b_sds, b_shards = specs_lib.batch_specs(cfg, shape, mesh, dtype)

    scopes = _scopes.control_scopes(cfg, control_static) \
        if control_static else {}
    if control_static and scopes:
        import dataclasses as _dc
        control_static = _dc.replace(
            control_static,
            scope_blocks=_scopes.scope_block_table(cfg, control_static))
        pl_sds, pl_shards = _scopes.plan_specs(control_static, cfg, mesh,
                                               scopes)
    else:
        control_static = None
        pl_sds = pl_shards = None

    metric_shards = {"loss": _replicated(mesh),
                     "grad_norm": _replicated(mesh), "lr": _replicated(mesh)}

    def train_step(params, opt_state, batch, plan=None):
        with sh.use_rules(rules):
            ctx = (make_ctx(mesh, control_static, plan,
                            use_kernel=use_kernel,
                            psum_chunks=psum_chunks)
                   if control_static is not None else None)

            def lf(p, b):
                loss, metrics = api.loss_fn(p, cfg, b, ctx=ctx,
                                            remat=train.remat)
                return loss, metrics

            n_micro = max(train.microbatch, 1)
            if n_micro > 1:
                # gradient accumulation: scan over micro-batches (memory
                # peak divides by n_micro; grads/loss averaged)
                def split(v):
                    return v.reshape((n_micro, v.shape[0] // n_micro)
                                     + v.shape[1:])
                micro = jax.tree.map(split, batch)
                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def acc_step(carry, mb):
                    g_acc, l_acc = carry
                    (l, _), g = jax.value_and_grad(lf, has_aux=True)(params, mb)
                    g_acc = jax.tree.map(
                        lambda a, b_: a + b_.astype(jnp.float32) / n_micro,
                        g_acc, g)
                    return (g_acc, l_acc + l / n_micro), None

                (grads, loss), _ = jax.lax.scan(
                    acc_step, (zeros, jnp.zeros((), jnp.float32)), micro)
                grads = jax.tree.map(lambda g, p: g.astype(p.dtype),
                                     grads, params)
            else:
                (loss, _), grads = jax.value_and_grad(
                    lf, has_aux=True)(params, batch)
            new_p, new_opt, om = adamw.apply(params, grads, opt_state, train,
                                             total_steps)
            out_metrics = {"loss": loss, "grad_norm": om["grad_norm"],
                           "lr": om["lr"]}
            return new_p, new_opt, out_metrics

    args = (p_sds, opt_sds, b_sds) + ((pl_sds,) if pl_sds else ())
    in_sh = (p_shards, opt_shards, b_shards) + ((pl_shards,) if pl_sds else ())
    out_sh = (p_shards, opt_shards, metric_shards)
    return train_step, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# prefill / serve steps
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       dtype=jnp.bfloat16):
    """Forward over the full sequence producing last-token logits (the
    inference-prefill workload)."""
    cfg = specs_lib.effective_model_cfg(cfg, shape)
    api = get_api(cfg)
    rules = specs_lib.rules_for(shape, mesh, cfg)
    p_sds, _, p_shards = specs_lib.param_specs(cfg, mesh, rules, dtype)
    b_sds, b_shards = specs_lib.batch_specs(cfg, shape, mesh, dtype)
    b_sds.pop("labels", None)
    b_shards.pop("labels", None)

    logits_spec = sh.filter_spec_for_mesh(
        sh.logical_to_spec(("batch", "vocab"), rules), mesh)
    logits_sh = NamedSharding(mesh, sh.fit_spec_to_shape(
        logits_spec, (shape.global_batch, cfg.vocab_size or 1), mesh))

    if cfg.num_classes:
        def prefill(params, batch):
            with sh.use_rules(rules):
                return api.forward(params, cfg, batch["patches"])
        out_sh = _replicated(mesh)
    elif cfg.encdec is not None:
        def prefill(params, batch):
            with sh.use_rules(rules):
                logits = api.forward(params, cfg, batch["tokens"],
                                     batch["frame_embeds"])
                return logits[:, -1]
        out_sh = logits_sh
    else:
        def prefill(params, batch):
            with sh.use_rules(rules):
                logits, _, _ = api.forward(
                    params, cfg, batch["tokens"],
                    patch_embeds=batch.get("patch_embeds"))
                return logits[:, -1]
        out_sh = logits_sh

    return prefill, (p_sds, b_sds), (p_shards, b_shards), out_sh


def build_serve_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     dtype=jnp.bfloat16,
                     control_static: Optional[PlanStatic] = None,
                     use_kernel: bool = False, fused_attention: bool = False,
                     psum_chunks: int = 1, paging=None):
    """One-token decode against a seq_len KV cache.

    With ``control_static`` the step takes an extra ``plan`` dict (same
    layout as the train step's) and threads a ControlContext into the
    model, so the controller can ZERO-resize the TP decode matmuls of a
    contended rank at serve time without recompiling (signature-keyed
    executables come from the engine's PlanCompileCache).

    ``fused_attention`` routes the decode-attention call through the
    fused Pallas kernel (cfg-level, so the DENSE ctx=None path gets it
    too); ``psum_chunks`` chunk-splits the controlled epilogue psums.
    ``paging`` (core.paging.PagedLayout) swaps the attention cache to
    the block-paged pool and adds a ``pages`` [B, pages_per_slot] arg
    right after ``cur_pos``.
    """
    cfg = specs_lib.effective_model_cfg(cfg, shape)
    if fused_attention:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, fused_decode_attn=True)
    api = get_api(cfg)
    rules = specs_lib.rules_for(shape, mesh, cfg)
    p_sds, _, p_shards = specs_lib.param_specs(cfg, mesh, rules, dtype)
    d_sds, d_shards = specs_lib.decode_specs(cfg, shape, mesh, dtype,
                                             paging=paging)

    logits_spec = sh.filter_spec_for_mesh(
        sh.logical_to_spec(("batch", "vocab"), rules), mesh)
    logits_sh = NamedSharding(mesh, sh.fit_spec_to_shape(
        logits_spec, (shape.global_batch, cfg.vocab_size or 1), mesh))

    scopes = (_scopes.control_scopes(cfg, control_static)
              if control_static and cfg.encdec is None else {})
    if control_static and scopes:
        import dataclasses as _dc
        control_static = _dc.replace(
            control_static,
            scope_blocks=_scopes.scope_block_table(cfg, control_static))
        pl_sds, pl_shards = _scopes.plan_specs(control_static, cfg, mesh,
                                               scopes)
    else:
        control_static = None
        pl_sds = pl_shards = None

    if cfg.encdec is not None:
        if paging is not None:
            raise ValueError("paged decode does not cover encoder-decoder "
                             "models (the serve engine rejects them)")
        def serve_step(params, cache, tokens, cur_pos, encoder_out):
            with sh.use_rules(rules):
                return api.decode_step(params, cfg, cache, tokens, cur_pos,
                                       encoder_out)
        args = (p_sds, d_sds["cache"], d_sds["tokens"], d_sds["cur_pos"],
                d_sds["encoder_out"])
        in_sh = (p_shards, d_shards["cache"], d_shards["tokens"],
                 d_shards["cur_pos"], d_shards["encoder_out"])
    elif control_static is not None and paging is not None:
        def serve_step(params, cache, tokens, cur_pos, pages, plan):
            with sh.use_rules(rules):
                ctx = make_ctx(mesh, control_static, plan,
                               use_kernel=use_kernel,
                               psum_chunks=psum_chunks)
                return api.decode_step(params, cfg, cache, tokens, cur_pos,
                                       ctx=ctx, pages=pages)
        args = (p_sds, d_sds["cache"], d_sds["tokens"], d_sds["cur_pos"],
                d_sds["pages"], pl_sds)
        in_sh = (p_shards, d_shards["cache"], d_shards["tokens"],
                 d_shards["cur_pos"], d_shards["pages"], pl_shards)
    elif control_static is not None:
        def serve_step(params, cache, tokens, cur_pos, plan):
            with sh.use_rules(rules):
                ctx = make_ctx(mesh, control_static, plan,
                               use_kernel=use_kernel,
                               psum_chunks=psum_chunks)
                return api.decode_step(params, cfg, cache, tokens, cur_pos,
                                       ctx=ctx)
        args = (p_sds, d_sds["cache"], d_sds["tokens"], d_sds["cur_pos"],
                pl_sds)
        in_sh = (p_shards, d_shards["cache"], d_shards["tokens"],
                 d_shards["cur_pos"], pl_shards)
    elif paging is not None:
        def serve_step(params, cache, tokens, cur_pos, pages):
            with sh.use_rules(rules):
                return api.decode_step(params, cfg, cache, tokens, cur_pos,
                                       pages=pages)
        args = (p_sds, d_sds["cache"], d_sds["tokens"], d_sds["cur_pos"],
                d_sds["pages"])
        in_sh = (p_shards, d_shards["cache"], d_shards["tokens"],
                 d_shards["cur_pos"], d_shards["pages"])
    else:
        def serve_step(params, cache, tokens, cur_pos):
            with sh.use_rules(rules):
                return api.decode_step(params, cfg, cache, tokens, cur_pos)
        args = (p_sds, d_sds["cache"], d_sds["tokens"], d_sds["cur_pos"])
        in_sh = (p_shards, d_shards["cache"], d_shards["tokens"],
                 d_shards["cur_pos"])

    out_sh = (logits_sh, d_shards["cache"])
    return serve_step, args, in_sh, out_sh


def build_step_for(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                   train: TrainConfig = TrainConfig(),
                   control_static: Optional[PlanStatic] = None,
                   use_kernel: bool = False, fused_attention: bool = False,
                   psum_chunks: int = 1):
    """Dispatch on the shape kind: train_4k -> train_step;
    prefill_32k -> prefill; decode shapes -> serve_step (controlled when
    ``control_static`` is given — decode is a control surface since the
    serve engine). Prefill has no control hook (full-sequence forward is
    not in the paper's per-iteration balancing loop)."""
    if shape.kind == "train":
        return build_train_step(cfg, shape, mesh, train, control_static,
                                use_kernel=use_kernel,
                                psum_chunks=psum_chunks)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, mesh,
                                  jnp.dtype(train.param_dtype))
    return build_serve_step(cfg, shape, mesh, jnp.dtype(train.param_dtype),
                            control_static=control_static,
                            use_kernel=use_kernel,
                            fused_attention=fused_attention,
                            psum_chunks=psum_chunks)


# ---------------------------------------------------------------------------
# static-analysis registration (repro.analysis; see DESIGN_ANALYSIS.md)
# ---------------------------------------------------------------------------

from repro.analysis import registry as _analysis  # noqa: E402


def _an_smoke():
    import numpy as np
    from repro.config import get_config, smoke_variant
    return np, smoke_variant(get_config("yi-6b"))


def _an_mesh(e: int):
    import numpy as np
    return Mesh(np.array(jax.devices()[:e]).reshape(1, e),
                ("data", "model"))


def _an_control_static(e: int, spelling: str) -> PlanStatic:
    """Two spellings of the SAME canonical plan (mig_shed vs the legacy
    mig_blocks scalar) — R1 proves they trace identically, which is what
    makes PlanCompileCache's canonical-signature keying sound."""
    kw = dict(buckets=(0.0, 0.25, 0.5), block_size=8, tp_size=e)
    if spelling == "mig_shed":
        return PlanStatic(mig_shed=(2,), **kw)
    return PlanStatic(mig_blocks=2, **kw)


def _an_train_cases(env):
    np, cfg = _an_smoke()
    shape = ShapeConfig("an_train", 16, 4, "train")
    mesh1 = _an_mesh(1)
    fn, args, in_sh, out_sh = build_train_step(cfg, shape, mesh1,
                                               TrainConfig())
    cases = [_analysis.TraceCase(
        step="train_step", name="dense_tp1", fn=fn, args=args, mesh=mesh1,
        in_shardings=in_sh, out_shardings=out_sh,
        compile_hlo=env.compile_hlo, signature="dense_tp1")]
    e = min(4, env.max_devices)
    if e >= 2:
        mesh = _an_mesh(e)

        def build(spelling):
            st = _an_control_static(e, spelling)
            f, a, _, _ = build_train_step(cfg, shape, mesh, TrainConfig(),
                                          control_static=st)
            return st, f, a

        st_a, fn_a, args_a = build("mig_shed")
        _, fn_b, args_b = build("mig_blocks")
        cases.append(_analysis.TraceCase(
            step="train_step", name=f"controlled_tp{e}", fn=fn_a,
            args=args_a, mesh=mesh,
            signature=st_a.canonical().signature_str(),
            retrace=(("mig_blocks-spelling", fn_b, args_b),)))
    return cases


def _an_prefill_cases(env):
    np, cfg = _an_smoke()
    mesh1 = _an_mesh(1)
    fn, args, in_sh, out_sh = build_prefill_step(
        cfg, ShapeConfig("an_prefill", 32, 4, "prefill"), mesh1)
    return [_analysis.TraceCase(
        step="prefill_step", name="dense_tp1", fn=fn, args=args,
        mesh=mesh1, signature="prefill_tp1")]


def _an_decode_cases(env):
    np, cfg = _an_smoke()
    shape = ShapeConfig("an_decode", 16, 2, "decode")
    mesh1 = _an_mesh(1)
    fn, args, in_sh, out_sh = build_serve_step(cfg, shape, mesh1)
    cases = [_analysis.TraceCase(
        step="serve_decode_step", name="dense_tp1", fn=fn, args=args,
        mesh=mesh1, in_shardings=in_sh, compile_hlo=env.compile_hlo,
        signature="decode_dense_tp1")]
    e = min(4, env.max_devices)
    if e >= 2:
        mesh = _an_mesh(e)

        def build(spelling):
            st = _an_control_static(e, spelling)
            f, a, _, _ = build_serve_step(cfg, shape, mesh,
                                          control_static=st)
            return st, f, a

        st_a, fn_a, args_a = build("mig_shed")
        _, fn_b, args_b = build("mig_blocks")
        cases.append(_analysis.TraceCase(
            step="serve_decode_step", name=f"controlled_tp{e}", fn=fn_a,
            args=args_a, mesh=mesh,
            signature=st_a.canonical().signature_str(),
            retrace=(("mig_blocks-spelling", fn_b, args_b),)))
    return cases


_analysis.register("train_step", _an_train_cases)
_analysis.register("prefill_step", _an_prefill_cases)
_analysis.register("serve_decode_step", _an_decode_cases)
