"""HLO inspection helpers for the §Perf loop: with no wall-clock profiler
(no TPU), the "profile" is the compiled HLO — these helpers surface the
patterns the methodology hunts for:

* redundant collectives (same kind+shape collected repeatedly outside the
  layer scan — a tensor gathered twice),
* reshape/transpose churn between sharded ops (layout mismatch),
* remat-inserted recompute (duplicate fusion bodies).

    PYTHONPATH=src python -m repro.launch.hlo_inspect --arch yi-6b \
        --shape train_4k
"""
from __future__ import annotations

import collections
import re
from typing import Dict, List, Tuple

from repro.launch.hlo_analysis import COLLECTIVE_KINDS, _shape_bytes

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s/]*?)\s*"
    r"([\w\-]+)\(")


def collective_histogram(hlo_text: str) -> List[Tuple[str, str, int, int]]:
    """[(kind, shape, count, total_bytes)] sorted by total bytes desc."""
    hist: Dict[Tuple[str, str], List[int]] = collections.defaultdict(
        lambda: [0, 0])
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line.strip())
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        if base not in COLLECTIVE_KINDS or op.endswith("-done"):
            continue
        key = (base, shape_str.strip())
        hist[key][0] += 1
        hist[key][1] += _shape_bytes(shape_str)
    rows = [(k, s, c, b) for (k, s), (c, b) in hist.items()]
    return sorted(rows, key=lambda r: -r[3])


def find_redundant_collectives(hlo_text: str, min_count: int = 2
                               ) -> List[Tuple[str, str, int, int]]:
    """Same-kind same-shape collectives appearing >= min_count times in the
    TOP-LEVEL computation (outside while bodies) — candidates for CSE or
    hoisting."""
    # isolate the entry computation (ENTRY ... { ... })
    m = re.search(r"ENTRY[^{]*\{(.*)", hlo_text, re.S)
    body = m.group(1) if m else hlo_text
    return [r for r in collective_histogram(body) if r[2] >= min_count]


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Opcode → count over the whole module (entry + nested computations).

    The kernel-backward acceptance check reads this: the pruned-matmul
    gradient path must stay free of ``gather``/``scatter`` (the XLA
    zero-imputation path materializes both)."""
    counts = collections.Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line.strip())
        if m:
            counts[m.group(2)] += 1
    return dict(counts)


def reshape_churn(hlo_text: str) -> Dict[str, int]:
    counts = collections.Counter()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line.strip())
        if m and m.group(2) in ("reshape", "transpose", "copy",
                                "all-to-all"):
            counts[m.group(2)] += 1
    return dict(counts)


def report(hlo_text: str, top: int = 10) -> str:
    lines = ["== collective histogram (top by bytes) =="]
    for kind, shape, count, nbytes in collective_histogram(hlo_text)[:top]:
        lines.append(f"  {kind:20s} ×{count:<4d} {nbytes/2**20:8.1f} MiB  {shape[:60]}")
    red = find_redundant_collectives(hlo_text)
    lines.append(f"== redundant top-level collectives: {len(red)} ==")
    for kind, shape, count, nbytes in red[:top]:
        lines.append(f"  {kind:20s} ×{count:<4d} {nbytes/2**20:8.1f} MiB  {shape[:60]}")
    lines.append(f"== layout churn: {reshape_churn(hlo_text)} ==")
    return "\n".join(lines)


def main() -> None:
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
    import argparse

    import jax

    from repro.config import INPUT_SHAPES, TrainConfig, get_config
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.sharding import use_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    mesh = make_production_mesh()
    with use_mesh(mesh):
        fn, a, ins, outs = steps.build_step_for(
            get_config(args.arch), INPUT_SHAPES[args.shape], mesh,
            TrainConfig())
        compiled = jax.jit(fn, in_shardings=ins,
                           out_shardings=outs).lower(*a).compile()
    print(report(compiled.as_text()))


if __name__ == "__main__":
    main()
