"""DEPRECATED shim — the HLO inspection helpers moved to
:mod:`repro.analysis.hlo`; importing through this module warns. The
``python -m repro.launch.hlo_inspect`` CLI keeps working (it reports on
a production-mesh compile of a chosen step).
"""
from __future__ import annotations

_FORWARDED = ("collective_histogram", "find_redundant_collectives",
              "op_histogram", "reshape_churn", "report")


def __getattr__(name: str):
    if name in _FORWARDED:
        import warnings
        warnings.warn(
            f"repro.launch.hlo_inspect.{name} is deprecated; import it "
            "from repro.analysis.hlo", DeprecationWarning, stacklevel=2)
        from repro.analysis import hlo
        return getattr(hlo, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def main() -> None:
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512")
    import argparse

    import jax

    from repro.analysis.hlo import report
    from repro.config import INPUT_SHAPES, TrainConfig, get_config
    from repro.launch import steps
    from repro.launch.mesh import make_production_mesh
    from repro.sharding import use_mesh

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    mesh = make_production_mesh()
    with use_mesh(mesh):
        fn, a, ins, outs = steps.build_step_for(
            get_config(args.arch), INPUT_SHAPES[args.shape], mesh,
            TrainConfig())
        compiled = jax.jit(fn, in_shardings=ins,
                           out_shardings=outs).lower(*a).compile()
    print(report(compiled.as_text()))


if __name__ == "__main__":
    main()
