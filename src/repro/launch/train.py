"""End-to-end training driver with the SEMI-migration control loop.

Runs a REAL (reduced-size) model on the host devices: data pipeline →
jitted train step (with the workload-control plan as a runtime input) →
host-side controller (straggler detection / Eq.1-3) → checkpointing.
Heterogeneity is simulated per the paper (Sec. V-A): a χ-schedule feeds
the iteration-time model, whose per-rank times drive the controller; the
*measured* wall-clock of the bulk-synchronous step is then modeled as the
max over ranks (the real cluster behavior the technique removes).

Control threading (plan assembly, signature-keyed compile cache,
mitigation dispatch, telemetry) lives in the unified
:class:`repro.control.ControlPlane` shared with the serve engine
(DESIGN_CONTROL.md) — this driver owns only what is train-specific: the
optimizer, the data pipeline, weight-statistics observation and the
full-state checkpoint.

Checkpoints carry the COMPLETE train state — params, AdamW moments +
step, controller/estimator state and the data-pipeline position — so a
crash-interrupted run resumed with ``--resume`` is bit-identical to an
uninterrupted one (pinned by tests/test_system.py).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 50 \
        --tp 4 --control semi --hetero round_robin --chi 4
"""
from __future__ import annotations

# CLI nicety: when invoked as a script with --tp/--dp > 1, request that many
# host devices BEFORE jax initializes (shared jax-free helper).
from repro.launch._bootstrap import argv_int as _argv_int, ensure_host_devices

ensure_host_devices(_argv_int("--tp") * _argv_int("--dp"))

import argparse
import dataclasses
import json
import os
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import store as ckpt_store
from repro.config import (ShapeConfig, TrainConfig, get_config,
                          smoke_variant)
from repro.control import ControlConfig, ControlPlane
from repro.control.plane import make_schedule
from repro.core import geometry as geom_lib
from repro.core import hetero as hetero_lib
from repro.core.workload import WorkloadPlan
from repro.data.pipeline import (PatternImageStream, TokenTaskStream,
                                 patchify, skip_batches)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_small_mesh
from repro.models import get_api
from repro.optim import adamw
from repro.sharding import ragged_local_width, use_mesh


@dataclasses.dataclass
class TrainerState:
    params: object
    opt: object
    step: int = 0


# batches eval_accuracy consumes per eval event — shared by the eval call
# and the resume fast-forward, which must skip exactly this many per past
# event for a resumed run to stay equivalent to an uninterrupted one
EVAL_BATCHES = 4

# FFN pruning granularity the trainer plans at (control_block_size adapts
# it down when d_ff/tp is small); the ragged geometry quantizes to the
# same grid so geometry block counts and plan block counts line up
TRAIN_BLOCK = 8


def _resolve_geometry(spec: Optional[str], cfg, tp: int, *, hetero_kind: str,
                      chi: float, period: int, seed: int,
                      trace_in: Optional[str]):
    """Parse ``--geometry`` into a ShardGeometry (None = classic split).

    ``"chi"`` seeds the static split from the hetero schedule's step-0
    speed ratios (core/geometry.py geometry_from_chi — the steady-state
    χ of a static/persistent schedule); ``"a,b,..."`` gives explicit
    per-rank block counts summing to d_ff/TRAIN_BLOCK. Equal splits
    collapse to None so the geometry-free path stays bit-identical.
    """
    if spec is None or not str(spec).strip() \
            or str(spec).strip().lower() == "none":
        return None
    reason = geom_lib.geometry_unsupported_reason(cfg)
    if reason:
        raise ValueError(f"--geometry unsupported for {cfg.name}: {reason}")
    if cfg.d_ff % TRAIN_BLOCK:
        raise ValueError(
            f"--geometry needs d_ff divisible by {TRAIN_BLOCK} "
            f"(got {cfg.d_ff})")
    nb_total = cfg.d_ff // TRAIN_BLOCK
    if str(spec).strip().lower() == "chi":
        sched = make_schedule(hetero_kind, tp, chi=chi, period=period,
                              seed=seed, trace_in=trace_in)
        if sched is None:
            raise ValueError("--geometry chi needs a hetero schedule "
                             "(--hetero != none)")
        geo = geom_lib.geometry_from_schedule(sched, nb_total, TRAIN_BLOCK)
    else:
        sizes = geom_lib.parse_geometry_arg(str(spec), tp)
        geo = geom_lib.geometry_for_cfg(cfg, sizes, TRAIN_BLOCK)
    return None if geo.is_equal else geo


def run_training(arch: str, *, steps: int = 50, tp: int = 1, dp: int = 1,
                 control_mode: str = "off", hetero_kind: str = "none",
                 chi: float = 2.0, lr: float = 3e-3, batch: int = 8,
                 seq: int = 64, seed: int = 0, log_every: int = 10,
                 ckpt_dir: Optional[str] = None, resume: bool = False,
                 imputation: str = "zero", selection: str = "priority",
                 hetero_period: int = 10, mig_blocks: int = 0,
                 max_sources: int = 3,
                 eval_every: int = 0, quiet: bool = False,
                 force_gamma: Optional[float] = None,
                 data_noise: float = 0.35,
                 use_kernel: bool = False,
                 psum_chunks: int = 1,
                 times: str = "modeled",
                 trace_in: Optional[str] = None,
                 trace_out: Optional[str] = None,
                 measure_noise: float = 0.0,
                 ckpt_every: int = 50,
                 geometry: Optional[str] = None) -> Dict:
    """Returns a summary dict (loss/acc curves, modeled step times)."""
    cfg = smoke_variant(get_config(arch))
    cfg_canonical = cfg
    geo = _resolve_geometry(geometry, cfg, tp, hetero_kind=hetero_kind,
                            chi=chi, period=hetero_period, seed=seed,
                            trace_in=trace_in)
    if geo is not None:
        # static uneven sharding, realized as a zero-padded equal GSPMD
        # split (core/geometry.py): the model config carries the padded
        # d_ff; params are initialized canonically and expanded below
        cfg = geom_lib.apply_geometry_cfg(cfg, geo)
    api = get_api(cfg)
    mesh = make_small_mesh(dp, tp)
    if geo is not None:
        ragged_local_width(geo.padded_width, mesh)
    train_cfg = TrainConfig(learning_rate=lr, steps=steps)
    shape = ShapeConfig("trainer", seq, batch, "train")

    control_cfg = ControlConfig(
        mode=control_mode, hetero_kind=hetero_kind, chi=chi,
        period=hetero_period, block_size=TRAIN_BLOCK,
        max_sources=max_sources, shed_cap=mig_blocks,
        # training default: Eq.(2) balances migration vs. resize cost
        # (the serve engine's ControlConfig default is "lossless")
        beta_policy="eq2",
        imputation=imputation, selection=selection,
        use_kernel=use_kernel, psum_chunks=psum_chunks,
        seed=seed, times=times,
        trace_in=trace_in, trace_out=trace_out,
        measure_noise=measure_noise,
        geometry=geo.sizes if geo is not None else None,
    ).to_workload(
        enabled=control_mode != "off" or force_gamma is not None,
        # legacy CLI contract: --mig-blocks 0 disables migration entirely;
        # otherwise it caps the per-source shed count
        migration_sources=max_sources if mig_blocks > 0 else 0)

    with use_mesh(mesh):
        # Plan-signature compile cache: the controller's multi-straggler
        # plans change the STATIC shed counts, so the step function is
        # (re)built per canonical signature; shed quantization keeps the
        # signature set small and each one compiles at most once.
        def _build_step(static):
            fn_, _, in_sh_, out_sh_ = steps_lib.build_train_step(
                cfg, shape, mesh, train_cfg, static, total_steps=steps,
                use_kernel=control_cfg.use_kernel,
                psum_chunks=control_cfg.psum_chunks)
            jitted = jax.jit(fn_, in_shardings=in_sh_, out_shardings=out_sh_)
            n_slots = max(1, static.num_sources) if static is not None else 0
            return jitted, n_slots, in_sh_

        # -- unified control plane (plan assembly / compile cache /
        # mitigation dispatch / telemetry, shared with the serve engine) --
        # the latency model prices the CANONICAL workload — under a ragged
        # geometry the padded lanes are inert zeros, not extra FLOPs, and
        # work_fraction reports in equal-shard (L_eq) units to match
        it_model = hetero_lib.iteration_model(cfg_canonical, shape,
                                              max(tp, 1),
                                              peak_flops=5e9, mfu=1.0)
        plane = ControlPlane(
            cfg, control_cfg, mesh=mesh, tp=tp, builder=_build_step,
            it_model=it_model, controller_blocks="global",
            hetero_kind=hetero_kind, chi=chi, period=hetero_period,
            seed=seed, trace_in=trace_in, trace_out=trace_out,
            trace_meta={"arch": arch, "hetero": hetero_kind,
                        "control": control_mode, "seed": seed},
            measure_noise=measure_noise,
            geometry=geo.sizes if geo is not None else None)
        step_jit, plan_slots, in_sh = plane.base
        controller = plane.controller
        scopes = plane.scopes

        # real init. Geometry runs initialize CANONICAL params (same RNG
        # draws as the equal-shard run) and expand them into the padded
        # ragged layout — rank r's shard holds its geometry[r] real blocks
        # first, zero padding after (inert fwd/bwd and under AdamW).
        box = {}
        if geo is not None:
            p_host, box["ax"] = api.init(jax.random.PRNGKey(seed),
                                         cfg_canonical,
                                         jnp.dtype(train_cfg.param_dtype))
            params = jax.device_put(
                geom_lib.expand_ffn_params(p_host, geo), in_sh[0])
        else:
            def init_fn():
                p, ax = api.init(jax.random.PRNGKey(seed), cfg,
                                 jnp.dtype(train_cfg.param_dtype))
                box["ax"] = ax
                return p
            params = jax.jit(init_fn, out_shardings=in_sh[0])()
        opt = jax.device_put(adamw.init(params), in_sh[1])

        # -- resume: restore the FULL train state (params + optimizer
        # moments/step + control-plane state + data position), so the
        # resumed run is equivalent to never having stopped. Legacy
        # params-only checkpoints restore what they have.
        start_step = 0
        batches_drawn = 0
        if ckpt_dir and resume:
            last = ckpt_store.latest_step(ckpt_dir)
            if last is not None:
                man = ckpt_store.read_manifest(ckpt_dir, last)
                extra = man.get("extra", {})
                # the checkpointed param layout is geometry-dependent —
                # resuming across geometries would silently misassign
                # blocks to ranks, so mismatches fail loudly (legacy
                # checkpoints carry no key == equal split)
                ck_geo = extra.get("geometry")
                cur_geo = list(geo.sizes) if geo is not None else None
                if (ck_geo or cur_geo) and list(ck_geo or []) != \
                        list(cur_geo or []):
                    raise ValueError(
                        f"checkpoint shard geometry {ck_geo} does not "
                        f"match this run's geometry {cur_geo}; resuming "
                        "across geometries is not supported")
                if extra.get("layout") == ckpt_store.TRAIN_STATE_LAYOUT:
                    params = ckpt_store.restore(ckpt_dir, last, params,
                                                in_sh[0], prefix="params")
                    opt = ckpt_store.restore(ckpt_dir, last, opt, in_sh[1],
                                             prefix="opt")
                    plane.load_state(
                        ckpt_store.load_arrays(ckpt_dir, last, "plane"),
                        extra.get("plane"))
                    start_step = int(extra.get("train_step", last))
                    batches_drawn = int(extra.get("data_batches", start_step))
                else:
                    params = ckpt_store.restore(ckpt_dir, last, params,
                                                in_sh[0])
                    start_step = last
                    batches_drawn = last

        def save_ckpt(step_now: int) -> None:
            tree = {"params": params, "opt": opt}
            plane_arrays = plane.state_arrays()
            if plane_arrays:
                tree["plane"] = plane_arrays
            ckpt_store.save(ckpt_dir, step_now, tree, extra={
                "layout": ckpt_store.TRAIN_STATE_LAYOUT,
                "train_step": step_now,
                "data_batches": batches_drawn,
                "plane": plane.state_meta(),
                "geometry": list(geo.sizes) if geo is not None else None,
                "arch": arch, "tp": tp, "dp": dp, "seed": seed})

        # data
        if cfg.num_classes:
            stream = iter(PatternImageStream(batch_size=batch, seed=seed,
                                             noise=data_noise))
            eval_stream = iter(PatternImageStream(batch_size=batch,
                                                  seed=seed + 777,
                                                  noise=data_noise))
        else:
            stream = iter(TokenTaskStream(cfg.vocab_size, seq, batch,
                                          seed=seed))
            eval_stream = None
        if batches_drawn:
            # re-align the synthetic streams with the checkpointed position
            skip_batches(stream, batches_drawn)
            if eval_stream is not None and eval_every:
                skip_batches(eval_stream,
                             EVAL_BATCHES * (start_step // eval_every))

        def make_batch():
            b = next(stream)
            if cfg.num_classes:
                b = {"patches": patchify(b["images"]), "labels": b["labels"]}
            if cfg.family == "vlm" and cfg.frontend and not cfg.num_classes:
                b["patch_embeds"] = np.random.default_rng(0).standard_normal(
                    (batch, cfg.frontend.num_tokens, cfg.d_model)).astype(
                        np.float32) * 0.02
            if cfg.encdec is not None:
                b["frame_embeds"] = np.random.default_rng(0).standard_normal(
                    (batch, cfg.encdec.encoder_seq_len, cfg.d_model)).astype(
                        np.float32) * 0.02
            return b

        work_frac = np.ones((tp,))
        history = {"loss": [], "acc": [], "modeled_step_s": [],
                   "gammas": [], "mig": [], "mig_shed": [],
                   "buckets": [], "signatures": [], "wall_s": []}

        def scope_stats():
            """Mean-over-layers weight matrices per controlled scope:
            ffn -> w_down [d_ff, d]; qkv -> wq [d, H*hd]; attn_out ->
            wo [H*hd, d] (contraction dim first in every case)."""
            st = params["stack"] if "stack" in params else params.get("decoder", {})
            scan = st.get("scan") if isinstance(st, dict) else None
            if scan is None:
                return {}
            out = {}
            for grp in (scan if isinstance(scan, tuple) else (scan,)):
                if not isinstance(grp, dict):
                    continue
                if "ffn" in grp and "ffn" in scopes and "ffn" not in out:
                    out["ffn"] = np.asarray(
                        jax.device_get(grp["ffn"]["w_down"])).mean(axis=0)
                if "attn" in grp and isinstance(grp["attn"], dict):
                    if "qkv" in scopes and "wq" in grp["attn"] and "qkv" not in out:
                        out["qkv"] = np.asarray(
                            jax.device_get(grp["attn"]["wq"])).mean(axis=0)
                    if "attn_out" in scopes and "wo" in grp["attn"]                             and "attn_out" not in out:
                        out["attn_out"] = np.asarray(
                            jax.device_get(grp["attn"]["wo"])).mean(axis=0)
            return out

        plan = None
        for it in range(start_step, steps):
            chis = plane.chis(it)
            plan_arrays = None
            report = None
            plan = None
            step_fn = step_jit
            if controller is not None:
                if force_gamma is not None:
                    # Figs. 5/6: force a uniform γ on EVERY rank
                    from repro.core.workload import (PlanDynamic,
                                                     bucket_for_gamma)
                    b = bucket_for_gamma(force_gamma, control_cfg.gamma_buckets)
                    plan = WorkloadPlan(
                        plane.static,
                        PlanDynamic(
                            bucket_by_rank=np.full((tp,), b, np.int32),
                            mig_src=np.array(-1, np.int32),
                            pri_lists=controller.pri_lists()))
                    report = None
                else:
                    # the controller consumes FULL-workload-equivalent
                    # times — from the χ-oracle, or (measured mode) the
                    # estimator's reconstruction of measured (mitigated)
                    # times of previous steps (Eq. 1 measures the
                    # heterogeneity degree, not the mitigated runtime)
                    times = plane.controller_times(chis)
                    plan, report = plane.decide(times)
                # pick the executable for this plan's signature and
                # assemble the dynamic plan arrays (projection is the
                # identity here: the trainer simulates at real-mesh scale)
                step_fn, plan_arrays, _ = plane.dispatch(plan)
                work_frac = plane.work_frac(plan)

            b = make_batch()
            batches_drawn += 1
            b = {k: jnp.asarray(v) for k, v in b.items()}
            plane.timer.start()
            if plan_arrays is not None:
                params, opt, metrics = step_fn(params, opt, b, plan_arrays)
            else:
                params, opt, metrics = step_fn(params, opt, b)
            wall = plane.timer.stop(metrics)
            metrics = jax.device_get(metrics)

            # modeled bulk-synchronous step time (the paper's RT metric)
            modeled = it_model.step_time(chis, work_frac)

            # -- measurement: what a real cluster would observe THIS step —
            # per-rank times under the ACTIVE plan (mitigated), gathered
            # across ranks once per control interval; feeds the estimator
            # and the trace
            plane.capture(chis, work_frac, step=it, plan=plan, wall=wall)

            history["loss"].append(float(metrics["loss"]))
            history["modeled_step_s"].append(modeled)
            history["wall_s"].append(wall)
            if report is not None:
                history["gammas"].append(
                    {int(k): float(v) for k, v in report.gammas.items()})
                history["mig"].append(int(report.mig_src))
                history["mig_shed"].append(
                    [list(map(int, report.mig_srcs)),
                     list(map(int, report.mig_shed))])
                history["buckets"].append(
                    [int(x) for x in report.bucket_by_rank])
                history["signatures"].append(plan.static.signature_str())

            if controller is not None and (it + 1) % 10 == 0:
                stats = scope_stats()
                if stats:
                    controller.observe_weights(stats, control_cfg.block_size)

            if eval_every and (it + 1) % eval_every == 0 and cfg.num_classes:
                from repro.data.pipeline import eval_accuracy
                def predict(bb):
                    return api.forward(params, cfg,
                                       jnp.asarray(patchify(bb["images"])))
                acc = eval_accuracy(predict, eval_stream, EVAL_BATCHES)
                history["acc"].append(acc)
                if not quiet:
                    print(f"  step {it+1}: eval acc {acc:.3f}")

            if not quiet and (it + 1) % log_every == 0:
                print(f"step {it+1:4d} loss={metrics['loss']:.4f} "
                      f"wall={wall*1e3:.0f}ms modeled={modeled*1e3:.1f}ms")

            if ckpt_dir and (it + 1) % max(ckpt_every, 1) == 0 \
                    and (it + 1) < steps:
                save_ckpt(it + 1)

        if ckpt_dir:
            save_ckpt(steps)
        plane.close()
        history["final_loss"] = history["loss"][-1] if history["loss"] else None
        history["mean_modeled_step_s"] = float(
            np.mean(history["modeled_step_s"])) if history["modeled_step_s"] else 0
        # compile-cache telemetry: distinct plan signatures built vs reused
        history["plan_compiles"] = plane.cache.compile_count
        history["plan_cache_hits"] = plane.cache.hit_count
        history["times_mode"] = control_cfg.times if control_cfg.enabled else "modeled"
        if geo is not None:
            history["geometry"] = list(geo.sizes)
        if plane.estimator is not None:
            history["chi_hat"] = [float(c) for c in plane.estimator.chi_hat]
            history["estimator_rejected"] = plane.estimator.rejected_total
            history["rank_gathers"] = plane.timer.gather_count
        if plane.writer is not None:
            history["trace_out"] = trace_out
        return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--control", default="off",
                    choices=["off", "zero", "mig", "semi"])
    ap.add_argument("--hetero", default="none",
                    choices=["none", "static", "round_robin", "contention",
                             "trace"])
    ap.add_argument("--chi", type=float, default=2.0)
    ap.add_argument("--times", default="modeled",
                    choices=["modeled", "measured"],
                    help="controller input: modeled χ-oracle, or measured "
                         "times through the online StragglerEstimator "
                         "(DESIGN_TELEMETRY.md)")
    ap.add_argument("--trace-in", default=None,
                    help="telemetry trace to replay (with --hetero trace)")
    ap.add_argument("--trace-out", default=None,
                    help="record a replayable telemetry trace here (JSONL)")
    ap.add_argument("--measure-noise", type=float, default=0.0,
                    help="multiplicative noise on simulated measurements")
    ap.add_argument("--mig-blocks", type=int, default=0,
                    help="per-source migration shed cap; 0 disables migration")
    ap.add_argument("--geometry", default=None,
                    help="static ragged TP shard geometry: 'chi' seeds "
                         "per-rank FFN block counts from the hetero "
                         "schedule's speed ratios; 'a,b,...' gives them "
                         "explicitly (DESIGN_SHARDING.md)")
    ap.add_argument("--max-sources", type=int, default=3,
                    help="max concurrent migration stragglers per TP group")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--imputation", default="zero",
                    choices=["zero", "average", "same"])
    ap.add_argument("--selection", default="priority",
                    choices=["random", "priority", "priority_diff"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="steps between mid-run full-state checkpoints")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--use-kernel", action="store_true",
                    help="route controlled matmuls through the Pallas "
                         "pruned-kernel family (fused FFN + kernel bwd)")
    ap.add_argument("--psum-chunks", type=int, default=1,
                    help="chunk-split the controlled epilogue all-reduce "
                         "into this many async-overlappable psums")
    ap.add_argument("--out", default=None, help="write history JSON here")
    args = ap.parse_args()

    hist = run_training(
        args.arch, steps=args.steps, tp=args.tp, dp=args.dp,
        control_mode=args.control, hetero_kind=args.hetero, chi=args.chi,
        lr=args.lr, batch=args.batch, seq=args.seq, seed=args.seed,
        ckpt_dir=args.ckpt_dir, resume=args.resume,
        imputation=args.imputation, selection=args.selection,
        mig_blocks=args.mig_blocks, max_sources=args.max_sources,
        eval_every=args.eval_every, use_kernel=args.use_kernel,
        psum_chunks=args.psum_chunks,
        times=args.times, trace_in=args.trace_in, trace_out=args.trace_out,
        measure_noise=args.measure_noise, ckpt_every=args.ckpt_every,
        geometry=args.geometry)
    print(f"final loss: {hist['final_loss']:.4f}  "
          f"mean modeled step: {hist['mean_modeled_step_s']*1e3:.2f} ms")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
