import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST run before any other import: jax locks the device count on first
#   init. The dry-run (and ONLY the dry-run) needs 512 placeholder devices.

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh, record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import time
import traceback

import jax

from repro.config import INPUT_SHAPES, TrainConfig, get_config, list_configs
from repro.analysis import hlo as hlo_analysis
from repro.launch import steps
from repro.launch.mesh import make_production_mesh
from repro.sharding import use_mesh

ASSIGNED = [
    "qwen2-vl-7b", "recurrentgemma-2b", "deepseek-7b", "deepseek-v2-lite-16b",
    "mixtral-8x7b", "falcon-mamba-7b", "yi-6b", "granite-3-8b",
    "whisper-small", "qwen2.5-32b",
]

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def out_path(arch: str, shape: str, mesh_name: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_name}.json")


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            remat: str = "none", control: bool = False,
            extra_tag: str = "", dtype: str = "float32",
            microbatch: int = 0, fsdp: bool = False) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    t0 = time.time()

    control_static = None
    if control:
        from repro.control.scopes import control_block_size
        from repro.core.workload import PlanStatic
        tp = int(mesh.shape["model"])
        control_static = PlanStatic(tp_size=tp, block_size=128, mig_blocks=2)
        b = control_block_size(cfg, control_static)
        if b == 0:
            raise RuntimeError(
                f"{arch}: FFN width {cfg.d_ff}/{tp} has no >=32 block — "
                "exempt from resizing at this TP (DESIGN.md §5)")
        control_static = PlanStatic(tp_size=tp, block_size=b, mig_blocks=2)

    train = TrainConfig(remat=remat, param_dtype=dtype,
                        microbatch=microbatch, fsdp_layers=fsdp)
    with use_mesh(mesh):
        fn, args, in_sh, out_sh = steps.build_step_for(
            cfg, shape, mesh, train, control_static)
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:                                    # noqa: BLE001
        mem["error"] = str(e)

    roof = hlo_analysis.roofline_from_compiled(compiled, chips)
    mf = hlo_analysis.model_flops(cfg, shape)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "chips": chips,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "model_flops_global": mf,
        "hlo_flops_global": roof.flops_per_device * chips,
        "useful_flops_ratio": (mf / (roof.flops_per_device * chips)
                               if roof.flops_per_device else 0.0),
        "memory_analysis": mem,
        "roofline": roof.as_dict(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "remat": remat, "control": control, "dtype": dtype,
        "microbatch": microbatch,
    }
    tag = mesh_name + (("__" + extra_tag) if extra_tag else "")
    with open(out_path(arch, shape_name, tag), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--dtype", default="float32")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--control", action="store_true",
                    help="enable the workload-control (SEMI) path")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    pairs = []
    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for a, s in pairs:
        tag = mesh_name + (("__" + args.tag) if args.tag else "")
        path = out_path(a, s, tag)
        if os.path.exists(path) and not args.force:
            print(f"[skip] {a} × {s} ({mesh_name}) — cached")
            continue
        print(f"[dryrun] {a} × {s} on {mesh_name} ...", flush=True)
        try:
            r = run_one(a, s, multi_pod=args.multi_pod, remat=args.remat,
                        control=args.control, extra_tag=args.tag,
                        dtype=args.dtype, microbatch=args.microbatch,
                        fsdp=args.fsdp)
            roof = r["roofline"]
            print(f"  ok: compile={r['compile_s']}s "
                  f"compute={roof['compute_s']:.4f}s "
                  f"memory={roof['memory_s']:.4f}s "
                  f"collective={roof['collective_s']:.4f}s "
                  f"dominant={roof['dominant']}", flush=True)
        except Exception as e:                                # noqa: BLE001
            failures.append((a, s, repr(e)))
            print(f"  FAIL: {e}\n{traceback.format_exc()}", flush=True)
        finally:
            jax.clear_caches()      # keep the 40-pair sweep's RSS bounded

    print(f"\n{len(pairs) - len(failures)}/{len(pairs)} lowered+compiled")
    for a, s, e in failures:
        print(f"  FAILED {a} × {s}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
