"""Block-pruned matmul Pallas TPU kernel — the compute hot-spot of
ZERO-resizing (DESIGN.md §2).

y = x[:, keep-blocks] @ w[keep-blocks, :]

The K (contraction) grid iterates ONLY the kept blocks; the pruning index
vector is scalar-prefetched (SMEM) and consumed by the BlockSpec index
maps, so the gather of pruned X columns / W rows happens during the
HBM→VMEM tile streaming — the pruned copies are never materialized (the
paper's "temporarily resize" without the temporary).

Tiling: (tm × block) X-tiles and (block × tn) W-tiles with a float32
VMEM accumulator; `block` is the pruning granularity (128 = MXU lane
width). Default tm=256, tn=256: VMEM footprint per step is
tm·block + block·tn + tm·tn floats ≈ 0.5 MiB, well under the ~16 MiB
v5e VMEM budget, and every matmul dim is a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, n_keep: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_keep - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tm", "tn", "interpret"))
def block_pruned_matmul_2d(x: jax.Array, w: jax.Array, keep_idx: jax.Array,
                           *, block: int = 128, tm: int = 256, tn: int = 256,
                           interpret: bool = True) -> jax.Array:
    """2-D core: x [M, K] @ w[K, N] over kept K-blocks. M % tm == 0,
    N % tn == 0, K % block == 0 are required (the ops.py wrapper pads).

    interpret=True executes the kernel body in Python on CPU (this
    container has no TPU); on TPU pass interpret=False.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and M % tm == 0 and N % tn == 0 and K % block == 0
    kb = keep_idx.shape[0]

    grid = (M // tm, N // tn, kb)
    kernel = functools.partial(_kernel, n_keep=kb)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, block), lambda i, j, k, idx: (i, idx[k])),
                pl.BlockSpec((block, tn), lambda i, j, k, idx: (idx[k], j)),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda i, j, k, idx: (i, j)),
            scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(keep_idx, x, w)
