"""Block-pruned matmul Pallas TPU kernel family — the compute hot-spot of
ZERO-resizing (DESIGN.md §2, DESIGN_KERNELS.md).

Forward:   y = x[:, keep-blocks] @ w[keep-blocks, :]

The K (contraction) grid iterates ONLY the kept blocks; the pruning index
vector is scalar-prefetched (SMEM) and consumed by the BlockSpec index
maps, so the gather of pruned X columns / W rows happens during the
HBM→VMEM tile streaming — the pruned copies are never materialized (the
paper's "temporarily resize" without the temporary).

Backward (kernel-level, no XLA gather/scatter):

    dX[:, b] = dy @ w[b, :]^T   if block b kept, else 0
    dW[b, :] = x[:, b]^T @ dy   if block b kept, else 0

Both backward kernels take the *inverse* permutation ``order`` =
concat(keep_idx, pruned_idx) as a scalar-prefetch vector. The grid's
block dimension runs over ALL nb blocks; slot k < kb streams tiles
through ``order[k]`` index maps and accumulates real matmuls, while slot
k >= kb only writes a zero tile at the pruned position ``order[k]`` —
pruned dX/dW blocks are zeroed IN-KERNEL, never via a full-size
zeros+scatter temporary, and the kept tiles land directly at their final
offsets through the inverse BlockSpec index maps.

Out-pruned family (for the fused-FFN dataflow): compact activations

    yc = x @ w[:, keep-blocks]            (outpruned_matmul_2d)
    dx = dyc @ w[:, keep-blocks]^T        (outpruned_matmul_dx_2d, dense out)
    dW[:, b] = x^T @ dyc[:, slot(b)]      (outpruned_matmul_dw_2d, 0 if pruned)

Fused FFN: y = act(x @ Wup[:, keep] [, · gate]) @ Wdown[keep, :] in ONE
pallas_call — the (resized) hidden activation lives only in a VMEM
scratch tile, never round-tripping through HBM.

Tiling: (tm × block) X-tiles and (block × tn) W-tiles with float32
VMEM accumulators; ``block`` is the pruning granularity (128 = MXU lane
width). Default tm=256, tn=256: VMEM per step is tm·block + block·tn +
tm·tn floats ≈ 0.5 MiB, well under the ~16 MiB v5e budget, and every
matmul dim is a multiple of 128. See DESIGN_KERNELS.md for the budget
math of the fused-FFN kernel (which holds full-width x/Wdown rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _params(*semantics):
    return _CompilerParams(dimension_semantics=semantics)


# ---------------------------------------------------------------------------
# forward: y[M, N] = x[:, keep] @ w[keep, :]
# ---------------------------------------------------------------------------


def _fwd_kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, n_keep: int):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_keep - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tm", "tn", "interpret"))
def block_pruned_matmul_2d(x: jax.Array, w: jax.Array, keep_idx: jax.Array,
                           *, block: int = 128, tm: int = 256, tn: int = 256,
                           interpret: bool = True) -> jax.Array:
    """2-D core: x [M, K] @ w [K, N] over kept K-blocks. M % tm == 0,
    N % tn == 0, K % block == 0 are required (the ops.py wrapper pads and
    validates with readable errors).

    interpret=True executes the kernel body on CPU (containers without a
    TPU); ops.py auto-detects the backend.
    """
    M, K = x.shape
    K2, N = w.shape
    if K != K2 or M % tm or N % tn or K % block:
        raise ValueError(
            f"block_pruned_matmul_2d: x {x.shape} @ w {w.shape} with "
            f"block={block}, tm={tm}, tn={tn} — K must match and M/N/K must "
            "be multiples of tm/tn/block (ops.py pads before calling)")
    kb = keep_idx.shape[0]

    grid = (M // tm, N // tn, kb)
    kernel = functools.partial(_fwd_kernel, n_keep=kb)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, block), lambda i, j, k, idx: (i, idx[k])),
                pl.BlockSpec((block, tn), lambda i, j, k, idx: (idx[k], j)),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda i, j, k, idx: (i, j)),
            scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "parallel", "arbitrary"),
    )(keep_idx, x, w)


# ---------------------------------------------------------------------------
# backward dX: dX[M, K] = dy @ w[kept]^T at kept blocks, zeros elsewhere
# ---------------------------------------------------------------------------


def _flat_k(s, kb: int, inner: int):
    """Slot id for the flattened backward grid: steps [0, kb·inner) sweep
    the contraction for the kb kept slots; the (nb−kb) trailing steps are
    single-visit zero-writes for the pruned slots."""
    return jnp.where(s < kb * inner, s // inner, kb + (s - kb * inner))


def _flat_inner(s, kb: int, inner: int):
    return jnp.where(s < kb * inner, s % inner, 0)


def _dx_kernel(order_ref, dy_ref, w_ref, o_ref, acc_ref,
               *, nj: int, kb: int):
    s = pl.program_id(1)
    compute = s < kb * nj
    j = _flat_inner(s, kb, nj)

    @pl.when(jnp.logical_and(compute, j == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(compute)
    def _mm():
        # dy tile [tm, tn] × w tile [block, tn] contracted over N → [tm, block]
        acc_ref[...] += lax.dot_general(
            dy_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(compute, j == nj - 1))
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    @pl.when(jnp.logical_not(compute))
    def _prune():
        # pruned slot: ONE grid step writing the zero tile in-kernel
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("kb", "block", "tm", "tn",
                                             "compact_out", "interpret"))
def pruned_matmul_dx_2d(dy: jax.Array, w: jax.Array, order: jax.Array,
                        *, kb: int, block: int = 128, tm: int = 256,
                        tn: int = 256, compact_out: bool = False,
                        interpret: bool = True) -> jax.Array:
    """dX of the pruned matmul, written tile-by-tile through the inverse
    index map ``order`` ([nb] = concat(keep_idx, pruned_idx) permutation;
    ``kb`` is the static kept count, i.e. the keep-prefix length).

    compact_out=False → full [M, K=nb·block] dX: the grid block-dim covers
    all nb slots; pruned slots (k >= kb) emit a zero tile in-kernel at
    position order[k] — no zeros+scatter temporary.
    compact_out=True → compact [M, kb·block] dh for the fused-FFN backward:
    the grid covers only the kb kept slots; output tile k lands at slot k
    (``order`` then only needs its keep prefix to be valid).
    """
    M, N = dy.shape
    K2, N2 = w.shape
    nslots = kb if compact_out else order.shape[0]
    if N != N2 or M % tm or N % tn or K2 % block:
        raise ValueError(
            f"pruned_matmul_dx_2d: dy {dy.shape} / w {w.shape} with "
            f"block={block}, tm={tm}, tn={tn} — N must match and M/N/K must "
            "be tile multiples (ops.py pads before calling)")
    nj = N // tn
    kernel = functools.partial(_dx_kernel, nj=nj, kb=kb)
    # flattened block×contraction grid: kb·nj compute steps, then ONE
    # zero-write step per pruned slot (nslots − kb of them)
    grid = (M // tm, kb * nj + (nslots - kb))

    def _k(s):
        return _flat_k(s, kb, nj)

    if compact_out:
        out_map = pl.BlockSpec((tm, block), lambda i, s, od: (i, _k(s)))
    else:
        out_map = pl.BlockSpec((tm, block), lambda i, s, od: (i, od[_k(s)]))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tn),
                             lambda i, s, od: (i, _flat_inner(s, kb, nj))),
                pl.BlockSpec((block, tn),
                             lambda i, s, od: (od[_k(s)],
                                               _flat_inner(s, kb, nj))),
            ],
            out_specs=out_map,
            scratch_shapes=[pltpu.VMEM((tm, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, nslots * block), dy.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(order, dy, w)


# ---------------------------------------------------------------------------
# backward dW: dW[K, N] = x[:, kept]^T @ dy at kept row-blocks, zeros else
# ---------------------------------------------------------------------------


def _dw_kernel(order_ref, x_ref, dy_ref, o_ref, acc_ref,
               *, nm: int, kb: int):
    s = pl.program_id(1)
    compute = s < kb * nm
    m = _flat_inner(s, kb, nm)

    @pl.when(jnp.logical_and(compute, m == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(compute)
    def _mm():
        # x tile [tm, block] × dy tile [tm, tn] contracted over M → [block, tn]
        acc_ref[...] += lax.dot_general(
            x_ref[...], dy_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(compute, m == nm - 1))
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    @pl.when(jnp.logical_not(compute))
    def _prune():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("kb", "block", "tm", "tn",
                                             "x_compact", "interpret"))
def pruned_matmul_dw_2d(x: jax.Array, dy: jax.Array, order: jax.Array,
                        *, kb: int, block: int = 128, tm: int = 256,
                        tn: int = 256, x_compact: bool = False,
                        interpret: bool = True) -> jax.Array:
    """dW [K, N] of the pruned matmul: kept row-block order[k] (k < kb)
    receives x[:, order[k]]^T @ dy; pruned slots emit zero tiles in-kernel.

    x_compact=True: x is the compact resized activation [M, kb·block] (the
    fused-FFN hidden); kept slot k streams its k-th compact block instead
    of gathering through order.
    """
    M, N = dy.shape
    M2, Kx = x.shape
    nb = order.shape[0]
    if M != M2 or M % tm or N % tn or Kx % block:
        raise ValueError(
            f"pruned_matmul_dw_2d: x {x.shape} / dy {dy.shape} with "
            f"block={block}, tm={tm}, tn={tn} — M must match and M/N/K must "
            "be tile multiples (ops.py pads before calling)")
    nm = M // tm
    kernel = functools.partial(_dw_kernel, nm=nm, kb=kb)

    def _k(s):
        return _flat_k(s, kb, nm)

    def _m(s):
        return _flat_inner(s, kb, nm)

    if x_compact:
        x_map = pl.BlockSpec(
            (tm, block), lambda j, s, od: (_m(s), jnp.minimum(_k(s), kb - 1)))
    else:
        x_map = pl.BlockSpec((tm, block), lambda j, s, od: (_m(s), od[_k(s)]))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            # flattened block×contraction grid: kb·nm compute steps plus one
            # zero-write step per pruned slot
            grid=(N // tn, kb * nm + (nb - kb)),
            in_specs=[
                x_map,
                pl.BlockSpec((tm, tn), lambda j, s, od: (_m(s), j)),
            ],
            out_specs=pl.BlockSpec((block, tn),
                                   lambda j, s, od: (od[_k(s)], j)),
            scratch_shapes=[pltpu.VMEM((block, tn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((nb * block, N), dy.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(order, x, dy)


# ---------------------------------------------------------------------------
# out-pruned forward: yc[M, kb·block] = x @ w[:, keep-blocks] (compact)
# ---------------------------------------------------------------------------


def _op_kernel(idx_ref, x_ref, w_ref, o_ref, acc_ref, *, nt: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(t == nt - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tm", "tk", "interpret"))
def outpruned_matmul_2d(x: jax.Array, w: jax.Array, keep_idx: jax.Array,
                        *, block: int = 128, tm: int = 256, tk: int = 128,
                        interpret: bool = True) -> jax.Array:
    """Compact out-pruned matmul: yc[:, k-th block] = x @ w[:, keep_idx[k]].
    Full contraction over K (tiled by tk); the gather of kept W column
    blocks rides the index map — no gathered W copy."""
    M, K = x.shape
    K2, H = w.shape
    if K != K2 or M % tm or K % tk or H % block:
        raise ValueError(
            f"outpruned_matmul_2d: x {x.shape} @ w {w.shape} with "
            f"block={block}, tm={tm}, tk={tk} — K must match and M/K/H must "
            "be tile multiples (ops.py pads before calling)")
    kb = keep_idx.shape[0]
    nt = K // tk
    kernel = functools.partial(_op_kernel, nt=nt)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // tm, kb, nt),
            in_specs=[
                pl.BlockSpec((tm, tk), lambda i, k, t, idx: (i, t)),
                pl.BlockSpec((tk, block), lambda i, k, t, idx: (t, idx[k])),
            ],
            out_specs=pl.BlockSpec((tm, block), lambda i, k, t, idx: (i, k)),
            scratch_shapes=[pltpu.VMEM((tm, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, kb * block), x.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary", "arbitrary"),
    )(keep_idx, x, w)


# ---------------------------------------------------------------------------
# out-pruned backward dx: dx[M, K] = dyc @ w[:, keep]^T (dense output —
# every K position receives contributions from the compact blocks)
# ---------------------------------------------------------------------------


def _op_dx_kernel(idx_ref, dyc_ref, w_ref, o_ref, acc_ref, *, kb: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # dyc tile [tm, block] × w tile [tk, block] contracted over block → [tm, tk]
    acc_ref[...] += lax.dot_general(
        dyc_ref[...], w_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == kb - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "tm", "tk", "interpret"))
def outpruned_matmul_dx_2d(dyc: jax.Array, w: jax.Array, keep_idx: jax.Array,
                           *, block: int = 128, tm: int = 256, tk: int = 128,
                           interpret: bool = True) -> jax.Array:
    """dx of the out-pruned matmul: dyc [M, kb·block] @ w[:, keep]^T →
    [M, K]. The contraction runs over the compact kept blocks only."""
    M, Kc = dyc.shape
    K, H = w.shape
    if Kc % block or M % tm or K % tk or H % block:
        raise ValueError(
            f"outpruned_matmul_dx_2d: dyc {dyc.shape} / w {w.shape} with "
            f"block={block}, tm={tm}, tk={tk} — dims must be tile multiples "
            "(ops.py pads before calling)")
    kb = Kc // block
    kernel = functools.partial(_op_dx_kernel, kb=kb)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // tm, K // tk, kb),
            in_specs=[
                pl.BlockSpec((tm, block), lambda i, t, k, idx: (i, k)),
                pl.BlockSpec((tk, block), lambda i, t, k, idx: (t, idx[k])),
            ],
            out_specs=pl.BlockSpec((tm, tk), lambda i, t, k, idx: (i, t)),
            scratch_shapes=[pltpu.VMEM((tm, tk), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, K), dyc.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "parallel", "arbitrary"),
    )(keep_idx, dyc, w)


# ---------------------------------------------------------------------------
# out-pruned backward dW: dW[K, H]; kept col-block order[k] = x^T @ dyc[:, k]
# ---------------------------------------------------------------------------


def _op_dw_kernel(order_ref, x_ref, dyc_ref, o_ref, acc_ref,
                  *, nm: int, kb: int):
    s = pl.program_id(1)
    compute = s < kb * nm
    m = _flat_inner(s, kb, nm)

    @pl.when(jnp.logical_and(compute, m == 0))
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(compute)
    def _mm():
        # x tile [tm, tk] × dyc tile [tm, block] contracted over M → [tk, block]
        acc_ref[...] += lax.dot_general(
            x_ref[...], dyc_ref[...], (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(compute, m == nm - 1))
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    @pl.when(jnp.logical_not(compute))
    def _prune():
        o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(jax.jit, static_argnames=("kb", "block", "tm", "tk",
                                             "interpret"))
def outpruned_matmul_dw_2d(x: jax.Array, dyc: jax.Array, order: jax.Array,
                           *, kb: int, block: int = 128, tm: int = 256,
                           tk: int = 128, interpret: bool = True) -> jax.Array:
    """dW [K, H] of the out-pruned matmul: kept col-block order[k] (k < kb)
    receives x^T @ dyc[:, k]; pruned slots emit zero tiles in-kernel."""
    M, K = x.shape
    M2, Kc = dyc.shape
    nb = order.shape[0]
    if M != M2 or M % tm or K % tk or Kc % block:
        raise ValueError(
            f"outpruned_matmul_dw_2d: x {x.shape} / dyc {dyc.shape} with "
            f"block={block}, tm={tm}, tk={tk} — M must match and dims must "
            "be tile multiples (ops.py pads before calling)")
    nm = M // tm
    kernel = functools.partial(_op_dw_kernel, nm=nm, kb=kb)

    def _k(s):
        return _flat_k(s, kb, nm)

    def _m(s):
        return _flat_inner(s, kb, nm)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            # flattened block×contraction grid (see _flat_k): pruned slots
            # cost one zero-write step, not a full M sweep
            grid=(K // tk, kb * nm + (nb - kb)),
            in_specs=[
                pl.BlockSpec((tm, tk), lambda t, s, od: (_m(s), t)),
                pl.BlockSpec(
                    (tm, block),
                    lambda t, s, od: (_m(s), jnp.minimum(_k(s), kb - 1))),
            ],
            out_specs=pl.BlockSpec((tk, block),
                                   lambda t, s, od: (t, od[_k(s)])),
            scratch_shapes=[pltpu.VMEM((tk, block), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((K, nb * block), dyc.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(order, x, dyc)


# ---------------------------------------------------------------------------
# fused pruned FFN: y = act(x @ Wup[:, keep] [, · gate]) @ Wdown[keep, :]
# ---------------------------------------------------------------------------


def _ffn_kernel(idx_ref, x_ref, wup_ref, wdown_ref, o_ref, acc_ref,
                *, act_fn, kb: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pre = jnp.dot(x_ref[...], wup_ref[...],
                  preferred_element_type=jnp.float32)
    h = act_fn(pre)
    # hidden tile h [tm, block] never leaves VMEM: immediately contracted
    # into the running [tm, d_out] accumulator (no HBM round-trip)
    acc_ref[...] += jnp.dot(h.astype(wdown_ref.dtype), wdown_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == kb - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _ffn_kernel_gated(idx_ref, x_ref, wup_ref, wgate_ref, wdown_ref, o_ref,
                      acc_ref, *, act_fn, kb: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pre = jnp.dot(x_ref[...], wup_ref[...],
                  preferred_element_type=jnp.float32)
    gate = jnp.dot(x_ref[...], wgate_ref[...],
                   preferred_element_type=jnp.float32)
    h = act_fn(gate) * pre
    acc_ref[...] += jnp.dot(h.astype(wdown_ref.dtype), wdown_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == kb - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("act_fn", "block", "tm",
                                             "interpret"))
def fused_ffn_2d(x: jax.Array, wup: jax.Array, wdown: jax.Array,
                 keep_idx: jax.Array, wgate: jax.Array = None, *, act_fn,
                 block: int = 128, tm: int = 256,
                 interpret: bool = True) -> jax.Array:
    """One-pallas_call pruned FFN forward.

    x [M, K]; wup/wgate [K, H]; wdown [H, d_out]; keep_idx [kb] kept
    H-block ids. Per grid step the kernel streams one kept (K × block)
    Wup (and Wgate) slice plus the matching (block × d_out) Wdown slice,
    computes the hidden tile in VMEM, applies the activation (· gate), and
    folds it straight into the f32 [tm, d_out] accumulator — the resized
    hidden activation never round-trips through HBM. VMEM budget:
    tm·K + (1|2)·K·block + block·d_out + 2·tm·d_out floats
    (see DESIGN_KERNELS.md).
    """
    M, K = x.shape
    H = wup.shape[1]
    H2, D2 = wdown.shape
    if wup.shape[0] != K or H != H2 or M % tm or H % block:
        raise ValueError(
            f"fused_ffn_2d: x {x.shape}, wup {wup.shape}, wdown "
            f"{wdown.shape} with block={block}, tm={tm} — contraction dims "
            "must match and M/H must be tile multiples (ops.py pads)")
    kb = keep_idx.shape[0]
    gated = wgate is not None
    x_spec = pl.BlockSpec((tm, K), lambda i, k, idx: (i, 0))
    w_spec = pl.BlockSpec((K, block), lambda i, k, idx: (0, idx[k]))
    down_spec = pl.BlockSpec((block, D2), lambda i, k, idx: (idx[k], 0))
    if gated:
        kernel = functools.partial(_ffn_kernel_gated, act_fn=act_fn, kb=kb)
        in_specs = [x_spec, w_spec, w_spec, down_spec]
        args = (keep_idx, x, wup, wgate, wdown)
    else:
        kernel = functools.partial(_ffn_kernel, act_fn=act_fn, kb=kb)
        in_specs = [x_spec, w_spec, down_spec]
        args = (keep_idx, x, wup, wdown)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(M // tm, kb),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((tm, D2), lambda i, k, idx: (i, 0)),
            scratch_shapes=[pltpu.VMEM((tm, D2), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((M, D2), x.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(*args)
