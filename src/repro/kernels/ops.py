"""jit'd public wrappers for the Pallas kernels.

``block_pruned_matmul`` handles arbitrary leading batch dims, pads M/N up
to tile multiples, and provides a custom VJP whose backward is ALSO
kernel-level: ``pruned_matmul_dx_2d`` / ``pruned_matmul_dw_2d`` write the
dX/dW tiles directly through inverse BlockSpec index maps and zero the
pruned blocks in-kernel — no full-size zeros+scatter temporaries and no
gathered ``wk``/``xk`` copies anywhere in the gradient path.

``fused_pruned_ffn`` is the whole controlled FFN pair
``y = act(x @ Wup[:, keep] [, · gate]) @ Wdown[keep, :]`` as ONE forward
pallas_call (the resized hidden activation never round-trips HBM), with a
custom VJP composed from the out-pruned kernel family plus an elementwise
activation VJP.

Interpret mode: auto-detected per backend (CPU containers interpret, real
TPUs compile) and overridable with ``REPRO_PALLAS_INTERPRET=0|1``.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import decode_attn as _dk
from repro.kernels import pruned_matmul as _pk
from repro.kernels import ref as _ref

# Tri-state: None = auto-detect (non-TPU backends interpret, TPU compiles),
# overridable via env REPRO_PALLAS_INTERPRET or by assigning True/False.
INTERPRET = None

_TRUTHY = ("1", "true", "yes", "on")
_FALSY = ("0", "false", "no", "off")

# cached env + backend resolution: every kernel wrapper consults
# interpret_mode() per call, and jax.default_backend() is not free —
# resolve once, invalidate explicitly via reset_interpret_cache()
_INTERPRET_CACHE = None


def interpret_mode() -> bool:
    """Resolve whether Pallas kernels run in interpret mode.

    Priority: module override (ops.INTERPRET = True/False) >
    REPRO_PALLAS_INTERPRET env var > backend auto-detection (anything
    but TPU interprets). The override is read live; the env + backend
    resolution is computed once and cached module-wide — call
    :func:`reset_interpret_cache` after mutating the env var or
    swapping the jax backend mid-process (tests do)."""
    if INTERPRET is not None:
        return bool(INTERPRET)
    global _INTERPRET_CACHE
    if _INTERPRET_CACHE is None:
        env = os.environ.get("REPRO_PALLAS_INTERPRET", "").strip().lower()
        if env in _TRUTHY:
            _INTERPRET_CACHE = True
        elif env in _FALSY:
            _INTERPRET_CACHE = False
        else:
            _INTERPRET_CACHE = jax.default_backend() != "tpu"
    return _INTERPRET_CACHE


def reset_interpret_cache() -> None:
    """Drop the cached env/backend interpret-mode resolution."""
    global _INTERPRET_CACHE
    _INTERPRET_CACHE = None


# ---------------------------------------------------------------------------
# shape utilities
# ---------------------------------------------------------------------------


def _pad_to(a: jax.Array, mult: int, axis: int) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _tile(dim: int, pref: int, align: int) -> int:
    """Static tile size: dim rounded up to `align`, capped at `pref` —
    avoids padding tiny benchmark shapes up to the full 256-wide tiles."""
    return min(pref, -(-dim // align) * align)


def _validate(K: int, w_rows: int, keep_idx: jax.Array, block: int,
              what: str) -> int:
    """Satellite guard: readable errors instead of a bare assert deep in
    the kernel (the old silent-truncation hazard). Returns num_blocks."""
    if block <= 0:
        raise ValueError(f"{what}: block size must be positive, got {block}")
    if K != w_rows:
        raise ValueError(
            f"{what}: contraction mismatch — x has K={K} but w has "
            f"{w_rows} rows")
    if K % block != 0:
        raise ValueError(
            f"{what}: contraction dim K={K} is not a multiple of the "
            f"pruning block size {block} (K would be silently truncated); "
            "choose a block via repro.core.workload.adapt_block_size")
    nb = K // block
    if keep_idx.ndim != 1:
        raise ValueError(
            f"{what}: keep_idx must be a 1-D block-id vector, got shape "
            f"{keep_idx.shape}")
    kb = keep_idx.shape[0]
    if kb < 1 or kb > nb:
        raise ValueError(
            f"{what}: keep_idx has {kb} entries but K={K} / block={block} "
            f"gives only {nb} blocks (need 1 <= kept <= {nb})")
    if not jnp.issubdtype(keep_idx.dtype, jnp.integer):
        raise ValueError(
            f"{what}: keep_idx must be integer block ids, got "
            f"{keep_idx.dtype}")
    return nb


def _inverse_order(keep_idx: jax.Array, nb: int) -> jax.Array:
    """[nb] permutation concat(keep_idx, pruned ids) for the backward
    kernels' inverse index maps. The keep prefix is keep_idx ITSELF (in
    caller order, sorted or not): compact slot k must map to block
    keep_idx[k], or the x_compact/compact_out kernels would pair hidden
    blocks with the wrong weight-gradient tiles. Built scatter-free
    (mask + stable argsort) so the gradient path stays free of
    scatter/gather HLO."""
    keep_idx = keep_idx.astype(jnp.int32)
    ids = jnp.arange(nb, dtype=jnp.int32)
    is_kept = jnp.any(ids[:, None] == keep_idx[None, :], axis=1)
    pruned = jnp.argsort(is_kept.astype(jnp.int32),
                         stable=True)[: nb - keep_idx.shape[0]]
    return jnp.concatenate([keep_idx, pruned.astype(jnp.int32)])


# ---------------------------------------------------------------------------
# block-pruned matmul (contraction pruning) with kernel-level VJP
# ---------------------------------------------------------------------------


def _run_fwd(x2d, w, keep_idx, block, tm, tn):
    M, N = x2d.shape[0], w.shape[1]
    _validate(x2d.shape[1], w.shape[0], keep_idx, block,
              "block_pruned_matmul")
    tm_e, tn_e = _tile(M, tm, 8), _tile(N, tn, 128)
    xp = _pad_to(x2d, tm_e, 0)
    wp = _pad_to(w, tn_e, 1)
    y = _pk.block_pruned_matmul_2d(
        xp, wp, keep_idx, block=block, tm=tm_e, tn=tn_e,
        interpret=interpret_mode())
    return y[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def block_pruned_matmul(x, w, keep_idx, block: int = 128,
                        tm: int = 256, tn: int = 256):
    """y = x[..., keep] @ w[keep, :] via the Pallas kernel.

    x: [..., K]; w: [K, N]; keep_idx: [kb] int32 sorted block ids.
    """
    *lead, K = x.shape
    x2d = x.reshape(-1, K)
    y = _run_fwd(x2d, w, keep_idx, block, tm, tn)
    return y.reshape(*lead, w.shape[1])


def _fwd(x, w, keep_idx, block, tm, tn):
    y = block_pruned_matmul(x, w, keep_idx, block, tm, tn)
    return y, (x, w, keep_idx)


def _bwd(block, tm, tn, res, dy):
    x, w, keep_idx = res
    *lead, K = x.shape
    N = w.shape[1]
    nb = K // block
    kb = keep_idx.shape[0]
    x2d = x.reshape(-1, K)
    dy2d = dy.reshape(-1, N)
    M = x2d.shape[0]
    order = _inverse_order(keep_idx, nb)
    interp = interpret_mode()

    tm_e, tn_e = _tile(M, tm, 8), _tile(N, tn, 128)
    dyp = _pad_to(_pad_to(dy2d, tm_e, 0), tn_e, 1)
    wp = _pad_to(w, tn_e, 1)
    # dX: dy @ w[kept]^T written straight to the kept column-blocks, pruned
    # blocks zeroed in-kernel (inverse index map — no zeros+scatter)
    dx = _pk.pruned_matmul_dx_2d(
        dyp, wp, order, kb=kb, block=block, tm=tm_e, tn=tn_e,
        interpret=interp)[:M]
    dx = dx.reshape(*lead, K).astype(x.dtype)
    # dW: x[:, kept]^T @ dy at kept row-blocks, pruned rows zeroed in-kernel
    xp = _pad_to(x2d, tm_e, 0)
    dw = _pk.pruned_matmul_dw_2d(
        xp, dyp, order, kb=kb, block=block, tm=tm_e, tn=tn_e,
        interpret=interp)[:, :N].astype(w.dtype)
    return dx, dw, None


block_pruned_matmul.defvjp(_fwd, _bwd)


# ---------------------------------------------------------------------------
# fused pruned FFN pair with kernel-level VJP
# ---------------------------------------------------------------------------


def _ffn_fwd_2d(x2d, w_up, w_down, w_gate, keep_idx, act_fn, block, tm):
    M = x2d.shape[0]
    D2 = w_down.shape[1]
    tm_e = _tile(M, tm, 8)
    xp = _pad_to(x2d, tm_e, 0)
    y = _pk.fused_ffn_2d(xp, w_up, w_down, keep_idx, w_gate, act_fn=act_fn,
                         block=block, tm=tm_e, interpret=interpret_mode())
    return y[:M, :D2]


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def fused_pruned_ffn(x, w_up, w_down, keep_idx, w_gate=None, act_fn=None,
                     block: int = 128, tm: int = 256):
    """Controlled FFN pair y = act(x @ Wup[:, keep] [, · gate]) @
    Wdown[keep, :] as ONE forward pallas_call.

    x: [..., K]; w_up/w_gate: [K, H]; w_down: [H, d_out]; keep_idx: [kb]
    int32 kept H-block ids. The resized hidden activation exists only as a
    VMEM tile (never written to HBM); the backward recomputes it compactly
    through the out-pruned kernel family.
    """
    *lead, K = x.shape
    _validate(w_up.shape[1], w_down.shape[0], keep_idx, block,
              "fused_pruned_ffn")
    x2d = x.reshape(-1, K)
    y = _ffn_fwd_2d(x2d, w_up, w_down, w_gate, keep_idx, act_fn, block, tm)
    return y.reshape(*lead, w_down.shape[1])


def _ffn_fwd(x, w_up, w_down, keep_idx, w_gate, act_fn, block, tm):
    y = fused_pruned_ffn(x, w_up, w_down, keep_idx, w_gate, act_fn, block, tm)
    return y, (x, w_up, w_down, w_gate, keep_idx)


def _ffn_bwd(act_fn, block, tm, res, dy):
    x, w_up, w_down, w_gate, keep_idx = res
    *lead, K = x.shape
    H = w_up.shape[1]
    D2 = w_down.shape[1]
    nb = H // block
    kb = keep_idx.shape[0]
    x2d = x.reshape(-1, K)
    dy2d = dy.reshape(-1, D2)
    M = x2d.shape[0]
    order = _inverse_order(keep_idx, nb)
    interp = interpret_mode()

    tm_e = _tile(M, tm, 8)
    tk_e = _tile(K, 128, 128)
    tn_e = _tile(D2, 256, 128)
    xp = _pad_to(_pad_to(x2d, tm_e, 0), tk_e, 1)
    wup_p = _pad_to(w_up, tk_e, 0)
    wgate_p = _pad_to(w_gate, tk_e, 0) if w_gate is not None else None
    dyp = _pad_to(_pad_to(dy2d, tm_e, 0), tn_e, 1)
    wdown_p = _pad_to(w_down, tn_e, 1)
    Mp = xp.shape[0]

    # compact recompute of the resized hidden pre-activations (out-pruned
    # kernel: the kept Wup columns stream through the index map)
    pre_up = _pk.outpruned_matmul_2d(
        xp, wup_p, keep_idx, block=block, tm=tm_e, tk=tk_e, interpret=interp)
    if w_gate is not None:
        pre_g = _pk.outpruned_matmul_2d(
            xp, wgate_p, keep_idx, block=block, tm=tm_e, tk=tk_e,
            interpret=interp)

        def _comb(pu, pg):
            return act_fn(pg) * pu

        h, act_vjp = jax.vjp(_comb, pre_up, pre_g)
    else:
        h, act_vjp = jax.vjp(act_fn, pre_up)

    # dWdown: compact h^T @ dy at kept rows, pruned rows zeroed in-kernel
    dw_down = _pk.pruned_matmul_dw_2d(
        h.astype(dyp.dtype), dyp, order, kb=kb, block=block, tm=tm_e,
        tn=tn_e, x_compact=True, interpret=interp)[:, :D2].astype(w_down.dtype)

    # dh (compact): dy @ Wdown[kept]^T — grid covers only kept slots
    dh = _pk.pruned_matmul_dx_2d(
        dyp, wdown_p, keep_idx.astype(jnp.int32), kb=kb, block=block,
        tm=tm_e, tn=tn_e, compact_out=True, interpret=interp)
    dpre = act_vjp(dh.astype(h.dtype))
    if w_gate is not None:
        dpre_up, dpre_g = dpre
    else:
        (dpre_up,) = dpre

    # dWup (and dWgate): x^T @ dpre at kept col-blocks, pruned cols zeroed
    dpre_up = dpre_up.astype(xp.dtype)
    dw_up = _pk.outpruned_matmul_dw_2d(
        xp, dpre_up, order, kb=kb, block=block, tm=tm_e, tk=tk_e,
        interpret=interp)[:K].astype(w_up.dtype)

    # dx: dpre @ Wup[:, kept]^T (dense — all K positions receive grads)
    dx2d = _pk.outpruned_matmul_dx_2d(
        dpre_up, wup_p, keep_idx, block=block, tm=tm_e, tk=tk_e,
        interpret=interp)
    if w_gate is not None:
        dpre_g = dpre_g.astype(xp.dtype)
        dw_gate = _pk.outpruned_matmul_dw_2d(
            xp, dpre_g, order, kb=kb, block=block, tm=tm_e, tk=tk_e,
            interpret=interp)[:K].astype(w_gate.dtype)
        dx2d = dx2d + _pk.outpruned_matmul_dx_2d(
            dpre_g, wgate_p, keep_idx, block=block, tm=tm_e, tk=tk_e,
            interpret=interp)
    else:
        dw_gate = None
    dx = dx2d[:M, :K].reshape(*lead, K).astype(x.dtype)
    return dx, dw_up, dw_down, None, dw_gate


fused_pruned_ffn.defvjp(_ffn_fwd, _ffn_bwd)

# re-export the oracle for convenience
block_pruned_matmul_ref = _ref.block_pruned_matmul_ref


# ---------------------------------------------------------------------------
# fused decode attention (inference-only: no VJP is defined — taking a
# gradient through these raises at trace time, which is the contract)
# ---------------------------------------------------------------------------


def _check_decode_attn(q, k_cache, v_cache, cur_pos):
    B, Hq, S1, _ = q.shape
    Hkv = k_cache.shape[1]
    if S1 != 1:
        raise ValueError(
            f"fused_decode_attention: q {q.shape} must carry exactly one "
            "query token (decode step), got seq len "
            f"{S1}")
    if Hq % Hkv != 0:
        raise ValueError(
            f"fused_decode_attention: Hq={Hq} is not a multiple of "
            f"Hkv={Hkv} (GQA groups must divide evenly)")
    if k_cache.shape[0] != B or v_cache.shape[:3] != k_cache.shape[:3]:
        raise ValueError(
            f"fused_decode_attention: cache shapes k {k_cache.shape} / "
            f"v {v_cache.shape} do not match q batch {B}")
    if cur_pos.shape != (B,):
        raise ValueError(
            f"fused_decode_attention: cur_pos {cur_pos.shape} must be "
            f"[{B}] (one ragged position per slot)")


def _decode_attn_padded(q, k_cache, v_cache, cur_pos):
    """Common GQA padding: (qg [B,Hkv,G',D'], k, v, G, Dv, scale)."""
    B, Hq, _, D = q.shape
    Hkv = k_cache.shape[1]
    Dv = v_cache.shape[3]
    G = Hq // Hkv
    scale = float(1.0 / (D ** 0.5))      # ORIGINAL head dim, pre-padding
    qg = q.reshape(B, Hkv, G, D)
    qg = _pad_to(_pad_to(qg, 8, 2), 128, 3)
    k = _pad_to(_pad_to(k_cache, _dk.TILE_S, 2), 128, 3)
    v = _pad_to(_pad_to(v_cache, _dk.TILE_S, 2), 128, 3)
    return qg, k, v, G, Dv, scale


def fused_decode_attention(q, k_cache, v_cache, *, cur_pos,
                           window: int = 0):
    """Fused GQA decode attention (single pallas_call, online softmax).

    Same contract as ``layers.attention.decode_attention``:
    q [B, Hq, 1, D]; caches [B, Hkv, S, D]/[B, Hkv, S, Dv]; cur_pos [B]
    int32 — attends cache positions p <= cur_pos[b] (windowed if set).
    Returns [B, Hq, 1, Dv] in q.dtype. Inference-only (no VJP).
    """
    _check_decode_attn(q, k_cache, v_cache, cur_pos)
    B, Hq = q.shape[0], q.shape[1]
    Hkv = k_cache.shape[1]
    qg, k, v, G, Dv, scale = _decode_attn_padded(q, k_cache, v_cache,
                                                 cur_pos)
    out = _dk.gqa_decode_attn_2d(
        cur_pos.astype(jnp.int32), qg, k, v, scale=scale,
        window=int(window), interpret=interpret_mode())
    return out[:, :, :G, :Dv].reshape(B, Hq, 1, Dv).astype(q.dtype)


def unfused_decode_attention(q, k_cache, v_cache, *, cur_pos,
                             window: int = 0):
    """The matched-layer UNFUSED baseline: three pallas_calls with the
    [B, Hkv, G, S] score matrix round-tripping HBM. Benchmark baseline
    only (kernel_bench's decode_attn leg) — the serve path uses either
    the fused kernel or the native-XLA oracle."""
    _check_decode_attn(q, k_cache, v_cache, cur_pos)
    B, Hq = q.shape[0], q.shape[1]
    qg, k, v, G, Dv, scale = _decode_attn_padded(q, k_cache, v_cache,
                                                 cur_pos)
    out = _dk.unfused_gqa_decode_attn_2d(
        cur_pos.astype(jnp.int32), qg, k, v, scale=scale,
        window=int(window), interpret=interpret_mode())
    return out[:, :, :G, :Dv].reshape(B, Hq, 1, Dv).astype(q.dtype)


def fused_mla_decode_attention(q_nope_abs, q_rope, latent_cache,
                               rope_cache, *, cur_pos,
                               head_dim_for_scale: int):
    """Fused absorbed-MLA decode attention against the compressed latent.

    Same contract as ``layers.attention.mla_decode_attention``:
    q_nope_abs [B, H, R]; q_rope [B, H, Dr]; latent_cache [B, S, R];
    rope_cache [B, S, Dr]; returns f32 [B, H, R]. Inference-only.
    """
    B, H, R = q_nope_abs.shape
    Dr = q_rope.shape[2]
    if q_rope.shape[:2] != (B, H):
        raise ValueError(
            f"fused_mla_decode_attention: q_rope {q_rope.shape} must "
            f"lead with [B={B}, H={H}]")
    if latent_cache.shape[0] != B or rope_cache.shape[:2] != \
            latent_cache.shape[:2]:
        raise ValueError(
            f"fused_mla_decode_attention: caches latent "
            f"{latent_cache.shape} / rope {rope_cache.shape} do not "
            f"match batch {B}")
    if cur_pos.shape != (B,):
        raise ValueError(
            f"fused_mla_decode_attention: cur_pos {cur_pos.shape} must "
            f"be [{B}]")
    scale = float(1.0 / (head_dim_for_scale ** 0.5))
    qa = _pad_to(_pad_to(q_nope_abs, 8, 1), 128, 2)
    qr = _pad_to(_pad_to(q_rope, 8, 1), 128, 2)
    lat = _pad_to(_pad_to(latent_cache, _dk.TILE_S, 1), 128, 2)
    rope = _pad_to(_pad_to(rope_cache, _dk.TILE_S, 1), 128, 2)
    out = _dk.mla_decode_attn_2d(
        cur_pos.astype(jnp.int32), qa, qr, lat, rope, scale=scale,
        interpret=interpret_mode())
    return out[:, :H, :R]


def fused_paged_decode_attention(q, k_pool, v_pool, *, pages, cur_pos,
                                 window: int = 0):
    """Fused GQA decode attention over the block-paged KV pool.

    q [B, Hq, 1, D]; pools [num_pages, Hkv, page_size, D] /
    [num_pages, Hkv, page_size, Dv]; pages int32 [B, pages_per_slot]
    (-1 = unallocated); cur_pos [B]. Same ragged-position contract as
    ``fused_decode_attention`` — the page table rides scalar prefetch,
    so unallocated pages are never streamed. Returns [B, Hq, 1, Dv].
    """
    B, Hq, S1, D = q.shape
    Hkv, ps = k_pool.shape[1], k_pool.shape[2]
    if S1 != 1:
        raise ValueError(
            f"fused_paged_decode_attention: q {q.shape} must carry "
            "exactly one query token")
    if Hq % Hkv != 0:
        raise ValueError(
            f"fused_paged_decode_attention: Hq={Hq} not a multiple of "
            f"Hkv={Hkv}")
    if ps % 8 != 0:
        raise ValueError(
            f"fused_paged_decode_attention: page_size={ps} must be a "
            "multiple of 8 (f32 sublane tiling) — use the oracle path "
            "or pick a multiple-of-8 --page-size")
    if pages.shape[0] != B or cur_pos.shape != (B,):
        raise ValueError(
            f"fused_paged_decode_attention: pages {pages.shape} / "
            f"cur_pos {cur_pos.shape} do not match q batch {B}")
    Dv = v_pool.shape[3]
    G = Hq // Hkv
    scale = float(1.0 / (D ** 0.5))
    qg = _pad_to(_pad_to(q.reshape(B, Hkv, G, D), 8, 2), 128, 3)
    k = _pad_to(k_pool, 128, 3)
    v = _pad_to(v_pool, 128, 3)
    out = _dk.gqa_paged_decode_attn_2d(
        cur_pos.astype(jnp.int32), pages.astype(jnp.int32), qg, k, v,
        scale=scale, window=int(window), interpret=interpret_mode())
    return out[:, :, :G, :Dv].reshape(B, Hq, 1, Dv).astype(q.dtype)


def fused_paged_mla_decode_attention(q_nope_abs, q_rope, latent_pool,
                                     rope_pool, *, pages, cur_pos,
                                     head_dim_for_scale: int):
    """Fused absorbed-MLA decode attention over the paged latent pool.

    q_nope_abs [B, H, R]; q_rope [B, H, Dr]; pools
    [num_pages, page_size, R] / [num_pages, page_size, Dr]; pages
    [B, pages_per_slot]; returns f32 [B, H, R]. Inference-only.
    """
    B, H, R = q_nope_abs.shape
    ps = latent_pool.shape[1]
    if q_rope.shape[:2] != (B, H):
        raise ValueError(
            f"fused_paged_mla_decode_attention: q_rope {q_rope.shape} "
            f"must lead with [B={B}, H={H}]")
    if ps % 8 != 0:
        raise ValueError(
            f"fused_paged_mla_decode_attention: page_size={ps} must be "
            "a multiple of 8 — use the oracle path or a multiple-of-8 "
            "--page-size")
    if pages.shape[0] != B or cur_pos.shape != (B,):
        raise ValueError(
            f"fused_paged_mla_decode_attention: pages {pages.shape} / "
            f"cur_pos {cur_pos.shape} do not match batch {B}")
    scale = float(1.0 / (head_dim_for_scale ** 0.5))
    qa = _pad_to(_pad_to(q_nope_abs, 8, 1), 128, 2)
    qr = _pad_to(_pad_to(q_rope, 8, 1), 128, 2)
    lat = _pad_to(latent_pool, 128, 2)
    rope = _pad_to(rope_pool, 128, 2)
    out = _dk.mla_paged_decode_attn_2d(
        cur_pos.astype(jnp.int32), pages.astype(jnp.int32), qa, qr,
        lat, rope, scale=scale, interpret=interpret_mode())
    return out[:, :H, :R]
