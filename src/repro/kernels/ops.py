"""jit'd public wrappers for the Pallas kernels.

`block_pruned_matmul` handles arbitrary leading batch dims, pads M/N up to
tile multiples, and provides a custom VJP: the forward runs the Pallas
kernel; the backward is the gather/scatter XLA path (zero-imputing, same
lineage) — dW/dX of the pruned matmul are themselves gather-matmuls and
reuse the same kernel when shapes allow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pruned_matmul as _pk
from repro.kernels import ref as _ref

# This container is CPU-only; flip to False on real TPUs.
INTERPRET = True


def _pad_to(a: jax.Array, mult: int, axis: int) -> jax.Array:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _run_kernel(x2d, w, keep_idx, block, tm, tn):
    M, N = x2d.shape[0], w.shape[1]
    xp = _pad_to(x2d, tm, 0)
    wp = _pad_to(w, tn, 1)
    y = _pk.block_pruned_matmul_2d(
        xp, wp, keep_idx, block=block, tm=tm, tn=tn, interpret=INTERPRET)
    return y[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def block_pruned_matmul(x, w, keep_idx, block: int = 128,
                        tm: int = 256, tn: int = 256):
    """y = x[..., keep] @ w[keep, :] via the Pallas kernel.

    x: [..., K]; w: [K, N]; keep_idx: [kb] int32 sorted block ids.
    """
    *lead, K = x.shape
    x2d = x.reshape(-1, K)
    y = _run_kernel(x2d, w, keep_idx, block, tm, tn)
    return y.reshape(*lead, w.shape[1])


def _fwd(x, w, keep_idx, block, tm, tn):
    y = block_pruned_matmul(x, w, keep_idx, block, tm, tn)
    return y, (x, w, keep_idx)


def _bwd(block, tm, tn, res, dy):
    x, w, keep_idx = res
    *lead, K = x.shape
    nb = K // block
    x2d = x.reshape(-1, K)
    dy2d = dy.reshape(-1, w.shape[1])
    # dX: dy @ wk^T, scattered back to kept column-blocks (zeros elsewhere)
    wk = jnp.take(w.reshape(nb, block, -1), keep_idx, axis=0).reshape(-1, w.shape[1])
    dxk = dy2d @ wk.T                                   # [M, kb*block]
    dx = jnp.zeros((x2d.shape[0], nb, block), x.dtype)
    dx = dx.at[:, keep_idx, :].set(dxk.reshape(x2d.shape[0], -1, block))
    dx = dx.reshape(*lead, K)
    # dW: xk^T @ dy, scattered to kept row-blocks (zero imputation + lineage)
    xk = jnp.take(x2d.reshape(-1, nb, block), keep_idx, axis=1)
    dwk = jnp.einsum("mkb,mn->kbn", xk, dy2d)
    dw = jnp.zeros((nb, block, w.shape[1]), w.dtype)
    dw = dw.at[keep_idx].set(dwk.astype(w.dtype)).reshape(K, w.shape[1])
    return dx, dw, None


block_pruned_matmul.defvjp(_fwd, _bwd)

# re-export the oracle for convenience
block_pruned_matmul_ref = _ref.block_pruned_matmul_ref
