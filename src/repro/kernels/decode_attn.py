"""Fused decode-attention Pallas TPU kernels (ISSUE 7 tentpole).

One-token GQA decode attention over the slot KV cache with RAGGED
per-slot positions: slot b attends cache rows ``pos <= cur_pos[b]``
(optionally windowed). The reference path
(``layers/attention.py:decode_attention``) materializes the full
``[num_slots, Hkv, G, max_len]`` score tensor in HBM, round-trips it
through softmax, and reads every cache row regardless of how full the
slot actually is. The fused kernel here is ONE ``pallas_call``:

* the KV cache is streamed in ``(ts=128, D)`` tiles along ``max_len``;
* ``cur_pos`` is scalar-prefetched (SMEM) and drives BOTH the in-kernel
  position mask (``broadcasted_iota`` — TPU has no 1-D iota) and a
  ``pl.when`` tile skip, so fully-out-of-range tiles of a mostly-empty
  slot are never multiplied;
* softmax is the online (m, l, acc) recurrence in f32 VMEM scratch —
  the score matrix never exists in HBM;
* the output tile is written once, on the last ``max_len`` tile.

``mla_decode_attn_2d`` covers the absorbed-MLA decode path
(``mla_decode_attention``): scores against the compressed latent cache
(nope·latent + rope·rope), weighted sum back over the latents.

The three-kernel UNFUSED pipeline at the bottom (scores → softmax →
weighted-sum, score matrix round-tripping HBM between calls) is the
matched-execution-layer baseline for ``benchmarks/kernel_bench.py`` —
comparing a fused pallas kernel against native XLA would measure the
interpreter gap on CPU, not the algorithm (see DESIGN_KERNELS.md §7).

Inference-only contract: none of these kernels define a VJP —
differentiating through them raises. Decode is the serve hot path; the
train/prefill path keeps the chunked flash oracle (which is
differentiable).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
TILE_S = 128          # cache-row tile: MXU lane width

# jax renamed TPUCompilerParams -> CompilerParams; support both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _params(*semantics):
    return _CompilerParams(dimension_semantics=semantics)


def _tile_valid(base, cur, *, ts: int, window: int):
    """Does cache tile [base, base+ts) intersect (cur-window, cur]?

    Skipping must be exact: an all-masked tile that still runs would
    feed exp(NEG_INF - NEG_INF) = 1 into the online-softmax state."""
    valid = base <= cur
    if window > 0:
        valid = jnp.logical_and(valid, base + ts - 1 > cur - window)
    return valid


# ---------------------------------------------------------------------------
# fused GQA decode attention
# ---------------------------------------------------------------------------


def _gqa_kernel(cur_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale: float, window: int, ts: int, ns: int, hkv: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[pl.program_id(0)]
    base = s * ts

    @pl.when(_tile_valid(base, cur, ts=ts, window=window))
    def _tile():
        G = q_ref.shape[2]
        # all KV heads of this slot share the tile loop: per-head dots
        # (hkv is static — the loop unrolls), one stacked [Hkv*G, ts]
        # online-softmax update
        scores = jnp.concatenate(
            [jax.lax.dot_general(
                q_ref[0, h], k_ref[0, h], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
             for h in range(hkv)], axis=0) * scale        # [Hkv*G, ts]
        R = scores.shape[0]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (R, ts), 1)
        ok = pos <= cur
        if window > 0:
            ok = jnp.logical_and(ok, pos > cur - window)
        scores = jnp.where(ok, scores, NEG_INF)

        m_prev = m_ref[...]                            # [Hkv*G, ts] replicated
        m_new = jnp.maximum(m_prev,
                            jnp.max(scores, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * corr \
            + jnp.sum(p, axis=1, keepdims=True)
        pv = jnp.concatenate(
            [jax.lax.dot_general(
                p[h * G:(h + 1) * G], v_ref[0, h].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
             for h in range(hkv)], axis=0)               # [Hkv*G, Dv]
        acc_ref[...] = acc_ref[...] * corr[:, 0:1] + pv
        m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _emit():
        G, Dv = q_ref.shape[2], acc_ref.shape[1]
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(
            o_ref.dtype).reshape(q_ref.shape[1], G, Dv)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "interpret"))
def gqa_decode_attn_2d(cur_pos: jax.Array, q: jax.Array, k: jax.Array,
                       v: jax.Array, *, scale: float, window: int = 0,
                       interpret: bool = True) -> jax.Array:
    """q [B, Hkv, G, D]; k [B, Hkv, S, D]; v [B, Hkv, S, Dv];
    cur_pos int32 [B]. Returns [B, Hkv, G, Dv] in q.dtype. S % 128 == 0,
    G % 8 == 0, D/Dv % 128 == 0 required (ops.py pads).

    The grid is (B, ns): every KV head of a slot is processed in the
    SAME grid step (part of the fusion — one pass over the slot's tile
    sequence instead of Hkv passes, q/scratch stay resident)."""
    B, Hkv, G, D = q.shape
    S, Dv = k.shape[2], v.shape[3]
    ts = TILE_S
    if S % ts or G % 8 or D % 128 or Dv % 128:
        raise ValueError(
            f"gqa_decode_attn_2d: q {q.shape}, k {k.shape}, v {v.shape} — "
            f"need S % {ts} == 0, G % 8 == 0, D/Dv % 128 == 0 "
            "(ops.py pads before calling)")
    ns = S // ts
    kernel = functools.partial(_gqa_kernel, scale=scale, window=window,
                               ts=ts, ns=ns, hkv=Hkv)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, ns),
            in_specs=[
                pl.BlockSpec((1, Hkv, G, D), lambda b, s, cur: (b, 0, 0, 0)),
                pl.BlockSpec((1, Hkv, ts, D), lambda b, s, cur: (b, 0, s, 0)),
                pl.BlockSpec((1, Hkv, ts, Dv), lambda b, s, cur: (b, 0, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, Hkv, G, Dv),
                                   lambda b, s, cur: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hkv * G, ts), jnp.float32),   # running max m
                pltpu.VMEM((Hkv * G, ts), jnp.float32),   # running sum l
                pltpu.VMEM((Hkv * G, Dv), jnp.float32),   # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(cur_pos, q, k, v)


# ---------------------------------------------------------------------------
# fused MLA decode attention (absorbed form, compressed latent cache)
# ---------------------------------------------------------------------------


def _mla_kernel(cur_ref, qa_ref, qr_ref, lat_ref, rope_ref, o_ref,
                m_ref, l_ref, acc_ref, *, scale: float, ts: int, ns: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[pl.program_id(0)]
    base = s * ts

    @pl.when(_tile_valid(base, cur, ts=ts, window=0))
    def _tile():
        qa = qa_ref[0]                                    # [H, R]
        qr = qr_ref[0]                                    # [H, Dr]
        lat = lat_ref[0]                                  # [ts, R]
        rope = rope_ref[0]                                # [ts, Dr]
        scores = (jax.lax.dot_general(
            qa, lat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
            + jax.lax.dot_general(
                qr, rope, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)) * scale    # [H, ts]
        H = scores.shape[0]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (H, ts), 1)
        scores = jnp.where(pos <= cur, scores, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev,
                            jnp.max(scores, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * corr \
            + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr[:, 0:1] + jax.lax.dot_general(
            p, lat.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = acc_ref[...] / l


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_decode_attn_2d(cur_pos: jax.Array, q_abs: jax.Array,
                       q_rope: jax.Array, latent: jax.Array,
                       rope: jax.Array, *, scale: float,
                       interpret: bool = True) -> jax.Array:
    """q_abs [B, H, R]; q_rope [B, H, Dr]; latent [B, S, R];
    rope [B, S, Dr]; cur_pos int32 [B]. Returns f32 [B, H, R] (the
    attention-weighted latents, matching ``mla_decode_attention``)."""
    B, H, R = q_abs.shape
    Dr, S = q_rope.shape[2], latent.shape[1]
    ts = TILE_S
    if S % ts or H % 8 or R % 128 or Dr % 128:
        raise ValueError(
            f"mla_decode_attn_2d: q_abs {q_abs.shape}, q_rope "
            f"{q_rope.shape}, latent {latent.shape} — need S % {ts} == 0, "
            "H % 8 == 0, R/Dr % 128 == 0 (ops.py pads before calling)")
    ns = S // ts
    kernel = functools.partial(_mla_kernel, scale=scale, ts=ts, ns=ns)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, ns),
            in_specs=[
                pl.BlockSpec((1, H, R), lambda b, s, cur: (b, 0, 0)),
                pl.BlockSpec((1, H, Dr), lambda b, s, cur: (b, 0, 0)),
                pl.BlockSpec((1, ts, R), lambda b, s, cur: (b, s, 0)),
                pl.BlockSpec((1, ts, Dr), lambda b, s, cur: (b, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, R), lambda b, s, cur: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, ts), jnp.float32),
                pltpu.VMEM((H, ts), jnp.float32),
                pltpu.VMEM((H, R), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, R), jnp.float32),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(cur_pos, q_abs, q_rope, latent, rope)


# ---------------------------------------------------------------------------
# unfused three-kernel pipeline (benchmark baseline, GQA only)
#
# What the fused kernel removes, made explicit: the full [B, Hkv, G, S]
# score matrix is WRITTEN to HBM by the scores kernel, READ + re-written
# by the softmax kernel, and READ again by the weighted-sum kernel —
# and every cache tile is touched regardless of cur_pos.
# ---------------------------------------------------------------------------


def _scores_kernel(cur_ref, q_ref, k_ref, s_ref, *, scale: float,
                   window: int, ts: int):
    cur = cur_ref[pl.program_id(0)]
    base = pl.program_id(2) * ts
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    G = scores.shape[0]
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (G, ts), 1)
    ok = pos <= cur
    if window > 0:
        ok = jnp.logical_and(ok, pos > cur - window)
    s_ref[0, 0] = jnp.where(ok, scores, NEG_INF)


def _softmax_kernel(s_ref, p_ref):
    p_ref[0, 0] = jax.nn.softmax(s_ref[0, 0], axis=-1)


def _wsum_kernel(p_ref, v_ref, o_ref, acc_ref, *, ns: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        p_ref[0, 0], v_ref[0, 0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _emit():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "interpret"))
def unfused_gqa_decode_attn_2d(cur_pos: jax.Array, q: jax.Array,
                               k: jax.Array, v: jax.Array, *, scale: float,
                               window: int = 0,
                               interpret: bool = True) -> jax.Array:
    """Same contract as :func:`gqa_decode_attn_2d`, computed as three
    pallas_calls with the score matrix round-tripping HBM twice."""
    B, Hkv, G, D = q.shape
    S, Dv = k.shape[2], v.shape[3]
    ts = TILE_S
    if S % ts or G % 8 or D % 128 or Dv % 128:
        raise ValueError(
            f"unfused_gqa_decode_attn_2d: q {q.shape}, k {k.shape}, "
            f"v {v.shape} — need S % {ts} == 0, G % 8 == 0, "
            "D/Dv % 128 == 0 (ops.py pads before calling)")
    ns = S // ts

    scores = pl.pallas_call(
        functools.partial(_scores_kernel, scale=scale, window=window, ts=ts),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hkv, ns),
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, s, cur: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, ts, D), lambda b, h, s, cur: (b, h, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, ts),
                                   lambda b, h, s, cur: (b, h, 0, s)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        interpret=interpret,
        compiler_params=_params("parallel", "parallel", "arbitrary"),
    )(cur_pos, q, k)

    probs = pl.pallas_call(
        _softmax_kernel,
        grid=(B, Hkv),
        in_specs=[pl.BlockSpec((1, 1, G, S), lambda b, h: (b, h, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, G, S), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, S), jnp.float32),
        interpret=interpret,
        compiler_params=_params("parallel", "parallel"),
    )(scores)

    return pl.pallas_call(
        functools.partial(_wsum_kernel, ns=ns),
        grid=(B, Hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, ts), lambda b, h, s: (b, h, 0, s)),
            pl.BlockSpec((1, 1, ts, Dv), lambda b, h, s: (b, h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, s: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, Dv), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "parallel", "arbitrary"),
    )(probs, v)


# ---------------------------------------------------------------------------
# paged variants (ISSUE 8): the per-slot seq axis is replaced by a shared
# [num_pages, ...] pool + a page table. The table rides scalar prefetch
# exactly like cur_pos: the k/v BlockSpec index maps look the page id up
# IN SMEM, so the pipeline streams pool pages (not slot rows), and every
# unallocated entry clamps to the same page-0 block — consecutive
# invalid grid steps re-use the resident block instead of issuing a new
# copy, and ``pl.when`` keeps their tiles out of the online softmax.
# ---------------------------------------------------------------------------


def _paged_gqa_kernel(cur_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                      m_ref, l_ref, acc_ref, *, scale: float, window: int,
                      ps: int, pps: int, hkv: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[b]
    page = pt_ref[b * pps + j]
    base = j * ps

    @pl.when(jnp.logical_and(page >= 0,
                             _tile_valid(base, cur, ts=ps, window=window)))
    def _tile():
        G = q_ref.shape[2]
        scores = jnp.concatenate(
            [jax.lax.dot_general(
                q_ref[0, h], k_ref[0, h], (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
             for h in range(hkv)], axis=0) * scale        # [Hkv*G, ps]
        R = scores.shape[0]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (R, ps), 1)
        ok = pos <= cur
        if window > 0:
            ok = jnp.logical_and(ok, pos > cur - window)
        scores = jnp.where(ok, scores, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev,
                            jnp.max(scores, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * corr \
            + jnp.sum(p, axis=1, keepdims=True)
        pv = jnp.concatenate(
            [jax.lax.dot_general(
                p[h * G:(h + 1) * G], v_ref[0, h].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
             for h in range(hkv)], axis=0)               # [Hkv*G, Dv]
        acc_ref[...] = acc_ref[...] * corr[:, 0:1] + pv
        m_ref[...] = m_new

    @pl.when(j == pps - 1)
    def _emit():
        G, Dv = q_ref.shape[2], acc_ref.shape[1]
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(
            o_ref.dtype).reshape(q_ref.shape[1], G, Dv)


@functools.partial(jax.jit,
                   static_argnames=("scale", "window", "interpret"))
def gqa_paged_decode_attn_2d(cur_pos: jax.Array, pages: jax.Array,
                             q: jax.Array, k: jax.Array, v: jax.Array, *,
                             scale: float, window: int = 0,
                             interpret: bool = True) -> jax.Array:
    """q [B, Hkv, G, D]; pools k [num_pages, Hkv, ps, D] /
    v [num_pages, Hkv, ps, Dv]; pages int32 [B, pps] (-1 = unallocated);
    cur_pos int32 [B]. Returns [B, Hkv, G, Dv] in q.dtype.
    ps % 8 == 0, G % 8 == 0, D/Dv % 128 == 0 required (ops.py pads
    G/D/Dv; the page size is a layout constant the engine validates)."""
    B, Hkv, G, D = q.shape
    ps, Dv = k.shape[2], v.shape[3]
    pps = pages.shape[1]
    if ps % 8 or G % 8 or D % 128 or Dv % 128:
        raise ValueError(
            f"gqa_paged_decode_attn_2d: q {q.shape}, k {k.shape}, "
            f"v {v.shape} — need page_size % 8 == 0, G % 8 == 0, "
            "D/Dv % 128 == 0 (ops.py pads heads/dims; pick a page size "
            "that is a multiple of 8)")
    pt = pages.reshape(-1).astype(jnp.int32)
    kernel = functools.partial(_paged_gqa_kernel, scale=scale,
                               window=window, ps=ps, pps=pps, hkv=Hkv)

    def _page_map(b, j, cur, pt):
        return (jnp.maximum(pt[b * pps + j], 0), 0, 0, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, pps),
            in_specs=[
                pl.BlockSpec((1, Hkv, G, D),
                             lambda b, j, cur, pt: (b, 0, 0, 0)),
                pl.BlockSpec((1, Hkv, ps, D), _page_map),
                pl.BlockSpec((1, Hkv, ps, Dv), _page_map),
            ],
            out_specs=pl.BlockSpec((1, Hkv, G, Dv),
                                   lambda b, j, cur, pt: (b, 0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((Hkv * G, ps), jnp.float32),   # running max m
                pltpu.VMEM((Hkv * G, ps), jnp.float32),   # running sum l
                pltpu.VMEM((Hkv * G, Dv), jnp.float32),   # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), q.dtype),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(cur_pos, pt, q, k, v)


def _paged_mla_kernel(cur_ref, pt_ref, qa_ref, qr_ref, lat_ref, rope_ref,
                      o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                      ps: int, pps: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur = cur_ref[b]
    page = pt_ref[b * pps + j]
    base = j * ps

    @pl.when(jnp.logical_and(page >= 0,
                             _tile_valid(base, cur, ts=ps, window=0)))
    def _tile():
        qa = qa_ref[0]                                    # [H, R]
        qr = qr_ref[0]                                    # [H, Dr]
        lat = lat_ref[0]                                  # [ps, R]
        rope = rope_ref[0]                                # [ps, Dr]
        scores = (jax.lax.dot_general(
            qa, lat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
            + jax.lax.dot_general(
                qr, rope, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)) * scale    # [H, ps]
        H = scores.shape[0]
        pos = base + jax.lax.broadcasted_iota(jnp.int32, (H, ps), 1)
        scores = jnp.where(pos <= cur, scores, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev,
                            jnp.max(scores, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)
        l_ref[...] = l_ref[...] * corr \
            + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr[:, 0:1] + jax.lax.dot_general(
            p, lat.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pps - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0:1], 1e-30)
        o_ref[0] = acc_ref[...] / l


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def mla_paged_decode_attn_2d(cur_pos: jax.Array, pages: jax.Array,
                             q_abs: jax.Array, q_rope: jax.Array,
                             latent: jax.Array, rope: jax.Array, *,
                             scale: float,
                             interpret: bool = True) -> jax.Array:
    """q_abs [B, H, R]; q_rope [B, H, Dr]; pools latent
    [num_pages, ps, R] / rope [num_pages, ps, Dr]; pages [B, pps];
    cur_pos [B]. Returns f32 [B, H, R]."""
    B, H, R = q_abs.shape
    Dr, ps = q_rope.shape[2], latent.shape[1]
    pps = pages.shape[1]
    if ps % 8 or H % 8 or R % 128 or Dr % 128:
        raise ValueError(
            f"mla_paged_decode_attn_2d: q_abs {q_abs.shape}, latent "
            f"{latent.shape} — need page_size % 8 == 0, H % 8 == 0, "
            "R/Dr % 128 == 0 (ops.py pads heads/dims)")
    pt = pages.reshape(-1).astype(jnp.int32)
    kernel = functools.partial(_paged_mla_kernel, scale=scale, ps=ps,
                               pps=pps)

    def _page_map(b, j, cur, pt):
        return (jnp.maximum(pt[b * pps + j], 0), 0, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, pps),
            in_specs=[
                pl.BlockSpec((1, H, R), lambda b, j, cur, pt: (b, 0, 0)),
                pl.BlockSpec((1, H, Dr), lambda b, j, cur, pt: (b, 0, 0)),
                pl.BlockSpec((1, ps, R), _page_map),
                pl.BlockSpec((1, ps, Dr), _page_map),
            ],
            out_specs=pl.BlockSpec((1, H, R),
                                   lambda b, j, cur, pt: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, ps), jnp.float32),
                pltpu.VMEM((H, ps), jnp.float32),
                pltpu.VMEM((H, R), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, R), jnp.float32),
        interpret=interpret,
        compiler_params=_params("parallel", "arbitrary"),
    )(cur_pos, pt, q_abs, q_rope, latent, rope)
