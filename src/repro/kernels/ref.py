"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_pruned_matmul_ref(x: jax.Array, w: jax.Array, keep_idx: jax.Array,
                            *, block: int) -> jax.Array:
    """y = x[:, keep-blocks] @ w[keep-blocks, :], float32 accumulation.

    x: [M, K]; w: [K, N]; keep_idx: [kb] int32 block indices. The output is
    identical to masking the pruned K blocks to zero in a dense matmul.
    """
    M, K = x.shape
    nb = K // block
    xb = x.reshape(M, nb, block)
    wb = w.reshape(nb, block, w.shape[1])
    xk = jnp.take(xb, keep_idx, axis=1).reshape(M, -1)
    wk = jnp.take(wb, keep_idx, axis=0).reshape(-1, w.shape[1])
    return jnp.dot(xk.astype(jnp.float32), wk.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(x.dtype)
