"""Checkpointing: pytree <-> npz with structure manifest.

Single-file npz per step plus a JSON manifest describing the pytree
structure and logical shardings, so a checkpoint written under one mesh
restores under another (values are saved unsharded; the launcher re-shards
on restore via device_put with the target NamedShardings).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    np.savez(path, **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(directory, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(directory: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays/ShapeDtype)."""
    data = np.load(os.path.join(directory, f"ckpt_{step:08d}.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in flat_like[0]:
        key = "/".join(_path_str(p) for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree
