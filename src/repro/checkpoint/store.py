"""Checkpointing: pytree <-> npz with structure manifest.

Single-file npz per step plus a JSON manifest describing the pytree
structure and logical shardings, so a checkpoint written under one mesh
restores under another (values are saved unsharded; the launcher re-shards
on restore via device_put with the target NamedShardings).

Durability contract (crash-safe by construction):

* both files are written to a temp path in the same directory and moved
  into place with ``os.replace`` (atomic on POSIX) — a crash mid-write
  leaves a ``.tmp`` orphan, never a torn checkpoint;
* the manifest is written AFTER the npz and acts as the commit marker:
  :func:`latest_step` only counts steps whose npz **and** manifest both
  exist, so a crash between the two renames leaves an ignorable orphan
  npz rather than a corrupt "latest" checkpoint;
* :func:`restore` validates dtypes/shapes against the manifest before
  touching the model and always closes the npz handle.

Flat keys join pytree path components with ``/``; literal ``/`` (and
``\\``) inside dict keys are escaped so distinct paths can never collide
on the same flat key (round-trip pinned by tests/test_checkpoint.py).

The full-train-state layout (params + optimizer moments + control-plane
state in one tree, step/data-position/RNG streams in the manifest
``extra``) is assembled by the trainer; :func:`restore`'s ``prefix``
selects one subtree of it, and :func:`load_params` transparently loads
either that layout or a legacy params-only checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np

# manifest "extra" layout tag for full-train-state checkpoints
TRAIN_STATE_LAYOUT = "train_state_v1"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = np.asarray(leaf)
    return out


def _escape(component: str) -> str:
    """Escape the path separator inside a single key component, so a dict
    key containing ``/`` cannot collide with genuine nesting
    ({"a/b": x} vs {"a": {"b": x}})."""
    return component.replace("\\", "\\\\").replace("/", "\\/")


def _split_key(key: str) -> list:
    """Split a flat key on UNESCAPED ``/`` and unescape the components."""
    parts, cur, i = [], [], 0
    while i < len(key):
        c = key[i]
        if c == "\\" and i + 1 < len(key):
            cur.append(key[i + 1])
            i += 2
            continue
        if c == "/":
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(c)
        i += 1
    parts.append("".join(cur))
    return parts


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return _escape(str(p.key))
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return _escape(str(p.name))
    return _escape(str(p))


def _npz_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.npz")


def _manifest_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"ckpt_{step:08d}.json")


def _atomic_write(path: str, write_fn) -> None:
    """Write via a same-directory temp file + ``os.replace`` so readers
    never observe a partially written checkpoint file."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save(directory: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    path = _npz_path(directory, step)
    # OVERWRITING a step: retract the old commit marker first, so a crash
    # between the new npz landing and its new manifest landing leaves a
    # manifest-less orphan (correctly skipped) — never a new npz silently
    # paired with the previous save's manifest/extra state.
    try:
        os.unlink(_manifest_path(directory, step))
    except FileNotFoundError:
        pass
    _atomic_write(path, lambda f: np.savez(f, **flat))
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
    }
    # the manifest commits the checkpoint: written (atomically) only after
    # the npz is durably in place, and required by latest_step/restore
    _atomic_write(_manifest_path(directory, step),
                  lambda f: f.write(json.dumps(manifest, indent=1)
                                    .encode("utf-8")))
    return path


def latest_step(directory: str) -> Optional[int]:
    """Newest COMMITTED step: an npz without its manifest is a torn write
    (crash between the data and the commit marker) and is skipped."""
    if not os.path.isdir(directory):
        return None
    steps = [int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")
             and os.path.exists(_manifest_path(directory, int(f[5:13])))]
    return max(steps) if steps else None


def read_manifest(directory: str, step: int) -> dict:
    path = _manifest_path(directory, step)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"checkpoint step {step} in {directory} has no manifest — "
            "either it predates the manifest format or its write was "
            "interrupted; re-save or delete the orphan npz")
    with open(path) as f:
        return json.load(f)


def restore(directory: str, step: int, like: Any, shardings: Any = None,
            *, prefix: Optional[str] = None) -> Any:
    """Restore into the structure of `like` (a pytree of arrays/ShapeDtype).

    Validates every leaf against the manifest (key present, dtype and
    shape match what was written) before materializing, so a truncated or
    mismatched checkpoint fails with an actionable error instead of
    feeding garbage into the model. ``prefix`` selects a subtree of a
    larger saved tree (e.g. ``"params"`` of a full-train-state
    checkpoint).
    """
    manifest = read_manifest(directory, step)
    m_shapes, m_dtypes = manifest["shapes"], manifest["dtypes"]
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    want = []
    for path, leaf in flat_like[0]:
        key = "/".join(_path_str(p) for p in path)
        if prefix:
            key = f"{_escape(prefix)}/{key}" if key else _escape(prefix)
        if key not in m_shapes:
            raise KeyError(
                f"checkpoint {directory} step {step} missing leaf {key!r} "
                f"(manifest has {len(m_shapes)} keys"
                + (f" under a different layout; prefix={prefix!r}" if prefix
                   else "") + ")")
        if tuple(m_shapes[key]) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {tuple(m_shapes[key])} vs "
                f"model {tuple(leaf.shape)} — architecture/shape config "
                "changed since this checkpoint was written")
        want.append((key, leaf))

    leaves = []
    with np.load(_npz_path(directory, step)) as data:
        for key, leaf in want:
            if key not in data:
                raise KeyError(
                    f"checkpoint npz missing leaf {key!r} declared by its "
                    "manifest — the npz is truncated/corrupt; restore from "
                    "an earlier step")
            arr = data[key]
            if str(arr.dtype) != m_dtypes[key]:
                raise ValueError(
                    f"dtype mismatch for {key}: npz {arr.dtype} vs manifest "
                    f"{m_dtypes[key]} — the checkpoint pair is inconsistent")
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs model "
                    f"{leaf.shape}")
            leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(flat_like[1], leaves)
    if shardings is not None:
        tree = jax.device_put(tree, shardings)
    return tree


def load_arrays(directory: str, step: int,
                prefix: Optional[str] = None) -> Dict[str, Any]:
    """Load a (sub)tree of a checkpoint as a NESTED dict of numpy arrays,
    without a ``like`` template — used for control-plane state, whose
    structure (e.g. which priority scopes exist) is data-dependent."""
    esc = _escape(prefix) + "/" if prefix else ""
    out: Dict[str, Any] = {}
    with np.load(_npz_path(directory, step)) as data:
        for key in data.files:
            if prefix and not key.startswith(esc):
                continue
            parts = _split_key(key[len(esc):])
            node = out
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = np.asarray(data[key])
    return out


def load_params(directory: str, step: int, like: Any,
                shardings: Any = None) -> Any:
    """Restore model params from either layout: a full-train-state
    checkpoint (params live under the ``params/`` subtree) or a legacy
    params-only checkpoint."""
    manifest = read_manifest(directory, step)
    full = manifest.get("extra", {}).get("layout") == TRAIN_STATE_LAYOUT
    return restore(directory, step, like, shardings,
                   prefix="params" if full else None)


def load_latest_params(directory: str, like: Any, shardings: Any = None,
                       retries: int = 2):
    """Warm-spare promotion path: ``(step, params)`` of the newest
    COMMITTED checkpoint, tolerant of a writer racing the read.

    A trainer overwriting a step retracts its manifest before rewriting
    the npz (see :func:`save`), so a reader that scanned just before the
    retraction can pick a step whose manifest vanishes by the time it
    opens it. Readers of a *different* process (a cluster manager
    promoting a spare while the trainer checkpoints) must not crash on
    that benign race: re-scan and fall back to the previous committed
    step. Returns ``(None, None)`` when the directory holds no committed
    checkpoint at all.
    """
    skip: set = set()
    for _ in range(max(1, retries + 1)):
        steps = [] if not os.path.isdir(directory) else sorted(
            (int(f[5:13]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")
             and int(f[5:13]) not in skip
             and os.path.exists(_manifest_path(directory, int(f[5:13])))),
            reverse=True)
        if not steps:
            return None, None
        step = steps[0]
        try:
            return step, load_params(directory, step, like, shardings)
        except FileNotFoundError:
            # manifest retracted between the scan and the read — the
            # writer is mid-overwrite of this step; try the next-newest
            skip.add(step)
    raise RuntimeError(
        f"checkpoint directory {directory} kept changing under the "
        f"reader ({retries + 1} attempts) — is a writer looping?")
