"""Workload-plan datatypes shared by the controller and the TP layers.

The paper's controller runs per-iteration on the host (Alg. 1/2) and emits
a plan. To stay SPMD-compilable on TPU we split the plan into:

* **static** parts (hashable; changing them recompiles): the γ-bucket set,
  pruning block size, migration block count. Buckets quantize the paper's
  continuous γ (DESIGN.md §7.2) — Eq.(1)'s γ is rounded *up* so waiting
  cost stays fully offset.
* **dynamic** parts (device arrays; changing them does NOT recompile):
  per-rank bucket assignment, per-layer priority permutations, the
  straggler's rank id for migration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np


DEFAULT_BUCKETS: Tuple[float, ...] = (0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875)


def keep_blocks_for_bucket(gamma: float, num_blocks: int) -> int:
    """Blocks KEPT for a pruning ratio γ; never below 1 block."""
    return max(1, num_blocks - int(round(gamma * num_blocks)))


def bucket_for_gamma(gamma: float, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket with γ_bucket >= γ (round UP: offset the full gap)."""
    for i, b in enumerate(buckets):
        if b >= gamma - 1e-9:
            return i
    return len(buckets) - 1


def adapt_block_size(contraction_dim: int, preferred: int = 128) -> int:
    """Largest TPU-friendly block size dividing the contraction dim.

    128 aligns with the MXU; fall back through 64/32. Returns 0 if even 32
    does not divide (that linear is exempt from resizing — recorded)."""
    for b in (preferred, 128, 64, 32):
        if b <= contraction_dim and contraction_dim % b == 0:
            return b
    return 0


@dataclasses.dataclass(frozen=True)
class PlanStatic:
    """Hashable plan skeleton; part of the jit static args."""

    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    block_size: int = 128
    mig_blocks: int = 0          # total migrated contraction blocks (0 = off)
    tp_size: int = 1
    imputation: str = "zero"
    per_layer: bool = False      # per-layer γ (PriDiff, Sec. III-B)
    num_layers: int = 0          # required when per_layer
    # per-scope block-size overrides ("qkv"/"attn_out"/"ffn"), hashable
    scope_blocks: Tuple[Tuple[str, int], ...] = ()

    @property
    def migration_enabled(self) -> bool:
        return self.mig_blocks > 0 and self.tp_size > 1

    def block_for(self, scope: str) -> int:
        for name, b in self.scope_blocks:
            if name == scope:
                return b
        return self.block_size


@dataclasses.dataclass
class PlanDynamic:
    """Device-array plan inputs (donated into the jitted step)."""

    bucket_by_rank: np.ndarray            # [tp] int32 index into buckets
    mig_src: np.ndarray                   # scalar int32 straggler rank (or -1)
    # per-layer-scope priority permutations keyed by scope name;
    # each is int32 [num_blocks] in KEEP-FIRST order (head = most important)
    pri_lists: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @staticmethod
    def neutral(tp: int) -> "PlanDynamic":
        return PlanDynamic(
            bucket_by_rank=np.zeros((tp,), np.int32),
            mig_src=np.array(-1, np.int32),
            pri_lists={},
        )


@dataclasses.dataclass
class WorkloadPlan:
    static: PlanStatic
    dynamic: PlanDynamic

    @staticmethod
    def neutral(tp: int = 1, **kw) -> "WorkloadPlan":
        return WorkloadPlan(PlanStatic(tp_size=tp, **kw), PlanDynamic.neutral(tp))

    def is_neutral(self) -> bool:
        return (not self.static.migration_enabled
                and int(np.max(self.dynamic.bucket_by_rank)) == 0)
