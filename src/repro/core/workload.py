"""Workload-plan datatypes shared by the controller and the TP layers.

The paper's controller runs per-iteration on the host (Alg. 1/2) and emits
a plan. To stay SPMD-compilable on TPU we split the plan into:

* **static** parts (hashable; changing them recompiles): the γ-bucket set,
  pruning block size, the per-source migration shed counts. Buckets
  quantize the paper's continuous γ (DESIGN.md §7.2) — Eq.(1)'s γ is
  rounded *up* so waiting cost stays fully offset. Migration shed counts
  are quantized onto the same grid (:func:`quantize_shed`) so the set of
  distinct static plans — and hence compiled executables — stays small.
* **dynamic** parts (device arrays; changing them does NOT recompile):
  per-rank bucket assignment, per-layer priority permutations, the
  straggler rank ids for migration (one per shed slot, −1 = slot idle).

Multi-straggler plans multiply the number of distinct static shapes, so
:class:`PlanCompileCache` keys built executables on the canonical plan
signature: replanning mid-training reuses compiled code instead of
triggering a recompilation storm (each bucketed signature compiles at
most once — asserted by the property tests via ``compile_count``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np


DEFAULT_BUCKETS: Tuple[float, ...] = (0.0, 0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875)


def keep_blocks_for_bucket(gamma: float, num_blocks: int) -> int:
    """Blocks KEPT for a pruning ratio γ; never below 1 block."""
    return max(1, num_blocks - int(round(gamma * num_blocks)))


def bucket_for_gamma(gamma: float, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket with γ_bucket >= γ (round UP: offset the full gap)."""
    for i, b in enumerate(buckets):
        if b >= gamma - 1e-9:
            return i
    return len(buckets) - 1


def shed_bucket_counts(num_blocks: int,
                       buckets: Tuple[float, ...] = DEFAULT_BUCKETS
                       ) -> Tuple[int, ...]:
    """Allowed per-source migration shed counts: the γ-bucket grid projected
    onto whole blocks (0 dropped; capped so the source keeps >= 1 block)."""
    cap = max(num_blocks - 1, 1)
    counts = {min(int(round(g * num_blocks)), cap) for g in buckets}
    return tuple(sorted(c for c in counts if c > 0))


def quantize_shed(m: int, num_blocks: int,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS) -> int:
    """Round a requested shed count UP onto the bucket grid.

    Rounding up mirrors :func:`bucket_for_gamma`: the straggler sheds at
    least as much as Eq.(1) asked for, so the waiting gap stays fully
    offset; the helpers absorb the (small) quantization surplus."""
    if m <= 0:
        return 0
    for c in shed_bucket_counts(num_blocks, buckets):
        if c >= m:
            return c
    grid = shed_bucket_counts(num_blocks, buckets)
    return grid[-1] if grid else 0


def adapt_block_size(contraction_dim: int, preferred: int = 128) -> int:
    """Largest TPU-friendly block size dividing the contraction dim.

    128 aligns with the MXU; fall back through 64/32. Returns 0 if even 32
    does not divide (that linear is exempt from resizing — recorded)."""
    for b in (preferred, 128, 64, 32):
        if b <= contraction_dim and contraction_dim % b == 0:
            return b
    return 0


@dataclasses.dataclass(frozen=True)
class PlanStatic:
    """Hashable plan skeleton; part of the jit static args."""

    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    block_size: int = 128
    mig_blocks: int = 0          # legacy single-source shed count (0 = off)
    tp_size: int = 1
    imputation: str = "zero"
    per_layer: bool = False      # per-layer γ (PriDiff, Sec. III-B)
    num_layers: int = 0          # required when per_layer
    # per-scope block-size overrides ("qkv"/"attn_out"/"ffn"), hashable
    scope_blocks: Tuple[Tuple[str, int], ...] = ()
    # per-source shed counts for CONCURRENT multi-straggler migration; one
    # entry per source slot, canonical order is descending. Supersedes
    # mig_blocks when non-empty.
    mig_shed: Tuple[int, ...] = ()
    # static ragged shard geometry: per-rank FFN block counts (sum = the
    # model's canonical block total; see core/geometry.py). Empty = the
    # implicit equal split. An all-equal tuple is normalized away by
    # :meth:`canonical` so equal-geometry plans hash/compile identically
    # to geometry-free ones.
    geometry: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.geometry:
            if len(self.geometry) != self.tp_size:
                raise ValueError(
                    f"geometry {self.geometry} has {len(self.geometry)} "
                    f"entries but tp_size={self.tp_size}")
            if any(s < 1 for s in self.geometry):
                raise ValueError(
                    f"geometry {self.geometry} entries must be >= 1")

    @property
    def mig_sheds(self) -> Tuple[int, ...]:
        """Per-source shed counts, unifying the legacy scalar field.

        Zero/negative entries are rejected rather than filtered: silently
        dropping a slot would shift the positional alignment with the
        dynamic ``mig_src`` vector and mispair sources with sheds. Idle
        slots are expressed dynamically (mig_src[slot] = -1)."""
        if self.mig_shed:
            if any(m <= 0 for m in self.mig_shed):
                raise ValueError(
                    f"mig_shed {self.mig_shed} entries must be positive; "
                    "mark idle slots with mig_src[slot] = -1 instead")
            return self.mig_shed
        return (self.mig_blocks,) if self.mig_blocks > 0 else ()

    @property
    def num_sources(self) -> int:
        return len(self.mig_sheds)

    @property
    def migration_enabled(self) -> bool:
        return sum(self.mig_sheds) > 0 and self.tp_size > 1

    def block_for(self, scope: str) -> int:
        for name, b in self.scope_blocks:
            if name == scope:
                return b
        return self.block_size

    def canonical(self) -> "PlanStatic":
        """Normal form used as the compile-cache key: the shed counts live
        in ``mig_shed`` sorted descending, ``mig_blocks`` is folded in,
        and an all-equal geometry (zero padding — byte-identical layout to
        the implicit split) drops to (), so equivalent plans hash
        identically."""
        sheds = tuple(sorted(self.mig_sheds, reverse=True))
        geo = self.geometry if len(set(self.geometry)) > 1 else ()
        if sheds == self.mig_shed and self.mig_blocks == 0 \
                and geo == self.geometry:
            return self
        return dataclasses.replace(self, mig_shed=sheds, mig_blocks=0,
                                   geometry=geo)

    def signature(self) -> "PlanStatic":
        """Alias of :meth:`canonical` — the hashable plan signature."""
        return self.canonical()

    def signature_str(self) -> str:
        """Compact string form of the canonical signature, used by the
        telemetry traces (StepSample.plan_signature) and run histories.
        Stable across processes — unlike hash() — so trace files can be
        diffed and compared between runs."""
        c = self.canonical()
        shed = ",".join(str(m) for m in c.mig_shed)
        sig = f"tp{c.tp_size}b{c.block_size}shed[{shed}]"
        if c.geometry:
            sig += "geo[" + ",".join(str(s) for s in c.geometry) + "]"
        return sig


@dataclasses.dataclass
class PlanDynamic:
    """Device-array plan inputs (donated into the jitted step)."""

    bucket_by_rank: np.ndarray            # [tp] int32 index into buckets
    # migration source rank(s): scalar int32 (legacy single-source) or
    # [S] int32 aligned with PlanStatic.mig_sheds; -1 = slot idle
    mig_src: np.ndarray
    # per-layer-scope priority permutations keyed by scope name;
    # each is int32 [num_blocks] in KEEP-FIRST order (head = most important)
    pri_lists: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    def mig_srcs(self, num_slots: int) -> np.ndarray:
        """Normalize ``mig_src`` to a padded [num_slots] int32 vector."""
        n = max(num_slots, 1)
        a = np.atleast_1d(np.asarray(self.mig_src, np.int32))
        out = np.full((n,), -1, np.int32)
        k = min(a.shape[0], n)
        out[:k] = a[:k]
        return out

    @staticmethod
    def neutral(tp: int) -> "PlanDynamic":
        return PlanDynamic(
            bucket_by_rank=np.zeros((tp,), np.int32),
            mig_src=np.array(-1, np.int32),
            pri_lists={},
        )


@dataclasses.dataclass
class WorkloadPlan:
    static: PlanStatic
    dynamic: PlanDynamic

    @staticmethod
    def neutral(tp: int = 1, **kw) -> "WorkloadPlan":
        return WorkloadPlan(PlanStatic(tp_size=tp, **kw), PlanDynamic.neutral(tp))

    def is_neutral(self) -> bool:
        return (not self.static.migration_enabled
                and int(np.max(self.dynamic.bucket_by_rank)) == 0)


# ---------------------------------------------------------------------------
# Plan-signature compile cache
# ---------------------------------------------------------------------------


class PlanCompileCache:
    """Signature-keyed cache of built (jitted) executables.

    The controller replans every iteration; with multi-straggler migration
    the *static* part of the plan (per-source shed counts) changes too.
    Shed counts are quantized onto the bucket grid, so the set of distinct
    signatures is small — this cache makes each of them build/compile at
    most once and replanning hit compiled code thereafter.

    ``builder(static_or_none)`` is called once per new signature (``None``
    is the key for the control-disabled step). ``compile_count`` /
    ``hit_count`` expose the compile hook the property tests assert on;
    ``on_compile`` (if set) is invoked with each new signature.
    """

    def __init__(self, builder: Callable[[Optional[PlanStatic]], Any]):
        self._builder = builder
        self._entries: Dict[Optional[PlanStatic], Any] = {}
        self.compile_count = 0
        self.hit_count = 0
        self.on_compile: Optional[Callable[[Optional[PlanStatic]], None]] = None

    @staticmethod
    def key_for(static: Optional[PlanStatic]) -> Optional[PlanStatic]:
        return static.canonical() if static is not None else None

    def get(self, static: Optional[PlanStatic]):
        key = self.key_for(static)
        entry = self._entries.get(key)
        if entry is None and key not in self._entries:
            self.compile_count += 1
            if self.on_compile is not None:
                self.on_compile(key)
            entry = self._builder(key)
            self._entries[key] = entry
        else:
            self.hit_count += 1
        return entry

    def __len__(self) -> int:
        return len(self._entries)

    def signatures(self):
        return list(self._entries)
