"""ZERO-resizing (paper Sec. III): temporarily resize the matrices of a TP
linear's matmuls by pruning contraction-dimension blocks, with lineage-
correct zero imputation of the missing gradient rows/columns.

TPU adaptation (DESIGN.md §2): pruning is 128-column-block granular and the
continuous γ is quantized into buckets selected per-rank via ``lax.switch``.

A key observation vs. the paper's imperative implementation: in JAX the
paper's *lineage table + imputation* machinery falls out of autodiff.
``resized_matmul`` is gather(keep blocks) → matmul; the VJP of the gather
is a scatter that places gradients at exactly the kept positions and
**zeros at the pruned positions** — i.e. the paper's Zero-imputation with
a correctly matched lineage, by construction. The `Average`/`Same`
imputation policies of Fig. 3 are provided as explicit gradient
transforms (:func:`impute_gradients`).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.workload import keep_blocks_for_bucket


# ---------------------------------------------------------------------------
# Block gather/scatter primitives
# ---------------------------------------------------------------------------


def gather_cols(x: jax.Array, keep_idx: jax.Array, block: int) -> jax.Array:
    """Keep the given blocks of the last dim: [..., K] -> [..., kb*block]."""
    *lead, K = x.shape
    nb = K // block
    xb = x.reshape(*lead, nb, block)
    xk = jnp.take(xb, keep_idx, axis=-2)
    return xk.reshape(*lead, keep_idx.shape[0] * block)


def gather_rows(w: jax.Array, keep_idx: jax.Array, block: int) -> jax.Array:
    """Keep the given blocks of the first dim: [K, N] -> [kb*block, N]."""
    K, N = w.shape
    wb = w.reshape(K // block, block, N)
    wk = jnp.take(wb, keep_idx, axis=0)
    return wk.reshape(keep_idx.shape[0] * block, N)


def scatter_cols(xk: jax.Array, keep_idx: jax.Array, block: int, K: int) -> jax.Array:
    """Inverse of gather_cols with zeros at pruned blocks (Zero imputation)."""
    *lead, Kk = xk.shape
    nb = K // block
    xb = xk.reshape(*lead, Kk // block, block)
    out = jnp.zeros((*lead, nb, block), xk.dtype)
    return out.at[..., keep_idx, :].set(xb).reshape(*lead, K)


def keep_mask(keep_idx: jax.Array, num_blocks: int, block: int) -> jax.Array:
    """Boolean [num_blocks*block] mask, True where the dimension was kept."""
    m = jnp.zeros((num_blocks,), bool).at[keep_idx].set(True)
    return jnp.repeat(m, block)


# ---------------------------------------------------------------------------
# Resized matmul (the paper's pruned computation, Fig. 2)
# ---------------------------------------------------------------------------


def resized_matmul(x: jax.Array, w: jax.Array, keep_idx: jax.Array,
                   *, block: int, use_kernel: bool = False) -> jax.Array:
    """y = x[:, keep] @ w[keep, :] with zero-imputing lineage-correct VJP.

    x: [..., K]; w: [K, N]; keep_idx: [kb] int32 *block* indices (sorted).
    Output: [..., N] — same shape as the unpruned matmul (consistency
    constraint, Sec. III-A).
    """
    if use_kernel:
        from repro.kernels import ops  # local import: kernels are optional
        return ops.block_pruned_matmul(x, w, keep_idx, block=block)
    xk = gather_cols(x, keep_idx, block)
    wk = gather_rows(w, keep_idx, block)
    return xk @ wk


def resized_ffn(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                keep_idx: jax.Array, act_fn, w_gate: Optional[jax.Array] = None,
                *, block: int, use_kernel: bool = False) -> jax.Array:
    """Pruned FFN pair y = act(x @ Wup[:, keep] [, · gate]) @ Wdown[keep, :].

    The single entry point both the migration dataflow and the plain
    resizing path use, so they share one kernel family: with
    ``use_kernel`` the whole pair is ONE fused pallas_call (the resized
    hidden activation never round-trips HBM, and the backward runs the
    kernel-level dX/dW family); otherwise the XLA gather path.
    """
    if use_kernel:
        from repro.kernels import ops  # local import: kernels are optional
        return ops.fused_pruned_ffn(x, w_up, w_down, keep_idx, w_gate,
                                    act_fn, block)
    h = x @ gather_cols(w_up, keep_idx, block)
    if w_gate is not None:
        h = act_fn(x @ gather_cols(w_gate, keep_idx, block)) * h
    else:
        h = act_fn(h)
    return h @ gather_rows(w_down, keep_idx, block)


def switched_matmul(x: jax.Array, w: jax.Array, pri_list: jax.Array,
                    bucket_idx: jax.Array, *, buckets: Sequence[float],
                    block: int, use_kernel: bool = False) -> jax.Array:
    """Per-rank γ-bucket dispatch: ``lax.switch`` over statically-shaped
    pruned matmuls. ``bucket_idx`` is the rank's runtime bucket; on real
    TPUs each core executes only its branch (true FLOP reduction).

    pri_list: [nb] int32 permutation of block ids, keep-first order.
    """
    K = w.shape[0]
    nb = K // block

    def make_branch(kc: int):
        if kc >= nb:
            def dense(ops_):
                x_, w_, _ = ops_
                return x_ @ w_
            return dense

        def pruned(ops_):
            x_, w_, pri = ops_
            keep = jnp.sort(pri[:kc])  # "concatenated in lexicographical order"
            return resized_matmul(x_, w_, keep, block=block,
                                  use_kernel=use_kernel)
        return pruned

    branches = [make_branch(keep_blocks_for_bucket(g, nb)) for g in buckets]
    return jax.lax.switch(bucket_idx, branches, (x, w, pri_list))


# ---------------------------------------------------------------------------
# Imputation policies (Fig. 3: Zero / Average / Same)
# ---------------------------------------------------------------------------


def impute_rows(grad: jax.Array, kept: jax.Array, mode: str,
                prev: Optional[jax.Array] = None) -> jax.Array:
    """Fill pruned (not-kept) rows of a [K, N] gradient.

    zero    — leave zeros (the paper's final choice; free).
    average — mean over kept rows of the current iteration.
    same    — value from the previous iteration's gradient (`prev`).
    """
    if mode == "zero":
        return grad
    kept_f = kept.astype(grad.dtype)[:, None]
    if mode == "average":
        denom = jnp.maximum(kept_f.sum(), 1.0)
        avg = (grad * kept_f).sum(axis=0, keepdims=True) / denom
        return grad * kept_f + avg * (1.0 - kept_f)
    if mode == "same":
        if prev is None:
            return grad
        return grad * kept_f + prev * (1.0 - kept_f)
    raise ValueError(f"unknown imputation mode {mode!r}")


def impute_gradients(grads, keep_masks, mode: str, prev_grads=None):
    """Apply :func:`impute_rows` across a pytree of weight gradients.

    keep_masks: pytree matching `grads`, entries either None (untouched
    weight) or a bool [K] mask of kept contraction rows.
    """
    if mode == "zero":
        return grads
    prev_leaves = (jax.tree.leaves(prev_grads) if prev_grads is not None
                   else [None] * len(jax.tree.leaves(grads)))
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(keep_masks)
    out = []
    for g, m, p in zip(flat_g, flat_m, prev_leaves):
        if m is None or g.ndim != 2:
            out.append(g)
        else:
            out.append(impute_rows(g, m, mode, p))
    return treedef.unflatten(out)
