"""Straggler detection and the SEMI-migration controller (Sec. III-A, IV-B).

Host-side logic that runs between training steps (the paper operates at
iteration/epoch granularity too). Consumes per-rank iteration times —
measured or produced by the heterogeneity model — and emits a
:class:`WorkloadPlan`.

Equations implemented:
  Eq.(1)  γ_i = (T_i − T_ref) / M_i            (T_ref = T_avg or T_min)
  Eq.(2)  Ω1 + Ω2(Lγ(1−β)) = Φ1(Lγβ) + Φ2(Lγβ/(e−1))   → β (closed form
          with the linear cost fits obtained from the pre-test)
  Eq.(3)  f(x) = (T_x − T_min) − Φ1(Γ(x)) − max_y (Γ(x)/(e−x) · T_y/L_y)
          → largest x with f(x) > 0 migrates; the rest resize.

T_avg maintenance: instead of an all-reduce per iteration, each rank
monitors its own runtime and the controller only refreshes the global
average when some rank drifted >10% since the last refresh (Sec. III-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.config import WorkloadControlConfig
from repro.core.hetero import IterationModel
from repro.core.priority import (PriorityState, build_pri_list,
                                 differentiated_gamma, mark_pruned,
                                 update_state)
from repro.core.workload import (PlanDynamic, PlanStatic, WorkloadPlan,
                                 bucket_for_gamma, keep_blocks_for_bucket,
                                 quantize_shed, shed_bucket_counts)


# ---------------------------------------------------------------------------
# Cost functions (pre-test, Sec. IV-B / Alg. 2 line 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostFunctions:
    """Linear fits of the cost curves sampled in the pre-test.

    Ω1: static allocation overhead of a resized submatrix (seconds).
    Ω2(n) = omega2_slope·n: dimension-extraction cost for n columns.
    Φ1(n) = phi1_base + phi1_slope·n: broadcast communication for n columns.
    Φ2(n) = phi2_slope·n: helper-side compute for n columns.
    """

    omega1: float
    omega2_slope: float
    phi1_base: float
    phi1_slope: float
    phi2_slope: float

    def phi1(self, n: float) -> float:
        """Broadcast cost of migrating n columns.

        Φ1(0) = 0 exactly: migrating nothing launches no collective, so
        the base (launch-latency) term applies only when n > 0. The
        function is therefore INTENTIONALLY discontinuous at n = 0 by
        ``phi1_base`` — Eq.(3) relies on this, pricing the first migrated
        column at the full collective-launch cost (pinned by
        tests/test_controller_properties.py).
        """
        if n <= 0:
            return 0.0
        return self.phi1_base + self.phi1_slope * n


def pretest_cost_functions(model: IterationModel, L_total: int,
                           *, e: int,
                           link_bytes_per_col: float = 0.0,
                           link_bw: float = 50e9) -> CostFunctions:
    """Derive the cost fits from the iteration model + ICI constants.

    In the paper this is measured by running a few ratios before training;
    without real heterogeneous hardware we sample the same analytic model
    the simulator uses (equivalent epistemics, and unit-consistent).
    """
    per_col_compute = model.matmul_time / max(L_total, 1)
    return CostFunctions(
        omega1=0.002 * model.matmul_time,          # small static realloc cost
        omega2_slope=0.05 * per_col_compute,        # gather/extract per column
        phi1_base=5e-5,                             # collective launch latency
        phi1_slope=(link_bytes_per_col / link_bw) if link_bytes_per_col
        else 0.20 * per_col_compute,
        phi2_slope=per_col_compute,                 # helper computes the column
    )


# ---------------------------------------------------------------------------
# Equations
# ---------------------------------------------------------------------------


def eq1_gamma(t_i: float, t_ref: float, m_i: float, gamma_max: float = 0.875) -> float:
    """Pruning ratio that offsets the runtime gap (Eq. 1)."""
    if m_i <= 0:
        return 0.0
    return float(np.clip((t_i - t_ref) / m_i, 0.0, gamma_max))


def eq2_beta(L_gamma: float, costs: CostFunctions, e: int) -> float:
    """Allocation ratio β between migration (β) and resizing (1−β), Eq. (2).

    With linear fits: Ω1 + a·Lγ(1−β) = c0 + c1·Lγβ + c2·Lγβ/(e−1)
    → β = (Ω1 + a·Lγ − c0) / (Lγ·(a + c1 + c2/(e−1))).
    """
    if L_gamma <= 0:
        return 0.0
    a = costs.omega2_slope
    denom = L_gamma * (a + costs.phi1_slope + costs.phi2_slope / max(e - 1, 1))
    if denom <= 0:
        return 1.0
    beta = (costs.omega1 + a * L_gamma - costs.phi1_base) / denom
    return float(np.clip(beta, 0.0, 1.0))


def eq3_migration_prefix(times_desc: np.ndarray, workloads: np.ndarray,
                         costs: CostFunctions, e: int) -> int:
    """Largest straggler prefix x for which migration stays cost-effective.

    times_desc: per-rank times sorted descending; workloads: matching L_i
    (current column workloads). Returns x (0 => nobody migrates).
    """
    t_min = float(times_desc.min())
    x_best = 0
    for x in range(1, len(times_desc)):
        # total migrated volume Γ(x)
        gamma_x = 0.0
        for k in range(x):
            if times_desc[k] > 0:
                gamma_x += workloads[k] * (times_desc[k] - t_min) / times_desc[k]
        helpers = np.arange(x, len(times_desc))
        if len(helpers) == 0:
            break
        # max additional runtime among receivers
        recv_cost = max(
            (gamma_x / max(e - x, 1)) * (times_desc[y] / max(workloads[y], 1e-12))
            for y in helpers)
        f_x = (times_desc[x - 1] - t_min) - costs.phi1(gamma_x) - recv_cost
        if f_x > 0:
            x_best = x
        else:
            break
    return x_best


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ControllerReport:
    """What the controller decided this step (for logs/benchmarks)."""

    stragglers: list
    gammas: Dict[int, float]
    bucket_by_rank: np.ndarray
    mig_src: int                       # first (heaviest) source, -1 = none
    mig_blocks: int                    # TOTAL shed blocks over all sources
    beta: float                        # β of the heaviest source
    x_migrating: int
    t_ref: float
    # concurrent multi-straggler decision (aligned, canonical shed-desc order)
    mig_srcs: tuple = ()
    mig_shed: tuple = ()
    betas: tuple = ()


class SemiController:
    """Implements Alg. 2 (SEMI) and its ZERO / MIG degenerate modes."""

    def __init__(self, cfg: WorkloadControlConfig, tp: int,
                 iter_model: IterationModel, num_blocks: int,
                 costs: Optional[CostFunctions] = None, seed: int = 0,
                 max_sources: Optional[int] = None,
                 shed_cap: Optional[int] = None,
                 workloads: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.tp = tp
        self.model = iter_model
        self.num_blocks = num_blocks            # prunable blocks per rank shard
        # per-rank static workloads L_i (block counts). Under a ragged
        # shard geometry (core/geometry.py) these are the geometry sizes,
        # and every Eq.(1)-(3) quantity scales rank-locally so the
        # controller plans only the RESIDUAL imbalance on top of the
        # static split. Default: the equal split (L_i = num_blocks), which
        # reproduces the geometry-free numerics exactly.
        if workloads is not None:
            w = np.asarray(workloads, np.float64)
            if w.shape != (tp,):
                raise ValueError(
                    f"workloads shape {w.shape} != ({tp},)")
            if np.any(w < 1):
                raise ValueError(f"workloads must be >= 1, got {w}")
            self.workloads = w
        else:
            self.workloads = np.full((tp,), float(num_blocks))
        # static geometry to stamp into emitted plans (uneven only — an
        # equal split is the geometry-free baseline)
        geo = tuple(int(round(v)) for v in self.workloads)
        self.geometry = geo if (workloads is not None
                                and len(set(geo)) > 1) else ()
        self.max_sources = (cfg.max_migration_sources
                            if max_sources is None else max_sources)
        self.shed_cap = (cfg.migration_shed_cap
                         if shed_cap is None else shed_cap)
        self.costs = costs or pretest_cost_functions(
            iter_model, num_blocks, e=tp)
        self.priority: Dict[str, PriorityState] = {}
        self.rng = np.random.default_rng(seed)
        self._t_avg: Optional[float] = None
        self._t_at_refresh: Optional[np.ndarray] = None

    # -- priority bookkeeping -------------------------------------------
    def observe_weights(self, named_weights: Dict[str, np.ndarray], block: int):
        """Epoch-granularity statistics refresh (Alg. 1)."""
        for name, w in named_weights.items():
            nb = w.shape[0] // block
            st = self.priority.get(name) or PriorityState.create(nb)
            self.priority[name] = update_state(st, np.asarray(w), block)

    def pri_lists(self) -> Dict[str, np.ndarray]:
        return {name: build_pri_list(st, self.rng, self.cfg.selection
                                     if self.cfg.selection != "priority_diff"
                                     else "priority")
                for name, st in self.priority.items()}

    # -- checkpoint / resume ----------------------------------------------
    def state_arrays(self) -> Dict[str, object]:
        """Numeric controller state as a pytree of numpy arrays: the
        passive T_avg bookkeeping plus the per-scope priority statistics.
        (The host RNG stream is 128-bit PCG64 state — checkpointed
        separately as JSON by the control plane.)"""
        out: Dict[str, object] = {}
        if self._t_avg is not None:
            out["t_avg"] = np.asarray(self._t_avg, np.float64)
        if self._t_at_refresh is not None:
            out["t_at_refresh"] = np.asarray(self._t_at_refresh, np.float64)
        pri = {}
        for name, st in self.priority.items():
            d = {"w_var": np.asarray(st.w_var, np.float64),
                 "pruned_last": np.asarray(st.pruned_last, bool)}
            if st.snapshot is not None:
                d["snapshot"] = np.asarray(st.snapshot)
            pri[name] = d
        if pri:
            out["pri"] = pri
        return out

    def load_state_arrays(self, arrays: Dict[str, object]) -> None:
        """Restore :meth:`state_arrays` output (missing keys keep the
        fresh-start default, so old checkpoints stay loadable)."""
        t_avg = arrays.get("t_avg")
        self._t_avg = float(np.asarray(t_avg)) if t_avg is not None else None
        t_ref = arrays.get("t_at_refresh")
        self._t_at_refresh = (np.asarray(t_ref, np.float64).copy()
                              if t_ref is not None else None)
        self.priority = {}
        for name, d in (arrays.get("pri") or {}).items():
            w_var = np.asarray(d["w_var"], np.float64)
            snap = d.get("snapshot")
            self.priority[name] = PriorityState(
                num_blocks=int(w_var.shape[0]), w_var=w_var.copy(),
                pruned_last=np.asarray(d["pruned_last"], bool).copy(),
                snapshot=np.asarray(snap).copy() if snap is not None
                else None)

    # -- T_avg maintenance (Sec. III-A) ----------------------------------
    def _t_ref(self, times: np.ndarray) -> float:
        if self.cfg.mode in ("semi", "mig"):
            return float(times.min())           # strictest criterion (Sec. IV-B)
        if (self._t_avg is None or self._t_at_refresh is None
                or np.any(np.abs(times - self._t_at_refresh)
                          > self.cfg.tavg_refresh_threshold * self._t_at_refresh)):
            self._t_avg = float(times.mean())   # "passive refresh on demand"
            self._t_at_refresh = times.copy()
        return self._t_avg

    # -- main entry -------------------------------------------------------
    def plan(self, times: np.ndarray) -> "tuple[WorkloadPlan, ControllerReport]":
        times = np.asarray(times, np.float64)
        e = self.tp
        cfg = self.cfg
        t_ref = self._t_ref(times)
        m_i = self.model.matmul_time
        # deadband: a rank within straggler_threshold of T_ref is noise,
        # not heterogeneity — reacting would flip plans on every jittered
        # measurement (the scenario tests pin this stability).
        band = max(cfg.straggler_threshold, 1e-9)
        stragglers = [i for i in range(e) if times[i] > t_ref * (1 + band)]

        # M_i^j: the straggler's own matmul time this iteration scales with
        # its slowdown — a rank running χ× slow also prunes χ×-cheaper work,
        # so Eq.(1) uses the rank-local matmul cost. Under a ragged
        # geometry it additionally scales with the rank's static workload
        # share L_i/L_eq (the model's matmul_time is the equal-shard M).
        wl_mean = max(float(self.workloads.mean()), 1e-12)
        gammas = {i: eq1_gamma(times[i], t_ref,
                               m_i * (self.workloads[i] / wl_mean)
                               * times[i] / max(t_ref, 1e-12))
                  for i in stragglers}
        bucket_by_rank = np.zeros((e,), np.int32)
        beta, x_mig = 0.0, 0
        srcs: list = []          # source ranks, time-desc order
        sheds: list = []         # matching quantized shed counts
        betas: list = []
        # the compiled program needs >= 1 helper slot per source set
        max_src = min(self.max_sources, e - 1, max(len(stragglers), 0))

        def _quantized_shed(want: float, nb: Optional[int] = None) -> int:
            nb = self.num_blocks if nb is None else nb
            # ceil BEFORE the grid round-up: `round()` here let a
            # fractional request (e.g. 8.42 blocks) quantize DOWN onto
            # the grid, leaving a residual resize bucket on a source the
            # lossless β-policy promises is output-preserving
            m_q = quantize_shed(int(np.ceil(want - 1e-9)), nb,
                                cfg.gamma_buckets)
            if self.shed_cap:
                m_q = min(m_q, self.shed_cap)
            if self.geometry:
                # compiled branch tables require every shed to leave the
                # smallest-geometry rank at least one real block
                m_q = min(m_q, min(self.geometry) - 1)
            return max(m_q, 0)

        if cfg.mode == "zero" or not stragglers or max_src == 0:
            for i, g in gammas.items():
                bucket_by_rank[i] = bucket_for_gamma(g, cfg.gamma_buckets)

        elif cfg.mode == "mig":
            # migrate everything for every straggler (slowest first)
            for i in sorted(stragglers, key=lambda r: -times[r])[:max_src]:
                nb_i = int(round(self.workloads[i]))
                m_q = _quantized_shed(gammas[i] * nb_i, nb_i)
                if m_q > 0:
                    srcs.append(i)
                    sheds.append(m_q)
                    betas.append(1.0)
            x_mig = len(srcs)

        else:  # semi (Alg. 2)
            order = np.argsort(-times)
            times_desc = times[order]
            workloads = self.workloads[order]
            if len(stragglers) == 1:
                x_mig = 1
            else:
                x_mig = eq3_migration_prefix(times_desc, workloads,
                                             self.costs, e)
            x_mig = min(x_mig, max_src)
            # Eq.(3) selection over the sorted straggler list: the first
            # x ranks migrate (β-split per source), the rest resize.
            for k in range(x_mig):
                i = int(order[k])
                g = gammas.get(i, 0.0)
                nb_i = int(round(self.workloads[i]))
                L_gamma = g * nb_i
                # helpers shrink as the source set grows: e' − 1 = e − x
                # "lossless" β-policy: every Eq.(3)-selected source sheds
                # its FULL offset volume, so the residual resize bucket is
                # 0 and the plan is output-preserving (serve default)
                b_k = (1.0 if cfg.beta_policy == "lossless"
                       else eq2_beta(L_gamma, self.costs,
                                     max(e - x_mig + 1, 2)))
                m_q = _quantized_shed(L_gamma * b_k, nb_i)
                # fit check: the source must KEEP >= 1 block after both its
                # residual-resize bucket and the migrated shed — otherwise
                # the compiled branch clamp would double-compute blocks.
                grid = shed_bucket_counts(nb_i, cfg.gamma_buckets)
                while m_q > 0:
                    resid_gamma = max(0.0, (L_gamma - m_q) / nb_i)
                    b_res = bucket_for_gamma(resid_gamma, cfg.gamma_buckets)
                    kc = keep_blocks_for_bucket(
                        cfg.gamma_buckets[b_res], nb_i)
                    if kc - m_q >= 1:
                        break
                    smaller = [cnt for cnt in grid if cnt < m_q]
                    m_q = smaller[-1] if smaller else 0
                if m_q > 0:
                    srcs.append(i)
                    sheds.append(m_q)
                    betas.append(b_k)
                    resid_gamma = max(0.0, (L_gamma - m_q) / nb_i)
                    bucket_by_rank[i] = bucket_for_gamma(
                        resid_gamma, cfg.gamma_buckets)
                else:
                    bucket_by_rank[i] = bucket_for_gamma(g, cfg.gamma_buckets)
            beta = betas[0] if betas else 0.0
            x_mig = len(srcs)
            for i in order:
                i = int(i)
                if i not in stragglers or i in srcs:
                    continue
                bucket_by_rank[i] = bucket_for_gamma(
                    gammas[i], cfg.gamma_buckets)

        # canonical plan-signature order: shed counts descending (stable on
        # the time-desc order above), sources aligned — equivalent plans
        # then hash to the same compiled executable.
        if srcs:
            pairs = sorted(zip(sheds, srcs, betas), key=lambda p: -p[0])
            sheds = [p[0] for p in pairs]
            srcs = [p[1] for p in pairs]
            betas = [p[2] for p in pairs]

        report = ControllerReport(
            stragglers=stragglers, gammas=gammas,
            bucket_by_rank=bucket_by_rank.copy(),
            mig_src=srcs[0] if srcs else -1,
            mig_blocks=int(sum(sheds)), beta=betas[0] if betas else beta,
            x_migrating=x_mig, t_ref=t_ref,
            mig_srcs=tuple(srcs), mig_shed=tuple(sheds), betas=tuple(betas))

        static = PlanStatic(
            buckets=tuple(cfg.gamma_buckets), block_size=cfg.block_size,
            mig_shed=tuple(sheds), tp_size=e, imputation=cfg.imputation,
            geometry=self.geometry)
        dynamic = PlanDynamic(
            bucket_by_rank=bucket_by_rank,
            mig_src=(np.asarray(srcs, np.int32) if srcs
                     else np.array(-1, np.int32)),
            pri_lists=self.pri_lists())
        # mark pruned blocks for the incremental-update rule
        for name, st in list(self.priority.items()):
            pri = dynamic.pri_lists.get(name)
            if pri is None:
                continue
            worst_bucket = int(bucket_by_rank.max())
            kc = keep_blocks_for_bucket(cfg.gamma_buckets[worst_bucket], st.num_blocks)
            self.priority[name] = mark_pruned(st, pri, kc)

        return WorkloadPlan(static, dynamic), report


def decision_key(report: ControllerReport) -> tuple:
    """Hashable summary of WHAT the controller decided: the per-rank
    resize buckets plus the (source, shed) migration set. Two plans with
    the same key drive identical compiled branches."""
    return (tuple(int(b) for b in report.bucket_by_rank),
            tuple(sorted(zip(map(int, report.mig_srcs),
                             map(int, report.mig_shed)))))


def reports_agree(a: ControllerReport, b: ControllerReport,
                  bucket_slack: int = 1) -> bool:
    """Deadband-aware agreement between two controller decisions.

    Used by the telemetry suite to compare measured-mode against
    modeled-mode runs: the measured path sees EWMA-smoothed estimates, so
    a γ sitting near a bucket boundary may land one bucket away from the
    oracle's choice — that is measurement jitter inside the controller's
    own ``straggler_threshold`` deadband (one bucket = 0.125 ≈ the 0.12
    deadband), not a different decision. Migration source/shed sets must
    match exactly (they change the compiled signature)."""
    ka, kb = decision_key(a), decision_key(b)
    if ka[1] != kb[1]:
        return False
    return all(abs(x - y) <= bucket_slack for x, y in zip(ka[0], kb[0]))


def work_fraction(plan: WorkloadPlan, num_blocks: int) -> np.ndarray:
    """Retained matmul-work fraction per rank implied by a plan (for the
    iteration model / benchmarks). Handles concurrent multi-source
    migration: each active source drops its shed fraction; the H = e − S
    working helpers (first non-source ranks in helper order) each absorb
    ceil(shed_s / H) blocks per slot — mirroring the padded partition of
    the real dataflow.

    Fractions are in units of the EQUAL-shard matmul workload (what
    ``IterationModel.matmul_time`` prices), so under a ragged geometry a
    rank's base fraction is kc_r / L_eq with L_eq = mean(geometry): the
    static split shows up as per-rank work, not as a plan decision."""
    geo = plan.static.geometry
    if len(set(geo)) > 1:
        return _geometry_work_fraction(plan)
    e = plan.static.tp_size
    frac = np.ones((e,), np.float64)
    for r in range(e):
        g = plan.static.buckets[int(plan.dynamic.bucket_by_rank[r])]
        frac[r] *= (keep_blocks_for_bucket(g, num_blocks) / num_blocks)
    sheds = plan.static.mig_sheds
    if plan.static.migration_enabled and sheds:
        srcs = plan.dynamic.mig_srcs(len(sheds))
        active = [(int(s), int(m)) for s, m in zip(srcs, sheds)
                  if s >= 0 and m > 0]
        if active:
            H = max(e - len(sheds), 1)
            src_set = {s for s, _ in active}
            helpers = [r for r in range(e) if r not in src_set][:H]
            extra = 0.0
            for s, m in active:
                frac[s] *= max(0.0, 1.0 - m / num_blocks)
                extra += -(-m // H) / num_blocks
            for r in helpers:
                frac[r] += extra
    return frac


def _geometry_work_fraction(plan: WorkloadPlan) -> np.ndarray:
    """Per-rank work fractions under a ragged geometry, in equal-shard
    units (L_eq = mean(geometry) blocks = the matmul_time workload)."""
    st = plan.static
    e = st.tp_size
    L = np.asarray(st.geometry, np.float64)
    L_eq = max(float(L.mean()), 1e-12)
    kc = np.zeros((e,), np.float64)
    for r in range(e):
        g = st.buckets[int(plan.dynamic.bucket_by_rank[r])]
        kc[r] = keep_blocks_for_bucket(g, int(L[r]))
    frac = kc / L_eq
    sheds = st.mig_sheds
    if st.migration_enabled and sheds:
        srcs = plan.dynamic.mig_srcs(len(sheds))
        active = [(int(s), int(m)) for s, m in zip(srcs, sheds)
                  if s >= 0 and m > 0]
        if active:
            H = max(e - len(sheds), 1)
            src_set = {s for s, _ in active}
            helpers = [r for r in range(e) if r not in src_set][:H]
            extra = 0.0
            for s, m in active:
                # the compiled source branch runs exactly max(kc − m, 1)
                frac[s] = max(kc[s] - m, 1.0) / L_eq
                extra += -(-m // H) / L_eq
            for r in helpers:
                frac[r] += extra
    return frac
