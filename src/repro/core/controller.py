"""Straggler detection and the SEMI-migration controller (Sec. III-A, IV-B).

Host-side logic that runs between training steps (the paper operates at
iteration/epoch granularity too). Consumes per-rank iteration times —
measured or produced by the heterogeneity model — and emits a
:class:`WorkloadPlan`.

Equations implemented:
  Eq.(1)  γ_i = (T_i − T_ref) / M_i            (T_ref = T_avg or T_min)
  Eq.(2)  Ω1 + Ω2(Lγ(1−β)) = Φ1(Lγβ) + Φ2(Lγβ/(e−1))   → β (closed form
          with the linear cost fits obtained from the pre-test)
  Eq.(3)  f(x) = (T_x − T_min) − Φ1(Γ(x)) − max_y (Γ(x)/(e−x) · T_y/L_y)
          → largest x with f(x) > 0 migrates; the rest resize.

T_avg maintenance: instead of an all-reduce per iteration, each rank
monitors its own runtime and the controller only refreshes the global
average when some rank drifted >10% since the last refresh (Sec. III-A).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.config import WorkloadControlConfig
from repro.core.hetero import IterationModel
from repro.core.priority import (PriorityState, build_pri_list,
                                 differentiated_gamma, mark_pruned,
                                 update_state)
from repro.core.workload import (PlanDynamic, PlanStatic, WorkloadPlan,
                                 bucket_for_gamma, keep_blocks_for_bucket)


# ---------------------------------------------------------------------------
# Cost functions (pre-test, Sec. IV-B / Alg. 2 line 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostFunctions:
    """Linear fits of the cost curves sampled in the pre-test.

    Ω1: static allocation overhead of a resized submatrix (seconds).
    Ω2(n) = omega2_slope·n: dimension-extraction cost for n columns.
    Φ1(n) = phi1_base + phi1_slope·n: broadcast communication for n columns.
    Φ2(n) = phi2_slope·n: helper-side compute for n columns.
    """

    omega1: float
    omega2_slope: float
    phi1_base: float
    phi1_slope: float
    phi2_slope: float

    def phi1(self, n: float) -> float:
        return self.phi1_base + self.phi1_slope * max(n, 0.0) if n > 0 else 0.0


def pretest_cost_functions(model: IterationModel, L_total: int,
                           *, e: int,
                           link_bytes_per_col: float = 0.0,
                           link_bw: float = 50e9) -> CostFunctions:
    """Derive the cost fits from the iteration model + ICI constants.

    In the paper this is measured by running a few ratios before training;
    without real heterogeneous hardware we sample the same analytic model
    the simulator uses (equivalent epistemics, and unit-consistent).
    """
    per_col_compute = model.matmul_time / max(L_total, 1)
    return CostFunctions(
        omega1=0.002 * model.matmul_time,          # small static realloc cost
        omega2_slope=0.05 * per_col_compute,        # gather/extract per column
        phi1_base=5e-5,                             # collective launch latency
        phi1_slope=(link_bytes_per_col / link_bw) if link_bytes_per_col
        else 0.20 * per_col_compute,
        phi2_slope=per_col_compute,                 # helper computes the column
    )


# ---------------------------------------------------------------------------
# Equations
# ---------------------------------------------------------------------------


def eq1_gamma(t_i: float, t_ref: float, m_i: float, gamma_max: float = 0.875) -> float:
    """Pruning ratio that offsets the runtime gap (Eq. 1)."""
    if m_i <= 0:
        return 0.0
    return float(np.clip((t_i - t_ref) / m_i, 0.0, gamma_max))


def eq2_beta(L_gamma: float, costs: CostFunctions, e: int) -> float:
    """Allocation ratio β between migration (β) and resizing (1−β), Eq. (2).

    With linear fits: Ω1 + a·Lγ(1−β) = c0 + c1·Lγβ + c2·Lγβ/(e−1)
    → β = (Ω1 + a·Lγ − c0) / (Lγ·(a + c1 + c2/(e−1))).
    """
    if L_gamma <= 0:
        return 0.0
    a = costs.omega2_slope
    denom = L_gamma * (a + costs.phi1_slope + costs.phi2_slope / max(e - 1, 1))
    if denom <= 0:
        return 1.0
    beta = (costs.omega1 + a * L_gamma - costs.phi1_base) / denom
    return float(np.clip(beta, 0.0, 1.0))


def eq3_migration_prefix(times_desc: np.ndarray, workloads: np.ndarray,
                         costs: CostFunctions, e: int) -> int:
    """Largest straggler prefix x for which migration stays cost-effective.

    times_desc: per-rank times sorted descending; workloads: matching L_i
    (current column workloads). Returns x (0 => nobody migrates).
    """
    t_min = float(times_desc.min())
    x_best = 0
    for x in range(1, len(times_desc)):
        # total migrated volume Γ(x)
        gamma_x = 0.0
        for k in range(x):
            if times_desc[k] > 0:
                gamma_x += workloads[k] * (times_desc[k] - t_min) / times_desc[k]
        helpers = np.arange(x, len(times_desc))
        if len(helpers) == 0:
            break
        # max additional runtime among receivers
        recv_cost = max(
            (gamma_x / max(e - x, 1)) * (times_desc[y] / max(workloads[y], 1e-12))
            for y in helpers)
        f_x = (times_desc[x - 1] - t_min) - costs.phi1(gamma_x) - recv_cost
        if f_x > 0:
            x_best = x
        else:
            break
    return x_best


# ---------------------------------------------------------------------------
# Controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ControllerReport:
    """What the controller decided this step (for logs/benchmarks)."""

    stragglers: list
    gammas: Dict[int, float]
    bucket_by_rank: np.ndarray
    mig_src: int
    mig_blocks: int
    beta: float
    x_migrating: int
    t_ref: float


class SemiController:
    """Implements Alg. 2 (SEMI) and its ZERO / MIG degenerate modes."""

    def __init__(self, cfg: WorkloadControlConfig, tp: int,
                 iter_model: IterationModel, num_blocks: int,
                 costs: Optional[CostFunctions] = None, seed: int = 0):
        self.cfg = cfg
        self.tp = tp
        self.model = iter_model
        self.num_blocks = num_blocks            # prunable blocks per rank shard
        self.costs = costs or pretest_cost_functions(
            iter_model, num_blocks, e=tp)
        self.priority: Dict[str, PriorityState] = {}
        self.rng = np.random.default_rng(seed)
        self._t_avg: Optional[float] = None
        self._t_at_refresh: Optional[np.ndarray] = None

    # -- priority bookkeeping -------------------------------------------
    def observe_weights(self, named_weights: Dict[str, np.ndarray], block: int):
        """Epoch-granularity statistics refresh (Alg. 1)."""
        for name, w in named_weights.items():
            nb = w.shape[0] // block
            st = self.priority.get(name) or PriorityState.create(nb)
            self.priority[name] = update_state(st, np.asarray(w), block)

    def pri_lists(self) -> Dict[str, np.ndarray]:
        return {name: build_pri_list(st, self.rng, self.cfg.selection
                                     if self.cfg.selection != "priority_diff"
                                     else "priority")
                for name, st in self.priority.items()}

    # -- T_avg maintenance (Sec. III-A) ----------------------------------
    def _t_ref(self, times: np.ndarray) -> float:
        if self.cfg.mode in ("semi", "mig"):
            return float(times.min())           # strictest criterion (Sec. IV-B)
        if (self._t_avg is None or self._t_at_refresh is None
                or np.any(np.abs(times - self._t_at_refresh)
                          > self.cfg.tavg_refresh_threshold * self._t_at_refresh)):
            self._t_avg = float(times.mean())   # "passive refresh on demand"
            self._t_at_refresh = times.copy()
        return self._t_avg

    # -- main entry -------------------------------------------------------
    def plan(self, times: np.ndarray) -> "tuple[WorkloadPlan, ControllerReport]":
        times = np.asarray(times, np.float64)
        e = self.tp
        cfg = self.cfg
        t_ref = self._t_ref(times)
        m_i = self.model.matmul_time
        stragglers = [i for i in range(e) if times[i] > t_ref * (1 + 1e-9)]

        # M_i^j: the straggler's own matmul time this iteration scales with
        # its slowdown — a rank running χ× slow also prunes χ×-cheaper work,
        # so Eq.(1) uses the rank-local matmul cost.
        gammas = {i: eq1_gamma(times[i], t_ref,
                               m_i * times[i] / max(t_ref, 1e-12))
                  for i in stragglers}
        bucket_by_rank = np.zeros((e,), np.int32)
        mig_src, mig_blocks, beta, x_mig = -1, 0, 0.0, 0

        if cfg.mode == "zero" or not stragglers:
            for i, g in gammas.items():
                bucket_by_rank[i] = bucket_for_gamma(g, cfg.gamma_buckets)

        elif cfg.mode == "mig":
            # migrate everything for the slowest straggler
            i = int(np.argmax(times))
            g = gammas.get(i, 0.0)
            mig_src, mig_blocks = i, int(round(g * self.num_blocks))

        else:  # semi (Alg. 2)
            order = np.argsort(-times)
            if len(stragglers) == 1:
                i = stragglers[0]
                g = gammas[i]
                L_gamma = g * self.num_blocks
                beta = eq2_beta(L_gamma, self.costs, e)
                mig_blocks = int(round(L_gamma * beta))
                mig_src = i if mig_blocks > 0 else -1
                resid_gamma = g * (1 - beta)
                bucket_by_rank[i] = bucket_for_gamma(resid_gamma, cfg.gamma_buckets)
                x_mig = 1 if mig_blocks > 0 else 0
            else:
                times_desc = times[order]
                workloads = np.full((e,), float(self.num_blocks))
                x_mig = eq3_migration_prefix(times_desc, workloads, self.costs, e)
                # jitted path supports one migration source: the slowest
                # rank migrates; ranks 2..x and the rest resize to T_min.
                if x_mig >= 1:
                    i = int(order[0])
                    g = gammas.get(i, 0.0)
                    mig_src, mig_blocks = i, int(round(g * self.num_blocks))
                for j, i in enumerate(order):
                    if i not in stragglers or i == mig_src:
                        continue
                    bucket_by_rank[i] = bucket_for_gamma(
                        gammas[i], cfg.gamma_buckets)

        report = ControllerReport(
            stragglers=stragglers, gammas=gammas,
            bucket_by_rank=bucket_by_rank.copy(), mig_src=mig_src,
            mig_blocks=mig_blocks, beta=beta, x_migrating=x_mig, t_ref=t_ref)

        static = PlanStatic(
            buckets=tuple(cfg.gamma_buckets), block_size=cfg.block_size,
            mig_blocks=mig_blocks, tp_size=e, imputation=cfg.imputation)
        dynamic = PlanDynamic(
            bucket_by_rank=bucket_by_rank,
            mig_src=np.array(mig_src, np.int32),
            pri_lists=self.pri_lists())
        # mark pruned blocks for the incremental-update rule
        for name, st in list(self.priority.items()):
            pri = dynamic.pri_lists.get(name)
            if pri is None:
                continue
            worst_bucket = int(bucket_by_rank.max())
            kc = keep_blocks_for_bucket(cfg.gamma_buckets[worst_bucket], st.num_blocks)
            self.priority[name] = mark_pruned(st, pri, kc)

        return WorkloadPlan(static, dynamic), report


def work_fraction(plan: WorkloadPlan, num_blocks: int) -> np.ndarray:
    """Retained matmul-work fraction per rank implied by a plan (for the
    iteration model / benchmarks)."""
    e = plan.static.tp_size
    frac = np.ones((e,), np.float64)
    for r in range(e):
        g = plan.static.buckets[int(plan.dynamic.bucket_by_rank[r])]
        frac[r] *= (keep_blocks_for_bucket(g, num_blocks) / num_blocks)
    src = int(plan.dynamic.mig_src)
    if plan.static.migration_enabled and src >= 0:
        mig_frac = plan.static.mig_blocks / num_blocks
        frac[src] *= max(0.0, 1.0 - mig_frac)
        for r in range(e):
            if r != src:
                frac[r] += mig_frac / max(e - 1, 1)
    return frac
