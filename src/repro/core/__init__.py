"""Core: the paper's contribution (ZERO-resizing / migration / SEMI)."""
from repro.core.workload import (  # noqa: F401
    DEFAULT_BUCKETS, PlanDynamic, PlanStatic, WorkloadPlan,
    adapt_block_size, bucket_for_gamma, keep_blocks_for_bucket)
from repro.core.resizing import (  # noqa: F401
    gather_cols, gather_rows, impute_gradients, keep_mask, resized_matmul,
    scatter_cols, switched_matmul)
from repro.core.controller import (  # noqa: F401
    ControllerReport, CostFunctions, SemiController, eq1_gamma, eq2_beta,
    eq3_migration_prefix, pretest_cost_functions, work_fraction)
from repro.core.hetero import (  # noqa: F401
    HeteroSchedule, IterationModel, iteration_model, matmul_flops_per_rank)
