"""Lightweight workload migration (paper Sec. IV-A), as shard_map dataflow.

Unit of migration: *intermediate-dimension blocks of a TP-split linear
pair* (e.g. the FFN's d_ff). The straggler sheds `m` blocks of its local
shard; every normal rank receives the straggler's weight slices for those
blocks ("broadcast"), computes a deterministic sub-range (the paper's rank
renumbering r' = (r + e - r_s) mod e), and **accumulates the result into
its own partial output before the layer's all-reduce** — the migration
`reduce` is merged into the already-required collective (reduce-merging).

Collective mapping (DESIGN.md §2):
* paper `broadcast` → masked ``psum`` of per-rank export buffers (each rank
  contributes zeros except the straggler). XLA lowers this to the ICI-
  optimal tree/ring — the paper's tree-broadcast insight for free.
* paper `reduce` → *eliminated*: helpers add their migrated partial product
  into their local partial sum; the single pre-existing ``psum`` collects.
* backward: JAX autodiff transposes the same dataflow — gradients of the
  broadcast slices flow back to the straggler's weight shards through the
  transposed psum, so migration is **lossless** (property-tested).

The forward on the straggler uses :func:`resized_matmul` with the
complement of the migrated blocks, so the straggler's FLOPs genuinely drop
(static shapes; the migrated blocks are computed nowhere locally).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import resizing


def _bcast_from(src: jax.Array, value: jax.Array, axis: str) -> jax.Array:
    """Broadcast `value` from rank `src` to all ranks of `axis`.

    Masked psum: every rank contributes zeros except `src`. (A true
    one-to-all broadcast primitive is not exposed by jax.lax; the masked
    all-reduce has the same tree/ring schedule on TPU.)
    """
    rank = lax.axis_index(axis)
    contrib = jnp.where(rank == src, value, jnp.zeros_like(value))
    return lax.psum(contrib, axis)


def migration_assignment(rank, src, e: int, m_pad: int):
    """Blocks [lo, lo+m_per) of the padded export this rank must compute.

    Renumbering r' = (rank + e - src) mod e; r'=0 is the straggler itself
    (computes none — handled by a zero mask), helpers r'=1..e-1 take
    consecutive m_per-block slices.
    """
    m_per = m_pad // (e - 1)
    rprime = (rank + e - src) % e
    is_helper = rprime > 0
    lo = (jnp.maximum(rprime, 1) - 1) * m_per
    return lo, m_per, is_helper


def migrated_pair_matmul(
    x: jax.Array,                 # [T, d] replicated activations
    w_in_loc: jax.Array,          # [d, Hloc]   column-split (up/gate fused ok)
    w_out_loc: jax.Array,         # [Hloc, d_out] row-split
    *,
    axis: str,
    mig_src: jax.Array,           # scalar int32; -1 disables
    mig_block_ids: jax.Array,     # [m] int32 block ids within the straggler's shard
    block: int,
    act_fn: Callable[[jax.Array], jax.Array],
    w_gate_loc: Optional[jax.Array] = None,   # optional gate for GLU acts
    psum_result: bool = True,
) -> jax.Array:
    """Forward of a TP linear pair with single-source migration.

    Returns the (optionally psum'd) output [T, d_out]. With mig_src = -1
    the result equals the plain TP pair (all ranks dense).
    """
    e = lax.axis_size(axis)
    rank = lax.axis_index(axis)
    Hloc = w_in_loc.shape[1]
    nb = Hloc // block
    m = mig_block_ids.shape[0]
    enabled = mig_src >= 0
    src = jnp.where(enabled, mig_src, 0)

    # ----- local compute: straggler drops the migrated blocks (resized) ---
    # keep-list: complement of mig_block_ids for the straggler, first
    # (nb - m) blocks otherwise (helpers run dense separately below).
    all_ids = jnp.arange(nb, dtype=mig_block_ids.dtype)
    in_mig = jnp.zeros((nb,), bool).at[jnp.clip(mig_block_ids, 0, nb - 1)].set(True)
    complement = jnp.argsort(in_mig.astype(jnp.int32), stable=True)[: nb - m]
    complement = jnp.sort(complement)

    def straggler_branch(ops_):
        x_, w_in, w_gate, w_out = ops_
        # prune migrated intermediate blocks out of BOTH matmuls
        w_in_k = _gather_cols_mat(w_in, complement, block)        # [d, (nb-m)B]
        h = x_ @ w_in_k
        if w_gate is not None:
            w_g_k = _gather_cols_mat(w_gate, complement, block)
            h = act_fn(x_ @ w_g_k) * h
        else:
            h = act_fn(h)
        w_out_k = resizing.gather_rows(w_out, complement, block)  # [(nb-m)B, d_out]
        return h @ w_out_k

    def dense_branch(ops_):
        x_, w_in, w_gate, w_out = ops_
        h = x_ @ w_in
        if w_gate is not None:
            h = act_fn(x_ @ w_gate) * h
        else:
            h = act_fn(h)
        return h @ w_out

    is_straggler = jnp.logical_and(enabled, rank == src)
    partial = lax.cond(
        is_straggler, straggler_branch, dense_branch,
        (x, w_in_loc, w_gate_loc, w_out_loc))

    if m > 0:
        # ----- broadcast migrated slices (weight-only; x is replicated) ---
        m_per = -(-m // max(e - 1, 1))
        m_pad = m_per * max(e - 1, 1)
        pad_ids = jnp.concatenate(
            [mig_block_ids, jnp.zeros((m_pad - m,), mig_block_ids.dtype)])
        valid = jnp.concatenate(
            [jnp.ones((m,), bool), jnp.zeros((m_pad - m,), bool)])

        exp_in = _gather_cols_mat(w_in_loc, pad_ids, block)       # [d, m_pad*B]
        exp_out = resizing.gather_rows(w_out_loc, pad_ids, block)  # [m_pad*B, d_out]
        exp_gate = (_gather_cols_mat(w_gate_loc, pad_ids, block)
                    if w_gate_loc is not None else None)

        b_in = _bcast_from(src, exp_in, axis)
        b_out = _bcast_from(src, exp_out, axis)
        b_gate = _bcast_from(src, exp_gate, axis) if exp_gate is not None else None

        lo, m_per_, is_helper = migration_assignment(rank, src, e, m_pad)
        sl_in = lax.dynamic_slice_in_dim(b_in, lo * block, m_per_ * block, axis=1)
        sl_out = lax.dynamic_slice_in_dim(b_out, lo * block, m_per_ * block, axis=0)
        sl_valid = lax.dynamic_slice_in_dim(valid.astype(x.dtype), lo, m_per_)
        sl_valid = jnp.repeat(sl_valid, block)

        h_mig = x @ sl_in
        if b_gate is not None:
            sl_gate = lax.dynamic_slice_in_dim(
                b_gate, lo * block, m_per_ * block, axis=1)
            h_mig = act_fn(x @ sl_gate) * h_mig
        else:
            h_mig = act_fn(h_mig)
        # zero the padded / non-helper / disabled lanes, then REDUCE-MERGE:
        gate_mask = (sl_valid * is_helper.astype(x.dtype)
                     * enabled.astype(x.dtype))
        delta = (h_mig * gate_mask[None, :]) @ sl_out
        partial = partial + delta

    return lax.psum(partial, axis) if psum_result else partial


def _gather_cols_mat(w: jax.Array, ids: jax.Array, block: int) -> jax.Array:
    """Keep given blocks of the LAST dim of a [d, H] matrix."""
    d, H = w.shape
    wb = w.reshape(d, H // block, block)
    return jnp.take(wb, ids, axis=1).reshape(d, ids.shape[0] * block)


def scatter_gather_pair_matmul(x, w_in_loc, w_out_loc, *, axis, mig_src,
                               mig_block_ids, block, act_fn,
                               w_gate_loc=None):
    """The paper's *baseline* comm pattern (scatter-gather) for Table I.

    Straggler point-to-point scatters a distinct slice to each helper
    (emulated with ppermute rounds), helpers compute, results are gathered
    back to the straggler and it injects them into its partial output —
    i.e. NO reduce-merging: the collected result transits twice. Used only
    for the migration-policy benchmark; semantics match migrated_pair_matmul.
    """
    e = lax.axis_size(axis)
    rank = lax.axis_index(axis)
    m = mig_block_ids.shape[0]
    m_per = -(-m // max(e - 1, 1))
    m_pad = m_per * max(e - 1, 1)
    src = jnp.where(mig_src >= 0, mig_src, 0)

    # Emulated scatter: each helper receives ONLY its slice, via one
    # ppermute per helper round (e-1 rounds of [d, m_per*B] + [m_per*B, d]).
    pad_ids = jnp.concatenate(
        [mig_block_ids, jnp.zeros((m_pad - m,), mig_block_ids.dtype)])
    valid = jnp.concatenate([jnp.ones((m,), bool), jnp.zeros((m_pad - m,), bool)])

    partial = None
    deltas = jnp.zeros((x.shape[0], w_out_loc.shape[1]), x.dtype)
    for h in range(1, e):  # helper with renumber r' = h
        ids_h = lax.dynamic_slice_in_dim(pad_ids, (h - 1) * m_per, m_per)
        val_h = lax.dynamic_slice_in_dim(valid.astype(x.dtype), (h - 1) * m_per, m_per)
        sl_in = _gather_cols_mat(w_in_loc, ids_h, block)
        sl_out = resizing.gather_rows(w_out_loc, ids_h, block)
        perm = [(int(s), int((s + h) % e)) for s in range(e)]
        r_in = lax.ppermute(sl_in, axis, perm)     # slice travels src -> src+h
        r_out = lax.ppermute(sl_out, axis, perm)
        hm = act_fn(x @ r_in)
        if w_gate_loc is not None:
            sl_g = _gather_cols_mat(w_gate_loc, ids_h, block)
            r_g = lax.ppermute(sl_g, axis, perm)
            hm = act_fn(x @ r_g) * (x @ r_in)
        is_h = (rank == (src + h) % e)
        mask = jnp.repeat(val_h, block) * is_h.astype(x.dtype)
        d_h = (hm * mask[None, :]) @ r_out
        # GATHER back to straggler (reverse permute) — the redundant hop
        d_back = lax.ppermute(d_h, axis, [(int((s + h) % e), int(s)) for s in range(e)])
        deltas = deltas + d_back

    # straggler-local resized compute
    nb = w_in_loc.shape[1] // block
    in_mig = jnp.zeros((nb,), bool).at[jnp.clip(mig_block_ids, 0, nb - 1)].set(True)
    complement = jnp.sort(jnp.argsort(in_mig.astype(jnp.int32), stable=True)[: nb - m])

    w_in_k = _gather_cols_mat(w_in_loc, complement, block)
    hloc = x @ w_in_k
    if w_gate_loc is not None:
        w_g_k = _gather_cols_mat(w_gate_loc, complement, block)
        hloc = act_fn(x @ w_g_k) * hloc
    else:
        hloc = act_fn(hloc)
    part_straggler = hloc @ resizing.gather_rows(w_out_loc, complement, block)

    def dense(_):
        hh = x @ w_in_loc
        if w_gate_loc is not None:
            hh = act_fn(x @ w_gate_loc) * hh
        else:
            hh = act_fn(hh)
        return hh @ w_out_loc

    partial = lax.cond(jnp.logical_and(mig_src >= 0, rank == src),
                       lambda _: part_straggler + deltas, dense, None)
    return lax.psum(partial, axis)
