"""Lightweight workload migration (paper Sec. IV-A), as shard_map dataflow.

Unit of migration: *intermediate-dimension blocks of a TP-split linear
pair* (e.g. the FFN's d_ff). A straggler sheds `m` blocks of its local
shard; every helper rank receives the straggler's weight slices for those
blocks ("broadcast"), computes a deterministic sub-range (the paper's rank
renumbering r' = (r + e - r_s) mod e), and **accumulates the result into
its own partial output before the layer's all-reduce** — the migration
`reduce` is merged into the already-required collective (reduce-merging).

Concurrent multi-straggler migration (paper Fig. 11): a *set* of S source
ranks shed simultaneously. The helper set is the ranks outside the source
set; each helper is renumbered by its position among helpers (hidx) and
slot s's export is partitioned as

    j_s(r) = (hidx(r) + H − (r_s mod H)) mod H,   H = e − S,

which for S = 1 reduces exactly to the paper's r' renumbering (see
:func:`multi_migration_assignment`). All S exports are concatenated into a
SINGLE masked ``psum`` pair, so the broadcast cost of S sources is one
fused collective, and every migrated partial still folds into the layer's
single pre-existing ``psum``.

Collective mapping (DESIGN.md §2):
* paper `broadcast` → masked ``psum`` of per-rank export buffers (each rank
  contributes zeros except the sources). XLA lowers this to the ICI-
  optimal tree/ring — the paper's tree-broadcast insight for free.
* paper `reduce` → *eliminated*: helpers add their migrated partial product
  into their local partial sum; the single pre-existing ``psum`` collects.
* backward: JAX autodiff transposes the same dataflow — gradients of the
  broadcast slices flow back to each straggler's weight shards through the
  transposed psum, so migration is **lossless** (property-tested for 1, 2
  and 3 concurrent stragglers).

The forward on each straggler uses the complement of its migrated blocks,
so straggler FLOPs genuinely drop (static shapes; the migrated blocks are
computed nowhere locally).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import resizing


def _axis_size(axis) -> int:
    """Static size of a mapped axis; ``lax.axis_size`` only exists on newer
    jax — ``psum(1, axis)`` constant-folds to the same int everywhere."""
    if hasattr(lax, "axis_size"):
        return int(lax.axis_size(axis))
    return int(lax.psum(1, axis))


def _bcast_from(src: jax.Array, value: jax.Array, axis: str) -> jax.Array:
    """Broadcast `value` from rank `src` to all ranks of `axis`.

    Masked psum: every rank contributes zeros except `src`. (A true
    one-to-all broadcast primitive is not exposed by jax.lax; the masked
    all-reduce has the same tree/ring schedule on TPU.)
    """
    rank = lax.axis_index(axis)
    contrib = jnp.where(rank == src, value, jnp.zeros_like(value))
    return lax.psum(contrib, axis)


def migration_assignment(rank, src, e: int, m_pad: int):
    """Blocks [lo, lo+m_per) of the padded export this rank must compute.

    Renumbering r' = (rank + e - src) mod e; r'=0 is the straggler itself
    (computes none — handled by a zero mask), helpers r'=1..e-1 take
    consecutive m_per-block slices. m_per ceil-divides, so an m_pad that
    is not a multiple of the helper count still gets full coverage (the
    caller masks the overhanging padded lanes).
    """
    m_per = -(-m_pad // max(e - 1, 1))
    rprime = (rank + e - src) % e
    is_helper = rprime > 0
    lo = (jnp.maximum(rprime, 1) - 1) * m_per
    return lo, m_per, is_helper


def multi_migration_assignment(rank, srcs, e: int, sheds: Sequence[int]):
    """Deterministic helper partition for S concurrent migration sources.

    ``srcs`` is an [S] int vector of source ranks (−1 = slot idle) and
    ``sheds`` the matching *static* per-source shed block counts. Helpers
    are the ranks outside the source set, renumbered by their position
    among helpers, hidx(r) = #{r'' < r : r'' not a source}. Only the first
    H = e − S helpers work (the surplus when slots are idle stays free).
    For slot s, helper j = (hidx + H − (src_s mod H)) mod H computes blocks
    [j·m_per_s, (j+1)·m_per_s) of that slot's padded export, where
    m_per_s = ceil(shed_s / H). For S = 1 this reduces exactly to the
    paper's renumbering r' = (r + e − r_s) mod e.

    Returns ``(los, m_pers, helps)``: per-slot lists of this rank's block
    offset into the slot's padded export (dynamic), the static per-helper
    block count, and whether this rank helps that slot (dynamic bool —
    false for sources, idle slots, and surplus helpers).
    """
    srcs = jnp.asarray(srcs)
    S = int(srcs.shape[0])
    H = max(e - S, 1)
    ranks = jnp.arange(e)
    is_src_vec = jnp.any(ranks[:, None] == srcs[None, :], axis=1)
    helper_pos = jnp.cumsum(jnp.logical_not(is_src_vec).astype(jnp.int32)) - 1
    hidx = helper_pos[rank]
    can_help = jnp.logical_and(jnp.logical_not(is_src_vec[rank]), hidx < H)
    los, m_pers, helps = [], [], []
    for s, m_s in enumerate(sheds):
        m_per = -(-int(m_s) // H)
        j = (hidx + H - (srcs[s] % H)) % H
        los.append(j * m_per)
        m_pers.append(m_per)
        helps.append(jnp.logical_and(can_help, srcs[s] >= 0))
    return los, tuple(m_pers), helps


def fused_migration_delta(x, *, axis, rank, srcs, sheds, block, act_fn,
                          exports):
    """Fused multi-source broadcast + helper compute (the shared core of
    :func:`migrated_pair_matmul` and ``controlled_ffn``).

    ``exports`` is a per-slot list of ``(exp_in [d, m_s·B], exp_out
    [m_s·B, n], exp_gate | None)`` gathered by EVERY rank from its own
    local shard; only the slot source's contribution survives the single
    masked ``psum`` pair. Helpers slice their partition (see
    :func:`multi_migration_assignment`), run one fused matmul over all
    slots, and the returned delta [T, n] is reduce-merged by the caller
    into its partial output ahead of the layer's existing all-reduce
    (zeros on sources / idle slots / surplus helpers).
    """
    e = _axis_size(axis)
    S = len(sheds)
    H = max(e - S, 1)
    los, m_pers, helps = multi_migration_assignment(rank, srcs, e, sheds)
    m_pads = [m_per * H for m_per in m_pers]

    c_in, c_out, c_gate = [], [], []
    for s, m_s in enumerate(sheds):
        exp_in, exp_out, exp_gate = exports[s]
        pad = m_pads[s] - m_s
        if pad:
            exp_in = jnp.pad(exp_in, ((0, 0), (0, pad * block)))
            exp_out = jnp.pad(exp_out, ((0, pad * block), (0, 0)))
            if exp_gate is not None:
                exp_gate = jnp.pad(exp_gate, ((0, 0), (0, pad * block)))
        sel = rank == srcs[s]
        c_in.append(jnp.where(sel, exp_in, jnp.zeros_like(exp_in)))
        c_out.append(jnp.where(sel, exp_out, jnp.zeros_like(exp_out)))
        if exp_gate is not None:
            c_gate.append(jnp.where(sel, exp_gate, jnp.zeros_like(exp_gate)))

    # ONE fused masked-psum broadcast for all slots AND all three weight
    # groups (in/out/gate): psum over a tuple lets XLA emit a single
    # grouped all-reduce instead of 2-3 back-to-back collectives
    bufs = (jnp.concatenate(c_in, axis=1), jnp.concatenate(c_out, axis=0)) \
        + ((jnp.concatenate(c_gate, axis=1),) if c_gate else ())
    bufs = lax.psum(bufs, axis)
    b_in, b_out = bufs[0], bufs[1]
    b_gate = bufs[2] if c_gate else None

    sl_in, sl_out, sl_gate, gates = [], [], [], []
    off = 0
    for s, m_s in enumerate(sheds):
        m_per = m_pers[s]
        lo = (off + los[s]) * block
        sl_in.append(lax.dynamic_slice_in_dim(b_in, lo, m_per * block, 1))
        sl_out.append(lax.dynamic_slice_in_dim(b_out, lo, m_per * block, 0))
        if b_gate is not None:
            sl_gate.append(lax.dynamic_slice_in_dim(
                b_gate, lo, m_per * block, 1))
        # mask padded block lanes, non-helpers and idle slots
        lane = jnp.arange(m_per * block) + los[s] * block
        gates.append((lane < m_s * block).astype(x.dtype)
                     * helps[s].astype(x.dtype))
        off += m_pads[s]

    cat_in = jnp.concatenate(sl_in, axis=1)
    cat_out = jnp.concatenate(sl_out, axis=0)
    gate_mask = jnp.concatenate(gates)
    h_mig = x @ cat_in
    if b_gate is not None:
        h_mig = act_fn(x @ jnp.concatenate(sl_gate, axis=1)) * h_mig
    else:
        h_mig = act_fn(h_mig)
    return (h_mig * gate_mask[None, :]) @ cat_out


def _normalize_slots(mig_src, mig_block_ids
                     ) -> Tuple[jax.Array, List[jax.Array]]:
    """Normalize (scalar src, [m] ids) / ([S] srcs, per-slot ids) inputs."""
    srcs = jnp.atleast_1d(jnp.asarray(mig_src, jnp.int32))
    if isinstance(mig_block_ids, (list, tuple)):
        ids = [jnp.asarray(i, jnp.int32) for i in mig_block_ids]
    else:
        arr = jnp.asarray(mig_block_ids, jnp.int32)
        ids = [arr] if arr.ndim == 1 else [arr[s] for s in range(arr.shape[0])]
    if srcs.shape[0] != len(ids):
        raise ValueError(
            f"mig_src has {srcs.shape[0]} slots but mig_block_ids has "
            f"{len(ids)} — straggler set and shed lists must align")
    return srcs, ids


def migrated_pair_matmul(
    x: jax.Array,                 # [T, d] replicated activations
    w_in_loc: jax.Array,          # [d, Hloc]   column-split (up/gate fused ok)
    w_out_loc: jax.Array,         # [Hloc, d_out] row-split
    *,
    axis: str,
    mig_src: jax.Array,           # int32 [] or [S] source ranks; -1 disables
    mig_block_ids,                # [m] int32, or per-slot list / [S, m] array
    block: int,
    act_fn: Callable[[jax.Array], jax.Array],
    w_gate_loc: Optional[jax.Array] = None,   # optional gate for GLU acts
    psum_result: bool = True,
) -> jax.Array:
    """Forward of a TP linear pair with multi-source migration.

    Returns the (optionally psum'd) output [T, d_out]. With every source
    slot at -1 the result equals the plain TP pair (all ranks dense).
    Source ranks must be distinct; each slot sheds its own block ids out
    of the *source's* local shard.
    """
    e = _axis_size(axis)
    rank = lax.axis_index(axis)
    srcs, ids_by_slot = _normalize_slots(mig_src, mig_block_ids)
    S = int(srcs.shape[0])
    sheds = tuple(int(i.shape[0]) for i in ids_by_slot)
    H = max(e - S, 1)
    Hloc = w_in_loc.shape[1]
    nb = Hloc // block

    ranks_v = jnp.arange(e)
    is_src_vec = jnp.any(ranks_v[:, None] == srcs[None, :], axis=1)
    i_am_src = is_src_vec[rank]
    my_slot = jnp.argmax(srcs == rank)

    # ----- local compute: each straggler drops ITS slot's blocks ---------
    def dense_branch(ops_):
        x_, w_in, w_gate, w_out = ops_
        h = x_ @ w_in
        if w_gate is not None:
            h = act_fn(x_ @ w_gate) * h
        else:
            h = act_fn(h)
        return h @ w_out

    def make_drop_branch(s: int):
        ids_s, m_s = ids_by_slot[s], sheds[s]

        def branch(ops_):
            x_, w_in, w_gate, w_out = ops_
            in_mig = jnp.zeros((nb,), bool).at[
                jnp.clip(ids_s, 0, nb - 1)].set(True)
            complement = jnp.sort(jnp.argsort(
                in_mig.astype(jnp.int32), stable=True)[: nb - m_s])
            w_in_k = _gather_cols_mat(w_in, complement, block)
            h = x_ @ w_in_k
            if w_gate is not None:
                h = act_fn(x_ @ _gather_cols_mat(w_gate, complement, block)) * h
            else:
                h = act_fn(h)
            return h @ resizing.gather_rows(w_out, complement, block)
        return branch

    branches = [dense_branch] + [make_drop_branch(s) for s in range(S)]
    branch_idx = jnp.where(i_am_src, 1 + my_slot, 0)
    partial = lax.switch(branch_idx, branches,
                         (x, w_in_loc, w_gate_loc, w_out_loc))

    if sum(sheds) > 0:
        # every rank gathers its own slices for each slot; only the slot
        # source's contribution survives the fused masked psum inside
        exports = []
        for s in range(S):
            exp_in = _gather_cols_mat(w_in_loc, ids_by_slot[s], block)
            exp_out = resizing.gather_rows(w_out_loc, ids_by_slot[s], block)
            exp_g = (_gather_cols_mat(w_gate_loc, ids_by_slot[s], block)
                     if w_gate_loc is not None else None)
            exports.append((exp_in, exp_out, exp_g))
        partial = partial + fused_migration_delta(
            x, axis=axis, rank=rank, srcs=srcs, sheds=sheds, block=block,
            act_fn=act_fn, exports=exports)

    return lax.psum(partial, axis) if psum_result else partial


def _gather_cols_mat(w: jax.Array, ids: jax.Array, block: int) -> jax.Array:
    """Keep given blocks of the LAST dim of a [d, H] matrix."""
    d, H = w.shape
    wb = w.reshape(d, H // block, block)
    return jnp.take(wb, ids, axis=1).reshape(d, ids.shape[0] * block)


def scatter_gather_pair_matmul(x, w_in_loc, w_out_loc, *, axis, mig_src,
                               mig_block_ids, block, act_fn,
                               w_gate_loc=None):
    """The paper's *baseline* comm pattern (scatter-gather) for Table I.

    Each source point-to-point scatters a distinct slice to each helper
    (emulated with ppermute rotation rounds), helpers compute, results are
    gathered back to the source which injects them into its partial output
    — i.e. NO reduce-merging: the collected result transits twice. Used
    only for the migration-policy benchmark; semantics match
    :func:`migrated_pair_matmul`, including multi-source slots (processed
    per slot: S · (e−1) rotation rounds).
    """
    e = _axis_size(axis)
    rank = lax.axis_index(axis)
    srcs, ids_by_slot = _normalize_slots(mig_src, mig_block_ids)
    S = int(srcs.shape[0])
    sheds = tuple(int(i.shape[0]) for i in ids_by_slot)
    H = max(e - S, 1)
    ranks_v = jnp.arange(e)
    is_src_vec = jnp.any(ranks_v[:, None] == srcs[None, :], axis=1)
    i_am_src = is_src_vec[rank]
    my_slot = jnp.argmax(srcs == rank)

    deltas = jnp.zeros((x.shape[0], w_out_loc.shape[1]), x.dtype)
    for s, m_s in enumerate(sheds):
        if m_s == 0:
            continue
        src_s = srcs[s]
        m_per = -(-m_s // H)
        m_pad = m_per * H
        pad_ids = jnp.concatenate(
            [ids_by_slot[s], jnp.zeros((m_pad - m_s,), jnp.int32)])
        # rotation rounds: round h carries chunk c(h) = #{h' < h landing on
        # a helper} from every rank to rank+h; only the slice leaving the
        # slot's source at a helper-landing rotation is real work.
        land = jnp.logical_not(is_src_vec[(src_s + jnp.arange(e)) % e])  # [e]
        for h in range(1, e):
            c_h = jnp.sum(land[1:h].astype(jnp.int32)) if h > 1 \
                else jnp.zeros((), jnp.int32)
            valid_h = jnp.logical_and(jnp.logical_and(land[h], c_h < H),
                                      src_s >= 0)
            ids_h = lax.dynamic_slice_in_dim(pad_ids, c_h * m_per, m_per)
            sl_in = _gather_cols_mat(w_in_loc, ids_h, block)
            sl_out = resizing.gather_rows(w_out_loc, ids_h, block)
            perm = [(int(r), int((r + h) % e)) for r in range(e)]
            r_in = lax.ppermute(sl_in, axis, perm)   # slice travels src->src+h
            r_out = lax.ppermute(sl_out, axis, perm)
            hm = act_fn(x @ r_in)
            if w_gate_loc is not None:
                r_g = lax.ppermute(
                    _gather_cols_mat(w_gate_loc, ids_h, block), axis, perm)
                hm = act_fn(x @ r_g) * (x @ r_in)
            is_recv = jnp.logical_and(rank == (src_s + h) % e, valid_h)
            lane = jnp.arange(m_per * block) + c_h * m_per * block
            mask = ((lane < m_s * block).astype(x.dtype)
                    * is_recv.astype(x.dtype))
            d_h = (hm * mask[None, :]) @ r_out
            # GATHER back to the source (reverse permute) — the redundant hop
            d_back = lax.ppermute(
                d_h, axis, [(int((r + h) % e), int(r)) for r in range(e)])
            deltas = deltas + jnp.where(rank == src_s, d_back,
                                        jnp.zeros_like(d_back))

    # source-local resized compute (each source drops its own slot's blocks)
    def dense_branch(ops_):
        x_, w_in, w_gate, w_out = ops_
        hh = x_ @ w_in
        if w_gate is not None:
            hh = act_fn(x_ @ w_gate) * hh
        else:
            hh = act_fn(hh)
        return hh @ w_out

    nb = w_in_loc.shape[1] // block

    def make_src_branch(s: int):
        ids_s, m_s = ids_by_slot[s], sheds[s]

        def branch(ops_):
            x_, w_in, w_gate, w_out = ops_
            in_mig = jnp.zeros((nb,), bool).at[
                jnp.clip(ids_s, 0, nb - 1)].set(True)
            complement = jnp.sort(jnp.argsort(
                in_mig.astype(jnp.int32), stable=True)[: nb - m_s])
            w_in_k = _gather_cols_mat(w_in, complement, block)
            hloc = x_ @ w_in_k
            if w_gate is not None:
                hloc = act_fn(
                    x_ @ _gather_cols_mat(w_gate, complement, block)) * hloc
            else:
                hloc = act_fn(hloc)
            return hloc @ resizing.gather_rows(w_out, complement, block)
        return branch

    branches = [dense_branch] + [make_src_branch(s) for s in range(S)]
    branch_idx = jnp.where(i_am_src, 1 + my_slot, 0)
    partial = lax.switch(branch_idx, branches,
                         (x, w_in_loc, w_gate_loc, w_out_loc))
    return lax.psum(partial + deltas, axis)
