"""Heterogeneity model & straggler simulation (paper Sec. V-A).

The paper simulates heterogeneity on homogeneous V100s by injecting sleeps
into the matmul path of chosen ranks, quantified by the straggling
skewness χ (matmul is χ× slower). We do the analogous thing for a TPU/CPU
SPMD runtime: a ``HeteroSchedule`` yields per-rank speed multipliers
χ_i(t) ≥ 1, and an ``IterationModel`` converts a workload plan + χ into
per-rank iteration times

    T_i = M·(workload share_i)·χ_i + C        (matmul time + comm/other)

which is what the controller consumes (the controller never sees χ
directly — only measured-style times, as in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class HeteroSchedule:
    """χ_i(step) generator."""

    num_ranks: int
    kind: str = "none"       # none | static | round_robin | contention | trace
    chis: Sequence[float] = ()         # static per-rank χ, or χ values to rotate
    period: int = 100                  # steps between round-robin moves
    contention_p: float = 0.15         # P(rank is contended at a step)
    contention_chi: float = 4.0
    seed: int = 0
    # kind="trace": per-step χ rows replayed from a recorded telemetry
    # trace (telemetry.trace.schedule_from_trace); wraps past the end
    trace_chis: "tuple[tuple[float, ...], ...]" = ()

    def chi(self, step: int) -> np.ndarray:
        x = np.ones((self.num_ranks,), np.float64)
        if self.kind == "none":
            return x
        if self.kind == "static":
            c = np.asarray(self.chis, np.float64)
            x[: len(c)] = c
            return x
        if self.kind == "round_robin":
            # one straggler at a time, rotating across ranks (paper Sec. V-B)
            chi = self.chis[0] if self.chis else 2.0
            x[(step // self.period) % self.num_ranks] = chi
            return x
        if self.kind == "contention":
            # per-step stream derived from the (seed, step) PAIR: a plain
            # seed+step sum aliases across schedules (seed=0/step=5 would
            # replay seed=5/step=0 exactly — pinned by tests/test_telemetry)
            rng = np.random.default_rng(np.random.SeedSequence(
                (int(self.seed), int(step))))
            hit = rng.random(self.num_ranks) < self.contention_p
            x[hit] = self.contention_chi
            return x
        if self.kind == "trace":
            if not self.trace_chis:
                raise ValueError(
                    "kind='trace' needs trace_chis — build the schedule "
                    "via repro.telemetry.trace.schedule_from_trace")
            row = np.asarray(self.trace_chis[step % len(self.trace_chis)],
                             np.float64)
            n = min(len(row), self.num_ranks)
            x[:n] = row[:n]
            return x
        raise ValueError(f"unknown hetero kind {self.kind!r}")


@dataclasses.dataclass
class IterationModel:
    """Per-rank iteration-time model for one training step.

    matmul_time: seconds of TP matmul work at χ=1 with the FULL workload
      (the paper's M_i^j); scaled by each rank's retained workload fraction.
    other_time: everything not tunable by the technique (comm, layernorm,
      optimizer, ...), assumed χ-insensitive (collectives are ICI-bound).
    """

    matmul_time: float
    other_time: float

    def times(self, chi: np.ndarray, work_frac: np.ndarray) -> np.ndarray:
        """T_i for each rank given χ_i and retained-work fraction_i."""
        return self.matmul_time * work_frac * chi + self.other_time

    def step_time(self, chi: np.ndarray, work_frac: np.ndarray) -> float:
        """Bulk-synchronous: the step takes as long as the slowest rank."""
        return float(self.times(chi, work_frac).max())


def matmul_flops_per_rank(model_cfg, shape_cfg, tp: int) -> float:
    """FLOPs of TP-matmul work per rank per iteration (fwd+bwd ≈ 3× fwd).

    Counts the linear projections/transformations (the paper's target
    workload): attention QKV/out + FFN, per token. MoE counts active
    experts. The recurrence/softmax parts are excluded (not prunable).
    """
    c = model_cfg
    d = c.d_model
    tokens = shape_cfg.global_batch * shape_cfg.seq_len
    hd = c.resolved_head_dim
    if c.family == "ssm":
        s = c.ssm
        d_in = s.expand * d
        per_tok = 2 * d * 2 * d_in + 2 * d_in * d     # in/out projections
    else:
        attn = 2 * d * (c.num_heads * hd) + 2 * 2 * d * (c.num_kv_heads * hd) \
            + 2 * (c.num_heads * hd) * d
        if c.moe is not None:
            ff_mult = 3 if c.act == "silu" else 2
            ff = ff_mult * 2 * d * c.moe.d_expert * (c.moe.top_k + c.moe.num_shared_experts)
        else:
            ff_mult = 3 if c.act in ("silu", "gelu_glu") else 2
            ff = ff_mult * 2 * d * c.d_ff
        per_tok = attn + ff
    fwd = tokens * per_tok * c.num_layers
    return 3.0 * fwd / tp           # fwd + 2x bwd, split over TP ranks


def iteration_model(model_cfg, shape_cfg, tp: int,
                    peak_flops: float = 197e12,
                    mfu: float = 0.4,
                    comm_frac: float = 0.15) -> IterationModel:
    """Build an IterationModel from the analytic workload (paper Sec. II-B)."""
    f = matmul_flops_per_rank(model_cfg, shape_cfg, tp)
    t_mm = f / (peak_flops * mfu)
    return IterationModel(matmul_time=t_mm, other_time=comm_frac * t_mm)


# ---------------------------------------------------------------------------
# decode-step overhead model (ISSUE 7): the terms the IterationModel
# deliberately excludes — decode attention's cache-read bandwidth and the
# exposed TP all-reduce — priced per step from the ACTUAL slot occupancy.
# ---------------------------------------------------------------------------

# HBM bandwidth per peak FLOP (TPU v5e: 819 GB/s against 197 TFLOP/s).
# The serve engine's latency model is calibrated in arbitrary peak_flops
# units (5e9 on the host simulator); keeping the bytes/FLOP ratio at the
# hardware's value keeps the RELATIVE weight of memory-bound attention
# vs compute-bound matmul realistic — decode at small batch is
# attention-read dominated, which is exactly what the fused kernel and
# the roofline correction (benchmarks/roofline.py) are about.
HBM_BYTES_PER_FLOP = 819e9 / 197e12


@dataclasses.dataclass
class DecodeOverheadModel:
    """Per-step decode overheads from actual per-slot cache occupancy.

    * attention memory term: the UNFUSED path reads every ``max_len``
      cache row of every slot each step plus a full score-matrix HBM
      round-trip; the FUSED kernel reads only the occupied 128-row tiles
      (``pl.when`` skip) and keeps scores in VMEM.
    * collective exposure: the IterationModel's ``other_time`` charges
      one fat synchronous all-reduce; with ``psum_chunks`` k > 1 only
      ~1/k of it stays exposed (the first chunk), the rest overlaps
      with compute under the latency-hiding scheduler.

    ``overhead_s`` returns the DELTA against the plain IterationModel
    step (which already includes ``comm_time``), so it can be added to
    ``IterationModel.step_time`` without double counting.
    """

    kv_bytes_per_pos: float     # cache bytes read per occupied row (all layers)
    score_bytes_per_pos: float  # unfused score round-trip per row (all layers)
    num_slots: int
    max_len: int
    tile: int                   # fused kernel touches whole tiles
    hbm_bw: float               # bytes/s at the calibrated scale
    comm_time: float            # modeled exposed all-reduce time (1 chunk), s

    def attn_s(self, cur_pos, fused: bool, active=None) -> float:
        """``active``: optional [num_slots] mask of OCCUPIED slots. An
        empty slot holds pos=0 in the engine's per-step vector; without
        the mask it is billed as one occupied cache row (tile), which
        inflated the occupancy roofline serve_bench gates on (ISSUE 8
        bugfix). The unfused path ignores it: that path physically
        reads every ``max_len`` row of every slot regardless."""
        cur = np.asarray(cur_pos, np.float64)
        if fused:
            # a tile can't be wider than the cache itself (a short
            # max_len is covered by a single tile), and a slot never
            # reads more rows than it has
            ts = min(self.tile, self.max_len)
            per_slot = np.minimum(np.ceil((cur + 1.0) / ts) * ts,
                                  self.max_len)
            if active is not None:
                per_slot = per_slot * np.asarray(active, np.float64)
            return float(per_slot.sum()) * self.kv_bytes_per_pos / self.hbm_bw
        rows = float(self.num_slots * self.max_len)
        return rows * (self.kv_bytes_per_pos
                       + self.score_bytes_per_pos) / self.hbm_bw

    def comm_exposed_s(self, psum_chunks: int) -> float:
        return self.comm_time / max(int(psum_chunks), 1)

    def overhead_s(self, cur_pos, *, fused: bool, psum_chunks: int,
                   active=None) -> float:
        # the chunking credit (comm_time - exposed) can only hide the
        # all-reduce behind the attention-read phase that actually
        # exists this step: clamp at zero so modeled latency never
        # drops below the compute-only IterationModel floor (ISSUE 8
        # bugfix — tiny occupancy + large psum_chunks went negative)
        return max(0.0, self.attn_s(cur_pos, fused, active=active)
                   - (self.comm_time - self.comm_exposed_s(psum_chunks)))


def decode_overhead_model(model_cfg, num_slots: int, max_len: int,
                          it_model: IterationModel, *,
                          peak_flops: float, bytes_per_el: int = 4,
                          tile: int = 128) -> DecodeOverheadModel:
    """Build the decode overhead model for one engine configuration.

    Attention-free (SSM) families have no cache-attention term; MLA
    reads the compressed latent+rope row (latent twice: scores and the
    weighted sum); GQA reads K and V. Score traffic counts 3 HBM
    accesses per score element (write, softmax read, weighted-sum read)
    at f32."""
    c = model_cfg
    L = c.num_layers
    if c.is_attention_free:
        kv_bytes = score_bytes = 0.0
    elif c.mla is not None:
        m = c.mla
        width = 2.0 * m.kv_lora_rank + m.qk_rope_head_dim
        kv_bytes = width * bytes_per_el * L
        score_bytes = 3.0 * c.num_heads * 4.0 * L
    else:
        kv = c.num_kv_heads * c.resolved_head_dim
        kv_bytes = 2.0 * kv * bytes_per_el * L
        score_bytes = 3.0 * c.num_heads * 4.0 * L
    return DecodeOverheadModel(
        kv_bytes_per_pos=kv_bytes, score_bytes_per_pos=score_bytes,
        num_slots=num_slots, max_len=max_len, tile=tile,
        hbm_bw=peak_flops * HBM_BYTES_PER_FLOP,
        comm_time=it_model.other_time)
