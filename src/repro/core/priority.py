"""Priority pruning principles (paper Sec. III-B, Algorithm 1).

Maintains, per prunable weight scope (layer × matrix), a per-block weight
variation statistic ``w_var`` and derives a keep-first priority permutation
``pri_list``. Three design points from the paper:

* **Priority selection** — blocks whose weights changed least are pruned
  first (they "have a relatively marginal impact on subsequent rounds").
* **Incremental update** — statistics of blocks that were pruned in the
  last window are *preserved*, not refreshed: zero-imputed gradients leave
  pruned weights unchanged, so refreshing would measure a false small
  variation and re-prune the same blocks forever (the paper's
  "endless loop"/false-positive phenomenon). Preserving the stat instead
  yields a round-robin yet prioritized schedule.
* **Differentiated per-layer ratios** — layer k's ratio γ_k is driven by
  how many of its blocks fall below the threshold θ = N_iter·θ_iter, with
  the floor α·γ so the aggregate heterogeneity target is still met.

Granularity note: the statistics are per 128-column *block* (mean of the
per-column mean |Δw|), per DESIGN.md §7.1.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core.workload import bucket_for_gamma


@dataclasses.dataclass
class PriorityState:
    """Host-side statistics for one prunable weight scope."""

    num_blocks: int
    w_var: np.ndarray                    # [nb] mean |Δw| per block
    pruned_last: np.ndarray              # [nb] bool: pruned in last window
    snapshot: Optional[np.ndarray] = None  # weight values at last update

    @staticmethod
    def create(num_blocks: int) -> "PriorityState":
        return PriorityState(
            num_blocks=num_blocks,
            w_var=np.full((num_blocks,), np.inf, np.float64),  # unseen = important
            pruned_last=np.zeros((num_blocks,), bool),
        )


def block_variation(w_old: np.ndarray, w_new: np.ndarray, block: int) -> np.ndarray:
    """Per-block mean |Δw| along the contraction (first) axis of a [K, N]
    weight: Alg. 1 line 4, extended to block granularity."""
    delta = np.abs(np.asarray(w_new, np.float64) - np.asarray(w_old, np.float64))
    per_row = delta.mean(axis=tuple(range(1, delta.ndim)))  # [K]
    K = per_row.shape[0]
    return per_row.reshape(K // block, block).mean(axis=1)


def update_state(state: PriorityState, w_new: np.ndarray, block: int) -> PriorityState:
    """Incremental statistics update (Alg. 1 lines 4-8).

    Blocks pruned in the last window keep their old statistic (their
    weights were frozen by zero-imputation — a fresh measurement would be
    a false positive). Others are refreshed from the weight delta.
    """
    w_new = np.asarray(w_new)
    if state.snapshot is None:
        return dataclasses.replace(
            state, snapshot=w_new.copy(),
            w_var=np.full((state.num_blocks,), np.inf, np.float64))
    fresh = block_variation(state.snapshot, w_new, block)
    w_var = np.where(state.pruned_last, state.w_var, fresh)
    # snapshot only advances for refreshed blocks, so a preserved block's
    # next real refinement is measured against its last *refined* value.
    K = w_new.shape[0]
    keep_rows = np.repeat(state.pruned_last, block)
    shape = (K,) + (1,) * (w_new.ndim - 1)
    snap = np.where(keep_rows.reshape(shape), state.snapshot, w_new)
    return dataclasses.replace(state, w_var=w_var, snapshot=snap)


def build_pri_list(state: PriorityState, rng: Optional[np.random.Generator] = None,
                   selection: str = "priority") -> np.ndarray:
    """Keep-first permutation of block ids.

    priority — descending variation (large-change blocks kept; Alg.1 l.5/13)
    random   — the paper's ZERO-Rd baseline.
    """
    if selection == "random":
        rng = rng or np.random.default_rng(0)
        return rng.permutation(state.num_blocks).astype(np.int32)
    order = np.argsort(-np.nan_to_num(state.w_var, posinf=np.finfo(np.float64).max),
                       kind="stable")
    return order.astype(np.int32)


def mark_pruned(state: PriorityState, pri_list: np.ndarray, keep_blocks: int) -> PriorityState:
    pruned = np.ones((state.num_blocks,), bool)
    pruned[pri_list[:keep_blocks]] = False
    return dataclasses.replace(state, pruned_last=pruned)


def differentiated_gamma(states: Dict[str, PriorityState], gamma_uniform: float,
                         *, alpha: float, theta: float,
                         buckets) -> Dict[str, int]:
    """Per-layer bucket indices (Alg. 1 lines 9-12).

    L_uni = #blocks with variation > θ (still "moving" → keep);
    γ_k = 1 - L_uni/L_k, floored by α·γ_uniform, then bucket-rounded UP.
    """
    out = {}
    for name, st in states.items():
        finite = np.nan_to_num(st.w_var, posinf=np.finfo(np.float64).max)
        l_uni = int((finite > theta).sum())
        gamma_k = 1.0 - l_uni / max(st.num_blocks, 1)
        gamma_k = max(gamma_k, alpha * gamma_uniform)
        gamma_k = min(gamma_k, max(buckets))
        out[name] = bucket_for_gamma(gamma_k, buckets)
    return out
