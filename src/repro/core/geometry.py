"""First-class ragged shard geometry (DESIGN_SHARDING.md).

The paper corrects heterogeneity purely *dynamically* on top of equal TP
shards. Persistent speed ratios (mixed accelerator generations, not
contention spikes) are better absorbed by statically unequal shards sized
from measured throughput — Cephalo / Poplar style — leaving ZERO/SEMI to
handle only the transient residual. This module makes that static shard
split a first-class object:

    ShardGeometry(sizes=(12, 12, 4, 4), block=8)

meaning rank r statically owns ``sizes[r]`` of the FFN's
``sum(sizes)`` controlled blocks (a *redistribution* of the canonical
width — nothing is pruned by the geometry itself).

Physical layout — padded equal split
------------------------------------
XLA/GSPMD wants one static, equal, per-rank buffer shape. We realize a
ragged geometry as a **padded** layout: the FFN hidden width is padded to

    Hp = tp · max(sizes) · block

and equal-split as usual; rank r's local slice holds its ``sizes[r]``
real blocks *first* and zero blocks after. Zero padding is numerically
inert in both directions and self-sustaining under AdamW-style updates:

* forward: padded w_up/w_gate columns are zero ⇒ h_pad = 0; padded
  w_down rows are zero ⇒ they contribute nothing to y;
* backward: dL/dh_pad = dy @ w_down[pad,:]^T = 0 ⇒ w_up/w_gate padding
  gradients are 0; h_pad = 0 ⇒ w_down padding gradients are 0;
* update: lr·(0 + weight_decay·0) = 0 — padding stays exactly zero.

An *equal* geometry therefore has zero padding and is byte-identical to
the implicit ``H // tp`` split — callers normalize it away (see
``PlanStatic.canonical``) so equal-geometry runs reproduce the pinned
equal-shard trajectories bit-for-bit.

The controlled path (layers/tp_linear.py) executes only the ``sizes[r]``
real blocks per rank (per-size-class branch tables), so an uneven
geometry is a genuine static FLOP rebalance, not just masking.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ShardGeometry:
    """Static per-rank FFN block counts for a ragged TP split.

    sizes: per-rank counts of *controlled blocks* (``block`` lanes each);
      ``sum(sizes)`` is the model's canonical total (d_ff // block).
    block: lanes per controlled block (= the control-plane block size for
      the "ffn" scope).
    """

    sizes: Tuple[int, ...]
    block: int

    def __post_init__(self):
        sizes = tuple(int(s) for s in self.sizes)
        object.__setattr__(self, "sizes", sizes)
        if not sizes:
            raise ValueError("ShardGeometry needs at least one rank")
        if any(s < 1 for s in sizes):
            raise ValueError(
                f"every rank needs >= 1 block, got sizes={sizes}")
        if self.block < 1:
            raise ValueError(f"block must be >= 1, got {self.block}")

    # -- shape arithmetic ---------------------------------------------------
    @property
    def tp(self) -> int:
        return len(self.sizes)

    @property
    def total_blocks(self) -> int:
        """Canonical (unpadded) block count: d_ff // block."""
        return sum(self.sizes)

    @property
    def max_blocks(self) -> int:
        """Per-rank padded local block count (every rank's buffer size)."""
        return max(self.sizes)

    @property
    def min_blocks(self) -> int:
        return min(self.sizes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Start of each rank's slice in canonical (global) block ids."""
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        return tuple(out)

    @property
    def padded_blocks(self) -> int:
        """Global block count of the padded layout: tp · max_blocks."""
        return self.tp * self.max_blocks

    @property
    def padded_width(self) -> int:
        """Padded FFN hidden width Hp (what cfg.d_ff becomes)."""
        return self.padded_blocks * self.block

    @property
    def width(self) -> int:
        """Canonical FFN hidden width (the model's true d_ff)."""
        return self.total_blocks * self.block

    @property
    def is_equal(self) -> bool:
        return len(set(self.sizes)) == 1

    def rank_of_block(self, g: int) -> int:
        """Owning rank of canonical global block id ``g``."""
        if not 0 <= g < self.total_blocks:
            raise ValueError(f"block {g} outside [0, {self.total_blocks})")
        for r, (off, s) in enumerate(zip(self.offsets, self.sizes)):
            if off <= g < off + s:
                return r
        raise AssertionError("unreachable")

    def describe(self) -> str:
        return (f"geometry tp={self.tp} sizes={list(self.sizes)} "
                f"block={self.block} width={self.width} "
                f"padded={self.padded_width}")


def equal_geometry(total_blocks: int, tp: int, block: int) -> ShardGeometry:
    """The canonical equal split as a ShardGeometry (zero padding)."""
    if total_blocks % tp:
        raise ValueError(f"{total_blocks} blocks do not equal-split over "
                         f"tp={tp}")
    return ShardGeometry(sizes=(total_blocks // tp,) * tp, block=block)


def geometry_from_chi(chis: Sequence[float], total_blocks: int, block: int,
                      *, chi_quantum: float = 0.25,
                      min_blocks: int = 1) -> ShardGeometry:
    """Size static shards inversely to steady-state slowdown χ̂.

    Rank r's matmul runs χ_r× slower than nominal, so give it ∝ 1/χ_r of
    the blocks: per-rank matmul time M·(L_r/L_eq)·χ_r equalizes across
    ranks. Two stability measures keep ``PlanCompileCache`` signatures
    from churning on estimator noise:

    * χ̂ is first snapped to a coarse grid (``chi_quantum``) — small χ̂
      drift maps to the same geometry;
    * block counts are integerized by largest-remainder apportionment so
      they sum *exactly* to ``total_blocks`` (the geometry redistributes,
      never prunes).
    """
    x = np.asarray(chis, np.float64)
    if x.ndim != 1 or x.size < 1:
        raise ValueError("chis must be a non-empty 1-D sequence")
    if np.any(~np.isfinite(x)) or np.any(x <= 0):
        raise ValueError(f"chis must be positive and finite, got {chis}")
    tp = int(x.size)
    if total_blocks < tp * min_blocks:
        raise ValueError(
            f"{total_blocks} blocks cannot give {tp} ranks "
            f">= {min_blocks} each")
    q = max(float(chi_quantum), 1e-6)
    # snap to the grid, never below nominal speed
    xq = np.maximum(np.round(x / q) * q, 1.0)
    share = (1.0 / xq) / (1.0 / xq).sum()
    ideal = share * total_blocks
    sizes = np.maximum(np.floor(ideal).astype(np.int64), min_blocks)
    # largest-remainder: hand out the residual blocks to the largest
    # fractional parts (ties broken by rank id — deterministic)
    rem = int(total_blocks - sizes.sum())
    if rem > 0:
        frac = ideal - np.floor(ideal)
        order = np.lexsort((np.arange(tp), -frac))
        for k in range(rem):
            sizes[order[k % tp]] += 1
    elif rem < 0:
        # min_blocks clamping overshot: take blocks back from the largest
        order = np.argsort(-sizes, kind="stable")
        i = 0
        while rem < 0:
            r = order[i % tp]
            if sizes[r] > min_blocks:
                sizes[r] -= 1
                rem += 1
            i += 1
    return ShardGeometry(sizes=tuple(int(s) for s in sizes), block=block)


# ---------------------------------------------------------------------------
# Config plumbing: canonical cfg -> padded cfg
# ---------------------------------------------------------------------------


def geometry_unsupported_reason(model_cfg) -> Optional[str]:
    """Why a ragged geometry cannot apply to this architecture (or None).

    The geometry redistributes the dense-FFN controlled scope; MoE expert
    widths and SSM inner widths have their own sharding stories and stay
    equal-split.
    """
    if getattr(model_cfg, "family", None) == "ssm":
        return "ssm family has no dense FFN controlled scope"
    if getattr(model_cfg, "moe", None) is not None:
        return "MoE expert widths stay equal-split (no ragged geometry)"
    return None


def geometry_for_cfg(model_cfg, sizes: Sequence[int],
                     block: int) -> ShardGeometry:
    """Validate per-rank block counts against a model config's d_ff."""
    reason = geometry_unsupported_reason(model_cfg)
    if reason is not None:
        raise ValueError(f"{model_cfg.name}: {reason}")
    geo = ShardGeometry(sizes=tuple(sizes), block=block)
    if geo.width != model_cfg.d_ff:
        raise ValueError(
            f"geometry covers width {geo.width} "
            f"({geo.total_blocks} x {block}) but {model_cfg.name} has "
            f"d_ff={model_cfg.d_ff}")
    return geo


def apply_geometry_cfg(model_cfg, geo: ShardGeometry):
    """Return the padded model config the ragged run actually compiles.

    Only ``d_ff`` changes (canonical width -> padded width); every other
    field — and therefore every non-FFN parameter shape — is untouched.
    Equal geometries pad nothing and return the config unchanged, so the
    equal case stays on the exact baseline code path.
    """
    reason = geometry_unsupported_reason(model_cfg)
    if reason is not None:
        raise ValueError(f"{model_cfg.name}: {reason}")
    if geo.width != model_cfg.d_ff:
        raise ValueError(
            f"geometry width {geo.width} != d_ff {model_cfg.d_ff}")
    if geo.is_equal:
        return model_cfg
    return dataclasses.replace(model_cfg, d_ff=geo.padded_width)


# ---------------------------------------------------------------------------
# Parameter layout transforms: canonical <-> padded
# ---------------------------------------------------------------------------


def _is_ffn_pair(d: dict, width: int) -> bool:
    wu = d.get("w_up")
    wd = d.get("w_down")
    return (hasattr(wu, "shape") and hasattr(wd, "shape")
            and wu.shape[-1] == width and wd.shape[-2] == width)


def _expand_axis(w, geo: ShardGeometry, axis: int):
    """Reorder+pad one array axis from canonical to padded layout.

    Canonical blocks [off_r, off_r + sizes[r]) land at rank r's local
    slots [0, sizes[r]); slots [sizes[r], max_blocks) are zero padding.
    Runs in numpy — this is a host-side load/save transform, and going
    through jax would silently truncate float64 params to float32.
    """
    w = np.asarray(w)
    axis = axis % w.ndim
    shp = w.shape
    nb, b = geo.total_blocks, geo.block
    if shp[axis] != nb * b:
        raise ValueError(f"axis {axis} has {shp[axis]} lanes, geometry "
                         f"covers {nb * b}")
    blocks = np.reshape(w, shp[:axis] + (nb, b) + shp[axis + 1:])
    parts = []
    for off, L in zip(geo.offsets, geo.sizes):
        mine = np.take(blocks, np.arange(off, off + L), axis=axis)
        pad = geo.max_blocks - L
        if pad:
            pshape = list(mine.shape)
            pshape[axis] = pad
            mine = np.concatenate(
                [mine, np.zeros(pshape, w.dtype)], axis=axis)
        parts.append(mine)
    out = np.concatenate(parts, axis=axis)
    return np.reshape(out, shp[:axis] + (geo.padded_width,) + shp[axis + 1:])


def _restrict_axis(w, geo: ShardGeometry, axis: int):
    """Inverse of :func:`_expand_axis`: drop padding, restore canonical order."""
    w = np.asarray(w)
    axis = axis % w.ndim
    shp = w.shape
    if shp[axis] != geo.padded_width:
        raise ValueError(f"axis {axis} has {shp[axis]} lanes, padded layout "
                         f"has {geo.padded_width}")
    blocks = np.reshape(
        w, shp[:axis] + (geo.padded_blocks, geo.block) + shp[axis + 1:])
    ids = []
    for r, (off, L) in enumerate(zip(geo.offsets, geo.sizes)):
        ids.extend(range(r * geo.max_blocks, r * geo.max_blocks + L))
    out = np.take(blocks, np.asarray(ids), axis=axis)
    return np.reshape(out, shp[:axis] + (geo.width,) + shp[axis + 1:])


def _map_ffn_params(params, width: int, fn_up, fn_down):
    """Apply (fn_up, fn_down) to every FFN pair dict in a param pytree.

    Matches dicts holding ``w_up``/``w_down`` whose widths equal ``width``
    on the last / second-to-last axis (leading scan-layer dims pass
    through untouched). Returns (new_params, pairs_found).
    """
    found = 0

    def walk(node):
        nonlocal found
        if isinstance(node, dict):
            if _is_ffn_pair(node, width):
                found += 1
                out = dict(node)
                out["w_up"] = fn_up(node["w_up"])
                out["w_down"] = fn_down(node["w_down"])
                if node.get("w_gate") is not None:
                    out["w_gate"] = fn_up(node["w_gate"])
                for k, v in node.items():
                    if k not in ("w_up", "w_down", "w_gate"):
                        out[k] = walk(v)
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(params), found


def expand_ffn_params(params, geo: ShardGeometry):
    """Canonical param tree -> padded ragged-layout tree.

    Initialize under the *canonical* config, then expand: the uneven run
    trains exactly the canonical parameters (plus inert zero padding), so
    it corresponds 1:1 to an equal-shard run of the same model.
    """
    if geo.is_equal:
        return params
    out, found = _map_ffn_params(
        params, geo.width,
        lambda w: _expand_axis(w, geo, -1),
        lambda w: _restrict_or_expand_down(w, geo, expand=True))
    if not found:
        raise ValueError(
            f"no FFN pair with width {geo.width} found in params")
    return out


def restrict_ffn_params(params, geo: ShardGeometry):
    """Padded ragged-layout tree -> canonical tree (for export/eval)."""
    if geo.is_equal:
        return params
    out, found = _map_ffn_params(
        params, geo.padded_width,
        lambda w: _restrict_axis(w, geo, -1),
        lambda w: _restrict_or_expand_down(w, geo, expand=False))
    if not found:
        raise ValueError(
            f"no FFN pair with padded width {geo.padded_width} in params")
    return out


def _restrict_or_expand_down(w, geo: ShardGeometry, *, expand: bool):
    return (_expand_axis(w, geo, -2) if expand
            else _restrict_axis(w, geo, -2))


# ---------------------------------------------------------------------------
# Parsing / seeding helpers for drivers
# ---------------------------------------------------------------------------


def parse_geometry_arg(spec: str, tp: int) -> Optional[Tuple[int, ...]]:
    """Parse a CLI ``--geometry`` value.

    ``"none"``/empty -> None; ``"12,12,4,4"`` -> explicit per-rank block
    counts (must have ``tp`` entries).
    """
    s = (spec or "").strip().lower()
    if s in ("", "none", "off"):
        return None
    try:
        sizes = tuple(int(v) for v in s.split(","))
    except ValueError as e:
        raise ValueError(f"--geometry {spec!r}: expected comma-separated "
                         f"per-rank block counts") from e
    if len(sizes) != tp:
        raise ValueError(f"--geometry has {len(sizes)} entries, tp={tp}")
    return sizes


def geometry_from_schedule(schedule, total_blocks: int, block: int,
                           *, step: int = 0,
                           chi_quantum: float = 0.25) -> ShardGeometry:
    """Chi-seed a geometry from a HeteroSchedule's steady state.

    The honest closed-loop path seeds from ``StragglerEstimator.chi_hat``
    once its warmup gate opens (see ``geometry_from_chi``); this helper is
    the modeled-times shortcut the drivers use when the persistent speed
    ratio is declared up front (``--hetero static``).
    """
    return geometry_from_chi(schedule.chi(step), total_blocks, block,
                             chi_quantum=chi_quantum)


def blocks_for_width(width: int, block: int) -> int:
    if width % block:
        raise ValueError(f"width {width} not divisible by block {block}")
    return width // block


def validate_even_padding(geo: ShardGeometry, tp: int) -> None:
    """The padded width must equal-split over the mesh TP axis."""
    if geo.tp != tp:
        raise ValueError(f"geometry has {geo.tp} ranks, mesh TP axis {tp}")
    if geo.padded_width % tp:
        raise AssertionError(
            f"padded width {geo.padded_width} not divisible by tp={tp}")


__all__ = [
    "ShardGeometry", "equal_geometry", "geometry_from_chi",
    "geometry_from_schedule", "geometry_for_cfg", "apply_geometry_cfg",
    "geometry_unsupported_reason", "expand_ffn_params",
    "restrict_ffn_params", "parse_geometry_arg", "blocks_for_width",
    "validate_even_padding",
]
