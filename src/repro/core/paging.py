"""Block-paged KV cache layout + host-side page allocator (ISSUE 8).

The serve engine's fixed slot cache reserves ``num_slots x max_len``
positions up front: a slot holding an 8-token prompt pays for all
``max_len`` rows, and the engine can never hold more resident requests
than slots even when every request is short. Paging (vLLM-style, cf.
saxml's batched serving path) splits each slot's sequence into
fixed-size pages over a SHARED ``[num_pages, page_size, ...]`` pool:

* the **pool** replaces the per-slot seq axis in every attention cache
  leaf (``models/lm.py``): GQA ``[num_pages, KV, page_size, hd]``, MLA
  ``[num_pages, page_size, R]`` — recurrent state leaves (SSM/RG-LRU)
  have no seq axis and keep their slot-batch layout;
* the **page table** ``[num_slots, pages_per_slot]`` (int32, -1 =
  unallocated) maps a slot's linear positions to pool pages; it is a
  regular per-step device input (like ``cur_pos``), host-owned by the
  :class:`PageAllocator` — allocation never touches jitted code;
* writes go through a redirect: an unallocated / out-of-range position
  maps to page id ``num_pages`` which jax's scatter ``mode="drop"``
  discards — invalid lanes of a chunked-prefill substep write nowhere;
* reads gather pool pages through the (clipped) table and mask by
  position exactly like the fixed path, so a freed page can be handed
  to a new slot WITHOUT zeroing (positions > cur_pos are masked,
  <= cur_pos are rewritten by prefill before they are ever attended).

``kv_int8`` stores the GQA K/V pool in int8 with a per-row f32 scale
(``abs(row).max()/127``), halving pool HBM so the same budget holds 2x
the pages. Quantized decode is NOT bit-exact vs f32 — the engine keeps
it opt-in and the bench gates it separately.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

# Positions redirected here never write: the page lookup sees an
# out-of-range page index and maps it to the dropped page id. Finite and
# far above any real max_len, so rope/masks stay NaN-free.
INVALID_POS = np.int32(2 ** 30)


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static shape of one paged-cache configuration."""

    page_size: int                 # positions per page
    pages_per_slot: int            # ceil(max_len / page_size)
    num_pages: int                 # shared pool size (all slots)
    kv_int8: bool = False          # int8 K/V pool + per-row f32 scales

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {self.num_pages}")

    @property
    def padded_len(self) -> int:
        """Linear positions addressable through one slot's page list."""
        return self.page_size * self.pages_per_slot

    def pages_for(self, num_positions: int) -> int:
        """Pages needed to hold ``num_positions`` cache rows."""
        return -(-int(num_positions) // self.page_size)


def paged_layout(max_len: int, page_size: int, num_slots: int,
                 num_pages: Optional[int] = None,
                 kv_int8: bool = False) -> PagedLayout:
    """Build a layout for an engine configuration.

    ``num_pages`` defaults to full fixed-cache capacity
    (``num_slots * pages_per_slot`` — every slot can grow to max_len
    simultaneously); benchmarks pass a smaller pool to realize the
    capacity win (more slots than the pool could hold at max_len)."""
    pps = -(-int(max_len) // int(page_size))
    return PagedLayout(page_size=int(page_size), pages_per_slot=pps,
                       num_pages=int(num_pages) if num_pages is not None
                       else int(num_slots) * pps, kv_int8=kv_int8)


class PageAllocator:
    """Host-side free-list allocator over the shared page pool.

    Invariants (asserted by tests/test_paged_serve.py):

    * a page id is owned by AT MOST one slot at a time — ``ensure`` only
      hands out ids from the free list, ``free_slot`` returns a slot's
      whole list (so a preempted neighbor can never alias a live page);
    * ``table()`` row ``s`` holds slot s's pages in sequence order,
      ``-1`` past the allocated frontier;
    * allocation is lazy and monotone per slot: ``ensure(s, upto_pos)``
      extends the slot's list just enough to cover ``upto_pos``.
    """

    def __init__(self, layout: PagedLayout, num_slots: int):
        self.layout = layout
        self.num_slots = num_slots
        # LIFO free list: recycled pages are re-issued hottest-first
        self._free: List[int] = list(range(layout.num_pages))[::-1]
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self._table = np.full((num_slots, layout.pages_per_slot), -1,
                              np.int32)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def used_pages(self, slot: int) -> int:
        return len(self._owned[slot])

    def can_fit(self, num_positions: int) -> bool:
        return self.layout.pages_for(num_positions) <= self.free_pages

    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Grow slot's page list to cover linear position ``upto_pos``.

        Returns False (allocating NOTHING) if the free list cannot cover
        the growth — the caller preempts or raises; a partial grant
        would leave a write with no page to land in."""
        need = self.layout.pages_for(upto_pos + 1)
        if need > self.layout.pages_per_slot:
            raise ValueError(
                f"slot {slot}: position {upto_pos} needs {need} pages but "
                f"the layout caps a slot at {self.layout.pages_per_slot}")
        grow = need - len(self._owned[slot])
        if grow <= 0:
            return True
        if grow > len(self._free):
            return False
        for _ in range(grow):
            page = self._free.pop()
            self._table[slot, len(self._owned[slot])] = page
            self._owned[slot].append(page)
        return True

    def free_slot(self, slot: int) -> None:
        """Return all of a slot's pages to the free list (no zeroing —
        reads mask by position, prefill rewrites before attending)."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self._table[slot, :] = -1

    def table(self) -> np.ndarray:
        """[num_slots, pages_per_slot] int32 page table (-1 = unset).
        A copy — the jitted step must never see in-place growth."""
        return self._table.copy()
