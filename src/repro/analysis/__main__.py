"""``python -m repro.analysis`` — the static invariant gate.

--check  (default) trace the full registered signature/geometry matrix
         and lint it against R1–R5; exit 1 on any violation.
--mutate seed the known-bad variants and assert every rule fires;
         exit 1 if any rule stays silent on its mutant.

Runs on CPU with forced host devices (``--devices``, default 8) and
Pallas interpret mode, so CI needs no accelerator. ``--rules R2,R3``
restricts the catalog; ``--steps`` restricts the matrix; ``--json``
emits a machine-readable report.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _parse(argv):
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static jaxpr/HLO invariant linter (R1-R5)")
    p.add_argument("--check", action="store_true",
                   help="lint HEAD across the signature matrix (default)")
    p.add_argument("--mutate", action="store_true",
                   help="seed known-bad variants; every rule must fire")
    p.add_argument("--rules", default="",
                   help="comma-separated rule ids (default: all)")
    p.add_argument("--steps", default="",
                   help="comma-separated step names (default: all)")
    p.add_argument("--devices", type=int, default=8,
                   help="forced XLA host device count (default 8)")
    p.add_argument("--json", action="store_true", dest="as_json")
    return p.parse_args(argv)


def main(argv=None) -> int:
    args = _parse(sys.argv[1:] if argv is None else argv)
    if not args.mutate:
        args.check = True

    # before any jax import: host devices + interpret-mode kernels
    from repro.launch._bootstrap import ensure_host_devices
    ensure_host_devices(args.devices)
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

    from repro.analysis import engine, mutants
    from repro.analysis.registry import CaseEnv

    rule_ids = [r for r in args.rules.split(",") if r.strip()] or None
    steps = [s for s in args.steps.split(",") if s.strip()] or None

    import jax
    env = CaseEnv(max_devices=jax.device_count())
    report = {}
    failed = False

    if args.check:
        violations, artifacts = engine.run_check(env, rule_ids, steps)
        report["check"] = {
            "cases": [a.case.label for a in artifacts],
            "violations": [str(v) for v in violations],
        }
        if violations:
            failed = True
        if not args.as_json:
            print(f"[analysis] --check: {len(artifacts)} cases, "
                  f"{len(violations)} violation(s)")
            for v in violations:
                print(f"  FAIL {v}")

    if args.mutate:
        results = mutants.run_mutants(env)
        report["mutate"] = {name: {"fired": fired, "detail": detail}
                           for name, (fired, detail) in results.items()}
        silent = [n for n, (fired, _) in results.items() if not fired]
        if silent:
            failed = True
        if not args.as_json:
            print(f"[analysis] --mutate: {len(results)} mutants, "
                  f"{len(silent)} silent")
            for name, (fired, detail) in sorted(results.items()):
                print(f"  {'FIRED' if fired else 'SILENT'} "
                      f"{name}: {detail}")

    if args.as_json:
        print(json.dumps(report, indent=2))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
