"""Step-builder registry for the static analyzer (DESIGN_ANALYSIS.md).

Every jit boundary the CLIs can reach registers a *case provider* here:
``launch/steps.py`` (train / prefill / controlled serve-decode steps),
``launch/serve.py`` (the serve engine's fused stepper) and
``cluster/replica.py`` (the step a cluster tick drives). The analyzer's
engine calls each provider with a :class:`CaseEnv` and lints the
returned :class:`TraceCase` list against the R1–R5 rules — so a new
driver that forgets to register is caught by the completeness test
(tests/test_analysis.py), and a registered driver gets the full
signature-matrix audit for free.

This module is deliberately dependency-free (no jax import): providers
import it at module scope without dragging the analyzer (or jax) into
library import time. The provider bodies do the heavy imports lazily.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

#: step names every CLI-reachable driver must register
#: (tests/test_analysis.py asserts completeness against this)
REQUIRED_STEPS = ("train_step", "prefill_step", "serve_decode_step",
                  "serve_engine_step", "cluster_tick")


@dataclasses.dataclass
class CaseEnv:
    """What the analyzer's host can afford for this run."""
    max_devices: int = 1          # XLA host device count available
    compile_hlo: bool = True      # lower+compile cases flagged compile_hlo
    heavy: bool = True            # allow providers that build live engines


@dataclasses.dataclass
class TraceCase:
    """One traceable (fn, args) point in the signature/geometry matrix.

    ``args`` are ShapeDtypeStructs (or arrays) — tracing never executes.
    ``signature`` buckets cases for the R1 cross-case retrace audit:
    cases sharing a (step, signature) bucket MUST produce identical
    jaxprs (that is exactly the PlanCompileCache keying contract).
    ``retrace`` lists alternative builds of the same signature — e.g. a
    PlanStatic expressed via the legacy ``mig_blocks`` field vs the
    canonical ``mig_shed`` tuple — that must trace identically.
    ``state_argnums`` are hot-loop state buffers (KV cache, …) that must
    be donated (R2); ``expect`` carries rule-specific expectations
    (R3 collective counts, R4 budget overrides, R5 allowances)."""
    step: str
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    mesh: Any = None
    donate_argnums: Tuple[int, ...] = ()
    state_argnums: Tuple[int, ...] = ()
    in_shardings: Any = None
    out_shardings: Any = None
    expect: Dict[str, Any] = dataclasses.field(default_factory=dict)
    compile_hlo: bool = False
    signature: str = ""
    retrace: Tuple[Tuple[str, Callable, Tuple[Any, ...]], ...] = ()

    @property
    def label(self) -> str:
        return f"{self.step}/{self.name}"


@dataclasses.dataclass
class Artifact:
    """Trace/compile products of one case, as the rules see them."""
    case: TraceCase
    jaxpr: Any = None             # ClosedJaxpr
    jaxpr_text: str = ""
    jaxpr_hash: str = ""
    retrace_hashes: Tuple[Tuple[str, str], ...] = ()
    hlo_text: str = ""
    error: str = ""


Provider = Callable[[CaseEnv], List[TraceCase]]

_PROVIDERS: Dict[str, Provider] = {}


def register(step: str, provider: Provider) -> None:
    """Idempotent: re-import of a driver module re-registers in place."""
    _PROVIDERS[step] = provider


def names() -> List[str]:
    return sorted(_PROVIDERS)


def provider(step: str) -> Provider:
    return _PROVIDERS[step]


def cases_for(env: CaseEnv,
              steps: Optional[List[str]] = None) -> List[TraceCase]:
    out: List[TraceCase] = []
    for step in names():
        if steps and step not in steps:
            continue
        out.extend(_PROVIDERS[step](env))
    return out


def load_providers() -> List[str]:
    """Import every module known to register providers; returns the
    resulting registry names. New drivers: register in your module and
    add the import here (the completeness test will remind you)."""
    import repro.launch.steps         # noqa: F401  train/prefill/serve-decode
    import repro.launch.serve         # noqa: F401  serve_engine_step
    import repro.cluster.replica      # noqa: F401  cluster_tick
    import repro.analysis.micro       # noqa: F401  collective/kernel micro-steps
    return names()
