"""Shared HLO-text parsing + analytic cost models (the ONE home).

Absorbs the ad-hoc parsers that grew in ``launch/hlo_analysis.py`` and
``launch/hlo_inspect.py`` (both are deprecation shims now): instruction
iteration, collective byte accounting, op/collective histograms, the
overlap report, rooflines and the analytic step-cost floors. The rule
engine (analysis/rules.py), the dry-run, the roofline bench and the HLO
tests all read compiled text through this module, so a parser fix lands
everywhere at once.

Byte-accounting semantics (fixes two long-standing edge cases):

* tuple-shaped collective outputs — a grouped psum like
  ``%ar = (f32[a], f32[b]) all-reduce(%x, %y)`` moves BOTH elements, so
  every real element is summed;
* async ``-start`` tuples — ``all-reduce-start`` carries the operand
  aliases AND the result in one tuple ``(op, result)``; counting the
  whole tuple doubled the payload. Mirrored halves are now counted once.
* ``-done`` lines never contribute bytes, whatever their result shape
  (a ``(f32[...], token[])`` result tuple used to be ambiguous).
"""
from __future__ import annotations

import collections
import dataclasses
import re
from typing import Dict, Iterator, List, NamedTuple, Optional, Tuple

# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_elements(shape_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """[(dtype, dims)] for every array element in a (possibly tuple)
    HLO shape string; layout annotations are ignored."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        out.append((dtype,
                    tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes(shape_str: str) -> int:
    """Total bytes over every known-dtype element of the shape string
    (tuples sum ALL their elements; token/opaque elements are skipped)."""
    total = 0
    for dtype, dims in shape_elements(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


shape_bytes = _shape_bytes     # public name; _shape_bytes kept for the shim


class Instr(NamedTuple):
    """One parsed HLO instruction line."""
    name: str
    shape: str
    op: str
    line: str


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\((?:[^()]|\([^()]*\))*\)|[\w\[\],{}\s/]*?)\s*"
    r"(?P<op>[\w\-]+)\(")


def iter_instructions(hlo_text: str) -> Iterator[Instr]:
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _INSTR_RE.match(s)
        if m:
            yield Instr(m.group("name"), m.group("shape").strip(),
                        m.group("op"), s)


def collective_base_kind(op: str) -> Optional[str]:
    """The collective family of an opcode (``all-reduce-start`` ->
    ``all-reduce``) or None for non-collective ops."""
    base = op
    for suffix in ("-start", "-done"):
        if base.endswith(suffix):
            base = base[: -len(suffix)]
    return base if base in COLLECTIVE_KINDS else None


def collective_payload_bytes(shape_str: str, op: str) -> int:
    """Payload bytes a collective instruction actually moves.

    ``-done``: 0 (the pair was counted at ``-start``). ``-start`` with a
    tuple shape: the tuple is ``(operand aliases..., results...)`` — when
    the two halves mirror (the canonical async form) only the result
    half is counted; otherwise every known-dtype element once. Sync
    tuple shapes (grouped psum) count every element."""
    if op.endswith("-done"):
        return 0
    elems = shape_elements(shape_str)

    def total(es):
        b = 0
        for dt, dims in es:
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims:
                n *= d
            b += n * _DTYPE_BYTES[dt]
        return b

    if op.endswith("-start") and shape_str.lstrip().startswith("("):
        half = len(elems) // 2
        if (len(elems) >= 2 and len(elems) % 2 == 0
                and [d for _, d in elems[:half]] == [d for _, d in elems[half:]]):
            return total(elems[half:])
        return total(elems)
    return _shape_bytes(shape_str)


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum payload bytes of every collective op in (per-device) HLO.

    Returns {kind: bytes} + {"total": ...}. ``-start``/``-done`` async
    pairs are counted once (on ``-start``)."""
    out = {k: 0 for k in COLLECTIVE_KINDS}
    for ins in iter_instructions(hlo_text):
        kind = collective_base_kind(ins.op)
        if kind is None:
            continue
        out[kind] += collective_payload_bytes(ins.shape, ins.op)
    out["total"] = sum(out[k] for k in COLLECTIVE_KINDS)
    return out


# ---------------------------------------------------------------------------
# histograms / inspection (from launch/hlo_inspect.py)
# ---------------------------------------------------------------------------


def collective_histogram(hlo_text: str) -> List[Tuple[str, str, int, int]]:
    """[(kind, shape, count, total_bytes)] sorted by total bytes desc."""
    hist: Dict[Tuple[str, str], List[int]] = collections.defaultdict(
        lambda: [0, 0])
    for ins in iter_instructions(hlo_text):
        kind = collective_base_kind(ins.op)
        if kind is None or ins.op.endswith("-done"):
            continue
        key = (kind, ins.shape)
        hist[key][0] += 1
        hist[key][1] += collective_payload_bytes(ins.shape, ins.op)
    rows = [(k, s, c, b) for (k, s), (c, b) in hist.items()]
    return sorted(rows, key=lambda r: -r[3])


def find_redundant_collectives(hlo_text: str, min_count: int = 2
                               ) -> List[Tuple[str, str, int, int]]:
    """Same-kind same-shape collectives appearing >= min_count times in the
    TOP-LEVEL computation (outside while bodies) — candidates for CSE or
    hoisting."""
    m = re.search(r"ENTRY[^{]*\{(.*)", hlo_text, re.S)
    body = m.group(1) if m else hlo_text
    return [r for r in collective_histogram(body) if r[2] >= min_count]


def op_histogram(hlo_text: str) -> Dict[str, int]:
    """Opcode → count over the whole module (entry + nested computations).

    The kernel-backward acceptance rule reads this: the pruned-matmul
    gradient path must stay free of ``gather``/``scatter`` (the XLA
    zero-imputation path materializes both)."""
    counts = collections.Counter()
    for ins in iter_instructions(hlo_text):
        counts[ins.op] += 1
    return dict(counts)


def reshape_churn(hlo_text: str) -> Dict[str, int]:
    counts = collections.Counter()
    for ins in iter_instructions(hlo_text):
        if ins.op in ("reshape", "transpose", "copy", "all-to-all"):
            counts[ins.op] += 1
    return dict(counts)


def report(hlo_text: str, top: int = 10) -> str:
    lines = ["== collective histogram (top by bytes) =="]
    for kind, shape, count, nbytes in collective_histogram(hlo_text)[:top]:
        lines.append(f"  {kind:20s} ×{count:<4d} {nbytes/2**20:8.1f} MiB  {shape[:60]}")
    red = find_redundant_collectives(hlo_text)
    lines.append(f"== redundant top-level collectives: {len(red)} ==")
    for kind, shape, count, nbytes in red[:top]:
        lines.append(f"  {kind:20s} ×{count:<4d} {nbytes/2**20:8.1f} MiB  {shape[:60]}")
    lines.append(f"== layout churn: {reshape_churn(hlo_text)} ==")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# collective/compute overlap report (ISSUE 7)
# ---------------------------------------------------------------------------

# kinds with an async -start/-done form worth pairing (all-to-all excluded:
# XLA emits it synchronously on the paths we audit)
_PAIRED_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                 "collective-permute")
_DONE_OPERAND_RE = re.compile(r"-done\(\s*%?([\w.\-]+)")

# instruction kinds that are bookkeeping, not schedulable compute
_NON_COMPUTE = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "after-all", "opt-barrier"}


def collective_overlap_report(hlo_text: str) -> dict:
    """Per-step report of how much collective traffic overlaps compute:
    walks the scheduled HLO, pairs every ``-start`` with its ``-done``,
    and counts the compute instructions the scheduler placed BETWEEN
    them. A pair with no intervening compute is async in name only — its
    bytes are fully exposed. Synchronous collectives (no -start form)
    are exposed by definition.

    Returns {"pairs": [...], "total_bytes", "overlapped_bytes",
    "fraction_overlapped", "async_pairs", "sync_collectives"}."""
    open_pairs: Dict[str, dict] = {}
    pairs = []
    sync_count = 0
    total = overlapped = 0
    for ins in iter_instructions(hlo_text):
        kind = collective_base_kind(ins.op)
        if kind in _PAIRED_KINDS and ins.op.endswith("-start"):
            open_pairs[ins.name] = {
                "kind": kind,
                "bytes": collective_payload_bytes(ins.shape, ins.op),
                "intervening_compute_ops": 0}
            continue
        if kind in _PAIRED_KINDS and ins.op.endswith("-done"):
            mo = _DONE_OPERAND_RE.search(ins.line)
            p = open_pairs.pop(mo.group(1), None) if mo else None
            if p is None:       # -done on a name we never saw start
                continue
            p["overlapped"] = p["intervening_compute_ops"] > 0
            pairs.append(p)
            total += p["bytes"]
            if p["overlapped"]:
                overlapped += p["bytes"]
            continue
        if kind in _PAIRED_KINDS:
            b = collective_payload_bytes(ins.shape, ins.op)
            pairs.append({"kind": kind, "bytes": b,
                          "intervening_compute_ops": 0,
                          "overlapped": False})
            sync_count += 1
            total += b
            continue
        if open_pairs and ins.op not in _NON_COMPUTE:
            for p in open_pairs.values():
                p["intervening_compute_ops"] += 1
    return {
        "pairs": pairs,
        "total_bytes": total,
        "overlapped_bytes": overlapped,
        "fraction_overlapped": overlapped / total if total else 0.0,
        "async_pairs": len(pairs) - sync_count,
        "sync_collectives": sync_count,
    }


# ---------------------------------------------------------------------------
# module-header facts (donation / aliasing)
# ---------------------------------------------------------------------------


def input_output_alias_pairs(hlo_text: str) -> List[Tuple[int, int]]:
    """[(param_number, output_index_head)] parsed from the module header's
    ``input_output_alias={ {out}: (param, {index}, kind), ... }`` — the
    compiled proof that donated buffers actually alias (R2)."""
    start = hlo_text.find("input_output_alias={")
    if start < 0:
        return []
    i = start + len("input_output_alias=")
    depth = 0
    for j in range(i, min(len(hlo_text), i + 100_000)):
        if hlo_text[j] == "{":
            depth += 1
        elif hlo_text[j] == "}":
            depth -= 1
            if depth == 0:
                body = hlo_text[i + 1:j]
                break
    else:
        return []
    out = []
    for m in re.finditer(r"\{\s*(\d*)[\d,\s]*\}\s*:\s*\(\s*(\d+)", body):
        head = int(m.group(1)) if m.group(1) else 0
        out.append((int(m.group(2)), head))
    return out


# ---------------------------------------------------------------------------
# roofline + analytic step-cost floors (from launch/hlo_analysis.py)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    coll_breakdown: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "coll_breakdown": self.coll_breakdown,
        }


def roofline_from_compiled(compiled, chips: int) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):      # older API returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text())
    return Roofline(flops_per_device=flops, bytes_per_device=nbytes,
                    coll_bytes_per_device=float(coll["total"]), chips=chips,
                    coll_breakdown=coll)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N_active·B decode."""
    n = cfg.active_param_count() if cfg.moe is not None else cfg.param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def analytic_step_flops(cfg, shape) -> float:
    """Analytic FLOOR for the step's global FLOPs: parameter matmuls
    (MODEL_FLOPS) + attention score/value matmuls (which 6·N·D omits).

    Needed because XLA's ``cost_analysis()`` counts a ``while`` body ONCE,
    not × trip-count — scan-over-layers models under-report by ~L×. The
    roofline's compute term uses max(HLO, analytic)."""
    base = model_flops(cfg, shape)
    if cfg.is_attention_free:
        return base
    B, S = shape.global_batch, shape.seq_len
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    L = cfg.num_layers
    window = cfg.sliding_window or 0
    if shape.kind == "decode":
        ctx = min(window, S) if window else S
        attn = 4.0 * B * ctx * H * hd * L          # one query vs the cache
    else:
        eff = (min(window, S) if window else S / 2.0)   # causal halves it
        attn = 4.0 * B * S * eff * H * hd * L
        if shape.kind == "train":
            attn *= 3.0                            # fwd + 2x bwd
    return base + attn


def analytic_step_bytes(cfg, shape, *, decode_occupancy: float = 1.0) -> float:
    """Analytic FLOOR for global HBM traffic of one step (same rationale
    as :func:`analytic_step_flops` — scan bodies are under-counted).

    train:   params f32 × (grad + AdamW moments rw ≈ 10 accesses)
             + activations (fwd write + bwd read) + logits traffic.
    prefill: params bf16 + activations + KV-cache write.
    decode:  params bf16 + KV-cache read (the classic decode bound).

    ``decode_occupancy`` is mean((cur_pos+1)/max_len) over the slots:
    the fused decode kernel reads only the OCCUPIED cache rows, so the
    decode memory term scales with actual occupancy, not max_len
    (ISSUE 7 — the old full-rows assumption overstated the roofline
    bound for mostly-empty slots). Default 1.0 = every row, which is
    both the unfused path's real traffic and the old behavior."""
    P = float(cfg.param_count())
    B, S = shape.global_batch, shape.seq_len
    d, L, V = cfg.d_model, cfg.num_layers, max(cfg.vocab_size, 1)
    tokens = B * (S if shape.kind != "decode" else 1)
    kv = max(cfg.num_kv_heads, 1) * cfg.resolved_head_dim
    if cfg.mla is not None:
        kv = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
    if cfg.is_attention_free:
        kv = 2 * (cfg.ssm.expand * d * cfg.ssm.d_state) // max(L, 1) if cfg.ssm else 0
    if shape.kind == "train":
        act = tokens * d * L * 16.0          # fwd write + bwd read, f32-ish
        logits = tokens * V * 4.0 * 3.0
        return P * 4.0 * 10.0 + act + logits
    if shape.kind == "prefill":
        act = tokens * d * L * 8.0
        cache_w = 2.0 * B * S * kv * 2.0
        return P * 2.0 + act + cache_w
    # decode: read the occupied cache rows (or the window for SWA archs)
    ctx = min(cfg.sliding_window, S) if cfg.sliding_window else S
    occ = min(max(float(decode_occupancy), 0.0), 1.0)
    cache_r = 2.0 * B * ctx * occ * kv * 2.0 * L
    return P * 2.0 + cache_r


def analytic_step_collective_bytes(cfg, shape, mesh_shape) -> float:
    """Analytic FLOOR for GLOBAL collective traffic of one step under the
    Megatron-1D sharding (same while-body-undercount rationale).

    Per transformer layer: 2 activation all-reduces over TP in fwd
    (attention out + FFN out) and 2 in bwd; ring all-reduce moves
    2·(e−1)/e · size through each device. Training adds the DP gradient
    all-reduce of the TP-sharded params. MoE (expert-parallel) adds the
    dispatch/return all-to-alls."""
    e = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = e * dp
    if e <= 1:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (S if shape.kind != "decode" else 1)
    d, L = cfg.d_model, cfg.num_layers
    bytes_el = 4.0 if shape.kind == "train" else 2.0
    ar_factor = 2.0 * (e - 1) / e
    n_ar = (4.0 if shape.kind == "train" else 2.0)
    if cfg.is_attention_free:
        n_ar /= 2.0                       # single mixer psum per layer
    # activation all-reduces run per TP group on data-local tokens;
    # global volume = per-device volume × chips
    act_coll_global = n_ar * L * ar_factor * (tokens / dp) * d * bytes_el * chips
    total = act_coll_global
    if shape.kind == "train":
        p_local = cfg.param_count() / e
        total += ar_factor * p_local * 4.0 * chips     # DP grad all-reduce
    if cfg.moe is not None and cfg.moe.expert_sharding == "expert":
        # dispatch + combine all-to-alls of the grouped token buffers
        k = cfg.moe.top_k * cfg.moe.capacity_factor
        total += 2.0 * k * tokens * d * bytes_el * (3.0 if shape.kind == "train" else 1.0)
    return total
