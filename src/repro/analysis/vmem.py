"""R4: static VMEM budgeting for every ``pallas_call`` in a jaxpr.

TPU cores hold ~16 MiB of VMEM (the Pallas pipeline stages every
BlockSpec tile of the inputs/outputs through it, double-buffered, plus
any explicit scratch). Mosaic reports an over-subscription only at
compile time, deep inside a real lowering, as an opaque OOM — this
module prices the tiles from the traced jaxpr instead, so a bad
``tm``/``tn``/``block`` choice in kernels/pruned_matmul.py or
kernels/decode_attn.py becomes a named pre-compile error.

Estimate per pallas_call::

    est = 2 × Σ block_bytes(inputs + outputs)   # double-buffered pipeline
        +     Σ scratch_bytes                   # resident, single copy

Scalar-prefetch operands live in SMEM and are excluded. The grid_mapping
introspection is version-sensitive (jax 0.4.x); failures degrade to an
"unpriced" report rather than a crash — the rule only fires on kernels
it could actually price.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Tuple

import numpy as np

#: default per-core budget (bytes): TPU v5e-class VMEM
DEFAULT_VMEM_BUDGET = 16 * 2 ** 20


class VmemBudgetError(RuntimeError):
    """A pallas_call's static tile footprint exceeds the VMEM budget."""


@dataclasses.dataclass
class PallasCallReport:
    name: str
    grid: tuple
    block_bytes: int              # Σ over in/out block tiles (single copy)
    scratch_bytes: int
    est_bytes: Optional[int]      # 2*blocks + scratch; None = unpriced
    detail: List[str] = dataclasses.field(default_factory=list)
    note: str = ""

    def over_budget(self, budget: int) -> bool:
        return self.est_bytes is not None and self.est_bytes > budget


def _dtype_bytes(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Every eqn in a (Closed)Jaxpr, recursing into call/control-flow
    sub-jaxprs (pjit, scan, while, cond, custom_vjp, shard_map, ...)."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)      # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn
        for key, val in eqn.params.items():
            if key == "branches":
                for b in val:
                    yield from iter_eqns(b)
            elif hasattr(val, "eqns") or hasattr(val, "jaxpr"):
                # pallas_call's own kernel jaxpr is priced separately;
                # still recurse so nested pallas_calls are found
                yield from iter_eqns(val)
            elif isinstance(val, (tuple, list)):
                for v in val:
                    if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                        yield from iter_eqns(v)


def _block_bytes(grid_mapping) -> Tuple[int, List[str]]:
    total = 0
    detail = []
    for i, bm in enumerate(grid_mapping.block_mappings):
        shape = tuple(int(d) if isinstance(d, (int, np.integer)) else 1
                      for d in bm.block_shape)
        sds = getattr(bm, "array_shape_dtype", None)
        nbytes = int(np.prod(shape or (1,))) * (
            _dtype_bytes(sds.dtype) if sds is not None else 4)
        total += nbytes
        detail.append(f"block[{i}] {shape} = {nbytes} B")
    return total, detail


def _scratch_bytes(eqn) -> Tuple[int, List[str]]:
    gm = eqn.params.get("grid_mapping")
    kernel = eqn.params.get("jaxpr")
    n = int(getattr(gm, "num_scratch_operands", 0) or 0)
    if not n or kernel is None:
        return 0, []
    inner = getattr(kernel, "jaxpr", kernel)
    total = 0
    detail = []
    for v in inner.invars[-n:]:
        aval = v.aval
        shape = tuple(getattr(aval, "shape", ()) or ())
        nbytes = int(np.prod(shape or (1,))) * _dtype_bytes(
            getattr(aval, "dtype", np.float32))
        total += nbytes
        detail.append(f"scratch {shape} = {nbytes} B")
    return total, detail


def pallas_reports(jaxpr) -> List[PallasCallReport]:
    """Price every pallas_call reachable from a (Closed)Jaxpr."""
    out = []
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        name = str(eqn.params.get("name_and_src_info",
                                  eqn.params.get("name", "pallas_call")))
        name = name.split(" ")[0]
        try:
            gm = eqn.params["grid_mapping"]
            blocks, bdetail = _block_bytes(gm)
            scratch, sdetail = _scratch_bytes(eqn)
            out.append(PallasCallReport(
                name=name, grid=tuple(gm.grid),
                block_bytes=blocks, scratch_bytes=scratch,
                est_bytes=2 * blocks + scratch,
                detail=bdetail + sdetail))
        except Exception as e:                        # noqa: BLE001
            out.append(PallasCallReport(
                name=name, grid=(), block_bytes=0, scratch_bytes=0,
                est_bytes=None, note=f"unpriced: {e!r}"))
    return out


def check_budget(jaxpr, budget: int = DEFAULT_VMEM_BUDGET) -> List[str]:
    """Violation messages for every over-budget pallas_call (R4)."""
    msgs = []
    for r in pallas_reports(jaxpr):
        if r.over_budget(budget):
            msgs.append(
                f"pallas_call '{r.name}' grid={r.grid} needs "
                f"~{r.est_bytes / 2**20:.1f} MiB VMEM "
                f"(2×{r.block_bytes} block + {r.scratch_bytes} scratch) "
                f"> budget {budget / 2**20:.1f} MiB; "
                f"tiles: {'; '.join(r.detail)}")
    return msgs


def assert_fits(fn, *args, budget: int = DEFAULT_VMEM_BUDGET) -> None:
    """Named pre-compile gate: trace ``fn(*args)`` abstractly and raise
    :class:`VmemBudgetError` if any pallas_call oversubscribes VMEM —
    use before handing a new tile configuration to Mosaic."""
    import jax
    msgs = check_budget(jax.make_jaxpr(fn)(*args), budget)
    if msgs:
        raise VmemBudgetError("; ".join(msgs))
