"""Known-bad variants that prove each rule actually fires.

``--mutate`` seeds one deliberate violation per rule — a retrace that
forks (R1), a host callback and a dropped donation (R2), psum chunking
silently ignored (R3), an oversubscribed Pallas tile (R4), an f64
promotion (R5) — and asserts the corresponding rule reports it. A rule
that stays silent on its mutant is a dead rule; CI fails on that just
as hard as on a dirty HEAD.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from repro.analysis import engine
from repro.analysis import registry as reg

# Hand-written bad HLO for the no-device R3 fallback: psum_chunks=4 was
# requested but the module kept the single fat full-width all-reduce.
_R3_BAD_HLO = """\
HloModule mutant_chunks_ignored, entry_computation_layout={(f32[2,8,256]{2,1,0})->f32[2,8,256]{2,1,0}}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(f32[] %a, f32[] %b)
}

ENTRY %main (p0: f32[2,8,256]) -> f32[2,8,256] {
  %p0 = f32[2,8,256]{2,1,0} parameter(0)
  ROOT %ar = f32[2,8,256]{2,1,0} all-reduce(f32[2,8,256]{2,1,0} %p0), replica_groups={}, to_apply=%sum
}
"""


def _sds(shape, dtype="float32"):
    import jax
    import jax.numpy as jnp
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _m_retrace_forks(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R1: two builds of the "same" plan signature bake different
    constants into the step — the moral equivalent of keying the
    compile cache on a non-canonical plan signature. (Two fresh builder
    closures, because jax's trace cache makes re-tracing one fn object
    trivially stable.)"""
    def build(c):
        return lambda x: x * c

    x = _sds((8,))
    case = reg.TraceCase(
        step="mutant", name="retrace_forks", fn=build(1.0), args=(x,),
        retrace=(("rebuild-same-signature", build(2.0), (x,)),))
    return [engine.trace_artifact(case, env)]


def _m_host_callback(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R2: a pure_callback smuggled into the hot step."""
    import jax
    import numpy as np

    def fn(x):
        y = jax.pure_callback(lambda a: np.asarray(a),
                              jax.ShapeDtypeStruct(x.shape, x.dtype), x)
        return y + 1.0

    case = reg.TraceCase(step="mutant", name="host_callback", fn=fn,
                         args=(_sds((8,)),))
    return [engine.trace_artifact(case, env)]


def _m_donation_dropped(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R2: a state buffer (argnum 1, think KV cache) declared hot but
    NOT in donate_argnums."""
    def fn(p, cache):
        return p, cache + 1.0

    case = reg.TraceCase(step="mutant", name="donation_dropped", fn=fn,
                         args=(_sds((4,)), _sds((4, 8))),
                         state_argnums=(1,), donate_argnums=())
    return [engine.trace_artifact(case, env)]


def _m_chunks_ignored(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R3: the plan says psum_chunks=4 but the compiled module kept one
    fat full-width all-reduce. With >= 8 host devices this compiles the
    REAL controlled projection built with psum_chunks=1 and lints it
    against the chunks=4 expectation; otherwise a handwritten bad module
    stands in."""
    expect = {"chunked_all_reduce": {
        "chunks": 4, "full_dims": "2,8,256", "chunk_dims": "2,8,64"}}
    if env.compile_hlo and env.max_devices >= 8:
        from repro.analysis import micro
        good = micro._collective_cases(env)
        k1 = next(c for c in good if c.name == "proj_psum_chunks1")
        bad = reg.TraceCase(step="mutant", name="chunks_ignored",
                            fn=k1.fn, args=k1.args, mesh=k1.mesh,
                            compile_hlo=True, expect=expect)
        return [engine.trace_artifact(bad, env)]
    case = reg.TraceCase(step="mutant", name="chunks_ignored",
                         fn=lambda: None, args=(), expect=expect)
    return [reg.Artifact(case=case, hlo_text=_R3_BAD_HLO)]


def _m_vmem_blowout(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R4: the fused FFN kernel at a hidden width whose default tiles
    oversubscribe the 16 MiB budget."""
    from repro.kernels import ops

    def fn(x, wu, wd, k):
        import jax
        return ops.fused_pruned_ffn(x, wu, wd, k, None, jax.nn.silu)

    case = reg.TraceCase(
        step="mutant", name="vmem_blowout", fn=fn,
        args=(_sds((256, 4096)), _sds((4096, 8192)), _sds((8192, 4096)),
              _sds((32,), "int32")))
    return [engine.trace_artifact(case, env)]


def _m_f64_leak(env: reg.CaseEnv) -> List[reg.Artifact]:
    """R5: an accidental float64 promotion inside the step."""
    from jax.experimental import enable_x64

    def fn(x):
        return x.astype("float64") * 2.0

    case = reg.TraceCase(step="mutant", name="f64_leak", fn=fn,
                         args=(_sds((8,)),))
    with enable_x64():
        return [engine.trace_artifact(case, env)]


#: rule id -> (mutant name, artifact builder)
MUTANTS: Tuple[Tuple[str, str, Callable], ...] = (
    ("R1", "retrace_forks", _m_retrace_forks),
    ("R2", "host_callback", _m_host_callback),
    ("R2", "donation_dropped", _m_donation_dropped),
    ("R3", "chunks_ignored", _m_chunks_ignored),
    ("R4", "vmem_blowout", _m_vmem_blowout),
    ("R5", "f64_leak", _m_f64_leak),
)


def run_mutants(env: reg.CaseEnv = None
                ) -> Dict[str, Tuple[bool, str]]:
    """Returns {mutant_name: (rule_fired, detail)}. Every entry must
    fire for the analyzer itself to be considered alive."""
    env = env or reg.CaseEnv()
    out: Dict[str, Tuple[bool, str]] = {}
    for rule_id, name, build in MUTANTS:
        try:
            arts = build(env)
        except Exception as e:                            # noqa: BLE001
            out[name] = (False, f"mutant build failed: {e!r}")
            continue
        errs = [a.error for a in arts if a.error]
        if errs:
            out[name] = (False, f"mutant trace failed: {errs}")
            continue
        hits = [v for v in engine.lint(arts, [rule_id])
                if v.rule == rule_id]
        if hits:
            out[name] = (True, str(hits[0]))
        else:
            out[name] = (False,
                         f"rule {rule_id} did NOT fire on its mutant")
    return out
