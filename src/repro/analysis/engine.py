"""The analyzer's trace/compile/lint driver.

For every registered :class:`~repro.analysis.registry.TraceCase` it
abstractly traces the step (``jax.make_jaxpr`` over ShapeDtypeStructs —
nothing executes), hashes the jaxpr twice plus every declared alternate
build (R1), optionally lowers+compiles to HLO text (cases flagged
``compile_hlo``), then runs the R1–R5 rule catalog over the full
artifact batch. A case that fails to trace or compile is itself a
violation (rule id ``engine``) — the matrix must stay green, not just
the rules.
"""
from __future__ import annotations

import contextlib
import hashlib
from typing import List, Optional, Sequence, Tuple

from repro.analysis import registry as reg
from repro.analysis import rules as R


def jaxpr_hash(text: str) -> str:
    return hashlib.sha256(text.encode()).hexdigest()[:16]


def _lower(case: reg.TraceCase):
    import jax
    if hasattr(case.fn, "lower"):          # already jitted (serve stepper)
        return case.fn.lower(*case.args)
    kw = {}
    if case.in_shardings is not None:
        kw["in_shardings"] = case.in_shardings
    if case.out_shardings is not None:
        kw["out_shardings"] = case.out_shardings
    if case.donate_argnums:
        kw["donate_argnums"] = case.donate_argnums
    return jax.jit(case.fn, **kw).lower(*case.args)


def trace_artifact(case: reg.TraceCase, env: reg.CaseEnv) -> reg.Artifact:
    import jax
    from repro.sharding import use_mesh
    ctx = use_mesh(case.mesh) if case.mesh is not None \
        else contextlib.nullcontext()
    try:
        with ctx:
            closed = jax.make_jaxpr(case.fn)(*case.args)
            text = str(closed)
            h = jaxpr_hash(text)
            retr: List[Tuple[str, str]] = [
                ("double-trace",
                 jaxpr_hash(str(jax.make_jaxpr(case.fn)(*case.args))))]
            for label, fn, args in case.retrace:
                retr.append(
                    (label, jaxpr_hash(str(jax.make_jaxpr(fn)(*args)))))
            hlo = ""
            if case.compile_hlo and env.compile_hlo:
                hlo = _lower(case).compile().as_text()
        return reg.Artifact(case=case, jaxpr=closed, jaxpr_text=text,
                            jaxpr_hash=h, retrace_hashes=tuple(retr),
                            hlo_text=hlo)
    except Exception as e:                                # noqa: BLE001
        return reg.Artifact(case=case,
                            error=f"{type(e).__name__}: {e}")


def lint(artifacts: List[reg.Artifact],
         rule_ids: Optional[Sequence[str]] = None) -> List[R.Violation]:
    """Rules over already-traced artifacts (reused by tests/mutants)."""
    violations: List[R.Violation] = []
    for a in artifacts:
        if a.error:
            violations.append(R.Violation(
                "engine", a.case.step, a.case.name,
                f"trace/compile failed: {a.error}"))
    clean = [a for a in artifacts if not a.error]
    for rule in R.rules_by_id(rule_ids):
        violations.extend(rule.check(clean))
    return violations


def run_check(env: Optional[reg.CaseEnv] = None,
              rule_ids: Optional[Sequence[str]] = None,
              steps: Optional[List[str]] = None,
              ) -> Tuple[List[R.Violation], List[reg.Artifact]]:
    """Trace the whole registered matrix and lint it."""
    env = env or reg.CaseEnv()
    reg.load_providers()
    cases = reg.cases_for(env, steps)
    artifacts = [trace_artifact(c, env) for c in cases]
    return lint(artifacts, rule_ids), artifacts
