"""The declarative rule catalog (R1–R5) the analyzer lints against.

Each rule sees the FULL artifact batch (jaxpr + optional compiled HLO
per :class:`~repro.analysis.registry.TraceCase`) and returns
:class:`Violation`\\ s. What each invariant protects:

R1 retrace audit      — PlanCompileCache keys executables on the plan's
                        canonical signature; two builds of the same
                        signature (or a double-trace of one build) must
                        produce the SAME jaxpr, or the cache silently
                        forks executables and the at-most-one-compile
                        guarantee (and its perf model) is fiction.
R2 host-sync detector — the hot decode/train loop must not host-sync:
                        no callback primitives in the jaxpr, no
                        infeed/outfeed/send/recv or host callbacks in
                        the HLO, and declared state buffers (KV cache)
                        must be donated — an undonated cache doubles
                        HBM and adds a copy per step.
R3 collective audit   — psum_chunks=k compiles to exactly k chunk-width
                        all-reduces and ZERO full-width ones (the
                        latency-hiding scheduler needs the split), and
                        the multi-source migration broadcast stays ONE
                        fused grouped (tuple-shaped) masked psum.
R4 VMEM budget        — every pallas_call's static tile bytes fit the
                        per-core budget (analysis/vmem.py): Mosaic OOM
                        becomes a named pre-compile error.
R5 dtype leak         — no f64/c128 anywhere in hot-path jaxprs or HLO
                        (an accidental x64 promotion doubles every
                        buffer and halves throughput silently).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.analysis import hlo as H
from repro.analysis import vmem as V
from repro.analysis.registry import Artifact


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    step: str
    case: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.step}/{self.case}: {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    description: str
    check: Callable[[List[Artifact]], List[Violation]]


def _v(rule: str, art: Artifact, msg: str) -> Violation:
    return Violation(rule, art.case.step, art.case.name, msg)


# ---------------------------------------------------------------------------
# R1 — retrace audit
# ---------------------------------------------------------------------------


def _check_retrace(arts: List[Artifact]) -> List[Violation]:
    out = []
    for a in arts:
        if not a.jaxpr_hash:
            continue
        for label, h in a.retrace_hashes:
            if h != a.jaxpr_hash:
                out.append(_v("R1", a, (
                    f"retrace '{label}' produced a DIFFERENT jaxpr "
                    f"({h} != {a.jaxpr_hash}): same plan signature would "
                    "fork executables in PlanCompileCache")))
    by_sig: Dict[Tuple[str, str], List[Artifact]] = {}
    for a in arts:
        if a.case.signature and a.jaxpr_hash:
            by_sig.setdefault((a.case.step, a.case.signature), []).append(a)
    for (step, sig), group in by_sig.items():
        hashes = {a.jaxpr_hash for a in group}
        if len(hashes) > 1:
            out.append(Violation("R1", step, sig, (
                f"signature bucket '{sig}' traced to {len(hashes)} distinct "
                f"jaxprs across cases {[a.case.name for a in group]} — "
                "the compile cache would alias different programs")))
    return out


# ---------------------------------------------------------------------------
# R2 — host-sync / donation
# ---------------------------------------------------------------------------

#: jaxpr primitives that round-trip through the host mid-step
BANNED_PRIMITIVES = frozenset({
    "pure_callback", "io_callback", "callback", "debug_callback",
    "outside_call", "infeed", "outfeed", "device_get"})

_HLO_HOST_OPS = frozenset({"infeed", "outfeed", "send", "recv",
                           "send-done", "recv-done"})
_HLO_CALLBACK_RE = re.compile(r'custom_call_target="[^"]*[Cc]allback[^"]*"')


def _jaxpr_prims(jaxpr) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for eqn in V.iter_eqns(jaxpr):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def _check_host_sync(arts: List[Artifact]) -> List[Violation]:
    out = []
    for a in arts:
        if a.jaxpr is not None:
            bad = sorted(set(_jaxpr_prims(a.jaxpr)) & BANNED_PRIMITIVES)
            if bad:
                out.append(_v("R2", a, (
                    f"host-sync primitives in the jitted step: {bad} — "
                    "each one stalls the device on the host every call")))
        if a.hlo_text:
            for ins in H.iter_instructions(a.hlo_text):
                if ins.op in _HLO_HOST_OPS:
                    out.append(_v("R2", a, (
                        f"HLO host transfer op '{ins.op}' compiled into "
                        "the step")))
                    break
            if _HLO_CALLBACK_RE.search(a.hlo_text):
                out.append(_v("R2", a,
                              "HLO custom-call into a host callback"))
        if a.case.state_argnums:
            missing = [i for i in a.case.state_argnums
                       if i not in a.case.donate_argnums]
            if missing:
                out.append(_v("R2", a, (
                    f"state buffers at argnums {missing} are not donated "
                    "(donate_argnums) — the hot loop double-buffers them "
                    "in HBM every step")))
            elif a.hlo_text and not H.input_output_alias_pairs(a.hlo_text):
                out.append(_v("R2", a, (
                    "donation declared but the compiled module has NO "
                    "input_output_alias — the donated state did not "
                    "alias (layout/sharding mismatch?)")))
    return out


# ---------------------------------------------------------------------------
# R3 — collective audit
# ---------------------------------------------------------------------------


def all_reduce_dims(hlo_text: str) -> List[str]:
    """Dims string of the (first element of the) output of every
    all-reduce / all-reduce-start in the module, in order."""
    out = []
    for ins in H.iter_instructions(hlo_text):
        if ins.op in ("all-reduce", "all-reduce-start"):
            elems = H.shape_elements(ins.shape)
            if elems:
                out.append(",".join(str(d) for d in elems[0][1]))
    return out


def audit_chunked_all_reduce(hlo_text: str, chunks: int, full_dims: str,
                             chunk_dims: str
                             ) -> Tuple[List[str], List[str]]:
    """The chunked-epilogue invariant (one source of truth for
    tests/test_kernel_hlo.py and the R3 rule): with psum_chunks=k the
    compiled module holds exactly k chunk-width all-reduces and ZERO
    full-width ones; with k=1 exactly the single full-width one.

    Returns (violations, observed_dims)."""
    observed = all_reduce_dims(hlo_text)
    n_full = sum(1 for d in observed if d == full_dims)
    n_chunk = sum(1 for d in observed if d == chunk_dims)
    msgs = []
    if chunks <= 1:
        if n_full != 1:
            msgs.append(f"expected exactly 1 full-width [{full_dims}] "
                        f"all-reduce, saw {n_full} (all: {observed})")
    else:
        if n_chunk != chunks:
            msgs.append(f"psum_chunks={chunks} but saw {n_chunk} "
                        f"chunk-width [{chunk_dims}] all-reduces "
                        f"(all: {observed})")
        if n_full != 0:
            msgs.append(f"psum_chunks={chunks} left {n_full} full-width "
                        f"[{full_dims}] all-reduce(s) — the epilogue "
                        f"was not split (all: {observed})")
    return msgs, observed


def grouped_psum_count(hlo_text: str, min_elems: int = 2) -> int:
    """Number of grouped (tuple-shaped, >= min_elems real elements)
    all-reduces in compiled HLO. Backend collective combiners can split
    or merge these — prefer :func:`grouped_psum_count_jaxpr` (the rule
    does); this HLO variant serves fixture-based tests."""
    n = 0
    for ins in H.iter_instructions(hlo_text):
        if ins.op in ("all-reduce", "all-reduce-start") \
                and ins.shape.startswith("("):
            elems = [e for e in H.shape_elements(ins.shape)
                     if e[0] in H._DTYPE_BYTES]
            if len(elems) >= min_elems:
                n += 1
    return n


def grouped_psum_count_jaxpr(jaxpr, min_operands: int = 2) -> int:
    """Number of GROUPED psum eqns (>= min_operands operands bound in
    ONE collective) in the traced step. The multi-source migration
    broadcast is exactly one such psum over all export buffers
    (core/migration.py); a regression to per-buffer psums shows up here
    as zero groups regardless of what the backend's collective combiner
    later does to the HLO. Single-operand psums (the TP epilogue) don't
    count."""
    n = 0
    for eqn in V.iter_eqns(jaxpr):
        if eqn.primitive.name == "psum" \
                and len(eqn.invars) >= min_operands:
            n += 1
    return n


def _check_collectives(arts: List[Artifact]) -> List[Violation]:
    out = []
    for a in arts:
        exp = a.case.expect
        ca = exp.get("chunked_all_reduce")
        if ca and a.hlo_text:
            msgs, _ = audit_chunked_all_reduce(
                a.hlo_text, ca["chunks"], ca["full_dims"], ca["chunk_dims"])
            out.extend(_v("R3", a, m) for m in msgs)
        gp = exp.get("grouped_psum")
        if gp and (a.jaxpr is not None or a.hlo_text):
            if a.jaxpr is not None:
                n = grouped_psum_count_jaxpr(a.jaxpr,
                                             gp.get("min_elems", 2))
            else:
                n = grouped_psum_count(a.hlo_text, gp.get("min_elems", 2))
            if n != gp["count"]:
                out.append(_v("R3", a, (
                    f"expected {gp['count']} fused grouped psum(s) "
                    f"(the one masked migration broadcast), saw {n}")))
    return out


# ---------------------------------------------------------------------------
# R4 — Pallas VMEM budget
# ---------------------------------------------------------------------------


def _check_vmem(arts: List[Artifact]) -> List[Violation]:
    out = []
    for a in arts:
        if a.jaxpr is None:
            continue
        budget = a.case.expect.get("vmem_budget", V.DEFAULT_VMEM_BUDGET)
        out.extend(_v("R4", a, m)
                   for m in V.check_budget(a.jaxpr, budget))
    return out


# ---------------------------------------------------------------------------
# R5 — dtype / f64 leak
# ---------------------------------------------------------------------------

_WIDE_DTYPES = ("float64", "complex128")
_HLO_WIDE = ("f64", "c128")


def wide_dtype_eqns(jaxpr) -> List[str]:
    bad = []
    for eqn in V.iter_eqns(jaxpr):
        for v in list(eqn.outvars):
            dt = str(getattr(v.aval, "dtype", ""))
            if dt in _WIDE_DTYPES:
                bad.append(f"{eqn.primitive.name} -> {dt}{list(v.aval.shape)}")
                break
    return bad


def _check_dtypes(arts: List[Artifact]) -> List[Violation]:
    out = []
    for a in arts:
        if a.case.expect.get("allow_f64"):
            continue
        if a.jaxpr is not None:
            bad = wide_dtype_eqns(a.jaxpr)
            if bad:
                out.append(_v("R5", a, (
                    f"f64/c128 values in the traced step: {bad[:4]}"
                    f"{' …' if len(bad) > 4 else ''}")))
        if a.hlo_text:
            wide = sorted({dt for ins in H.iter_instructions(a.hlo_text)
                           for dt, _ in H.shape_elements(ins.shape)
                           if dt in _HLO_WIDE})
            if wide:
                out.append(_v("R5", a,
                              f"wide dtypes {wide} in compiled HLO"))
    return out


# ---------------------------------------------------------------------------
# catalog
# ---------------------------------------------------------------------------

RULES: Tuple[Rule, ...] = (
    Rule("R1", "retrace audit",
         "one plan signature == one jaxpr (PlanCompileCache can't fork)",
         _check_retrace),
    Rule("R2", "host-sync detector",
         "no host callbacks/transfers; hot-loop state is donated",
         _check_host_sync),
    Rule("R3", "collective audit",
         "psum_chunks=k => k chunk-width all-reduces, 0 full-width; "
         "migration broadcast is one fused grouped psum",
         _check_collectives),
    Rule("R4", "Pallas VMEM budget",
         "static tile bytes per pallas_call fit the per-core budget",
         _check_vmem),
    Rule("R5", "dtype/f64-leak check",
         "no f64/c128 in hot-path jaxprs or HLO",
         _check_dtypes),
)

RULE_IDS = tuple(r.id for r in RULES)


def rules_by_id(ids: Optional[Sequence[str]] = None) -> Tuple[Rule, ...]:
    if not ids:
        return RULES
    wanted = {i.strip().upper() for i in ids}
    unknown = wanted - set(RULE_IDS)
    if unknown:
        raise ValueError(f"unknown rule ids {sorted(unknown)}; "
                         f"have {RULE_IDS}")
    return tuple(r for r in RULES if r.id in wanted)
