"""Analyzer-owned micro step cases: the collective (R3) and kernel (R4)
probes that don't belong to any one CLI driver.

``micro_collective`` compiles the controlled row-projection with
``psum_chunks`` in {1, 4} and the migrating controlled FFN — the exact
harness tests/test_kernel_hlo.py and tests/test_multidevice.py pin —
and attaches the R3 expectations (chunk counts, one fused grouped
migration psum). Needs >= 8 host devices; providers degrade to zero
cases below that so the registry stays importable anywhere.

``micro_kernel`` abstractly traces the Pallas kernels of
kernels/pruned_matmul.py and kernels/decode_attn.py at their default
production tiles so R4 prices every shipped tile configuration each
run, not just whichever step happened to take the kernel path.
"""
from __future__ import annotations

from typing import List

from repro.analysis import registry as reg

_E, _B, _S, _D, _N, _BLOCK = 8, 2, 8, 128, 256, 8
_H = 256


def _collective_cases(env: reg.CaseEnv) -> List[reg.TraceCase]:
    if env.max_devices < _E:
        return []
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core.workload import PlanStatic
    from repro.layers.tp_linear import (ControlContext, controlled_ffn,
                                        controlled_proj)

    e = _E
    mesh = Mesh(np.array(jax.devices()[:e]).reshape(1, e), ("data", "model"))
    sds = jax.ShapeDtypeStruct
    x = sds((_B, _S, _D), jnp.float32)
    w = sds((_D, _N), jnp.float32)

    def proj_fn(chunks):
        st = PlanStatic(buckets=(0.0, 0.25, 0.5), block_size=_BLOCK,
                        mig_blocks=0, tp_size=e)
        nb_loc = (_D // e) // _BLOCK
        pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))
        ctx = ControlContext(mesh=mesh, axis="model", static=st,
                             bucket_by_rank=jnp.zeros((e,), jnp.int32),
                             mig_src=jnp.array(-1, jnp.int32),
                             pri={"proj": pri}, psum_chunks=chunks)
        return lambda x_, w_: controlled_proj(x_, w_, ctx, "proj",
                                              split="row")

    full = f"{_B},{_S},{_N}"
    chunk4 = f"{_B},{_S},{_N // 4}"
    cases = [
        reg.TraceCase(
            step="micro_collective", name="proj_psum_chunks1",
            fn=proj_fn(1), args=(x, w), mesh=mesh, compile_hlo=True,
            expect={"chunked_all_reduce": {
                "chunks": 1, "full_dims": full, "chunk_dims": chunk4}}),
        reg.TraceCase(
            step="micro_collective", name="proj_psum_chunks4",
            fn=proj_fn(4), args=(x, w), mesh=mesh, compile_hlo=True,
            expect={"chunked_all_reduce": {
                "chunks": 4, "full_dims": full, "chunk_dims": chunk4}}),
    ]

    # migration: SEMI sheds 2 blocks from rank 5; its helper broadcast
    # must stay ONE fused grouped (tuple) masked psum (R3)
    xh = sds((_B, _S, 64), jnp.float32)
    wu = sds((64, _H), jnp.float32)
    wd = sds((_H, 64), jnp.float32)
    st = PlanStatic(buckets=(0.0, 0.25, 0.5), block_size=_BLOCK,
                    mig_blocks=2, tp_size=e)
    nb_loc = (_H // e) // _BLOCK
    pri = jnp.tile(jnp.arange(nb_loc, dtype=jnp.int32)[None], (e, 1))
    ctx_mig = ControlContext(mesh=mesh, axis="model", static=st,
                             bucket_by_rank=jnp.zeros((e,), jnp.int32),
                             mig_src=jnp.array(5, jnp.int32),
                             pri={"ffn": pri})
    cases.append(reg.TraceCase(
        step="micro_collective", name="ffn_migration_broadcast",
        fn=lambda x_, wu_, wd_: controlled_ffn(
            x_, wu_, wd_, ctx_mig, "ffn", jax.nn.silu),
        args=(xh, wu, wd), mesh=mesh,
        expect={"grouped_psum": {"count": 1}}))
    return cases


def _kernel_cases(env: reg.CaseEnv) -> List[reg.TraceCase]:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    sds = jax.ShapeDtypeStruct
    f32, i32 = jnp.float32, jnp.int32
    cases = [
        reg.TraceCase(
            step="micro_kernel", name="block_pruned_matmul_default_tiles",
            fn=lambda x, w, k: ops.block_pruned_matmul(x, w, k),
            args=(sds((512, 1024), f32), sds((1024, 1024), f32),
                  sds((4,), i32))),
        reg.TraceCase(
            step="micro_kernel", name="fused_pruned_ffn_default_tiles",
            fn=lambda x, wu, wd, k: ops.fused_pruned_ffn(
                x, wu, wd, k, None, jax.nn.silu),
            args=(sds((256, 512), f32), sds((512, 1024), f32),
                  sds((1024, 512), f32), sds((2,), i32))),
        reg.TraceCase(
            step="micro_kernel", name="fused_decode_attention",
            fn=lambda q, k, v, p: ops.fused_decode_attention(
                q, k, v, cur_pos=p),
            args=(sds((4, 32, 1, 128), f32), sds((4, 8, 256, 128), f32),
                  sds((4, 8, 256, 128), f32), sds((4,), i32))),
        reg.TraceCase(
            step="micro_kernel", name="unfused_decode_attention",
            fn=lambda q, k, v, p: ops.unfused_decode_attention(
                q, k, v, cur_pos=p),
            args=(sds((4, 32, 1, 128), f32), sds((4, 8, 256, 128), f32),
                  sds((4, 8, 256, 128), f32), sds((4,), i32))),
    ]
    return cases


reg.register("micro_collective", _collective_cases)
reg.register("micro_kernel", _kernel_cases)
