"""repro.analysis — static jaxpr/HLO invariant linter (DESIGN_ANALYSIS.md).

Proves, without running a benchmark, the plan-safety and hot-path rules
the rest of the system assumes: R1 one-signature-one-jaxpr, R2 no
host syncs / donated hot state, R3 exact collective shapes, R4 Pallas
tiles fit VMEM, R5 no f64 leaks. Run ``python -m repro.analysis
--check [--mutate]``.

Importing this package is cheap (no jax); the engine and rules load
lazily on first attribute access so the registry can be populated from
library modules without dragging the analyzer in.
"""
from __future__ import annotations

_LAZY = {
    "CaseEnv": "registry", "TraceCase": "registry", "Artifact": "registry",
    "REQUIRED_STEPS": "registry", "register": "registry",
    "load_providers": "registry",
    "RULES": "rules", "RULE_IDS": "rules", "Violation": "rules",
    "rules_by_id": "rules",
    "run_check": "engine", "lint": "engine", "trace_artifact": "engine",
    "run_mutants": "mutants",
    "DEFAULT_VMEM_BUDGET": "vmem", "VmemBudgetError": "vmem",
    "assert_fits": "vmem", "check_budget": "vmem",
}

__all__ = sorted(_LAZY)


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.analysis' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.analysis.{mod}"), name)
