"""Tensor-parallel linear layers with flexible workload control.

Two execution paths per op:

* **plain** (ctx is None / neutral): einsum + logical-axis sharding
  constraints — GSPMD handles the TP partitioning (used for baseline
  dry-runs and when the controller reports no stragglers).
* **controlled**: a ``jax.shard_map`` block over the TP ("model") axis in
  which each rank applies its γ-bucket (ZERO-resizing ``lax.switch``) and,
  for FFN pairs, each straggler in the CONCURRENT source set sheds its
  slot's `m_s` intermediate blocks to the helpers (migration with
  reduce-merging; see core/migration.py for the multi-source partition).
  Plan semantics per rank, over its local keep-first priority list `pri`:

      [ keep (kc_b - m_s·is_straggler) | migrate m_s (slot source only) | pruned ]

  Branches are duplicated per source slot (keep kc_b − m_s) so migrated
  blocks are truly not computed locally (static shapes, real FLOP cut).
  The per-slot shed counts live in ``PlanStatic.mig_sheds`` (static —
  quantized + compile-cached upstream); the source rank ids arrive as the
  dynamic ``mig_src`` vector, so retargeting stragglers never recompiles.

A ragged static shard geometry (``PlanStatic.geometry``, core/geometry.py)
changes what "the local workload" means: rank r owns ``geometry[r]`` real
blocks of its padded local slice, branch tables are built per distinct
size class, and every keep count quantizes against the rank's own block
count — so statically-small ranks do statically less work before SEMI
splits the residual imbalance.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import resizing
from repro.core.migration import fused_migration_delta
from repro.core.workload import PlanStatic, keep_blocks_for_bucket
from repro.sharding import filter_spec_for_mesh, shard, shard_map


@dataclasses.dataclass
class ControlContext:
    """Device-side plan handed to controlled layers.

    Arrays may carry a leading layer dimension (scan slices it off):
      bucket_by_rank: [e] or [L, e] int32
      mig_src:        [] or [S] int32 source ranks, aligned with
                      static.mig_sheds (−1 = slot idle / no migration)
      pri:            scope -> [nb] / [e, nb_loc] (+ optional leading L)
    """

    mesh: Mesh
    axis: str
    static: PlanStatic
    bucket_by_rank: jax.Array
    mig_src: jax.Array
    pri: Dict[str, jax.Array]
    use_kernel: bool = False
    per_layer: bool = False      # arrays carry a leading layer dim (PriDiff)
    psum_chunks: int = 1         # chunk-split epilogue all-reduces (>1)

    @property
    def tp(self) -> int:
        return self.static.tp_size

    def layer_slice(self, bucket, pri) -> "ControlContext":
        """Rebind per-layer arrays (used inside scan bodies / unrolled ends)."""
        return dataclasses.replace(self, bucket_by_rank=bucket, pri=pri,
                                   per_layer=False)


def _spec(mesh: Mesh, *parts) -> P:
    return filter_spec_for_mesh(P(*parts), mesh)


def chunked_psum(y: jax.Array, axis: str, n_chunks: int) -> jax.Array:
    """Epilogue all-reduce split into independent per-chunk ``psum``s.

    One fat ``lax.psum`` over the full ``[M, d_out]`` partial serializes
    compute → all-reduce on the decode hot path. Splitting the last dim
    into ``n_chunks`` independent psums gives XLA's latency-hiding
    scheduler (async collectives) chunks it can START while other work
    (the remaining branch compute, the next layer's prologue) is still
    in flight — the "bidirectional chunking" of the ISSUE 7 tentpole,
    expressed at the collective level where the scheduler can see it.
    ``n_chunks`` falls back to the largest divisor of ``d_out`` at or
    below the request (1 ⇒ the classic single psum, byte-identical).
    """
    if n_chunks <= 1:
        return lax.psum(y, axis)
    d = y.shape[-1]
    n = min(n_chunks, d)
    while n > 1 and d % n:
        n -= 1
    if n <= 1:
        return lax.psum(y, axis)
    parts = jnp.split(y, n, axis=-1)
    return jnp.concatenate([lax.psum(p, axis) for p in parts], axis=-1)


# ---------------------------------------------------------------------------
# Plain path
# ---------------------------------------------------------------------------


def dense(x: jax.Array, w: jax.Array, out_axes, *, mesh=None) -> jax.Array:
    """x [..., K] @ w [K, N] with a logical sharding constraint on y."""
    y = jnp.einsum("...k,kn->...n", x, w)
    return shard(y, out_axes, mesh=mesh)


# ---------------------------------------------------------------------------
# Controlled projection (resizing only) — attention/SSM projections
# ---------------------------------------------------------------------------


def controlled_proj(x: jax.Array, w: jax.Array, ctx: Optional[ControlContext],
                    scope: str, *, split: str, out_axes=None) -> jax.Array:
    """TP linear with per-rank ZERO-resizing on the contraction dim.

    split="col": w [K, N] partitioned on N over the TP axis; x replicated
      on TP. Resizing prunes K blocks (the paper's Fig. 2 forward case).
    split="row": w [K, N] partitioned on K; x partitioned on its last dim.
      Resizing prunes local K blocks; output psum'd over the TP axis.
    """
    if ctx is None or scope not in ctx.pri:
        if split == "row":
            y = jnp.einsum("...k,kn->...n", x, w)
            return shard(y, out_axes, mesh=ctx.mesh if ctx else None) \
                if out_axes else y
        return dense(x, w, out_axes, mesh=ctx.mesh if ctx else None) \
            if out_axes else jnp.einsum("...k,kn->...n", x, w)

    mesh, axis = ctx.mesh, ctx.axis
    st = ctx.static
    blk = st.block_for(scope)
    pri = ctx.pri[scope]
    lead = x.shape[:-1]

    if split == "col":
        in_specs = (_spec(mesh, *([None] * len(lead)), None),
                    _spec(mesh, None, axis),
                    _spec(mesh, axis),            # bucket_by_rank [e] -> [1]
                    _spec(mesh, None))            # pri [nb] replicated
        out_spec = _spec(mesh, *([None] * len(lead)), axis)

        def body(x_, w_, bucket_, pri_):
            return resizing.switched_matmul(
                x_, w_, pri_, bucket_[0], buckets=st.buckets,
                block=blk, use_kernel=ctx.use_kernel)

        return shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_spec, check_vma=False)(
            x, w, ctx.bucket_by_rank, pri)

    # row-split: x last dim and w first dim are sharded; per-rank pri [e, nb]
    in_specs = (_spec(mesh, *([None] * len(lead)), axis),
                _spec(mesh, axis, None),
                _spec(mesh, axis),
                _spec(mesh, axis, None))
    out_spec = _spec(mesh, *([None] * len(lead)), None)

    def body_row(x_, w_, bucket_, pri_):
        y = resizing.switched_matmul(
            x_, w_, pri_[0], bucket_[0], buckets=st.buckets,
            block=blk, use_kernel=ctx.use_kernel)
        return chunked_psum(y, axis, ctx.psum_chunks)

    return shard_map(body_row, mesh=mesh, in_specs=in_specs,
                     out_specs=out_spec, check_vma=False)(
        x, w, ctx.bucket_by_rank, pri)


# ---------------------------------------------------------------------------
# Controlled FFN pair (resizing + migration with reduce-merging)
# ---------------------------------------------------------------------------


def _gather_cols_mat(w, ids, block):
    d, H = w.shape
    return jnp.take(w.reshape(d, H // block, block), ids, axis=1) \
        .reshape(d, ids.shape[0] * block)


def controlled_ffn(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                   ctx: Optional[ControlContext], scope: str,
                   act_fn: Callable, w_gate: Optional[jax.Array] = None,
                   out_axes=("batch", None, "embed")) -> jax.Array:
    """FFN pair y = act(x@w_up[,·gate]) @ w_down under workload control.

    w_up/w_gate: [d, H] column-split over TP; w_down: [H, d_out] row-split.
    The intermediate H blocks are the controlled workload unit: each rank
    resizes by its bucket; the straggler additionally migrates `m` blocks
    which helpers compute from broadcast slices and merge into the final
    psum (reduce-merging, Sec. IV-A).
    """
    if ctx is None or scope not in ctx.pri:
        h = jnp.einsum("...k,kh->...h", x, w_up)
        mesh = ctx.mesh if ctx else None
        h = shard(h, ("batch", None, "mlp"), mesh=mesh) if h.ndim == 3 else h
        if w_gate is not None:
            h = act_fn(jnp.einsum("...k,kh->...h", x, w_gate)) * h
        else:
            h = act_fn(h)
        y = jnp.einsum("...h,hd->...d", h, w_down)
        return shard(y, out_axes, mesh=mesh) if y.ndim == 3 else y

    mesh, axis = ctx.mesh, ctx.axis
    st = ctx.static
    blk = st.block_for(scope)
    e = st.tp_size
    sheds = st.mig_sheds                       # per-source shed counts (static)
    S = len(sheds)
    # ragged static shard geometry (core/geometry.py): per-rank real block
    # counts under the padded layout. An all-equal geometry is the plain
    # equal split — normalize it away here too so equal-geometry plans
    # trace the exact baseline jaxpr.
    geo = st.geometry if len(set(st.geometry)) > 1 else ()
    pri = ctx.pri[scope]                       # [e, nb_loc]
    lead = x.shape[:-1]
    nl = len(lead)

    in_specs = (_spec(mesh, *([None] * nl), None),       # x replicated on TP
                _spec(mesh, None, axis),                 # w_up col-split
                _spec(mesh, axis, None),                 # w_down row-split
                _spec(mesh, None, axis) if w_gate is not None else None,
                _spec(mesh, axis),                       # bucket [e]
                _spec(mesh, axis, None),                 # pri [e, nb]
                _spec(mesh),                             # mig_src scalar
                )
    if w_gate is None:
        in_specs = in_specs[:3] + in_specs[4:]
    out_spec = _spec(mesh, *([None] * nl), None)

    def body(x_, w_up_, w_down_, *rest):
        if w_gate is not None:
            w_gate_, bucket_, pri_, mig_src_ = rest
        else:
            bucket_, pri_, mig_src_ = rest
            w_gate_ = None
        x2 = x_.reshape(-1, x_.shape[-1])
        pri_ = pri_[0]
        bucket_self = bucket_[0]
        rank = lax.axis_index(axis)
        Hloc = w_up_.shape[1]
        nb = Hloc // blk
        if S > 0 and max(sheds) >= nb:
            raise ValueError(
                f"mig_shed {sheds} must leave each source at least one of "
                f"its {nb} local blocks")
        if geo:
            if len(geo) != e:
                raise ValueError(
                    f"geometry {geo} has {len(geo)} ranks, tp_size={e}")
            if max(geo) != nb:
                raise ValueError(
                    f"geometry {geo}: max size {max(geo)} must equal the "
                    f"padded local block count {nb} (Hloc={Hloc}, blk={blk})")
            if S > 0 and max(sheds) >= min(geo):
                raise ValueError(
                    f"mig_shed {sheds} must leave the smallest-geometry "
                    f"rank (L={min(geo)}) at least one real block")

        # source-slot vector: pad/trim the dynamic mig_src to S entries
        if S > 0:
            srcs = jnp.atleast_1d(mig_src_)[:S]
            if srcs.shape[0] < S:
                srcs = jnp.concatenate(
                    [srcs, jnp.full((S - srcs.shape[0],), -1, srcs.dtype)])
            ranks_v = jnp.arange(e)
            is_src_vec = jnp.any(ranks_v[:, None] == srcs[None, :], axis=1)
            is_straggler = is_src_vec[rank]
            my_slot = jnp.argmax(srcs == rank)
        else:
            is_straggler = jnp.zeros((), bool)
            my_slot = jnp.zeros((), jnp.int32)

        # ---- per-rank local compute: switch over (bucket × source slot) --
        def make_branch(kc: int):
            kc = max(1, min(kc, nb))

            def branch(ops_):
                x2_, wu, wg, wd, pri_b = ops_
                if kc >= nb:
                    # dense shortcut: keeping every block, the gather is an
                    # identity copy — skip it (helpers/buckets at γ=0 run
                    # the true dense pair)
                    h = x2_ @ wu
                    h = act_fn(x2_ @ wg) * h if wg is not None else act_fn(h)
                    return h @ wd
                keep = jnp.sort(pri_b[:kc])
                return resizing.resized_ffn(x2_, wu, wd, keep, act_fn, wg,
                                            block=blk,
                                            use_kernel=ctx.use_kernel)
            return branch

        if geo:
            # one branch table per distinct rank size L ("size class"):
            # keep counts are quantized against L, so a small rank at
            # γ=0 runs exactly its L real blocks — the padding is never
            # gathered and the static FLOP rebalance is real.
            classes = sorted(set(geo))
            branches, kc_rows = [], []
            for L in classes:
                kcs_L = [keep_blocks_for_bucket(g, L) for g in st.buckets]
                branches += [make_branch(kc) for kc in kcs_L]
                for m_s in sheds:
                    branches += [make_branch(kc - m_s) for kc in kcs_L]
                kc_rows.append(kcs_L)
            class_self = jnp.asarray(
                [classes.index(L) for L in geo], jnp.int32)[rank]
            branch_idx = bucket_self + len(st.buckets) * jnp.where(
                is_straggler, 1 + my_slot, 0).astype(jnp.int32) \
                + len(st.buckets) * (1 + S) * class_self
        else:
            kcs = [keep_blocks_for_bucket(g, nb) for g in st.buckets]
            branches = [make_branch(kc) for kc in kcs]
            for m_s in sheds:
                branches += [make_branch(kc - m_s) for kc in kcs]
            branch_idx = bucket_self + len(st.buckets) * jnp.where(
                is_straggler, 1 + my_slot, 0).astype(jnp.int32)
        partial = lax.switch(branch_idx, branches,
                             (x2, w_up_, w_gate_, w_down_, pri_))

        # ---- migration: slot source s exports the m_s blocks right after
        # its (clamped) locally-kept prefix; all slots share ONE fused
        # masked-psum broadcast and helpers fold their partials into the
        # layer's single psum (core/migration.py:fused_migration_delta).
        if S > 0:
            if geo:
                # [n_classes, n_buckets]: this rank's keep count depends on
                # its size class as well as its bucket
                kc_self = jnp.asarray(kc_rows, jnp.int32)[
                    class_self, bucket_self]
            else:
                kc_table = jnp.array(kcs, jnp.int32)
                kc_self = kc_table[bucket_self]
            exports = []
            for s, m_s in enumerate(sheds):
                # start from the CLAMPED keep count max(kc − m_s, 1): the
                # local branch never keeps fewer than 1 block, so the
                # migrated window must start after it to stay disjoint
                # (no double compute even when kc − m_s < 1)
                start = jnp.clip(jnp.maximum(kc_self - m_s, 1), 0, nb - m_s)
                mig_ids = lax.dynamic_slice_in_dim(pri_, start, m_s)
                exp_up = _gather_cols_mat(w_up_, mig_ids, blk)
                exp_down = resizing.gather_rows(w_down_, mig_ids, blk)
                exp_g = (_gather_cols_mat(w_gate_, mig_ids, blk)
                         if w_gate_ is not None else None)
                exports.append((exp_up, exp_down, exp_g))
            partial = partial + fused_migration_delta(
                x2, axis=axis, rank=rank, srcs=srcs, sheds=sheds, block=blk,
                act_fn=act_fn, exports=exports)

        # chunked epilogue: applied AFTER the branch switch/migration
        # merge so every lax.switch branch keeps its uniform shape
        y = chunked_psum(partial, axis, ctx.psum_chunks)
        return y.reshape(*lead, w_down_.shape[1])

    args = (x, w_up, w_down) + ((w_gate,) if w_gate is not None else ()) + (
        ctx.bucket_by_rank, pri, ctx.mig_src)
    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_spec, check_vma=False)(*args)
