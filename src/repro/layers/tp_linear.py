"""Tensor-parallel linear layers with flexible workload control.

Two execution paths per op:

* **plain** (ctx is None / neutral): einsum + logical-axis sharding
  constraints — GSPMD handles the TP partitioning (used for baseline
  dry-runs and when the controller reports no stragglers).
* **controlled**: a ``jax.shard_map`` block over the TP ("model") axis in
  which each rank applies its γ-bucket (ZERO-resizing ``lax.switch``) and,
  for FFN pairs, the straggler sheds `m` intermediate blocks to helpers
  (migration with reduce-merging). Plan semantics per rank, over its local
  keep-first priority list `pri`:

      [ keep (kc_b - m·is_straggler) | migrate m (straggler only) | pruned ]

  Branches are duplicated for the straggler (keep kc_b − m) so migrated
  blocks are truly not computed locally (static shapes, real FLOP cut).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import resizing
from repro.core.workload import PlanStatic, keep_blocks_for_bucket
from repro.sharding import filter_spec_for_mesh, shard


@dataclasses.dataclass
class ControlContext:
    """Device-side plan handed to controlled layers.

    Arrays may carry a leading layer dimension (scan slices it off):
      bucket_by_rank: [e] or [L, e] int32
      mig_src:        [] int32 (−1 = no migration this step)
      pri:            scope -> [nb] / [e, nb_loc] (+ optional leading L)
    """

    mesh: Mesh
    axis: str
    static: PlanStatic
    bucket_by_rank: jax.Array
    mig_src: jax.Array
    pri: Dict[str, jax.Array]
    use_kernel: bool = False
    per_layer: bool = False      # arrays carry a leading layer dim (PriDiff)

    @property
    def tp(self) -> int:
        return self.static.tp_size

    def layer_slice(self, bucket, pri) -> "ControlContext":
        """Rebind per-layer arrays (used inside scan bodies / unrolled ends)."""
        return dataclasses.replace(self, bucket_by_rank=bucket, pri=pri,
                                   per_layer=False)


def _spec(mesh: Mesh, *parts) -> P:
    return filter_spec_for_mesh(P(*parts), mesh)


# ---------------------------------------------------------------------------
# Plain path
# ---------------------------------------------------------------------------


def dense(x: jax.Array, w: jax.Array, out_axes, *, mesh=None) -> jax.Array:
    """x [..., K] @ w [K, N] with a logical sharding constraint on y."""
    y = jnp.einsum("...k,kn->...n", x, w)
    return shard(y, out_axes, mesh=mesh)


# ---------------------------------------------------------------------------
# Controlled projection (resizing only) — attention/SSM projections
# ---------------------------------------------------------------------------


def controlled_proj(x: jax.Array, w: jax.Array, ctx: Optional[ControlContext],
                    scope: str, *, split: str, out_axes=None) -> jax.Array:
    """TP linear with per-rank ZERO-resizing on the contraction dim.

    split="col": w [K, N] partitioned on N over the TP axis; x replicated
      on TP. Resizing prunes K blocks (the paper's Fig. 2 forward case).
    split="row": w [K, N] partitioned on K; x partitioned on its last dim.
      Resizing prunes local K blocks; output psum'd over the TP axis.
    """
    if ctx is None or scope not in ctx.pri:
        if split == "row":
            y = jnp.einsum("...k,kn->...n", x, w)
            return shard(y, out_axes, mesh=ctx.mesh if ctx else None) \
                if out_axes else y
        return dense(x, w, out_axes, mesh=ctx.mesh if ctx else None) \
            if out_axes else jnp.einsum("...k,kn->...n", x, w)

    mesh, axis = ctx.mesh, ctx.axis
    st = ctx.static
    blk = st.block_for(scope)
    pri = ctx.pri[scope]
    lead = x.shape[:-1]

    if split == "col":
        in_specs = (_spec(mesh, *([None] * len(lead)), None),
                    _spec(mesh, None, axis),
                    _spec(mesh, axis),            # bucket_by_rank [e] -> [1]
                    _spec(mesh, None))            # pri [nb] replicated
        out_spec = _spec(mesh, *([None] * len(lead)), axis)

        def body(x_, w_, bucket_, pri_):
            return resizing.switched_matmul(
                x_, w_, pri_, bucket_[0], buckets=st.buckets,
                block=blk, use_kernel=ctx.use_kernel)

        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_spec, check_vma=False)(
            x, w, ctx.bucket_by_rank, pri)

    # row-split: x last dim and w first dim are sharded; per-rank pri [e, nb]
    in_specs = (_spec(mesh, *([None] * len(lead)), axis),
                _spec(mesh, axis, None),
                _spec(mesh, axis),
                _spec(mesh, axis, None))
    out_spec = _spec(mesh, *([None] * len(lead)), None)

    def body_row(x_, w_, bucket_, pri_):
        y = resizing.switched_matmul(
            x_, w_, pri_[0], bucket_[0], buckets=st.buckets,
            block=blk, use_kernel=ctx.use_kernel)
        return lax.psum(y, axis)

    return jax.shard_map(body_row, mesh=mesh, in_specs=in_specs,
                         out_specs=out_spec, check_vma=False)(
        x, w, ctx.bucket_by_rank, pri)


# ---------------------------------------------------------------------------
# Controlled FFN pair (resizing + migration with reduce-merging)
# ---------------------------------------------------------------------------


def _gather_cols_mat(w, ids, block):
    d, H = w.shape
    return jnp.take(w.reshape(d, H // block, block), ids, axis=1) \
        .reshape(d, ids.shape[0] * block)


def controlled_ffn(x: jax.Array, w_up: jax.Array, w_down: jax.Array,
                   ctx: Optional[ControlContext], scope: str,
                   act_fn: Callable, w_gate: Optional[jax.Array] = None,
                   out_axes=("batch", None, "embed")) -> jax.Array:
    """FFN pair y = act(x@w_up[,·gate]) @ w_down under workload control.

    w_up/w_gate: [d, H] column-split over TP; w_down: [H, d_out] row-split.
    The intermediate H blocks are the controlled workload unit: each rank
    resizes by its bucket; the straggler additionally migrates `m` blocks
    which helpers compute from broadcast slices and merge into the final
    psum (reduce-merging, Sec. IV-A).
    """
    if ctx is None or scope not in ctx.pri:
        h = jnp.einsum("...k,kh->...h", x, w_up)
        mesh = ctx.mesh if ctx else None
        h = shard(h, ("batch", None, "mlp"), mesh=mesh) if h.ndim == 3 else h
        if w_gate is not None:
            h = act_fn(jnp.einsum("...k,kh->...h", x, w_gate)) * h
        else:
            h = act_fn(h)
        y = jnp.einsum("...h,hd->...d", h, w_down)
        return shard(y, out_axes, mesh=mesh) if y.ndim == 3 else y

    mesh, axis = ctx.mesh, ctx.axis
    st = ctx.static
    blk = st.block_for(scope)
    e = st.tp_size
    m = st.mig_blocks
    pri = ctx.pri[scope]                       # [e, nb_loc]
    lead = x.shape[:-1]
    nl = len(lead)

    in_specs = (_spec(mesh, *([None] * nl), None),       # x replicated on TP
                _spec(mesh, None, axis),                 # w_up col-split
                _spec(mesh, axis, None),                 # w_down row-split
                _spec(mesh, None, axis) if w_gate is not None else None,
                _spec(mesh, axis),                       # bucket [e]
                _spec(mesh, axis, None),                 # pri [e, nb]
                _spec(mesh),                             # mig_src scalar
                )
    if w_gate is None:
        in_specs = in_specs[:3] + in_specs[4:]
    out_spec = _spec(mesh, *([None] * nl), None)

    def body(x_, w_up_, w_down_, *rest):
        if w_gate is not None:
            w_gate_, bucket_, pri_, mig_src_ = rest
        else:
            bucket_, pri_, mig_src_ = rest
            w_gate_ = None
        x2 = x_.reshape(-1, x_.shape[-1])
        pri_ = pri_[0]
        bucket_self = bucket_[0]
        rank = lax.axis_index(axis)
        Hloc = w_up_.shape[1]
        nb = Hloc // blk
        enabled = jnp.logical_and(mig_src_ >= 0, m > 0)
        is_straggler = jnp.logical_and(enabled, rank == mig_src_)

        # ---- per-rank local compute: switch over (bucket × straggler) ----
        def make_branch(kc: int):
            kc = max(1, min(kc, nb))

            def branch(ops_):
                x2_, wu, wg, wd, pri_b = ops_
                keep = jnp.sort(pri_b[:kc])
                wu_k = _gather_cols_mat(wu, keep, blk)
                h = x2_ @ wu_k
                if wg is not None:
                    h = act_fn(x2_ @ _gather_cols_mat(wg, keep, blk)) * h
                else:
                    h = act_fn(h)
                return h @ resizing.gather_rows(wd, keep, blk)
            return branch

        kcs = [keep_blocks_for_bucket(g, nb) for g in st.buckets]
        branches = [make_branch(kc) for kc in kcs]
        if m > 0:
            branches += [make_branch(kc - m) for kc in kcs]
        branch_idx = bucket_self + len(st.buckets) * is_straggler.astype(jnp.int32)
        partial = lax.switch(branch_idx, branches,
                             (x2, w_up_, w_gate_, w_down_, pri_))

        # ---- migration: straggler exports blocks [kc_self - m, kc_self) --
        if m > 0:
            kc_table = jnp.array(kcs, jnp.int32)
            kc_self = kc_table[bucket_self]
            start = jnp.clip(kc_self - m, 0, nb - m)
            mig_ids = lax.dynamic_slice_in_dim(pri_, start, m)

            exp_up = _gather_cols_mat(w_up_, mig_ids, blk)
            exp_down = resizing.gather_rows(w_down_, mig_ids, blk)
            src = jnp.where(enabled, mig_src_, 0)

            def bcast(v):
                contrib = jnp.where(rank == src, v, jnp.zeros_like(v))
                return lax.psum(contrib, axis)

            b_up, b_down = bcast(exp_up), bcast(exp_down)
            b_gate = bcast(_gather_cols_mat(w_gate_, mig_ids, blk)) \
                if w_gate_ is not None else None

            m_per = -(-m // max(e - 1, 1))
            m_pad = m_per * max(e - 1, 1)
            pad = m_pad - m
            if pad:
                b_up = jnp.pad(b_up, ((0, 0), (0, pad * blk)))
                b_down = jnp.pad(b_down, ((0, pad * blk), (0, 0)))
                if b_gate is not None:
                    b_gate = jnp.pad(b_gate, ((0, 0), (0, pad * blk)))

            rprime = (rank + e - src) % e
            is_helper = jnp.logical_and(enabled, rprime > 0)
            lo = (jnp.maximum(rprime, 1) - 1) * m_per * blk
            sl_up = lax.dynamic_slice_in_dim(b_up, lo, m_per * blk, 1)
            sl_down = lax.dynamic_slice_in_dim(b_down, lo, m_per * blk, 0)
            h_mig = x2 @ sl_up
            if b_gate is not None:
                sl_gate = lax.dynamic_slice_in_dim(b_gate, lo, m_per * blk, 1)
                h_mig = act_fn(x2 @ sl_gate) * h_mig
            else:
                h_mig = act_fn(h_mig)
            # mask padded block lanes and non-helpers, then REDUCE-MERGE
            col = jnp.arange(m_per * blk) + lo
            lane_ok = (col < m * blk).astype(x2.dtype)
            delta = (h_mig * (lane_ok * is_helper.astype(x2.dtype))[None, :]) @ sl_down
            partial = partial + delta

        y = lax.psum(partial, axis)
        return y.reshape(*lead, w_down_.shape[1])

    args = (x, w_up, w_down) + ((w_gate,) if w_gate is not None else ()) + (
        ctx.bucket_by_rank, pri, ctx.mig_src)
    return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                         out_specs=out_spec, check_vma=False)(*args)
