"""Transformer blocks: init + apply for every assigned family, with
scan-over-layers stacking (O(1) HLO size in depth) and workload-control
hooks on every TP linear.

Parameter pytrees are plain nested dicts; each init function also returns
a matching *logical-axes* pytree consumed by the launcher to build
NamedShardings (MaxText-style logical axis rules, repro/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.layers import attention as attn_lib
from repro.layers import moe as moe_lib
from repro.layers import rglru as rglru_lib
from repro.layers import ssm as ssm_lib
from repro.layers.tp_linear import ControlContext, controlled_ffn, controlled_proj
from repro.sharding import shard

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Small pieces
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * (1.0 + scale)
    return y.astype(x.dtype)


def _normal(rng, shape, std=0.02, dtype=jnp.float32):
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def act_of(name: str) -> Tuple[Callable, bool]:
    """Returns (activation, gated)."""
    if name == "silu":
        return jax.nn.silu, True
    if name == "gelu_glu":
        return jax.nn.gelu, True
    if name == "gelu":
        return jax.nn.gelu, False
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Attention layer (GQA / MLA)
# ---------------------------------------------------------------------------


def init_attention(rng, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 8)
    if cfg.mla is not None:
        m = cfg.mla
        qdim = H * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        p = {
            "wq": _normal(ks[0], (d, qdim), dtype=dtype),
            "w_dkv": _normal(ks[1], (d, m.kv_lora_rank), dtype=dtype),
            "w_kr": _normal(ks[2], (d, m.qk_rope_head_dim), dtype=dtype),
            "w_uk": _normal(ks[3], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype=dtype),
            "w_uv": _normal(ks[4], (m.kv_lora_rank, H * m.v_head_dim), dtype=dtype),
            "wo": _normal(ks[5], (H * m.v_head_dim, d),
                          std=0.02 / (2 * cfg.num_layers) ** 0.5, dtype=dtype),
        }
        ax = {
            "wq": ("embed", "heads"), "w_dkv": ("embed", "kv_lora"),
            "w_kr": ("embed", None), "w_uk": ("kv_lora", "heads"),
            "w_uv": ("kv_lora", "heads"), "wo": ("heads", "embed"),
        }
        return p, ax
    p = {
        "wq": _normal(ks[0], (d, H * hd), dtype=dtype),
        "wk": _normal(ks[1], (d, KV * hd), dtype=dtype),
        "wv": _normal(ks[2], (d, KV * hd), dtype=dtype),
        "wo": _normal(ks[3], (H * hd, d),
                      std=0.02 / (2 * cfg.num_layers) ** 0.5, dtype=dtype),
    }
    ax = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
          "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((H * hd,), dtype), bk=jnp.zeros((KV * hd,), dtype),
                 bv=jnp.zeros((KV * hd,), dtype))
        ax.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    return p, ax


def _paged_write_ids(pages: jax.Array, cur_pos: jax.Array,
                     page_size: int, num_pages: int):
    """Map each slot's write position to a (pool page id, in-page offset).

    Invalid positions — the ``INVALID_POS`` lanes of a chunked-prefill
    substep, or a position past the slot's allocated frontier — redirect
    to page id ``num_pages``: POSITIVE out-of-range, which the caller's
    ``mode="drop"`` scatter discards. (A -1 sentinel would not work:
    jax's default scatter WRAPS negative indices, silently corrupting
    the last pool page.)"""
    pps = pages.shape[1]
    pi = cur_pos // page_size
    p = jnp.take_along_axis(pages, jnp.clip(pi, 0, pps - 1)[:, None],
                            axis=1)[:, 0]
    ok = jnp.logical_and(jnp.logical_and(pi >= 0, pi < pps), p >= 0)
    page = jnp.where(ok, p, num_pages)
    return page, cur_pos % page_size


def apply_attention(p: Params, x: jax.Array, cfg: ModelConfig, *,
                    ctx: Optional[ControlContext], positions: jax.Array,
                    causal: bool = True, window: int = 0,
                    cache: Optional[Params] = None,
                    cur_pos: Optional[jax.Array] = None,
                    kv_source: Optional[jax.Array] = None,
                    mrope_positions: Optional[jax.Array] = None,
                    pages: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Optional[Params]]:
    """Self- (or cross-, via kv_source) attention.

    cache None => train/prefill (full sequence). cache given => decode:
    x is [B, 1, d], the cache is updated at cur_pos and attended.
    ``pages`` [B, pages_per_slot] switches the decode cache to the
    block-paged pool layout (core/paging.py).
    """
    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    mesh = ctx.mesh if ctx else None

    if cfg.mla is not None:
        return _apply_mla(p, x, cfg, ctx=ctx, positions=positions,
                          cache=cache, cur_pos=cur_pos, pages=pages)

    q = controlled_proj(x, p["wq"], ctx, "qkv", split="col")
    src = x if kv_source is None else kv_source
    k = controlled_proj(src, p["wk"], ctx, "qkv", split="col")
    v = controlled_proj(src, p["wv"], ctx, "qkv", split="col")
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    Skv = src.shape[1]
    q = shard(q.reshape(B, S, H, hd), ("batch", None, "heads", None), mesh=mesh)
    k = shard(k.reshape(B, Skv, KV, hd), ("batch", None, "kv_heads", None), mesh=mesh)
    v = shard(v.reshape(B, Skv, KV, hd), ("batch", None, "kv_heads", None), mesh=mesh)

    # positions: [S] (train/prefill) or [B, S=1] (decode, = cur_pos[:, None])
    if cfg.pos_embedding == "rope" and kv_source is None:
        q = attn_lib.apply_rope(q, positions, cfg.rope_theta)
        k = attn_lib.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_embedding == "mrope" and kv_source is None:
        assert mrope_positions is not None
        q = attn_lib.apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = attn_lib.apply_mrope(k, mrope_positions if cache is None else
                                 mrope_positions[:, -1:], cfg.rope_theta)

    q = q.transpose(0, 2, 1, 3)                       # [B, H, S, hd]
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None and S == 1 and pages is not None:
        # paged decode: scatter into the shared pool through the page
        # table; invalid lanes redirect to the dropped page id
        kc, vc = cache["k"], cache["v"]
        num_pages, ps_len = kc.shape[0], kc.shape[2]
        page, off = _paged_write_ids(pages, cur_pos, ps_len, num_pages)
        k_new, v_new = k[:, :, 0, :], v[:, :, 0, :]           # [B, KV, hd]
        k_scale = v_scale = None
        if "k_scale" in cache:
            # int8 pool: per (slot, kv-head) row scale = max|.|/127
            ksc = jnp.maximum(jnp.abs(k_new).max(axis=-1), 1e-12) / 127.0
            vsc = jnp.maximum(jnp.abs(v_new).max(axis=-1), 1e-12) / 127.0
            k_new = jnp.clip(jnp.round(k_new / ksc[..., None]),
                             -127, 127)
            v_new = jnp.clip(jnp.round(v_new / vsc[..., None]),
                             -127, 127)
            k_scale = cache["k_scale"].at[page, :, off].set(
                ksc, mode="drop")
            v_scale = cache["v_scale"].at[page, :, off].set(
                vsc, mode="drop")
        kc = kc.at[page, :, off, :].set(k_new.astype(kc.dtype),
                                        mode="drop")
        vc = vc.at[page, :, off, :].set(v_new.astype(vc.dtype),
                                        mode="drop")
        kc = shard(kc, (None, "kv_heads", None, None), mesh=mesh)
        vc = shard(vc, (None, "kv_heads", None, None), mesh=mesh)
        if cfg.fused_decode_attn:
            if k_scale is not None:
                raise ValueError(
                    "kv_int8 paging has no fused kernel path — run with "
                    "fused_attention off (oracle dequant)")
            from repro.kernels import ops as _kops
            o = _kops.fused_paged_decode_attention(
                q, kc, vc, pages=pages, cur_pos=cur_pos, window=window)
        else:
            o = attn_lib.paged_decode_attention(
                q, kc, vc, pages=pages, cur_pos=cur_pos, window=window,
                k_scale=k_scale, v_scale=v_scale)
        new_cache = {"k": kc, "v": vc}
        if k_scale is not None:
            new_cache["k_scale"] = k_scale
            new_cache["v_scale"] = v_scale
    elif cache is not None and S == 1:
        # decode: write new K/V at each row's OWN cur_pos (continuous
        # batching runs slots at ragged positions), attend over the cache
        kc, vc = cache["k"], cache["v"]
        b_idx = jnp.arange(B)
        kc = kc.at[b_idx, :, cur_pos, :].set(k[:, :, 0, :].astype(kc.dtype))
        vc = vc.at[b_idx, :, cur_pos, :].set(v[:, :, 0, :].astype(vc.dtype))
        kc = shard(kc, ("batch", "kv_heads", "decode_seq", None), mesh=mesh)
        vc = shard(vc, ("batch", "kv_heads", "decode_seq", None), mesh=mesh)
        if cfg.fused_decode_attn:
            # fused Pallas decode attention (kernels/decode_attn.py):
            # online softmax over the ragged cache, no [B, H, S] scores
            # in HBM; interpret-mode fallback keeps CPU containers green
            from repro.kernels import ops as _kops
            o = _kops.fused_decode_attention(q, kc, vc, cur_pos=cur_pos,
                                             window=window)
        else:
            o = attn_lib.decode_attention(q, kc, vc, cur_pos=cur_pos,
                                          window=window)
        new_cache = {"k": kc, "v": vc}
    elif cache is not None:
        # prefill: fill the cache from position 0, attend with flash
        kc = lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), 0, axis=2)
        vc = lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), 0, axis=2)
        kc = shard(kc, ("batch", "kv_heads", "decode_seq", None), mesh=mesh)
        vc = shard(vc, ("batch", "kv_heads", "decode_seq", None), mesh=mesh)
        o = attn_lib.flash_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, window=window)
        new_cache = {"k": kc, "v": vc}
    elif kv_source is not None:
        # cross-attention is non-causal: positions only gate validity
        o = attn_lib.flash_attention(
            q, k, v, q_positions=jnp.arange(S),
            kv_positions=jnp.arange(Skv), causal=False, window=0)
    else:
        o = attn_lib.flash_attention(
            q, k, v, q_positions=positions, kv_positions=positions,
            causal=causal, window=window)

    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    y = controlled_proj(o, p["wo"], ctx, "attn_out", split="row",
                        out_axes=("batch", None, "embed"))
    if ctx is None or "attn_out" not in (ctx.pri if ctx else {}):
        y = shard(y, ("batch", None, "embed"), mesh=mesh)
    return y, new_cache


def _apply_mla(p, x, cfg, *, ctx, positions, cache, cur_pos, pages=None):
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    mesh = ctx.mesh if ctx else None

    q = controlled_proj(x, p["wq"], ctx, "qkv", split="col")
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    latent = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])      # [B,S,R]
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])       # [B,S,dr]
    # `positions` is [S] (train/prefill) or [B, 1] == cur_pos (decode)
    q_rope = attn_lib.apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = attn_lib.apply_rope(k_rope[:, :, None, :], positions,
                                 cfg.rope_theta)[:, :, 0]

    if cache is not None and S > 1:
        # prefill: fill the latent cache, then run the expanded-form path
        lc = lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), 0, axis=1)
        rc = lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), 0, axis=1)
        cache = None  # fall through to the expanded path below
        prefill_cache = {"latent": shard(lc, ("batch", "decode_seq", None), mesh=mesh),
                         "k_rope": shard(rc, ("batch", "decode_seq", None), mesh=mesh)}
    else:
        prefill_cache = None

    if cache is not None:
        if pages is not None:
            # paged decode: pool scatter through the page table
            lc0, rc0 = cache["latent"], cache["k_rope"]
            num_pages, ps_len = lc0.shape[0], lc0.shape[1]
            page, off = _paged_write_ids(pages, cur_pos, ps_len,
                                         num_pages)
            lc = lc0.at[page, off, :].set(
                latent[:, 0].astype(lc0.dtype), mode="drop")
            rc = rc0.at[page, off, :].set(
                k_rope[:, 0].astype(rc0.dtype), mode="drop")
            lc = shard(lc, (None, None, None), mesh=mesh)
            rc = shard(rc, (None, None, None), mesh=mesh)
        else:
            # decode: per-row ragged write (see the GQA decode path above)
            b_idx = jnp.arange(B)
            lc = cache["latent"].at[b_idx, cur_pos, :].set(
                latent[:, 0].astype(cache["latent"].dtype))
            rc = cache["k_rope"].at[b_idx, cur_pos, :].set(
                k_rope[:, 0].astype(cache["k_rope"].dtype))
            lc = shard(lc, ("batch", "decode_seq", None), mesh=mesh)
            rc = shard(rc, ("batch", "decode_seq", None), mesh=mesh)
        # absorbed decode: q_abs = W_uk^T q_nope per head
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, dn)
        q_abs = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
        if pages is not None and cfg.fused_decode_attn:
            from repro.kernels import ops as _kops
            o_lat = _kops.fused_paged_mla_decode_attention(
                q_abs, q_rope[:, 0], lc, rc, pages=pages,
                cur_pos=cur_pos, head_dim_for_scale=dn + dr)
        elif pages is not None:
            o_lat = attn_lib.paged_mla_decode_attention(
                q_abs, q_rope[:, 0], lc, rc, pages=pages,
                cur_pos=cur_pos, head_dim_for_scale=dn + dr)
        elif cfg.fused_decode_attn:
            from repro.kernels import ops as _kops
            o_lat = _kops.fused_mla_decode_attention(
                q_abs, q_rope[:, 0], lc, rc, cur_pos=cur_pos,
                head_dim_for_scale=dn + dr)                # [B,H,R]
        else:
            o_lat = attn_lib.mla_decode_attention(
                q_abs, q_rope[:, 0], lc, rc, cur_pos=cur_pos,
                head_dim_for_scale=dn + dr)                # [B,H,R]
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, dv)
        o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(x.dtype), w_uv)
        o = o.reshape(B, 1, H * dv)
        y = controlled_proj(o, p["wo"], ctx, "attn_out", split="row",
                            out_axes=("batch", None, "embed"))
        return y, {"latent": lc, "k_rope": rc}

    # train/prefill: expand K/V from the latent
    k_nope = jnp.einsum("bsr,rh->bsh", latent, p["w_uk"]).reshape(B, S, H, dn)
    v = jnp.einsum("bsr,rh->bsh", latent, p["w_uv"]).reshape(B, S, H, dv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr))], -1)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    qf = shard(qf, ("batch", None, "heads", None), mesh=mesh).transpose(0, 2, 1, 3)
    k = shard(k, ("batch", None, "heads", None), mesh=mesh).transpose(0, 2, 1, 3)
    v = shard(v, ("batch", None, "heads", None), mesh=mesh).transpose(0, 2, 1, 3)
    o = attn_lib.flash_attention(qf, k, v, q_positions=positions,
                                 kv_positions=positions, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, H * dv)
    y = controlled_proj(o, p["wo"], ctx, "attn_out", split="row",
                        out_axes=("batch", None, "embed"))
    return y, prefill_cache


# ---------------------------------------------------------------------------
# FFN (dense, controlled) + MoE wrapper
# ---------------------------------------------------------------------------


def init_ffn(rng, d: int, d_ff: int, gated: bool, num_layers: int, dtype
             ) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 3)
    p = {"w_up": _normal(ks[0], (d, d_ff), dtype=dtype),
         "w_down": _normal(ks[1], (d_ff, d),
                           std=0.02 / (2 * num_layers) ** 0.5, dtype=dtype)}
    ax = {"w_up": ("embed", "mlp"), "w_down": ("mlp", "embed")}
    if gated:
        p["w_gate"] = _normal(ks[2], (d, d_ff), dtype=dtype)
        ax["w_gate"] = ("embed", "mlp")
    return p, ax


def apply_ffn(p: Params, x: jax.Array, cfg: ModelConfig,
              ctx: Optional[ControlContext]) -> jax.Array:
    act, gated = act_of(cfg.act)
    return controlled_ffn(x, p["w_up"], p["w_down"], ctx, "ffn", act,
                          w_gate=p.get("w_gate"))


def init_moe(rng, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    mo = cfg.moe
    d = cfg.d_model
    _, gated = act_of(cfg.act)
    ks = jax.random.split(rng, 8)
    E, f = mo.num_experts, mo.d_expert
    p = {"router": _normal(ks[0], (d, E), dtype=jnp.float32),
         "w_up": _normal(ks[1], (E, d, f), dtype=dtype),
         "w_down": _normal(ks[2], (E, f, d),
                           std=0.02 / (2 * cfg.num_layers) ** 0.5, dtype=dtype)}
    if mo.expert_sharding == "tp":
        # few big experts (Mixtral): shard d_expert over the model axis —
        # sharding E (8) over a 16-way axis would silently replicate.
        up_ax, down_ax = (None, "embed", "mlp"), (None, "mlp", "embed")
    else:
        up_ax, down_ax = (("expert", "embed", "expert_mlp"),
                          ("expert", "expert_mlp", "embed"))
    ax = {"router": ("embed", None), "w_up": up_ax, "w_down": down_ax}
    if gated:
        p["w_gate"] = _normal(ks[3], (E, d, f), dtype=dtype)
        ax["w_gate"] = up_ax
    if mo.num_shared_experts:
        sh, shax = init_ffn(ks[4], d, mo.num_shared_experts * (mo.d_shared or f),
                            gated, cfg.num_layers, dtype)
        p["shared"], ax["shared"] = sh, shax
    return p, ax


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig,
              ctx: Optional[ControlContext]) -> Tuple[jax.Array, jax.Array]:
    act, _ = act_of(cfg.act)
    mo = cfg.moe
    sharding = getattr(mo, "expert_sharding", None) or (
        "tp" if mo.num_experts <= 8 else "expert")
    from repro import sharding as sh_mod
    y, aux = moe_lib.moe_ffn(x, p, mo, act,
                             mesh=ctx.mesh if ctx else sh_mod.current_mesh(),
                             expert_sharding=sharding)
    if "shared" in p:
        y = y + controlled_ffn(x, p["shared"]["w_up"], p["shared"]["w_down"],
                               ctx, "ffn", act, w_gate=p["shared"].get("w_gate"))
    return y, aux


# ---------------------------------------------------------------------------
# SSM / RG-LRU inits
# ---------------------------------------------------------------------------


def init_mamba(rng, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    dt_rank = s.dt_rank or -(-d // 16)
    ks = jax.random.split(rng, 8)
    A = jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32)[None],
                 (d_in, 1))
    p = {
        "w_in": _normal(ks[0], (d, 2 * d_in), dtype=dtype),
        "conv_w": _normal(ks[1], (s.d_conv, d_in), std=0.1, dtype=dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "w_x": _normal(ks[2], (d_in, dt_rank + 2 * s.d_state), dtype=dtype),
        "w_dt": _normal(ks[3], (dt_rank, d_in), std=dt_rank ** -0.5, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (d_in,)) * 0.099 + 0.001,
                     1e-4, None))).astype(dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), dtype),
        "w_out": _normal(ks[5], (d_in, d),
                         std=0.02 / (2 * cfg.num_layers) ** 0.5, dtype=dtype),
    }
    ax = {"w_in": ("embed", "lru"), "conv_w": (None, "lru"), "conv_b": ("lru",),
          "w_x": ("lru", None), "w_dt": (None, "lru"), "dt_bias": ("lru",),
          "A_log": ("lru", None), "D": ("lru",), "w_out": ("lru", "embed")}
    return p, ax


def init_rglru(rng, cfg: ModelConfig, dtype) -> Tuple[Params, Params]:
    g = cfg.rglru
    d = cfg.d_model
    W = g.lru_width or d
    ks = jax.random.split(rng, 8)
    p = {
        "w_gate_branch": _normal(ks[0], (d, W), dtype=dtype),
        "w_rec_branch": _normal(ks[1], (d, W), dtype=dtype),
        "conv_w": _normal(ks[2], (g.conv1d_width, W), std=0.1, dtype=dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": _normal(ks[3], (W, W), std=W ** -0.5, dtype=dtype),
        "b_a": jnp.zeros((W,), dtype),
        "w_x": _normal(ks[4], (W, W), std=W ** -0.5, dtype=dtype),
        "b_x": jnp.zeros((W,), dtype),
        "lam": jax.random.uniform(ks[5], (W,), minval=0.3, maxval=0.9),
        "w_out": _normal(ks[6], (W, d),
                         std=0.02 / (2 * cfg.num_layers) ** 0.5, dtype=dtype),
    }
    ax = {"w_gate_branch": ("embed", "lru"), "w_rec_branch": ("embed", "lru"),
          "conv_w": (None, "lru"), "conv_b": ("lru",),
          "w_a": ("lru", None), "b_a": ("lru",), "w_x": ("lru", None),
          "b_x": ("lru",), "lam": ("lru",), "w_out": ("lru", "embed")}
    return p, ax


# ---------------------------------------------------------------------------
# One block (pre-norm residual) — kind dispatch
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str, dtype) -> Tuple[Params, Params]:
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    p: Params = {"norm1": jnp.zeros((d,), jnp.float32)}
    ax: Params = {"norm1": ("embed",)}
    if kind == "mamba":
        p["mixer"], ax["mixer"] = init_mamba(ks[0], cfg, dtype)
        return p, ax
    if kind == "rglru":
        p["mixer"], ax["mixer"] = init_rglru(ks[0], cfg, dtype)
    elif kind in ("attn", "attn_local", "attn_bidir"):
        p["attn"], ax["attn"] = init_attention(ks[0], cfg, dtype)
    elif kind == "attn_cross":
        p["attn"], ax["attn"] = init_attention(ks[0], cfg, dtype)
        p["xattn"], ax["xattn"] = init_attention(ks[1], cfg, dtype)
        p["norm_x"], ax["norm_x"] = jnp.zeros((d,), jnp.float32), ("embed",)
    p["norm2"], ax["norm2"] = jnp.zeros((d,), jnp.float32), ("embed",)
    if kind == "moe":
        p["attn"], ax["attn"] = init_attention(ks[0], cfg, dtype)
        p["moe"], ax["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        _, gated = act_of(cfg.act)
        dff = cfg.d_ff if cfg.moe is None else (cfg.moe.d_ff_dense or cfg.d_ff)
        p["ffn"], ax["ffn"] = init_ffn(ks[3], d, dff, gated, cfg.num_layers, dtype)
    return p, ax


def apply_block(p: Params, x: jax.Array, cfg: ModelConfig, kind: str, *,
                ctx: Optional[ControlContext], positions: jax.Array,
                cache: Optional[Params] = None,
                cur_pos: Optional[jax.Array] = None,
                encoder_out: Optional[jax.Array] = None,
                mrope_positions: Optional[jax.Array] = None,
                causal: bool = True,
                pages: Optional[jax.Array] = None):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    new_cache: Optional[Params] = None

    if kind == "mamba":
        h, st = ssm_lib.mamba_mixer(
            rms_norm(x, p["norm1"], eps), p["mixer"], cfg.ssm,
            state=None if cache is None else (cache["h"], cache["conv"]))
        new_cache = None if cache is None else {"h": st[0], "conv": st[1]}
        return x + h, new_cache, aux

    if kind == "rglru":
        h, st = rglru_lib.rglru_block(
            rms_norm(x, p["norm1"], eps), p["mixer"], cfg.rglru,
            state=None if cache is None else (cache["h"], cache["conv"]))
        cache_out = None if cache is None else {"h": st[0], "conv": st[1]}
        x = x + h
        h2 = apply_ffn(p["ffn"], rms_norm(x, p["norm2"], eps), cfg, ctx)
        return x + h2, cache_out, aux

    window = 0
    if kind == "attn_local":
        window = cfg.rglru.local_window if cfg.rglru else cfg.sliding_window
    elif cfg.sliding_window:
        window = cfg.sliding_window

    attn_cache = None if cache is None else cache.get("attn", cache)
    h, ac = apply_attention(
        p["attn"], rms_norm(x, p["norm1"], eps), cfg, ctx=ctx,
        positions=positions, causal=causal and kind != "attn_bidir",
        window=window, cache=attn_cache, cur_pos=cur_pos,
        mrope_positions=mrope_positions, pages=pages)
    x = x + h
    if kind == "attn_cross":
        hx, _ = apply_attention(
            p["xattn"], rms_norm(x, p["norm_x"], eps), cfg, ctx=ctx,
            positions=positions, causal=False, cache=None,
            kv_source=encoder_out)
        x = x + hx
    if ac is not None:
        new_cache = {"attn": ac}

    if kind == "moe":
        h2, aux = apply_moe(p["moe"], rms_norm(x, p["norm2"], eps), cfg, ctx)
    else:
        h2 = apply_ffn(p["ffn"], rms_norm(x, p["norm2"], eps), cfg, ctx)
    return x + h2, new_cache, aux


# ---------------------------------------------------------------------------
# Layer-kind schedule + stacked init/apply (scan over layers)
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "ssm":
        return ("mamba",) * cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.rglru.block_pattern
        return tuple(("attn_local" if pat[i % len(pat)] == "attn" else "rglru")
                     for i in range(cfg.num_layers))
    if cfg.moe is not None:
        fd = cfg.moe.first_dense_layers
        return ("attn",) * fd + ("moe",) * (cfg.num_layers - fd)
    return ("attn",) * cfg.num_layers


def split_layers(cfg: ModelConfig):
    """Decompose the layer schedule into (prefix_kinds, pattern, repeat,
    suffix_kinds) so the `repeat` homogeneous pattern groups run under one
    ``lax.scan`` (O(1) HLO in depth) and the ragged ends run unrolled."""
    kinds = layer_kinds(cfg)
    L = len(kinds)
    if cfg.family == "hybrid":
        pat = tuple("attn_local" if k == "attn" else "rglru"
                    for k in cfg.rglru.block_pattern)
        repeat = L // len(pat)
        return (), pat, repeat, kinds[repeat * len(pat):]
    if cfg.moe is not None and cfg.moe.first_dense_layers:
        fd = cfg.moe.first_dense_layers
        return kinds[:fd], ("moe",), L - fd, ()
    return (), (kinds[0],), L, ()


def init_stack(rng, cfg: ModelConfig, dtype, kind_override=None
               ) -> Tuple[Params, Params]:
    """Stacked layer params: {"prefix": [...], "scan": stacked, "suffix": [...]}."""
    prefix, pattern, repeat, suffix = split_layers(cfg)
    if kind_override:
        prefix, pattern, repeat, suffix = (), (kind_override,), cfg.num_layers, ()
    out_p: Params = {}
    out_ax: Params = {}

    def init_list(kinds, key):
        ps, axs = [], []
        for i, kind in enumerate(kinds):
            p, ax = init_block(jax.random.fold_in(key, i), cfg, kind, dtype)
            ps.append(p)
            axs.append(ax)
        return ps, axs

    if prefix:
        out_p["prefix"], out_ax["prefix"] = init_list(
            prefix, jax.random.fold_in(rng, 1000))

    def init_group(key):
        return tuple(init_block(jax.random.fold_in(key, j), cfg, kind, dtype)[0]
                     for j, kind in enumerate(pattern))

    keys = jax.random.split(jax.random.fold_in(rng, 2000), repeat)
    out_p["scan"] = jax.vmap(init_group)(keys)
    axes = []
    for j, kind in enumerate(pattern):
        _, axk = init_block(rng, cfg, kind, dtype)
        axes.append(jax.tree.map(
            lambda t: ("layers",) + tuple(t), axk,
            is_leaf=lambda t: isinstance(t, tuple)
            and all(e is None or isinstance(e, str) for e in t)))
    out_ax["scan"] = tuple(axes)

    if suffix:
        out_p["suffix"], out_ax["suffix"] = init_list(
            suffix, jax.random.fold_in(rng, 3000))
    return out_p, out_ax


def apply_stack(stack: Params, x: jax.Array, cfg: ModelConfig, *,
                ctx=None, positions=None, caches=None, cur_pos=None,
                encoder_out=None, mrope_positions=None, causal=True,
                remat: str = "none", kind_override=None, pages=None):
    """Run all layers. caches: {"prefix": [...], "scan": stacked, ...} or None.

    Returns (x, new_caches, total_aux)."""
    prefix, pattern, repeat, suffix = split_layers(cfg)
    if kind_override:
        prefix, pattern, repeat, suffix = (), (kind_override,), cfg.num_layers, ()
    aux_tot = jnp.zeros((), jnp.float32)
    new_caches: Params = {}
    per_layer = ctx is not None and ctx.per_layer

    def ctx_at(layer_idx):
        if ctx is None or not per_layer:
            return ctx
        return ctx.layer_slice(ctx.bucket_by_rank[layer_idx],
                               {k: v[layer_idx] for k, v in ctx.pri.items()})

    def run_list(x, kinds, plist, clist, aux_tot, base):
        ncs = []
        for i, kind in enumerate(kinds):
            c = None if clist is None else clist[i]
            x, nc, aux = apply_block(
                plist[i], x, cfg, kind, ctx=ctx_at(base + i),
                positions=positions, cache=c, cur_pos=cur_pos,
                encoder_out=encoder_out, mrope_positions=mrope_positions,
                causal=causal, pages=pages)
            aux_tot = aux_tot + aux
            ncs.append(nc)
        return x, ncs, aux_tot

    if prefix:
        x, ncs, aux_tot = run_list(
            x, prefix, stack["prefix"],
            None if caches is None else caches.get("prefix"), aux_tot, 0)
        if caches is not None:
            new_caches["prefix"] = ncs

    # per-layer plan arrays for the scanned region: [repeat, pat, ...]
    ctx_xs = None
    if per_layer:
        lo = len(prefix)
        pl = len(pattern)

        def grp(a):
            return a[lo: lo + repeat * pl].reshape(
                (repeat, pl) + a.shape[1:])
        ctx_xs = (grp(ctx.bucket_by_rank),
                  {k: grp(v) for k, v in ctx.pri.items()})

    def scan_body(carry, xs):
        x, aux_in = carry
        group_params, group_caches, group_ctx = xs
        aux_g = jnp.zeros((), jnp.float32)
        ncs = []
        for j, kind in enumerate(pattern):
            c = None if group_caches is None else group_caches[j]
            if group_ctx is not None:
                b, pr = group_ctx
                ctx_j = ctx.layer_slice(b[j], {k: v[j] for k, v in pr.items()})
            else:
                ctx_j = ctx
            x, nc, aux = apply_block(
                group_params[j], x, cfg, kind, ctx=ctx_j, positions=positions,
                cache=c, cur_pos=cur_pos, encoder_out=encoder_out,
                mrope_positions=mrope_positions, causal=causal, pages=pages)
            aux_g = aux_g + aux
            ncs.append(nc)
        ys = tuple(ncs) if group_caches is not None else None
        return (x, aux_in + aux_g), ys

    body = scan_body
    if remat != "none":
        body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.nothing_saveable
            if remat == "full" else
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    scan_caches = None if caches is None else caches.get("scan")
    (x, aux_tot), ncs = lax.scan(
        body, (x, aux_tot), (stack["scan"], scan_caches, ctx_xs))
    if caches is not None:
        new_caches["scan"] = ncs

    if suffix:
        x, ncs, aux_tot = run_list(
            x, suffix, stack["suffix"],
            None if caches is None else caches.get("suffix"), aux_tot,
            len(prefix) + repeat * len(pattern))
        if caches is not None:
            new_caches["suffix"] = ncs

    return x, (new_caches if caches is not None else None), aux_tot
