"""Mamba-1 selective SSM block (Falcon-Mamba) in JAX.

Recurrence: h_t = exp(dt_t ⊙ A) h_{t-1} + dt_t ⊙ B_t x_t ;  y_t = C_t·h_t + D x_t

Training/prefill runs a chunked scan: ``lax.scan`` over sequence chunks
carrying the [B, d_inner, N] state; inside a chunk the linear recurrence
is solved with ``lax.associative_scan`` (work-efficient, parallel). Decode
is a single state update. The recurrence itself is not a TP matmul and is
excluded from ZERO-resizing (DESIGN.md §5); the in/out projections (the
FLOPs majority) are TP-split and controlled.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import SSMConfig


def _ssm_assoc_scan(a: jax.Array, bx: jax.Array, h0: jax.Array):
    """Solve h_t = a_t * h_{t-1} + bx_t along axis 1 (seq). a, bx:
    [B, S, d, N]; h0 [B, d, N]. Returns (h [B,S,d,N], h_last)."""
    # fold h0 into the first step: bx_0' = a_0*h0 + bx_0
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    a_s, h = lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1]


def mamba_mixer(x: jax.Array, params: dict, cfg: SSMConfig, *,
                state: Optional[Tuple[jax.Array, jax.Array]] = None,
                chunk: int = 256
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x [B, S, d_model] -> (y [B, S, d_model], (ssm_state, conv_state)).

    state: (h [B, d_inner, N], conv buf [B, d_conv-1, d_inner]) for decode
    continuation; None starts from zeros.
    """
    B, S, d_model = x.shape
    d_in = params["A_log"].shape[0]
    N = cfg.d_state

    xz = jnp.einsum("bsd,de->bse", x, params["w_in"])       # [B,S,2*d_in]
    xi, z = jnp.split(xz, 2, axis=-1)

    # depthwise causal conv1d (width d_conv) over sequence
    wconv = params["conv_w"]                                # [d_conv, d_in]
    prev = (state[1] if state is not None
            else jnp.zeros((B, cfg.d_conv - 1, d_in), x.dtype))
    xpad = jnp.concatenate([prev, xi], axis=1)              # [B, S+dc-1, d_in]
    conv = sum(xpad[:, i:i + S] * wconv[i][None, None]
               for i in range(cfg.d_conv))
    conv = conv + params["conv_b"][None, None]
    new_conv_state = xpad[:, S:, :] if cfg.d_conv > 1 else prev
    xi = jax.nn.silu(conv)

    # input-dependent dt, B, C
    dt_rank = params["w_dt"].shape[0]
    dbc = jnp.einsum("bsd,dr->bsr", xi, params["w_x"])      # [B,S,dt_rank+2N]
    dt, Bmat, Cmat = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,rd->bsd", dt, params["w_dt"])
                         + params["dt_bias"][None, None])   # [B,S,d_in]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))       # [d_in, N]

    h0 = (state[0].astype(jnp.float32) if state is not None
          else jnp.zeros((B, d_in, N), jnp.float32))

    if S == 1:  # decode fast path
        a1 = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * A[None])
        bx1 = (dt[:, 0, :, None] * Bmat[:, 0, None, :].astype(dt.dtype)
               * xi[:, 0, :, None]).astype(jnp.float32)
        h_last = a1 * h0 + bx1
        y = jnp.einsum("bdn,bn->bd", h_last.astype(x.dtype),
                       Cmat[:, 0])[:, None]
    else:
        # §Perf: the discretized (a, bx) and state trajectories live ONLY
        # inside the chunk scan — the [B, S, d_in, N] tensors that
        # dominated memory (1.5 TB/device at train_4k) never materialize.
        pad = (-S) % chunk
        nc = (S + pad) // chunk

        def cpad(v, fill=0.0):
            return jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 2),
                           constant_values=fill)

        def chunked(v):
            return v.reshape((B, nc, chunk) + v.shape[2:]).transpose(
                (1, 0, 2) + tuple(range(3, v.ndim + 1)))

        xi_c = chunked(cpad(xi))
        dt_c = chunked(cpad(dt))
        B_c = chunked(cpad(Bmat))
        C_c = chunked(cpad(Cmat))

        @jax.checkpoint
        def step(h, blk):
            # remat: without this, autodiff saves the [B,chunk,d_in,N]
            # (a, bx, h) trajectories of EVERY chunk — the 1.4 TB/device
            # §Perf finding. Recomputing them in bwd costs ~1 extra scan.
            xi_i, dt_i, B_i, C_i = blk                  # [B, chunk, ...]
            a_i = jnp.exp(dt_i[..., None].astype(jnp.float32) * A[None, None])
            bx_i = (dt_i[..., None] * B_i[:, :, None, :].astype(dt_i.dtype)
                    * xi_i[..., None]).astype(jnp.float32)
            h_i, h_next = _ssm_assoc_scan(a_i, bx_i, h)
            y_i = jnp.einsum("bsdn,bsn->bsd", h_i.astype(xi_i.dtype), C_i)
            return h_next, y_i

        h_last, y_chunks = lax.scan(step, h0, (xi_c, dt_c, B_c, C_c))
        y = y_chunks.transpose(1, 0, 2, 3).reshape(B, S + pad, d_in)[:, :S]

    y = y + params["D"][None, None] * xi
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, (h_last.astype(jnp.float32), new_conv_state)
