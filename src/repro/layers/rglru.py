"""RG-LRU recurrent block (RecurrentGemma / Griffin).

h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)
a_t = exp(−c · softplus(Λ) ⊙ σ(W_a x_t)),   i_t = σ(W_x x_t)

wrapped in the Griffin recurrent block: linear in (2 branches), depthwise
conv1d on the recurrent branch, RG-LRU, gated merge, linear out. Solved
with the same chunked associative scan as the SSM (linear diagonal
recurrence). Decode carries (h, conv) state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import RGLRUConfig
from repro.layers.ssm import _ssm_assoc_scan

_C = 8.0  # Griffin's fixed constant


def rglru_block(x: jax.Array, params: dict, cfg: RGLRUConfig, *,
                state: Optional[Tuple[jax.Array, jax.Array]] = None,
                chunk: int = 256
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """x [B, S, d_model] -> (y [B, S, d_model], (h_state, conv_state))."""
    B, S, _ = x.shape
    W = params["lam"].shape[0]                              # lru_width

    gate_br = jnp.einsum("bsd,dw->bsw", x, params["w_gate_branch"])
    rec = jnp.einsum("bsd,dw->bsw", x, params["w_rec_branch"])

    # depthwise causal conv on the recurrent branch
    wconv = params["conv_w"]                                # [width, W]
    prev = (state[1] if state is not None
            else jnp.zeros((B, cfg.conv1d_width - 1, W), x.dtype))
    xpad = jnp.concatenate([prev, rec], axis=1)
    rec = sum(xpad[:, i:i + S] * wconv[i][None, None]
              for i in range(cfg.conv1d_width)) + params["conv_b"][None, None]
    new_conv_state = xpad[:, S:, :]

    # RG-LRU gates
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", rec, params["w_a"])
                       + params["b_a"][None, None])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", rec, params["w_x"])
                       + params["b_x"][None, None])
    log_a = -_C * jax.nn.softplus(params["lam"])[None, None] * r
    a = jnp.exp(log_a.astype(jnp.float32))
    gated = (i * rec).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a ** 2, 1e-12))
    bx = beta * gated

    h0 = (state[0].astype(jnp.float32) if state is not None
          else jnp.zeros((B, W), jnp.float32))

    if S == 1:
        h_last = a[:, 0] * h0 + bx[:, 0]
        h_all = h_last[:, None]
    else:
        pad = (-S) % chunk
        a_p = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        bx_p = jnp.pad(bx, ((0, 0), (0, pad), (0, 0)))
        nc = (S + pad) // chunk
        # reuse the [B,S,d,N] scan with N=1
        a_c = a_p.reshape(B, nc, chunk, W, 1).transpose(1, 0, 2, 3, 4)
        bx_c = bx_p.reshape(B, nc, chunk, W, 1).transpose(1, 0, 2, 3, 4)

        def step(h, blk):
            a_i, bx_i = blk
            h_i, h_next = _ssm_assoc_scan(a_i, bx_i, h[..., None])
            return h_next[..., 0], h_i[..., 0]

        h_last, h_chunks = lax.scan(step, h0, (a_c, bx_c))
        h_all = h_chunks.transpose(1, 0, 2, 3).reshape(B, S + pad, W)[:, :S]

    merged = h_all.astype(x.dtype) * jax.nn.gelu(gate_br)
    out = jnp.einsum("bsw,wd->bsd", merged, params["w_out"])
    return out, (h_last, new_conv_state)
