"""Attention layers: GQA with RoPE / M-RoPE / learned positions, sliding
windows, MLA (DeepSeek-V2), chunked flash-style softmax, and decode paths.

Memory discipline: prefill/train attention never materializes the [S, S]
score matrix — a two-level ``lax.scan`` over query/KV chunks maintains the
online-softmax (m, l, acc) state, so 32k-sequence prefill lowers with
bounded per-device memory. Decode (single query) materializes [*, S]
scores, which GSPMD shards over the mesh (sequence over `data` for the
500k cache).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> jax.Array:
    """positions [...,] -> angles [..., head_dim/2]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x [B, S, H, D]; positions [B, S] (or [S])."""
    d = x.shape[-1]
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = rope_angles(positions, d, theta)              # [B, S, D/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


MROPE_SECTIONS = (16, 24, 24)   # temporal/height/width halves (Qwen2-VL)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: Tuple[int, ...] = MROPE_SECTIONS) -> jax.Array:
    """Multimodal RoPE: x [B, S, H, D]; positions3 [B, S, 3].

    The D/2 frequency lanes are split into (temporal, height, width)
    sections; each section rotates by its own position stream. For pure
    text all three streams are equal and M-RoPE reduces to RoPE.
    """
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    sec = jnp.cumsum(jnp.array((0,) + tuple(sections)))
    lane = jnp.arange(d // 2)
    which = jnp.searchsorted(sec[1:], lane, side="right")   # [D/2] in {0,1,2}
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                      # [B, S, 3]
        jnp.broadcast_to(which[None, None, :], positions3.shape[:2] + (d // 2,)),
        axis=-1)                                             # [B, S, D/2]
    ang = pos * freqs[None, None, :]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    causal: bool = True, window: int = 0,
                    q_chunk: int = 512, kv_chunk: int = 1024,
                    bias: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention.

    q [B, Hq, Sq, D]; k, v [B, Hkv, Skv, D]; Hq % Hkv == 0 (GQA groups are
    kept factored — KV is never repeated to Hq). positions are int32 [Sq] /
    [Skv] used for causal and sliding-window masks (window=0 => full).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]                                     # may differ (MLA)
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Skv)
    pad_q = (-Sq) % qc
    pad_k = (-Skv) % kc
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    posq = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    posk = jnp.pad(kv_positions, (0, pad_k), constant_values=2 ** 30)

    nq, nk = (Sq + pad_q) // qc, (Skv + pad_k) // kc
    qp = qp.reshape(B, Hkv, G, nq, qc, D).transpose(3, 0, 1, 2, 4, 5)
    kp = kp.reshape(B, Hkv, nk, kc, D).transpose(2, 0, 1, 3, 4)
    vp = vp.reshape(B, Hkv, nk, kc, Dv).transpose(2, 0, 1, 3, 4)
    posq = posq.reshape(nq, qc)
    posk = posk.reshape(nk, kc)

    def q_step(_, q_blk):
        q_i, pq = q_blk                                      # [B,Hkv,G,qc,D], [qc]
        m0 = jnp.full((B, Hkv, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qc, Dv), jnp.float32)

        def kv_step(carry, kv_blk):
            m, l, acc = carry
            k_j, v_j, pk = kv_blk
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= pk[None, :] <= pq[:, None]
            if window > 0:
                mask &= pq[:, None] - pk[None, :] < window
            mask &= (pq[:, None] >= 0) & (pk[None, :] < 2 ** 30)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_j, preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), (kp, vp, posk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, out = lax.scan(q_step, None, (qp, posq))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, Sq + pad_q, Dv)
    return out[:, :, :Sq]


# ---------------------------------------------------------------------------
# Decode attention (single new token vs. a cache)
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     cur_pos: jax.Array, window: int = 0) -> jax.Array:
    """q [B, Hq, 1, D]; caches [B, Hkv, S, D]; cur_pos [B] (position of the
    new token). Attends to cache positions p <= cur_pos (and within the
    sliding window if set). Scores [B, Hkv, G, S] — GSPMD shards S."""
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = 1.0 / jnp.sqrt(jnp.float32(D))
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S)[None, :]                     # [1, S]
    ok = pos <= cur_pos[:, None]
    if window > 0:
        ok &= pos > cur_pos[:, None] - window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def mla_decode_attention(q_nope_abs: jax.Array, q_rope: jax.Array,
                         latent_cache: jax.Array, rope_cache: jax.Array, *,
                         cur_pos: jax.Array, head_dim_for_scale: int) -> jax.Array:
    """Absorbed MLA decode (DeepSeek-V2): scores against the compressed
    latent — K/V are never expanded.

    q_nope_abs [B, H, R]   (W_uk^T q_nope, R = kv_lora_rank)
    q_rope     [B, H, Dr]
    latent_cache [B, S, R]; rope_cache [B, S, Dr]. Returns [B, H, R]
    (attention-weighted latents; caller applies W_uv). The softmax scale
    uses the ORIGINAL qk head dim (nope+rope), not the latent rank."""
    scale = 1.0 / jnp.sqrt(jnp.float32(head_dim_for_scale))
    s = (jnp.einsum("bhr,bsr->bhs", q_nope_abs, latent_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bsd->bhs", q_rope, rope_cache,
                      preferred_element_type=jnp.float32)) * scale
    S = latent_cache.shape[1]
    ok = jnp.arange(S)[None, :] <= cur_pos[:, None]
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", p, latent_cache,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Paged decode (oracle): gather pool pages through the page table, then
# run the exact fixed-layout decode attention. The gather clips the table
# (unallocated entries are -1), which is safe: every position <= cur_pos
# lies in an allocated page, and positions beyond cur_pos are masked.
# ---------------------------------------------------------------------------


def gather_paged_kv(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """pool [num_pages, KV, ps, d]; pages [B, pps] int32 (-1 = unset).
    Returns the linearized per-slot cache [B, KV, pps*ps, d]."""
    num_pages = pool.shape[0]
    B, pps = pages.shape
    k = pool[jnp.clip(pages, 0, num_pages - 1)]    # [B, pps, KV, ps, d]
    KV, ps, d = k.shape[2], k.shape[3], k.shape[4]
    return k.transpose(0, 2, 1, 3, 4).reshape(B, KV, pps * ps, d)


def gather_paged_rows(pool: jax.Array, pages: jax.Array) -> jax.Array:
    """pool [num_pages, ps, d]; pages [B, pps] -> [B, pps*ps, d] (MLA)."""
    num_pages = pool.shape[0]
    B, pps = pages.shape
    x = pool[jnp.clip(pages, 0, num_pages - 1)]    # [B, pps, ps, d]
    return x.reshape(B, pps * x.shape[2], x.shape[3])


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, *, pages: jax.Array,
                           cur_pos: jax.Array, window: int = 0,
                           k_scale: Optional[jax.Array] = None,
                           v_scale: Optional[jax.Array] = None) -> jax.Array:
    """GQA decode over the paged pool: q [B, Hq, 1, D]; pools
    [num_pages, Hkv, ps, D]; pages [B, pps]; cur_pos [B]. With
    ``k_scale``/``v_scale`` ([num_pages, Hkv, ps] f32) the pools are
    int8 and dequantized per row after the gather."""
    k = gather_paged_kv(k_pool, pages)
    v = gather_paged_kv(v_pool, pages)
    if k_scale is not None:
        ks = gather_paged_kv(k_scale[..., None], pages)
        vs = gather_paged_kv(v_scale[..., None], pages)
        k = (k.astype(jnp.float32) * ks).astype(q.dtype)
        v = (v.astype(jnp.float32) * vs).astype(q.dtype)
    return decode_attention(q, k, v, cur_pos=cur_pos, window=window)


def paged_mla_decode_attention(q_nope_abs: jax.Array, q_rope: jax.Array,
                               latent_pool: jax.Array, rope_pool: jax.Array,
                               *, pages: jax.Array, cur_pos: jax.Array,
                               head_dim_for_scale: int) -> jax.Array:
    """Absorbed-MLA decode over paged latent/rope pools
    ([num_pages, ps, R] / [num_pages, ps, Dr])."""
    lat = gather_paged_rows(latent_pool, pages)
    rope = gather_paged_rows(rope_pool, pages)
    return mla_decode_attention(q_nope_abs, q_rope, lat, rope,
                                cur_pos=cur_pos,
                                head_dim_for_scale=head_dim_for_scale)
