"""Mixture-of-experts FFN with two sharding strategies.

Dispatch is sort-based and grouped (megablocks-style, static shapes):
tokens are argsorted by assigned expert, scattered into a fixed [E, G, d]
buffer (G = capacity), expert matmuls run as grouped einsums, and results
combine back with the router weights. Over-capacity tokens drop (their
residual path still carries them — standard Switch behavior).

Sharding strategies (per MoEConfig.expert_sharding):
* ``expert`` — expert-parallel (DeepSeek-V2: 64 experts over the model
  axis; 4 experts/rank on a 16-way mesh). GSPMD materializes the
  all-to-all between the data-sharded token axis and the expert-sharded
  group axis.
* ``tp`` — tensor-parallel within each expert (Mixtral: 8 big experts,
  d_expert split over the model axis like a dense FFN). No all-to-all;
  the second matmul psums over the model axis.

The router auxiliary load-balance loss (Switch-style) is returned to the
caller. Routed experts are excluded from ZERO-resizing (token→expert
assignment changes every step, so a per-expert lineage is not stable);
shared experts and dense-FFN layers use the controlled path instead.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import MoEConfig
from repro.sharding import shard, shard_map


def router_topk(x: jax.Array, w_router: jax.Array, cfg: MoEConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (expert_idx [T,k], weights [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x, w_router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-transformer load-balance aux loss
    T, E = logits.shape
    density = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * cfg.top_k)
    mean_prob = probs.mean(axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(density * mean_prob)
    return idx, weights.astype(x.dtype), aux


def _grouped_dispatch(idx: jax.Array, weights: jax.Array, T: int,
                      num_experts: int, capacity: int):
    """Sort-based dispatch. idx/weights [T, k].

    Returns gather ids [E, G] (into tokens; ==T for empty slots) and
    combine weights [E, G] (0 for empty slots)."""
    k = idx.shape[1]
    flat_e = idx.reshape(-1)                               # [T*k]
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    w_sorted = flat_w[order]
    # position within the expert segment
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(num_experts))
    pos = jnp.arange(T * k) - seg_start[e_sorted]
    keep = pos < capacity
    slot = jnp.where(keep, e_sorted * capacity + jnp.clip(pos, 0, capacity - 1),
                     num_experts * capacity)               # OOB -> dropped
    gather_t = jnp.full((num_experts * capacity,), T, jnp.int32)
    gather_t = gather_t.at[slot].set(t_sorted.astype(jnp.int32), mode="drop")
    comb_w = jnp.zeros((num_experts * capacity,), w_sorted.dtype)
    comb_w = comb_w.at[slot].set(w_sorted, mode="drop")
    return (gather_t.reshape(num_experts, capacity),
            comb_w.reshape(num_experts, capacity))


def moe_ffn(x: jax.Array, params: dict, cfg: MoEConfig, act_fn,
            mesh=None, expert_sharding: str = "expert"
            ) -> Tuple[jax.Array, jax.Array]:
    """x [B, S, d] -> (y [B, S, d], aux_loss). Routed experts only; shared
    experts / dense layers are composed by the caller."""
    if expert_sharding == "tp" and mesh is not None and "model" in mesh.axis_names:
        return _moe_tp_local(x, params, cfg, act_fn, mesh)
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    idx, weights, aux = router_topk(xt, params["router"], cfg)

    capacity = max(8, int(T * cfg.top_k * cfg.capacity_factor / cfg.num_experts))
    capacity = -(-capacity // 8) * 8
    gather_t, comb_w = _grouped_dispatch(idx, weights, T, cfg.num_experts, capacity)

    if expert_sharding == "tp":
        xe_axes = (None, "batch", "embed")     # G over data; experts replicated
        h_axes = (None, "batch", "mlp")        # expert hidden over model
    else:
        xe_axes = ("expert", None, "embed")    # experts over model (all-to-all)
        h_axes = ("expert", None, None)

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    xe = xpad[gather_t]                                    # [E, G, d]
    xe = shard(xe, xe_axes, mesh=mesh)

    wg, wu, wd = params.get("w_gate"), params["w_up"], params["w_down"]
    h = jnp.einsum("egd,edf->egf", xe, wu)
    if wg is not None:
        h = act_fn(jnp.einsum("egd,edf->egf", xe, wg)) * h
    else:
        h = act_fn(h)
    h = shard(h, h_axes, mesh=mesh)
    ye = jnp.einsum("egf,efd->egd", h, wd)                 # [E, G, d]
    ye = shard(ye, xe_axes, mesh=mesh)

    ye = ye * comb_w[..., None].astype(ye.dtype)
    y = jnp.zeros((T + 1, d), ye.dtype).at[gather_t.reshape(-1)].add(
        ye.reshape(-1, d))[:T]
    y = shard(y.reshape(B, S, d), ("batch", None, "embed"), mesh=mesh)
    return y, aux


def _moe_tp_local(x: jax.Array, params: dict, cfg: MoEConfig, act_fn, mesh
                  ) -> Tuple[jax.Array, jax.Array]:
    """TP-sharded experts with DATA-LOCAL dispatch (§Perf iteration).

    The GSPMD gather from data-sharded tokens into the grouped buffer
    forced an all-gather of the full token array every layer (~17 GB × L
    for Mixtral train_4k). Inside shard_map each data shard routes and
    groups only its own tokens; the second expert matmul's partials are
    combined back per-token BEFORE the single psum over the model axis, so
    the collective is tokens_loc×d (reduce-merging, same trick as the
    paper's migration) instead of E×G×d."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    E = cfg.num_experts
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    gated = params.get("w_gate") is not None

    def body(x_, router_, wu_, wd_, *maybe_gate):
        wg_ = maybe_gate[0] if maybe_gate else None
        Bl, S_, d_ = x_.shape
        Tl = Bl * S_
        xt = x_.reshape(Tl, d_)
        idx, weights, aux = router_topk(xt, router_, cfg)
        cap = max(8, int(Tl * cfg.top_k * cfg.capacity_factor / E))
        cap = -(-cap // 8) * 8
        gather_t, comb_w = _grouped_dispatch(idx, weights, Tl, E, cap)
        xpad = jnp.concatenate([xt, jnp.zeros((1, d_), xt.dtype)], axis=0)
        xe = xpad[gather_t]                              # [E, G, d] local
        h = jnp.einsum("egd,edf->egf", xe, wu_)          # f model-sharded
        if wg_ is not None:
            h = act_fn(jnp.einsum("egd,edf->egf", xe, wg_)) * h
        else:
            h = act_fn(h)
        ye = jnp.einsum("egf,efd->egd", h, wd_)          # partial over model
        ye = ye * comb_w[..., None].astype(ye.dtype)
        y = jnp.zeros((Tl + 1, d_), ye.dtype).at[gather_t.reshape(-1)].add(
            ye.reshape(-1, d_))[:Tl]
        y = lax.psum(y, "model")                         # combine-then-psum
        aux = lax.pmean(aux, dp_axes) if dp_axes else aux
        return y.reshape(Bl, S_, d_), aux

    dp = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    in_specs = [P(dp, None, None), P(None, None),
                P(None, None, "model"), P(None, "model", None)]
    args = [x, params["router"], params["w_up"], params["w_down"]]
    if gated:
        in_specs.append(P(None, None, "model"))
        args.append(params["w_gate"])
    y, aux = shard_map(
        body, mesh=mesh, in_specs=tuple(in_specs),
        out_specs=(P(dp, None, None), P()), check_vma=False)(*args)
    return y, aux
