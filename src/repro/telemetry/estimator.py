"""Online straggler estimation from measured times (DESIGN_TELEMETRY.md §2).

The controller (Eq. 1-3) wants to know each rank's heterogeneity degree,
but a closed measurement loop only observes the MITIGATED runtime: once
the plan prunes a straggler, its measured time drops and a naive loop
would immediately un-prune it (prune/un-prune oscillation). The fix is to
invert the iteration-time decomposition under the plan that was active
for the measured step:

    T_i = M · f_i · χ_i + C            (measured, f_i = retained-work
                                        fraction of the active plan)
    χ̂_i = (T_i − C) / (M · f_i)        (inversion; M, C from the pretest
                                        / IterationModel)
    T̂_i = M · χ̂_i + C                  (full-workload-equivalent time the
                                        controller consumes)

χ̂ is maintained per rank with:

* **median/MAD outlier rejection** — a single spiked sample (GC pause,
  page fault) deviating from the rank's recent median by more than
  ``outlier_nmad`` robust standard deviations is dropped, not smoothed
  in. ``regime_steps`` CONSECUTIVE rejections in a row are not noise but
  a regime change (contention burst start/end): the rank's window is
  flushed and χ̂ re-locks to the new level immediately.
* **EWMA smoothing** — accepted samples fold in with weight
  ``ewma_alpha`` (first accepted sample after a flush seeds χ̂ directly).
* **warmup gate** — ``ready`` is False until ``warmup_steps`` updates
  have been ingested; the drivers keep the plan neutral until then.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.hetero import IterationModel


@dataclasses.dataclass(frozen=True)
class EstimatorConfig:
    ewma_alpha: float = 0.4        # weight of the newest accepted sample
    warmup_steps: int = 3          # updates before `ready` (and per-rank
    #                                history needed before outlier tests)
    outlier_nmad: float = 4.0      # rejection threshold in robust sigmas
    outlier_rel_floor: float = 0.05  # MAD floor as a fraction of the median
    #                                (an all-identical window has MAD 0 and
    #                                 would otherwise reject everything)
    regime_steps: int = 2          # consecutive rejections = regime change
    window: int = 16               # per-rank accepted-sample history
    min_work_frac: float = 1e-3    # guard for inverting near-zero fractions
    min_chi: float = 1e-3

    @staticmethod
    def from_control(wc) -> "EstimatorConfig":
        """Build from a WorkloadControlConfig (the --times=measured knobs)."""
        return EstimatorConfig(ewma_alpha=wc.ewma_alpha,
                               warmup_steps=wc.estimator_warmup,
                               outlier_nmad=wc.outlier_nmad)


class StragglerEstimator:
    """Per-rank χ̂ from a stream of measured (mitigated) step times."""

    def __init__(self, model: IterationModel, num_ranks: int,
                 cfg: Optional[EstimatorConfig] = None):
        self.model = model
        self.num_ranks = num_ranks
        self.cfg = cfg or EstimatorConfig()
        w = self.cfg.window
        self._buf = np.full((num_ranks, w), np.nan)
        self._ptr = np.zeros(num_ranks, np.int64)
        self._count = np.zeros(num_ranks, np.int64)
        self._rejects = np.zeros(num_ranks, np.int64)
        self.chi_hat = np.ones(num_ranks, np.float64)
        self.updates = 0
        self.rejected_total = 0
        self.relocks = 0

    # -- core --------------------------------------------------------------
    def invert(self, rank_times: np.ndarray,
               work_frac: Optional[np.ndarray] = None) -> np.ndarray:
        """Raw per-sample χ from measured times under the active plan."""
        t = np.asarray(rank_times, np.float64)
        f = (np.ones_like(t) if work_frac is None
             else np.asarray(work_frac, np.float64))
        f = np.maximum(f, self.cfg.min_work_frac)
        m = max(self.model.matmul_time, 1e-12)
        return np.maximum((t - self.model.other_time) / (m * f),
                          self.cfg.min_chi)

    def update(self, rank_times: np.ndarray,
               work_frac: Optional[np.ndarray] = None) -> np.ndarray:
        """Ingest one measured sample; returns the updated χ̂ vector."""
        cfg = self.cfg
        raw = self.invert(rank_times, work_frac)
        reject = np.zeros(self.num_ranks, bool)
        have = self._count >= max(cfg.warmup_steps, 1)
        if have.any():
            sub = self._buf[have]
            med = np.nanmedian(sub, axis=1)
            mad = np.nanmedian(np.abs(sub - med[:, None]), axis=1)
            thr = cfg.outlier_nmad * np.maximum(
                1.4826 * mad, cfg.outlier_rel_floor * np.abs(med))
            reject[have] = np.abs(raw[have] - med) > thr
        self._rejects = np.where(reject, self._rejects + 1, 0)
        self.rejected_total += int(reject.sum())

        # persistent deviation is not a spike but a regime change
        # (contention burst start/end): flush and re-lock
        relock = self._rejects >= cfg.regime_steps
        if relock.any():
            self.relocks += int(relock.sum())
            self._buf[relock] = np.nan
            self._ptr[relock] = 0
            self._count[relock] = 0
            self._rejects[relock] = 0
            reject &= ~relock

        accept = ~reject
        idx = np.nonzero(accept)[0]
        self._buf[idx, self._ptr[idx] % cfg.window] = raw[idx]
        self._ptr[idx] += 1
        self._count[idx] = np.minimum(self._count[idx] + 1, cfg.window)
        first = accept & (self._count == 1)
        a = cfg.ewma_alpha
        self.chi_hat = np.where(
            first, raw,
            np.where(accept, (1 - a) * self.chi_hat + a * raw, self.chi_hat))
        self.updates += 1
        return self.chi_hat

    def observe(self, sample) -> np.ndarray:
        """Ingest a :class:`StepSample` (rank_times + its work_frac)."""
        return self.update(sample.rank_times, sample.work_frac)

    # -- checkpoint / resume ------------------------------------------------
    def state_arrays(self) -> dict:
        """Full estimator state as numpy arrays, so a resumed run's χ̂
        stream is bit-identical to an uninterrupted one."""
        return {"buf": self._buf.copy(), "ptr": self._ptr.copy(),
                "count": self._count.copy(), "rejects": self._rejects.copy(),
                "chi_hat": self.chi_hat.copy(),
                "counters": np.asarray([self.updates, self.rejected_total,
                                        self.relocks], np.int64)}

    def load_state_arrays(self, arrays: dict) -> None:
        """Restore :meth:`state_arrays` output (shape-checked)."""
        buf = np.asarray(arrays["buf"], np.float64)
        if buf.shape != self._buf.shape:
            raise ValueError(
                f"estimator checkpoint window {buf.shape} does not match "
                f"the configured ({self.num_ranks}, {self.cfg.window})")
        self._buf = buf.copy()
        self._ptr = np.asarray(arrays["ptr"], np.int64).copy()
        self._count = np.asarray(arrays["count"], np.int64).copy()
        self._rejects = np.asarray(arrays["rejects"], np.int64).copy()
        self.chi_hat = np.asarray(arrays["chi_hat"], np.float64).copy()
        updates, rejected, relocks = np.asarray(arrays["counters"], np.int64)
        self.updates = int(updates)
        self.rejected_total = int(rejected)
        self.relocks = int(relocks)

    # -- what the controller consumes --------------------------------------
    @property
    def ready(self) -> bool:
        """Warmup gate: enough samples ingested to trust the estimate."""
        return self.updates >= self.cfg.warmup_steps

    def full_times(self) -> np.ndarray:
        """Full-workload-equivalent per-rank times T̂ = M·χ̂ + C."""
        return self.model.matmul_time * self.chi_hat + self.model.other_time

    def nominal_times(self) -> np.ndarray:
        """Homogeneous (χ=1) times — what the drivers feed the controller
        while the warmup gate is closed, so the plan stays neutral."""
        return np.full((self.num_ranks,),
                       self.model.matmul_time + self.model.other_time)
