"""Record/replay traces for measured per-rank timing (DESIGN_TELEMETRY.md §3).

Format: JSONL, one object per line. Line 1 is the header; every later
line is a :class:`StepSample`:

    {"kind": "header", "schema": "repro.telemetry.trace", "version": 1,
     "num_ranks": 8, "matmul_time": 0.01, "other_time": 0.0015, ...meta}
    {"kind": "sample", "step": 0, "rank_times": [...], "work_frac": [...],
     "plan_signature": "", "wall_s": 0.0}

The header pins the iteration-model constants the trace was recorded
under, so replay can reconstruct each rank's full-workload-equivalent χ
EXACTLY — ``χ = (T − C) / (M · f)`` with the RECORDED M and C — no matter
what model the replaying run uses. That turns every recorded contention
episode into a deterministic regression scenario
(``HeteroSchedule(kind="trace")`` via :func:`schedule_from_trace`).

Writers flush per sample, so a crashed run still leaves a readable trace
prefix. Readers hard-fail on schema/version mismatch: traces are
regression fixtures, and silently reinterpreting an old layout would turn
a format drift into a wrong-answer bug.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from repro.core.hetero import HeteroSchedule
from repro.telemetry.timing import StepSample

TRACE_SCHEMA = "repro.telemetry.trace"
TRACE_VERSION = 1


class TraceFormatError(ValueError):
    """Raised on schema/version mismatch or a malformed trace file."""


class TraceWriter:
    """Append-only JSONL trace writer (context manager)."""

    def __init__(self, path: str, num_ranks: int, *,
                 matmul_time: float = 0.0, other_time: float = 0.0,
                 meta: Optional[Dict[str, Any]] = None):
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self.path = path
        self.num_ranks = num_ranks
        self.num_samples = 0
        self._f = open(path, "w")
        header = {"kind": "header", "schema": TRACE_SCHEMA,
                  "version": TRACE_VERSION, "num_ranks": int(num_ranks),
                  "matmul_time": float(matmul_time),
                  "other_time": float(other_time)}
        header.update(meta or {})
        self._f.write(json.dumps(header) + "\n")
        self._f.flush()

    def append(self, sample: StepSample) -> None:
        if self._f is None:
            raise ValueError(f"trace {self.path} already closed")
        self._f.write(json.dumps(sample.to_json()) + "\n")
        self._f.flush()
        self.num_samples += 1

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self) -> "TraceWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class TraceReader:
    """Validating JSONL trace reader.

    Header fields surface as attributes (``num_ranks``, ``matmul_time``,
    ``other_time``, ``meta``); iterate for :class:`StepSample`s.
    """

    def __init__(self, path: str):
        self.path = path
        with open(path) as f:
            first = f.readline()
        if not first.strip():
            raise TraceFormatError(f"{path}: empty trace file")
        try:
            header = json.loads(first)
        except json.JSONDecodeError as e:
            raise TraceFormatError(f"{path}: unparseable header: {e}") from e
        if header.get("schema") != TRACE_SCHEMA:
            raise TraceFormatError(
                f"{path}: not a telemetry trace "
                f"(schema {header.get('schema')!r} != {TRACE_SCHEMA!r})")
        if header.get("version") != TRACE_VERSION:
            raise TraceFormatError(
                f"{path}: trace version {header.get('version')!r} != "
                f"supported {TRACE_VERSION} — regenerate the trace (see "
                "examples/traces/make_fixtures.py)")
        self.header = header
        self.num_ranks = int(header["num_ranks"])
        self.matmul_time = float(header.get("matmul_time", 0.0))
        self.other_time = float(header.get("other_time", 0.0))
        self.meta = {k: v for k, v in header.items()
                     if k not in ("kind", "schema", "version", "num_ranks",
                                  "matmul_time", "other_time")}

    def __iter__(self) -> Iterator[StepSample]:
        with open(self.path) as f:
            f.readline()                       # header, validated in __init__
            for ln, line in enumerate(f, start=2):
                if not line.strip():
                    continue
                d = json.loads(line)
                if d.get("kind") != "sample":
                    raise TraceFormatError(
                        f"{self.path}:{ln}: unexpected record kind "
                        f"{d.get('kind')!r}")
                s = StepSample.from_json(d)
                if len(s.rank_times) != self.num_ranks:
                    raise TraceFormatError(
                        f"{self.path}:{ln}: sample has "
                        f"{len(s.rank_times)} rank times, header declares "
                        f"{self.num_ranks} ranks")
                yield s

    def samples(self) -> List[StepSample]:
        return list(self)


def trace_chis(reader: TraceReader) -> np.ndarray:
    """Full-workload-equivalent χ per (step, rank) from a recorded trace,
    inverted with the RECORDED model constants."""
    if reader.matmul_time <= 0:
        raise TraceFormatError(
            f"{reader.path}: header matmul_time must be > 0 to reconstruct "
            "χ for replay (was the trace recorded without an iteration "
            "model?)")
    rows = []
    for s in reader.samples():
        f = (np.ones(reader.num_ranks) if s.work_frac is None
             else np.maximum(np.asarray(s.work_frac, np.float64), 1e-3))
        chi = (np.asarray(s.rank_times, np.float64) - reader.other_time) \
            / (reader.matmul_time * f)
        rows.append(np.maximum(chi, 1e-3))
    if not rows:
        raise TraceFormatError(f"{reader.path}: trace has no samples")
    return np.stack(rows)


def schedule_from_trace(path: str, num_ranks: Optional[int] = None,
                        rank_offset: int = 0) -> HeteroSchedule:
    """Build a replaying ``HeteroSchedule(kind="trace")`` from a trace.

    ``num_ranks`` overrides the recorded rank count (χ rows are truncated
    or padded with 1.0 by ``HeteroSchedule.chi``); steps past the end of
    the trace wrap around, so short traces replay as periodic schedules.

    ``rank_offset`` replays a SLICE of a wider trace: χ lanes
    ``[rank_offset, rank_offset + num_ranks)``. This is how one recorded
    cluster trace feeds R replicas — each replica replays its own lane
    block of the shared JSONL (see :func:`replica_schedules`).
    """
    reader = TraceReader(path)
    chis = trace_chis(reader)
    if rank_offset:
        if num_ranks is None:
            raise ValueError("rank_offset needs an explicit num_ranks "
                             "(the width of the slice to replay)")
        if rank_offset + num_ranks > reader.num_ranks:
            raise TraceFormatError(
                f"{path}: slice [{rank_offset}, {rank_offset + num_ranks})"
                f" exceeds the recorded {reader.num_ranks} ranks")
        chis = chis[:, rank_offset:rank_offset + num_ranks]
    return HeteroSchedule(
        num_ranks=num_ranks or reader.num_ranks, kind="trace",
        trace_chis=tuple(tuple(float(c) for c in row) for row in chis))


def replica_schedules(path: str) -> List[HeteroSchedule]:
    """Split ONE recorded cluster trace into per-replica replay schedules.

    The header must carry the cluster tagging written by
    :class:`repro.cluster.ReplicaManager` (or a fixture): ``replicas``
    (R) and ``ranks_per_replica`` (W), with ``num_ranks == R * W`` —
    replica i replays χ lanes ``[i*W, (i+1)*W)``. One JSONL set thus
    replays a whole cluster run deterministically.
    """
    reader = TraceReader(path)
    meta = reader.meta
    if "replicas" not in meta or "ranks_per_replica" not in meta:
        raise TraceFormatError(
            f"{path}: not a cluster trace — header lacks 'replicas'/"
            "'ranks_per_replica' tagging (record one via "
            "repro.cluster.ReplicaManager(record_trace=...))")
    R, W = int(meta["replicas"]), int(meta["ranks_per_replica"])
    if R * W != reader.num_ranks:
        raise TraceFormatError(
            f"{path}: header declares {R} replicas x {W} ranks but the "
            f"trace is {reader.num_ranks} lanes wide")
    return [schedule_from_trace(path, num_ranks=W, rank_offset=i * W)
            for i in range(R)]
