"""Closed-loop telemetry: measured per-rank timing, online straggler
estimation, and record/replay traces (DESIGN_TELEMETRY.md).

Three layers, consumed bottom-up by the launch drivers:

* :mod:`repro.telemetry.timing` — measurement. ``RankTimer`` wraps the
  jitted step with a host ``perf_counter`` around ``block_until_ready``
  and owns the in-graph per-rank gather (every host sees all TP ranks'
  clocks, refreshed once per control interval). ``StepSample`` is the
  unit record: ``{step, rank_times, plan_signature, work_frac, wall_s}``.
* :mod:`repro.telemetry.estimator` — estimation. ``StragglerEstimator``
  inverts the iteration-time decomposition under the ACTIVE plan's
  retained-work fraction, smooths with an EWMA, rejects single-sample
  spikes by median/MAD, gates on warmup, and serves the controller
  FULL-workload-equivalent times so the loop is not fooled by its own
  mitigation.
* :mod:`repro.telemetry.trace` — record/replay. Versioned JSONL
  ``TraceWriter``/``TraceReader`` for ``StepSample`` streams and
  ``schedule_from_trace`` which turns a recorded trace into a
  ``HeteroSchedule(kind="trace")`` replay.
"""
from repro.telemetry.estimator import EstimatorConfig, StragglerEstimator
from repro.telemetry.timing import (RankTimer, StepSample, capture_sample,
                                    measurement_rng)
from repro.telemetry.trace import (TRACE_SCHEMA, TRACE_VERSION,
                                   TraceFormatError, TraceReader,
                                   TraceWriter, replica_schedules,
                                   schedule_from_trace)

__all__ = [
    "EstimatorConfig", "StragglerEstimator", "RankTimer", "StepSample",
    "capture_sample", "measurement_rng",
    "TRACE_SCHEMA", "TRACE_VERSION", "TraceFormatError", "TraceReader",
    "TraceWriter", "replica_schedules", "schedule_from_trace",
]
